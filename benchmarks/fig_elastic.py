"""Elastic topology timeline: kill -> drain -> recover -> re-add.

The paper's pooling endgame (§8): CXL devices come and go under a live
workload.  This benchmark drives the elastic runtime through the full
degraded-mode timeline and audits every leg:

Segment A (controller): Caption converges a weight vector on the
SNC-clipped fast tier + the three CXL devices (Table 1), then
  1. a FaultInjector bandwidth fault makes the EWMA slow-route drift
     detector re-open the converged walk (and restore re-converges it);
  2. a device kill silences its heartbeats, the HeartbeatMonitor flags
     it, and ``CaptionController.remove_device`` renormalizes the
     simplex over the survivors and re-converges;
  3. revive + ``add_device`` re-opens probing on the returned device's
     coordinate, and the walk lands back within 5pp per device of the
     pre-kill operating point.

Segment B (serving engine): a 3-device ServingEngine with a live
BulkMover loses a device mid-decode.  The drain ships the dead device's
KV pages through the bulk lane on real dead->survivor routes
(byte-for-byte checked against telemetry), the latency-SLO slot stays
pinned fast, no request is dropped, and the generated tokens are
IDENTICAL to a run with no kill at all.  After recovery the device is
re-added and serves again.

``--smoke`` runs Segment B only (the CI fault-injection lane: kill +
recover one device on the 3-device preset); ``--out`` writes the rows
as a JSON artifact for the nightly trajectory.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from benchmarks.fig8_dlrm import throughput_nd
from repro.core import perfmodel
from repro.core.caption import CaptionConfig, CaptionController, EpochMetrics
from repro.core.mover import BulkMover
from repro.core.policy import MemPolicy
from repro.core.telemetry import Telemetry
from repro.core.tiers import (CXL_A, CXL_B, CXL_C, DDR5_L8, OpClass,
                              TierTopology)
from repro.runtime.elastic import FaultInjector
from repro.runtime.fault_tolerance import HeartbeatMonitor, WorkerFailure

THREADS = 32
MAX_EPOCHS = 512


def elastic_topology() -> TierTopology:
    """SNC-clipped fast node (Fig. 9 regime: interleaving helps) + the
    paper's three CXL devices — the pool the elastic runtime manages."""
    snc = dataclasses.replace(DDR5_L8, name="snc-2ch", load_bw=55e9,
                              load_peak_streams=12)
    return TierTopology(fast=snc, slows=(CXL_A, CXL_B, CXL_C))


# -- Segment A: controller timeline -------------------------------------------
def _tput(ctl: CaptionController, fast) -> float:
    """Throughput on the LIVE topology (degradations flow in through the
    perfmodel, so a FaultInjector fault is visible here automatically)."""
    return throughput_nd(fast, ctl.topology.slows, tuple(ctl.weights),
                         THREADS)


def _slow_bw(ctl: CaptionController) -> float:
    """Slow-route bandwidth proxy (the drift detector's counter signal)."""
    return sum(perfmodel.stream_bandwidth(d, OpClass.LOAD, 4)
               for d in ctl.topology.slows)


def _observe(ctl: CaptionController, fast):
    return ctl.observe(EpochMetrics(throughput=_tput(ctl, fast),
                                    slow_bw=_slow_bw(ctl)))


def _converge(ctl: CaptionController, fast, label: str) -> int:
    for epoch in range(MAX_EPOCHS):
        _observe(ctl, fast)
        if ctl.converged:
            return epoch
    raise AssertionError(f"{label}: no convergence in {MAX_EPOCHS} epochs")


def _by_name(ctl: CaptionController) -> dict[str, float]:
    return dict(zip(ctl.topology.slow_names, ctl.weights))


def run_controller_timeline() -> list[str]:
    rows = []
    topo = elastic_topology()
    mon = HeartbeatMonitor(timeout=2.5)
    ctl = CaptionController(
        topo, CaptionConfig(probe_epochs=2, step=0.05, min_step=0.01,
                            hysteresis=0.01, drift_threshold=0.15))
    with FaultInjector(mon) as inj:
        # 1. cold start -> converged operating point (the pre-kill anchor)
        e0 = _converge(ctl, topo.fast, "cold start")
        w0 = _by_name(ctl)
        rows.append(
            "fig_elastic/ctl/converged,0,"
            + f"epochs={e0};" + ";".join(f"{n}={w:.3f}"
                                         for n, w in w0.items())
            + f";tput={_tput(ctl, topo.fast):.0f}")

        # 2. bandwidth fault -> EWMA drift re-opens the walk
        _observe(ctl, topo.fast)  # establish the drift reference
        inj.degrade("cxl-a", bw_scale=0.4)
        drift_reason = None
        for epoch in range(8):
            d = _observe(ctl, topo.fast)
            if "drift" in d.reason:
                drift_reason = d.reason
                break
        assert drift_reason is not None, "degradation never tripped drift"
        assert not ctl.converged
        rows.append(f"fig_elastic/ctl/drift_reprobe,0,epoch={epoch};"
                    f"reason={drift_reason.split(';')[0]}")
        inj.restore("cxl-a")
        _converge(ctl, topo.fast, "post-restore")

        # 3. kill: heartbeats go silent -> monitor flags -> drain + re-seed
        inj.beat_alive(ctl.topology.slow_names, now=0.0)
        inj.kill("cxl-c")
        inj.beat_alive(ctl.topology.slow_names, now=3.0)
        try:
            mon.check(now=3.0)
            raise AssertionError("kill went undetected")
        except WorkerFailure as e:
            assert "cxl-c" in str(e)
        pre_kill_total = ctl.fraction
        ctl.remove_device("cxl-c")
        mon.remove("cxl-c")
        mon.check(now=3.0)  # recovery acknowledged: monitor unpoisoned
        assert ctl.topology.slow_names == ("cxl-a", "cxl-b")
        assert ctl.fraction <= pre_kill_total + 1e-9
        e1 = _converge(ctl, topo.fast, "survivors")
        wk = _by_name(ctl)
        rows.append(
            "fig_elastic/ctl/killed_reconverged,0,"
            + f"epochs={e1};" + ";".join(f"{n}={w:.3f}"
                                         for n, w in wk.items())
            + f";tput={_tput(ctl, topo.fast):.0f}")

        # 4. revive + re-add: probing re-opens on the returned coordinate
        inj.revive("cxl-c")
        ctl.add_device("cxl-c")
        assert ctl.active_slow_device == "cxl-c"
        e2 = _converge(ctl, topo.fast, "re-add")
        w2 = _by_name(ctl)
        rows.append(
            "fig_elastic/ctl/readded_converged,0,"
            + f"epochs={e2};" + ";".join(f"{n}={w:.3f}"
                                         for n, w in w2.items())
            + f";tput={_tput(ctl, topo.fast):.0f}")
        # Acceptance: the restored pool re-finds the pre-kill operating
        # point within 5pp per device.
        for name, w in w0.items():
            assert abs(w2[name] - w) <= 0.05, (name, w2[name], w)
    return rows


# -- Segment B: serving-engine drain audit -------------------------------------
def run_engine_drain(smoke: bool = False) -> list[str]:
    from repro.models import registry
    from repro.serving.engine import ServingEngine

    rows = []
    topo = elastic_topology()
    arch = registry.get("internvl2-2b").tiny()
    params = arch.module.init(arch.cfg, jax.random.PRNGKey(0))
    names = (topo.fast.name,) + topo.slow_names
    new_tokens = 6 if smoke else 12

    def build(tel, mover):
        return ServingEngine(
            arch.cfg, params, max_batch=2, max_len=32,
            policy=MemPolicy.weighted(names, (5, 1, 1, 1)),
            topology=topo, page_t=4, mover=mover, telemetry=tel)

    def serve(kill: bool):
        tel = Telemetry()
        mon = HeartbeatMonitor(timeout=1.5)
        audit = {"recovered": [], "drain_bytes": 0, "dead_pages": 0,
                 "step_s": {}}
        with BulkMover(topo, asynchronous=False, telemetry=tel) as mover, \
                FaultInjector(mon) as inj:
            eng = build(tel, mover)
            eng.submit([5, 6, 7], max_new_tokens=new_tokens, slo="latency")
            for _ in range(2):
                eng.submit([5, 6, 7], max_new_tokens=new_tokens)
            steps = 0
            while eng.queue or any(eng.slots):
                steps += 1
                now = float(steps)
                eng.step()
                if steps == 2:
                    audit["step_s"]["pre_kill"] = eng.modeled_step_seconds()
                inj.beat_alive(topo.slow_names, now=now)
                if kill and steps == 3:
                    inj.kill("cxl-c")
                try:
                    mon.check(now=now)
                except WorkerFailure:
                    for name in mon.dead_workers(now=now):
                        dev = np.asarray(eng.cache.page_device)
                        audit["dead_pages"] = int((dev == 3).sum())
                        pre = {d: tel.route(name, d).bytes_moved
                               for d in names}
                        eng.remove_device(name, monitor=mon)
                        audit["drain_bytes"] = sum(
                            tel.route(name, d).bytes_moved - pre[d]
                            for d in names)
                        audit["recovered"].append(name)
                        audit["step_s"]["post_drain"] = \
                            eng.modeled_step_seconds()
                        # the SLO pin survived the drain untouched
                        dev = np.asarray(eng.cache.page_device)
                        assert (dev[0] == 0).all()
                        assert not (dev == 3).any()
            if kill:
                # recovery done: revive the device and re-add it live
                inj.revive("cxl-c")
                eng.add_device("cxl-c")
                eng.submit([5, 6, 7], max_new_tokens=new_tokens)
                eng.run_until_drained()
                audit["step_s"]["post_readd"] = eng.modeled_step_seconds()
            toks = sorted((r.rid, tuple(r.generated)) for r in eng.done)
            return eng, audit, toks

    eng, audit, toks_kill = serve(kill=True)
    _, _, toks_clean = serve(kill=False)

    # zero dropped requests; tokens bit-identical through the fault
    assert audit["recovered"] == ["cxl-c"]
    assert [t for t in toks_kill[:3]] == toks_clean, "tokens diverged"
    assert len(toks_kill) == 4  # incl. the post-re-add request
    assert all(len(t) == new_tokens for _, t in toks_kill)
    # page conservation: the drain billed exactly the dead population
    item = eng.cache.k_fast.dtype.itemsize
    L = eng.cache.k_fast.shape[0]
    K, hd = eng.cache.k_fast.shape[3:]
    page_kv_bytes = 2 * L * eng.cache.page_t * K * hd * item
    assert audit["dead_pages"] > 0
    assert audit["drain_bytes"] == audit["dead_pages"] * page_kv_bytes, \
        (audit["drain_bytes"], audit["dead_pages"], page_kv_bytes)
    # the pool healed end to end
    assert eng.topology.slow_names == topo.slow_names

    rows.append("fig_elastic/engine/kill_drain,0,"
                f"device=cxl-c;dead_pages={audit['dead_pages']};"
                f"drain_bytes={audit['drain_bytes']}")
    rows.append("fig_elastic/engine/recovered,0,"
                "requests=4;dropped=0;tokens_match=True")
    rows.append("fig_elastic/engine/timeline,0," + ";".join(
        f"{k}_step_us={v * 1e6:.2f}"
        for k, v in sorted(audit["step_s"].items())))
    return rows


def run(smoke: bool = False) -> list[str]:
    rows = run_engine_drain(smoke=smoke)
    if not smoke:
        rows = run_controller_timeline() + rows
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI lane: engine kill+recover on the 3-device "
                         "preset only")
    ap.add_argument("--out", default=None,
                    help="write rows as a JSON artifact")
    args = ap.parse_args()
    try:
        rows = run(smoke=args.smoke)
        ok = True
    except AssertionError as e:
        rows, ok = [f"fig_elastic/claims,0,CLAIM-FAILED: {e}"], False
    for row in rows:
        print(row)
    if ok:
        print("fig_elastic/claims,0,ALL-VALIDATED")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "ok": ok}, f, indent=2)
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
