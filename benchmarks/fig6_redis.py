"""Figs. 6-7 — µs-latency KV store (Redis-YCSB analogue) on tiered memory.

Model (calibrated to the paper's narrative): a GET is ~30 dependent
pointer hops + a 1 KiB value read + ~8 µs of software time; pages land on
the slow tier with probability = interleave fraction.  p99 under load is
M/M/1-inflated.  Validates F6:
  * pure-CXL p99 gap ~2x at low QPS (amortized by software time),
  * saturation QPS ordering DRAM > 50% > 100% CXL,
  * interleaving reduces but never erases the penalty (latency-bound).
Also drives the REAL ServingEngine (tiny LM, tiered KV cache) as the
end-to-end artifact of the same placement decision.
"""
from __future__ import annotations

import jax

from repro.core import perfmodel
from repro.core.policy import MemPolicy
from repro.core.tiers import OpClass, paper_topology

SW_NS = 8_000.0  # per-query software path (parse, hash, syscall)
HOPS = 30  # dependent-chain depth per GET
VALUE_B = 1024


def query_ns(topo, slow_fraction: float) -> float:
    fast, slow = topo.fast, topo.slow
    chase = (HOPS * (1 - slow_fraction) * fast.chase_latency_ns
             + HOPS * slow_fraction * slow.chase_latency_ns)
    read = VALUE_B / ((1 - slow_fraction) * perfmodel.stream_bandwidth(fast, OpClass.LOAD, 1)
                      + slow_fraction * perfmodel.stream_bandwidth(slow, OpClass.LOAD, 1)) * 1e9
    return SW_NS + chase + read


def p99_ms(service_ns: float, qps: float, servers: int = 4) -> float:
    lam = qps / servers
    mu = 1e9 / service_ns
    rho = min(lam / mu, 0.999)
    # M/M/1: p99 sojourn = -ln(0.01)/(mu - lam)
    return 4.6 / (mu * (1 - rho)) * 1e3


def run() -> list[str]:
    rows = []
    topo = paper_topology()
    fracs = {"dram": 0.0, "cxl50": 0.5, "cxl100": 1.0}
    service = {k: query_ns(topo, f) for k, f in fracs.items()}
    sat = {k: 4 * 1e9 / s for k, s in service.items()}  # max sustainable QPS
    for k in fracs:
        rows.append(f"fig6/sim/{k}/service,{service[k]/1e3:.2f},"
                    f"satQPS={sat[k]:.0f}")
        for qps in (20_000, 55_000, 80_000):
            if qps < sat[k] * 0.98:
                rows.append(f"fig6/sim/{k}/p99@{qps//1000}k,"
                            f"{p99_ms(service[k], qps)*1e3:.1f},ms="
                            f"{p99_ms(service[k], qps):.3f}")
    gap = service["cxl100"] / service["dram"]
    assert 1.5 < gap < 4.0, gap  # paper: ~2x tail gap before saturation
    assert sat["dram"] > sat["cxl50"] > sat["cxl100"]  # Fig. 7 ordering
    mid = (service["dram"] < service["cxl50"] < service["cxl100"])
    assert mid  # interleaving reduces but never erases the penalty
    rows.append(f"fig6/claim/tail_gap,0,x{gap:.2f};paper=~2x")
    rows.append(f"fig6/claim/qps_order,0,"
                f"{sat['dram']:.0f}>{sat['cxl50']:.0f}>{sat['cxl100']:.0f}")

    # end-to-end: the real engine with the same placement knobs
    from repro.models import registry
    from repro.serving.engine import ServingEngine
    arch = registry.get("internvl2-2b").tiny()
    params = arch.module.init(arch.cfg, jax.random.PRNGKey(0))
    for k, f in fracs.items():
        eng = ServingEngine(arch.cfg, params, max_batch=2, max_len=32,
                            policy=MemPolicy.from_slow_fraction("fast", "slow", f),
                            topology=topo, page_t=8)
        for _ in range(4):
            eng.submit([1, 2, 3], max_new_tokens=4)
        done = eng.run_until_drained()
        modeled = sorted(r.modeled_seconds for r in done)[-1]
        rows.append(f"fig6/engine/{k},{modeled*1e6:.2f},"
                    f"slow_frac={eng.cache.slow_fraction():.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
