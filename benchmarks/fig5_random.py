"""Fig. 5 — random block access bandwidth (block size x streams x tier).

Validates F5: all tiers suffer equally at 1 KiB blocks; as blocks grow,
DDR5-L8 scales with streams while CXL/DDR5-R1 saturate early (one
channel); random converges to sequential with block size.
"""
from __future__ import annotations

from repro.core import memo, perfmodel
from repro.core.tiers import OpClass, paper_topology


def run() -> list[str]:
    rows = []
    topo = paper_topology()
    for r in memo.simulate_random_bw(topo, blocks=(1024, 16384, 262144),
                                     lanes=(1, 4, 16)):
        rows.append(
            f"fig5/sim/{r['tier']}/{r['op']}/b{r['block']}/l{r['lanes']},"
            f"0,GBps={r['GBps']:.2f}")
    l8, cxl = topo.fast, topo.slow
    # 16 KiB blocks: DDR5-L8 gains much more from 4->16 streams than CXL
    l8_gain = (perfmodel.random_block_bandwidth(l8, OpClass.LOAD, 16384, 16)
               / perfmodel.random_block_bandwidth(l8, OpClass.LOAD, 16384, 4))
    cxl_gain = (perfmodel.random_block_bandwidth(cxl, OpClass.LOAD, 16384, 16)
                / perfmodel.random_block_bandwidth(cxl, OpClass.LOAD, 16384, 4))
    assert l8_gain > cxl_gain, (l8_gain, cxl_gain)
    rows.append(f"fig5/claim/thread_scaling,0,"
                f"l8_gain={l8_gain:.2f};cxl_gain={cxl_gain:.2f}")
    conv = (perfmodel.random_block_bandwidth(cxl, OpClass.LOAD, 262144, 4)
            / perfmodel.stream_bandwidth(cxl, OpClass.LOAD, 4))
    assert conv > 0.9
    rows.append(f"fig5/claim/converges_to_seq,0,ratio_at_256KiB={conv:.3f}")
    for rec in memo.measure_random_block(table_bytes=1 << 24,
                                         block_bytes_list=(1024, 16384),
                                         n_blocks=256):
        rows.append(f"fig5/measured/load/b{rec.block_bytes},"
                    f"{rec.seconds*1e6:.1f},GBps={rec.gbps:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
