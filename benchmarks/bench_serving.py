"""Serving-plane benchmark: shared-prefix paged KV + migration overlap
(ISSUE 8).

Open-loop arrivals of grouped prompts — every group shares a long
prefix (system prompt / few-shot header) and diverges mid-page — run
through four engine configurations:

* ``nosharing``  — decode-replay prefill from token zero (baseline);
* ``sharing``    — radix-matched prefix pages attached BY REFERENCE,
  partial-page divergence copy-on-write, suffix-only replay;
* ``sync``       — sharing + a churning re-tier schedule through an
  async BulkMover with the legacy submit+fence (every migration is an
  exposed decode stall);
* ``overlap``    — same churn through the unfenced issue path:
  stream_copy migrations run under decode compute and drain at epoch
  boundaries (hidden vs exposed time split via perfmodel.overlap_cost).

Metrics per mode: wall time, goodput (generated tokens / s), TTFT
p50/p99, prefill tokens avoided, migration stall/hidden/exposed time.
Asserted (full size): token-identical outputs across ALL modes,
sharing goodput >= 1.5x baseline, >= 30% prefill-token reduction, and
overlap stalls < synchronous stalls at equal migration traffic.  The
``--smoke`` lane (CI tier-1) asserts prefill-tokens-avoided > 0 and
zero correctness drift; the nightly uploads ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core.mover import BulkMover
from repro.core.policy import MemPolicy
from repro.core.telemetry import Telemetry
from repro.core.tiers import paper_topology
from repro.models import registry
from repro.serving.engine import ServingEngine

ARCH = "starcoder2-3b"
PAGE_T = 8

# full-size workload: 8 groups x 6 requests, 100-token shared prefix
# (12.5 pages: the half page exercises copy-on-write), 16 new tokens
FULL = dict(groups=8, per_group=6, pre_len=100, suf_len=4, new_tokens=16,
            max_len=128, max_batch=8, pool_pages=128, churn_every=8)
SMOKE = dict(groups=3, per_group=3, pre_len=20, suf_len=4, new_tokens=6,
             max_len=32, max_batch=4, pool_pages=32, churn_every=4)


def _workload(cfg, p, seed=0):
    """Grouped shared-prefix prompts + open-loop arrival steps."""
    rng = np.random.default_rng(seed)
    prompts = []
    for _ in range(p["groups"]):
        pre = rng.integers(0, cfg.vocab_padded, size=p["pre_len"]).tolist()
        for _ in range(p["per_group"]):
            suf = rng.integers(0, cfg.vocab_padded,
                               size=p["suf_len"]).tolist()
            prompts.append(pre + suf)
    order = rng.permutation(len(prompts))
    prompts = [prompts[i] for i in order]
    # open loop: Poisson arrivals in engine-step time, ~2 steps apart
    gaps = rng.exponential(scale=2.0, size=len(prompts))
    arrive = np.floor(np.cumsum(gaps)).astype(int)
    return prompts, arrive


def _run_mode(mode, cfg, params, p, prompts, arrive):
    topo = paper_topology()
    share = mode != "nosharing"
    churn = mode in ("sync", "overlap")
    mover = (BulkMover(topo, asynchronous=True, batch_size=16)
             if churn else None)
    tel = Telemetry()
    eng = ServingEngine(
        cfg, params, max_batch=p["max_batch"], max_len=p["max_len"],
        policy=MemPolicy.from_slow_fraction(topo.fast.name,
                                            topo.slow.name, 0.5),
        page_t=PAGE_T, topology=topo, mover=mover, telemetry=tel,
        prefix_pages=p["pool_pages"] if share else 0,
        overlap=(mode == "overlap"))
    fracs = (0.25, 0.5)
    moved = 0
    next_req = 0
    t0 = time.perf_counter()
    step_i = 0
    while next_req < len(prompts) or eng.queue or any(eng.slots):
        while next_req < len(prompts) and arrive[next_req] <= step_i:
            eng.submit(prompts[next_req], max_new_tokens=p["new_tokens"])
            next_req += 1
        eng.step()
        step_i += 1
        if churn and step_i % p["churn_every"] == 0:
            # deterministic migration churn (stands in for a Caption
            # walk's actuations): re-tier the batch population through
            # the mover, fenced (sync) or unfenced (overlap)
            eng._drain_migrations()
            b0 = mover.bytes_submitted
            ta = time.perf_counter()
            eng.cache = eng.cache.repartition_fraction(
                fracs[(step_i // p["churn_every"]) % 2],
                pinned_slots=eng.pinned_slots, mover=mover,
                telemetry=tel, fast_tier=topo.fast.name,
                slow_tier=topo.slow.name, source=eng.buffer_name,
                donate=eng.donate_kv, wait=not eng.overlap)
            eng._account_actuation(mover.bytes_submitted - b0,
                                   time.perf_counter() - ta)
            moved += mover.bytes_submitted - b0
    done = eng.run_until_drained()
    wall = time.perf_counter() - t0
    if mover is not None:
        mover.close()
    done = sorted(done, key=lambda r: r.rid)
    gen_tokens = sum(len(r.generated) for r in done)
    ttft = sorted((r.first_token_at - r.submitted_at) for r in done)
    out = {
        "wall_s": wall,
        "goodput_tok_s": gen_tokens / wall,
        "ttft_p50_ms": ttft[len(ttft) // 2] * 1e3,
        "ttft_p99_ms": ttft[min(int(len(ttft) * 0.99),
                                len(ttft) - 1)] * 1e3,
        "prefill_tokens_total": eng.prefill_tokens_total,
        "prefill_tokens_avoided": eng.prefill_tokens_avoided,
        "migration_stall_s": eng.migration_stall_s,
        "migration_hidden_s": eng.migration_hidden_s,
        "migration_exposed_s": eng.migration_exposed_s,
        "moved_bytes": int(moved),
        "decode_traces": eng.decode_traces,
    }
    if share:
        idx = eng.prefix_index
        out["prefix"] = {"hits": idx.hits, "misses": idx.misses,
                         "cow_copies": idx.cow_copies,
                         "evictions": idx.evictions,
                         "allocated_pages": idx.allocated_pages()}
    return out, [r.generated for r in done]


def run(smoke: bool = False) -> tuple[list[str], dict]:
    p = SMOKE if smoke else FULL
    arch = registry.get(ARCH).tiny()
    cfg = arch.cfg
    params = arch.module.init(cfg, jax.random.PRNGKey(0))
    prompts, arrive = _workload(cfg, p)
    payload = {"config": {"arch": ARCH, "page_t": PAGE_T, "smoke": smoke,
                          **p, "n_requests": len(prompts)},
               "modes": {}}
    tokens = {}
    for mode in ("nosharing", "sharing", "sync", "overlap"):
        payload["modes"][mode], tokens[mode] = _run_mode(
            mode, cfg, params, p, prompts, arrive)

    m = payload["modes"]
    # zero correctness drift: every mode generates identical tokens per
    # request — sharing, CoW, and unfenced migration are all invariant
    for mode in ("sharing", "sync", "overlap"):
        assert tokens[mode] == tokens["nosharing"], \
            f"token drift in mode {mode!r}"
    assert m["sharing"]["prefill_tokens_avoided"] > 0
    reduction = (m["sharing"]["prefill_tokens_avoided"]
                 / max(m["sharing"]["prefill_tokens_total"], 1))
    speedup = (m["sharing"]["goodput_tok_s"]
               / m["nosharing"]["goodput_tok_s"])
    payload["prefill_token_reduction"] = reduction
    payload["sharing_goodput_speedup"] = speedup
    stall_ratio = (m["overlap"]["migration_stall_s"]
                   / max(m["sync"]["migration_stall_s"], 1e-12))
    payload["overlap_stall_ratio"] = stall_ratio
    if not smoke:
        # acceptance bars (full size; smoke sizes are noise-bound)
        assert speedup >= 1.5, f"goodput speedup {speedup:.2f}x < 1.5x"
        assert reduction >= 0.30, f"prefill reduction {reduction:.0%} < 30%"
        assert (m["overlap"]["migration_stall_s"]
                < m["sync"]["migration_stall_s"]), \
            (m["overlap"]["migration_stall_s"],
             m["sync"]["migration_stall_s"])
        assert m["overlap"]["migration_hidden_s"] > 0
        # hiding migrations must show up end-to-end, not just in the
        # stall split: unfenced churn serves at least sync's goodput
        assert (m["overlap"]["goodput_tok_s"]
                >= m["sync"]["goodput_tok_s"]), \
            (m["overlap"]["goodput_tok_s"], m["sync"]["goodput_tok_s"])

    rows = [
        f"serving/goodput,0,sharing=x{speedup:.2f};"
        f"prefill_avoided={reduction:.0%};"
        f"cow={m['sharing']['prefix']['cow_copies']}",
        f"serving/ttft,0,p50_base={m['nosharing']['ttft_p50_ms']:.0f}ms;"
        f"p50_shared={m['sharing']['ttft_p50_ms']:.0f}ms;"
        f"p99_shared={m['sharing']['ttft_p99_ms']:.0f}ms",
        f"serving/overlap,0,stall_sync={m['sync']['migration_stall_s']*1e3:.1f}ms;"
        f"stall_overlap={m['overlap']['migration_stall_s']*1e3:.1f}ms;"
        f"hidden={m['overlap']['migration_hidden_s']*1e3:.3f}ms",
    ]
    return rows, payload


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (asserts sharing correctness only)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    rows, payload = run(smoke=args.smoke)
    for r in rows:
        print(r)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"serving/json,0,wrote={args.out}")


if __name__ == "__main__":
    main()
