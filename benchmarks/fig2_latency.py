"""Fig. 2 — access latency per tier/instruction class.

Reports (a) real measured latencies on this host (MEMO measure mode) and
(b) the calibrated tier model's Fig. 2 table, validating the paper's
headline ratios: CXL flushed-load = 2.2x DDR5-L8, ptr-chase = 3.7x.
"""
from __future__ import annotations

from repro.core import memo
from repro.core.tiers import paper_topology, tpu_v5e_topology


def run() -> list[str]:
    rows = []
    topo = paper_topology()
    sim = memo.simulate_latency(topo)
    by = {r["tier"]: r for r in sim}
    for r in sim:
        rows.append(f"fig2/sim/{r['tier']}/ld,{r['ld_ns']/1e3:.4f},ns={r['ld_ns']}")
        rows.append(f"fig2/sim/{r['tier']}/ptr_chase,"
                    f"{r['ptr_chase_ns']/1e3:.4f},ns={r['ptr_chase_ns']}")
    ld_ratio = by["cxl-agilex"]["ld_ns"] / by["ddr5-l8"]["ld_ns"]
    chase_ratio = by["cxl-agilex"]["ptr_chase_ns"] / by["ddr5-l8"]["ptr_chase_ns"]
    assert abs(ld_ratio - 2.2) < 0.1, "F1 load ratio drifted"
    assert abs(chase_ratio - 3.7) < 0.1, "F1 chase ratio drifted"
    rows.append(f"fig2/claim/ld_ratio,{ld_ratio:.3f},paper=2.2")
    rows.append(f"fig2/claim/chase_ratio,{chase_ratio:.3f},paper=3.7")
    # measured pointer-chase on this host (real)
    rec = memo.measure_pointer_chase(1 << 20, 1 << 14)
    ns_hop = rec.seconds / (1 << 14) * 1e9
    rows.append(f"fig2/measured/local_chase,{rec.seconds*1e6:.1f},ns_per_hop={ns_hop:.1f}")
    # target-hardware prediction (TPU HBM vs host tier)
    for r in memo.simulate_latency(tpu_v5e_topology()):
        rows.append(f"fig2/tpu/{r['tier']}/ptr_chase,"
                    f"{r['ptr_chase_ns']/1e3:.4f},ns={r['ptr_chase_ns']}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
