"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (us_per_call = 0 for purely
derived/simulated rows).  ``--skip-roofline`` when no dry-run artifacts
exist yet.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (fig2_latency, fig3_seq_bw, fig4_dsa, fig5_random,
                            fig6_redis, fig8_dlrm, fig10_dsb, fig11_caption,
                            fig_elastic)
    figs = {
        "fig2": fig2_latency.run,
        "fig3": fig3_seq_bw.run,
        "fig4": fig4_dsa.run,
        "fig5": fig5_random.run,
        "fig6": fig6_redis.run,
        "fig8": fig8_dlrm.run,
        "fig10": fig10_dsb.run,
        "fig11": fig11_caption.run,
        "elastic": fig_elastic.run,
    }
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in figs.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(row)
            print(f"{name}/claims,0,ALL-VALIDATED ({time.time()-t0:.1f}s)")
        except AssertionError as e:
            failures += 1
            print(f"{name}/claims,0,CLAIM-FAILED: {e}")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name}/claims,0,ERROR")
    if not args.skip_roofline and not args.only:
        try:
            from benchmarks import roofline
            recs = roofline.load_records()
            if recs:
                for row in roofline.csv_rows(recs):
                    print(row)
            else:
                print("roofline,0,NO-DRYRUN-ARTIFACTS (run repro.launch.dryrun)")
        except Exception:
            traceback.print_exc()
            failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
