"""Fig. 3 — sequential bandwidth vs stream count per tier/op.

Validates F2: DDR5-L8 load peaks ~221 GB/s (~26 streams); CXL load peaks
near 8 streams then collapses past 12; CXL nt-store hits ~22 GB/s at just
2 streams (DDR4-2666 theoretical max) then degrades.  Also reports real
measured host bandwidth (MEMO measure mode).
"""
from __future__ import annotations

from repro.core import memo, perfmodel
from repro.core.tiers import OpClass, paper_topology


def run() -> list[str]:
    rows = []
    topo = paper_topology()
    for r in memo.simulate_seq_bw(topo, lanes=(1, 2, 4, 8, 12, 16, 26, 32)):
        rows.append(f"fig3/sim/{r['tier']}/{r['op']}/lanes{r['lanes']},"
                    f"0,GBps={r['GBps']:.2f}")
    l8, cxl = topo.fast, topo.slow
    peak_l8 = perfmodel.stream_bandwidth(l8, OpClass.LOAD, 26) / 1e9
    assert abs(peak_l8 - 221) < 5, peak_l8
    cxl8 = perfmodel.stream_bandwidth(cxl, OpClass.LOAD, 8) / 1e9
    cxl16 = perfmodel.stream_bandwidth(cxl, OpClass.LOAD, 16) / 1e9
    assert cxl16 < cxl8 and abs(cxl16 - 16.8) < 3.0
    nt2 = perfmodel.stream_bandwidth(cxl, OpClass.NT_STORE, 2) / 1e9
    nt16 = perfmodel.stream_bandwidth(cxl, OpClass.NT_STORE, 16) / 1e9
    assert abs(nt2 - 22) < 2 and nt16 < nt2
    rows.append(f"fig3/claim/ddr5l8_load_peak,0,GBps={peak_l8:.1f};paper=221")
    rows.append(f"fig3/claim/cxl_load_collapse,0,{cxl8:.1f}->{cxl16:.1f};paper=~20->16.8")
    rows.append(f"fig3/claim/cxl_ntstore_2streams,0,GBps={nt2:.1f};paper=22")
    for rec in memo.measure_sequential(nbytes=1 << 25, lanes_list=(1, 2, 4)):
        rows.append(f"fig3/measured/{rec.op}/lanes{rec.lanes},"
                    f"{rec.seconds*1e6:.1f},GBps={rec.gbps:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
