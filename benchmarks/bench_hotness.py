"""Hotness-driven semantic tiering benchmark (ISSUE 10).

The paper's DLRM experiments (Figs. 8/9) fix WHERE pages live but not
WHICH pages: placement is address-anonymous.  Under Zipf-skewed access
— embedding rows in a recommender, experts under a hot routing mix —
the same fast-tier page budget buys far more served traffic when the
hot keys are pinned fast and only the cold tail interleaves across the
CXL devices.  This benchmark gates the semantic layer end-to-end:

* ``placement`` — a Zipf-skewed row ledger over a three-CXL-device
  topology: hotness-aware placement must STRICTLY beat the
  hotness-blind N:M uniform interleave on modeled throughput (the
  Fig. 8 closed-loop model fed with each placement's real per-device
  traffic shares), at the identical page budget.
* ``dlrm`` — the real Pallas ``embedding_reduce`` kernel through a
  :class:`SemanticTensor`: blind and hotness-aware placements produce
  byte-identical bag reductions (and match the dense reference).
* ``moe`` — deepseek-moe-16b-style routed MLP with a skewed router:
  ``aux["expert_counts"]`` feeds the ledger, per-expert weight pages
  re-tier, and reconstructed-parameter logits stay bit-exact.
* ``flip`` — a mid-run skew flip re-tiers in O(moved-keys)
  run-coalesced descriptors (``descriptors <= moved_keys <
  moved_pages``) with ZERO retraces of a jitted consumer.
* ``caption`` — the hot-set size as a walked coordinate: the
  controller converges onto the fast-tier budget floor, a hotness
  flip re-opens the converged walk via membership drift, and the walk
  re-converges with the new hot set pinned fast.

``--smoke`` runs the CI-sized lane; the nightly uploads
``BENCH_hotness.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.fig8_dlrm import throughput_nd
from repro.core.caption import CaptionConfig, CaptionController, EpochMetrics
from repro.core.hotness import HotnessLedger, HotSetCoordinator, SemanticTensor
from repro.core.mover import BulkMover
from repro.core.telemetry import Telemetry
from repro.core.tiers import paper_three_device_topology

THREADS = 32
#: fast tier holds this fraction of the table; the rest must live on CXL.
FAST_BUDGET = 0.25

SMOKE = dict(n_keys=64, rows_per_key=8, page_rows=2, dim=8, alpha=1.1,
             n_experts=16, walk_epochs=40, flip_epochs=10)
FULL = dict(n_keys=512, rows_per_key=8, page_rows=2, dim=32, alpha=1.1,
            n_experts=32, walk_epochs=64, flip_epochs=16)


def _zipf_scores(n_keys: int, alpha: float, rng) -> np.ndarray:
    """Zipf popularity over a RANDOM key permutation — hot keys are
    scattered in address space, so rank order != address order and a
    blind interleave cannot pin them fast by accident."""
    s = np.zeros(n_keys)
    s[rng.permutation(n_keys)] = 1.0 / (1.0 + np.arange(n_keys)) ** alpha
    return s / s.sum()


def _traffic_weights(st: SemanticTensor, topo) -> tuple[float, ...]:
    """Per-slow-device share of OBSERVED traffic under the current
    placement — what the closed-loop model actually serves from each
    device (page shares are what blind placement optimizes; traffic
    shares are what the memory system sees)."""
    dev = st.key_device()
    s = st.ledger.scores()
    total = max(float(s.sum()), 1e-12)
    return tuple(float(s[dev == i + 1].sum()) / total
                 for i in range(len(topo.slows)))


def _modeled(st: SemanticTensor, topo) -> float:
    return throughput_nd(topo.fast, topo.slows, _traffic_weights(st, topo),
                         THREADS)


def _budget_weights(topo, budget: float = FAST_BUDGET) -> tuple[float, ...]:
    """Slow-share vector for a fixed fast-tier page budget, split
    bandwidth-proportionally across the CXL devices (Fig. 10 prior)."""
    bw = topo.bandwidth_weights()
    return tuple((1.0 - budget) * b for b in bw)


def _section_placement(p, topo, names, payload) -> tuple[list[str], object]:
    """Same page budget, same data, same traffic — placement is the only
    variable.  Returns the semantic tensor for the flip section."""
    rng = np.random.default_rng(0)
    arr = jnp.asarray(
        rng.normal(size=(p["n_keys"] * p["rows_per_key"], p["dim"])),
        jnp.float32)
    led = HotnessLedger(p["n_keys"], decay=0.5)
    led.record(_zipf_scores(p["n_keys"], p["alpha"], rng) * 1e6)
    weights = _budget_weights(topo)
    st = SemanticTensor.from_array(
        arr, rows_per_key=p["rows_per_key"], weights=weights,
        device_names=names, page_rows=p["page_rows"], ledger=led,
        headroom=p["n_keys"] * p["rows_per_key"] // p["page_rows"],
        placement="blind")
    ref = np.asarray(st.to_array())
    blind_share, t_blind = st.hot_traffic_share(), _modeled(st, topo)

    mover = BulkMover(topo)
    telem = Telemetry()
    try:
        st = st.retier(weights, mover=mover, telemetry=telem)
    finally:
        mover.close()
    sem_share, t_sem = st.hot_traffic_share(), _modeled(st, topo)

    assert np.array_equal(ref, np.asarray(st.to_array())), \
        "re-tier corrupted the table"
    assert sem_share > blind_share, (sem_share, blind_share)
    assert t_sem > t_blind, \
        f"hotness-aware {t_sem:.0f} <= blind {t_blind:.0f} inf/s"
    counters = telem.snapshot()["counters"]
    payload["placement"] = {
        "fast_budget": FAST_BUDGET,
        "blind": {"hot_traffic": blind_share, "modeled_inf_s": t_blind},
        "semantic": {"hot_traffic": sem_share, "modeled_inf_s": t_sem},
        "speedup": t_sem / t_blind,
        "promoted_pages": counters.get("semantic_promoted_pages", 0),
        "demoted_pages": counters.get("semantic_demoted_pages", 0),
        "retier": st.last_retier,
    }
    rows = [
        f"hotness/placement/win,0,blind={t_blind:.0f};sem={t_sem:.0f}"
        f";x{t_sem / t_blind:.2f};hot_traffic={blind_share:.2f}"
        f"->{sem_share:.2f}",
    ]
    return rows, st


def _section_dlrm(p, topo, names, payload) -> list[str]:
    """Real Pallas kernel through both placements: byte-identical."""
    from repro.kernels.embedding_reduce import ops
    rng = np.random.default_rng(1)
    rows_total = p["n_keys"] * p["rows_per_key"]
    # integer-valued fp32: bag sums are exact under ANY accumulation
    # order, so cross-placement equality is bitwise — a single
    # misplaced row changes the result, fp rounding never does
    table = jnp.asarray(rng.integers(-8, 9, size=(rows_total, 64)),
                        jnp.float32)
    # Zipf-skewed bag lookups over ROWS (the DLRM access pattern)
    row_p = np.repeat(_zipf_scores(p["n_keys"], p["alpha"], rng),
                      p["rows_per_key"])
    idx = jnp.asarray(rng.choice(rows_total, p=row_p / row_p.sum(),
                                 size=(32, 16)))
    w = jnp.ones((32, 16), jnp.float32)
    weights = _budget_weights(topo)
    st = SemanticTensor.from_array(
        table, rows_per_key=p["rows_per_key"], weights=weights,
        device_names=names, page_rows=p["page_rows"],
        headroom=rows_total // p["page_rows"], placement="blind")
    # bag_reduce records the touched rows into the ledger for free
    out_blind = st.bag_reduce(idx, w, reduce_fn=ops.embedding_reduce)
    st.ledger.tick()
    t0 = time.perf_counter()
    st = st.retier(weights)
    dt = time.perf_counter() - t0
    out_sem = st.bag_reduce(idx, w, reduce_fn=ops.embedding_reduce)
    dense = (jnp.take(table, idx, axis=0) * w[..., None]).sum(axis=1)
    assert np.array_equal(np.asarray(out_blind), np.asarray(out_sem)), \
        "DLRM bag reduction drifted across placements"
    assert np.array_equal(np.asarray(out_sem), np.asarray(dense))
    payload["dlrm"] = {
        "hot_traffic": st.hot_traffic_share(),
        "retier": st.last_retier,
        "retier_s": dt,
        "bitexact": True,
    }
    return [
        f"hotness/dlrm/bitexact,{dt * 1e6:.0f},"
        f"hot_traffic={st.hot_traffic_share():.2f}"
        f";moved_keys={st.last_retier.get('moved_keys', 0)}",
    ]


def _section_moe(p, topo, names, payload) -> list[str]:
    """Router dispatch counts -> ledger -> per-expert re-tier; logits
    bit-exact with the expert stack reconstructed from either layout."""
    from repro.models import moe, registry
    arch = registry.get("deepseek-moe-16b").tiny()
    cfg = dataclasses.replace(
        arch.cfg,
        moe=dataclasses.replace(arch.cfg.moe, n_experts=p["n_experts"],
                                top_k=2))
    params = moe.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    # unit 0's stacked expert up-projection: (E, d, f) -> E keys of d rows
    w_up = params["units"]["moe"]["experts"]["w_up"][0]
    E, d, f = w_up.shape
    led = HotnessLedger(p["n_experts"], decay=0.5)
    weights = _budget_weights(topo)
    st = SemanticTensor.from_array(
        w_up.reshape(E * d, f), rows_per_key=d, weights=weights,
        device_names=names, page_rows=d // 4, ledger=led,
        headroom=E * 4, placement="blind")
    # Skew the routing mix: bias the router toward a hot subset drawn
    # from the experts the blind interleave put on SLOW devices — the
    # adversarial case the semantic layer exists for (heavily-routed
    # experts serving their dispatches over the CXL link).
    cold_placed = np.nonzero(st.key_device() != 0)[0]
    hot = rng.choice(cold_placed, size=max(2, p["n_experts"] // 8),
                     replace=False)
    bias = np.zeros(p["n_experts"], np.float32)
    bias[hot] = 4.0
    params["units"]["moe"]["router"] = (
        params["units"]["moe"]["router"] + jnp.asarray(bias))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_padded, size=(2, 16)))
    logits0, aux = moe.forward_with_aux(cfg, params, tokens)
    counts = np.asarray(aux["expert_counts"])
    assert counts.sum() > 0
    led.record(counts)

    def with_stack(stack):
        p2 = jax.tree_util.tree_map(lambda x: x, params)
        p2["units"]["moe"]["experts"] = dict(
            params["units"]["moe"]["experts"])
        p2["units"]["moe"]["experts"]["w_up"] = (
            params["units"]["moe"]["experts"]["w_up"].at[0].set(stack))
        return p2

    lb = moe.forward(cfg, with_stack(st.to_array().reshape(E, d, f)), tokens)
    st = st.retier(weights)
    ls = moe.forward(cfg, with_stack(st.to_array().reshape(E, d, f)), tokens)
    assert np.array_equal(np.asarray(lb), np.asarray(logits0))
    assert np.array_equal(np.asarray(ls), np.asarray(logits0)), \
        "MoE logits drifted across expert placements"
    assert st.last_retier.get("promoted_pages", 0) > 0, \
        "hot experts were never promoted off the CXL devices"
    hot_share = st.hot_traffic_share()
    # the skewed routing concentrates on few experts; pinning them fast
    # captures well above the page budget's worth of dispatches
    assert hot_share > FAST_BUDGET + 0.1, hot_share
    payload["moe"] = {
        "n_experts": E,
        "hot_router_experts": sorted(int(x) for x in hot),
        "dispatch_top4": np.argsort(-counts)[:4].tolist(),
        "hot_traffic": hot_share,
        "retier": st.last_retier,
        "bitexact": True,
    }
    return [
        f"hotness/moe/bitexact,0,E={E};hot_traffic={hot_share:.2f}"
        f";promoted={st.last_retier.get('promoted_pages', 0)}",
    ]


def _section_flip(p, topo, names, st: SemanticTensor, payload) -> list[str]:
    """Mid-run skew flip: O(moved-keys) descriptors, zero retraces."""
    rng = np.random.default_rng(3)
    traces = [0]

    def step(t, i):
        traces[0] += 1
        return t.gather_rows(i)

    fn = jax.jit(step)
    idx = jnp.arange(min(64, st.logical_rows))
    before = np.asarray(fn(st.it, idx))

    # flip the skew: a fresh permutation, fed until the EWMA crosses
    flipped = _zipf_scores(p["n_keys"], p["alpha"], rng) * 1e6
    for _ in range(p["flip_epochs"]):
        st.ledger.record(flipped)
        st.ledger.tick()
    drift = st.drift()
    mover = BulkMover(topo)
    try:
        d0 = mover.descriptors_submitted
        st = st.retier(_budget_weights(topo), mover=mover)
        descs = mover.descriptors_submitted - d0
    finally:
        mover.close()
    after = np.asarray(fn(st.it, idx))

    r = st.last_retier
    assert r["moved_pages"] > 0, "flip moved nothing"
    assert descs <= r["moved_keys"], (descs, r)
    assert descs < r["moved_pages"], (descs, r)
    assert np.array_equal(before, after), "flip corrupted the table"
    assert traces[0] == 1, f"{traces[0]} traces across the flip"
    payload["flip"] = {"drift": drift, "descriptors": int(descs),
                       "traces": traces[0], **r}
    return [
        f"hotness/flip/odelta,0,drift={drift:.2f};descs={descs}"
        f"<=keys={r['moved_keys']}<pages={r['moved_pages']};traces=1",
    ]


def _section_caption(p, topo, names, payload) -> list[str]:
    """The hot-set size as a walked coordinate with drift re-opening."""
    rng = np.random.default_rng(4)
    arr = jnp.asarray(
        rng.normal(size=(p["n_keys"] * p["rows_per_key"], p["dim"])),
        jnp.float32)
    led = HotnessLedger(p["n_keys"], decay=0.5)
    skew = _zipf_scores(p["n_keys"], p["alpha"], rng) * 1e6
    led.record(skew)
    cfg = CaptionConfig(epoch_steps=1, probe_epochs=1, step=0.1,
                        min_step=0.02, hysteresis=0.005, drift_threshold=0.0,
                        write_damp=False)
    # the fast tier can hold FAST_BUDGET of the pages: the walk may not
    # shrink the slow share below the capacity floor
    ctl = CaptionController(topo, cfg, initial_fraction=0.9,
                            min_fraction=1.0 - FAST_BUDGET)
    st = SemanticTensor.from_array(
        arr, rows_per_key=p["rows_per_key"],
        weights=ctl.weights, device_names=names, page_rows=p["page_rows"],
        ledger=led, headroom=p["n_keys"] * p["rows_per_key"]
        // p["page_rows"], placement="semantic")
    coord = HotSetCoordinator(st, ctl, drift_threshold=0.5)
    trail, flip_at = [], None
    for e in range(p["walk_epochs"]):
        if ctl.converged and flip_at is None:
            # workload shift mid-run: a brand-new hot set
            skew = _zipf_scores(p["n_keys"], p["alpha"], rng) * 1e6
            flip_at = e
        coord.st.ledger.record(skew)
        t = _modeled(coord.st, topo)
        coord.epoch(EpochMetrics(throughput=t))
        trail.append((round(ctl.fraction, 3), round(t)))
    assert flip_at is not None, "walk never converged before the flip"
    assert coord.reopens >= 1, "hot-set drift did not re-open the walk"
    assert ctl.converged, "walk did not re-converge after the flip"
    final_share = coord.st.hot_traffic_share()
    assert final_share > FAST_BUDGET, final_share
    payload["caption"] = {
        "flip_epoch": flip_at, "reopens": coord.reopens,
        "final_fraction": ctl.fraction, "final_hot_traffic": final_share,
        "trail": trail,
    }
    return [
        f"hotness/caption/walk,0,flip@{flip_at};reopens={coord.reopens}"
        f";frac={ctl.fraction:.2f};hot_traffic={final_share:.2f}",
    ]


def run(smoke: bool = False) -> tuple[list[str], dict]:
    p = SMOKE if smoke else FULL
    topo = paper_three_device_topology()
    names = (topo.fast.name,) + tuple(t.name for t in topo.slows)
    payload = {"config": {"smoke": smoke, **p, "threads": THREADS,
                          "devices": list(names)}}
    rows, st = _section_placement(p, topo, names, payload)
    rows += _section_dlrm(p, topo, names, payload)
    rows += _section_moe(p, topo, names, payload)
    rows += _section_flip(p, topo, names, st, payload)
    rows += _section_caption(p, topo, names, payload)
    return rows, payload


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized lane")
    ap.add_argument("--out", default="BENCH_hotness.json")
    args = ap.parse_args()
    rows, payload = run(smoke=args.smoke)
    payload["timestamp"] = time.time()
    for r in rows:
        print(r)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"hotness/json,0,wrote={args.out}")


if __name__ == "__main__":
    main()
