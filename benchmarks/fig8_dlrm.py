"""Figs. 8-9 — DLRM embedding reduction (MERCI analogue) on tiered memory.

Fig. 8: inference throughput vs thread count per placement — linear in
threads, slope set by the tier's random-access bandwidth; even 3.23% on
CXL cannot match pure DRAM when DRAM is NOT bandwidth-bound.
Fig. 9: the SNC mode (fast tier cut to 2 channels) makes inference
bandwidth-bound past ~24 threads; putting ~20% of pages on CXL then
RAISES throughput ~11% — the paper's key positive interleaving result,
which the placement planner must reproduce from first principles.

The ``fig8/semantic`` section extends the figure with ISSUE 10's
Zipf-skewed lane: the SAME page budget, but the embedding rows a
Zipf-80/20 lookup stream actually hammers are pinned to the fast tier
by a hotness ledger, and the real Pallas ``embedding_reduce`` kernel
runs through the semantic layout bit-exactly in both placements.

Also times the real Pallas embedding_reduce kernel over an
InterleavedTensor (exactness asserted in tests).  ``--smoke`` is the
CI lane; the nightly run writes ``BENCH_dlrm.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perfmodel
from repro.core.interleave import InterleavedTensor
from repro.core.policy import MemPolicy
from repro.core.tiers import DDR5_L8, OpClass, TierTopology, paper_topology

ROW_B = 256  # 64-dim fp32 embedding rows
BYTES_PER_INFER = 80 * ROW_B  # 80 lookups per sample (bags)
GATHER_B = ROW_B * 8  # per-lookup granule for the latency (R) term
BURST_B = 16384  # coalesced burst granule for the channel caps (Fig. 5)
COMPUTE_NS = 400.0  # per-inference reduction compute (MERCI)


def throughput(fast, slow, f_slow: float, threads: int) -> float:
    """samples/s: closed-loop (threads / per-inference latency) bounded by
    each tier's random-access channel.  Captures both paper regimes:
    interleaving HURTS while the fast tier has headroom (latency adds),
    and HELPS once the fast tier saturates (extra parallel channel)."""
    return throughput_nd(fast, (slow,), (f_slow,), threads)


def throughput_nd(fast, devs, weights, threads: int) -> float:
    """N-device form: the table interleaved across ``fast`` + ``devs``
    with per-device page shares ``weights`` (the Fig. 10 device-mix
    model).  Each device is an independent parallel channel: per-
    inference latency sums the per-device shares, and every device caps
    throughput at its own random-access bandwidth over its share."""
    f_slow = sum(weights)
    sbw_f = perfmodel.random_block_bandwidth(fast, OpClass.LOAD, GATHER_B, 1)
    r = (1 - f_slow) * BYTES_PER_INFER / sbw_f + COMPUTE_NS * 1e-9
    for dev, w in zip(devs, weights):
        if w <= 0:
            continue
        sbw = perfmodel.random_block_bandwidth(dev, OpClass.LOAD, GATHER_B, 1)
        r += w * BYTES_PER_INFER / sbw
    x = threads / r
    cap_f = perfmodel.random_block_bandwidth(fast, OpClass.LOAD, BURST_B, threads) \
        / max((1 - f_slow) * BYTES_PER_INFER, 1e-9)
    x = min(x, cap_f)
    for dev, w in zip(devs, weights):
        if w <= 0:
            continue
        cap = perfmodel.random_block_bandwidth(dev, OpClass.LOAD, BURST_B, threads) \
            / (w * BYTES_PER_INFER)
        x = min(x, cap)
    return x


def _semantic_section(smoke: bool, payload: dict) -> list[str]:
    """Zipf-skewed hotness lane over the three-CXL-device preset."""
    from repro.core.hotness import SemanticTensor
    from repro.core.tiers import paper_three_device_topology
    from repro.kernels.embedding_reduce import ops

    topo = paper_three_device_topology()
    names = (topo.fast.name,) + tuple(t.name for t in topo.slows)
    n_keys, rpk = (64, 8) if smoke else (512, 8)
    rows_total = n_keys * rpk
    rng = np.random.default_rng(0)
    # integer-valued fp32 rows: bag sums are order-independent, so the
    # cross-placement equality below is bitwise
    table = jnp.asarray(rng.integers(-8, 9, size=(rows_total, 64)),
                        jnp.float32)
    # Zipf popularity over a random row->rank permutation (hot rows
    # scattered in address space, the case blind interleave cannot win)
    zipf = np.zeros(n_keys)
    zipf[rng.permutation(n_keys)] = 1.0 / (1.0 + np.arange(n_keys)) ** 1.1
    row_p = np.repeat(zipf, rpk)
    idx = jnp.asarray(rng.choice(rows_total, p=row_p / row_p.sum(),
                                 size=(64, 80)))
    w = jnp.ones((64, 80), jnp.float32)

    budget = 0.25  # fast tier holds a quarter of the table
    bw = topo.bandwidth_weights()
    weights = tuple((1.0 - budget) * b for b in bw)
    st = SemanticTensor.from_array(
        table, rows_per_key=rpk, weights=weights, device_names=names,
        page_rows=2, headroom=rows_total // 2, placement="blind")
    out_blind = st.bag_reduce(idx, w, reduce_fn=ops.embedding_reduce)

    def modeled(s):
        dev, sc = s.key_device(), s.ledger.scores()
        total = max(float(sc.sum()), 1e-12)
        shares = tuple(float(sc[dev == i + 1].sum()) / total
                       for i in range(len(topo.slows)))
        return throughput_nd(topo.fast, topo.slows, shares, 32)

    st.ledger.tick()  # bag_reduce recorded the touched rows
    t_blind, share_blind = modeled(st), st.hot_traffic_share()
    st = st.retier(weights)
    t_sem, share_sem = modeled(st), st.hot_traffic_share()
    out_sem = st.bag_reduce(idx, w, reduce_fn=ops.embedding_reduce)
    assert np.array_equal(np.asarray(out_blind), np.asarray(out_sem)), \
        "semantic re-tier changed the bag reduction"
    assert t_sem > t_blind, (t_sem, t_blind)
    payload["semantic"] = {
        "fast_budget": budget,
        "blind": {"hot_traffic": share_blind, "modeled_inf_s": t_blind},
        "hotness": {"hot_traffic": share_sem, "modeled_inf_s": t_sem},
        "speedup": t_sem / t_blind,
        "retier": st.last_retier,
    }
    return [
        f"fig8/semantic/zipf,0,blind={t_blind:.0f};hot={t_sem:.0f}"
        f";x{t_sem / t_blind:.2f};hot_traffic={share_blind:.2f}"
        f"->{share_sem:.2f}",
    ]


def run(smoke: bool = False, payload: dict | None = None) -> list[str]:
    payload = payload if payload is not None else {}
    rows = []
    topo = paper_topology()
    l8, cxl = topo.fast, topo.slow
    # Fig. 8: full 8-channel DRAM is never the bottleneck <=32 threads
    for f, tag in ((0.0, "dram"), (0.0323, "cxl3.23"), (0.5, "cxl50"),
                   (1.0, "cxl100")):
        for th in (8, 16, 32):
            rows.append(f"fig8/sim/{tag}/threads{th},0,"
                        f"inf_s={throughput(l8, cxl, f, th):.0f}")
    t_dram = throughput(l8, cxl, 0.0, 32)
    t_323 = throughput(l8, cxl, 0.0323, 32)
    assert t_323 < t_dram  # even 3.23% can't match pure DRAM (F7 first half)
    rows.append(f"fig8/claim/interleave_below_dram,0,"
                f"{t_323:.0f}<{t_dram:.0f}")

    # Fig. 9: SNC = fast tier clipped to 2 channels
    snc = dataclasses.replace(DDR5_L8, name="snc-2ch", load_bw=55e9,
                              load_peak_streams=12)
    base = throughput(snc, cxl, 0.0, 32)
    best_f, best_t = 0.0, base
    for f in np.linspace(0, 0.4, 41):
        t = throughput(snc, cxl, float(f), 32)
        if t > best_t:
            best_f, best_t = float(f), t
    gain = best_t / base - 1
    rows.append(f"fig9/sim/snc_gain,0,f*={best_f:.2f};gain={gain*100:.1f}%"
                f";paper=+11%@20%")
    assert 0.05 < gain < 0.35 and 0.08 < best_f < 0.35, (gain, best_f)
    # and in the UNbound regime (8-channel DRAM) interleaving never helps
    assert all(throughput(l8, cxl, f, 32) <= throughput(l8, cxl, 0.0, 32)
               for f in (0.0323, 0.1, 0.2))

    # the planner discovers the same regime from the access profile
    from repro.core.classifier import AccessProfile
    from repro.core.planner import BufferReq, plan
    from repro.core.policy import BufferClass
    table_bytes = 8 << 30
    reads = 55e9 * 1.3  # demand exceeds the SNC node's bandwidth
    topo_snc = TierTopology(fast=dataclasses.replace(snc, capacity_bytes=96 << 30),
                            slow=cxl)
    p = plan([BufferReq("emb", BufferClass.EMBEDDING, table_bytes,
                        AccessProfile(reads, 0, 1, 1024, ROW_B, 1.0))],
             topo_snc, compute_seconds=1.0)
    f_planner = p.slow_fraction("emb")
    rows.append(f"fig9/planner/fraction,0,f={f_planner:.3f}")
    assert 0.05 < f_planner < 0.45  # planner lands in the beneficial band

    # real kernel over a tiered table (wall time, correctness in tests)
    from repro.kernels.embedding_reduce import ops
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(4096, 64)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 4096, size=(64, 80)))
    w = jnp.ones((64, 80), jnp.float32)
    it = InterleavedTensor.from_array(
        table, MemPolicy.weighted(("fast", "slow"), (4, 1)), page_rows=64)
    fn = jax.jit(lambda: it.bag_reduce(idx, w, reduce_fn=ops.embedding_reduce))
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    dt = time.perf_counter() - t0
    rows.append(f"fig8/measured/kernel_bag64x80,{dt*1e6:.1f},"
                f"rows_per_s={64*80/dt:.0f}")
    rows += _semantic_section(smoke, payload)
    payload["rows"] = list(rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized lane")
    ap.add_argument("--out", default="BENCH_dlrm.json")
    args = ap.parse_args()
    payload: dict = {"smoke": args.smoke}
    rows = run(smoke=args.smoke, payload=payload)
    payload["timestamp"] = time.time()
    print("\n".join(rows))
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"fig8/json,0,wrote={args.out}")


if __name__ == "__main__":
    main()
