"""Fig. 10 — DeathStarBench microservices + §6 bandwidth expansion.

Part 1 (tail latency): request = chain of compute stages (nginx/RPC/ML,
ms-scale) + database stages whose latency depends on where the
storage/caching tier lives.  Validates F8: compose-post (db-heavy)
shows a visible tail gap with storage on CXL; read-user-timeline
(front-end-heavy) shows ~none; the mixed workload saturates at a
similar point either way — so ms-latency layered services are the right
offloading candidates (§6).

Part 2 (bandwidth expansion): the paper's interleave-ratio sweep on a
multi-device pool.  A bandwidth-bound streaming workload over a
DDR + CXL-A + CXL-B topology, swept across page-interleave weight
vectors: throughput peaks when the ratio matches each device's relative
bandwidth — **bandwidth-proportional weighted interleaving beats
uniform interleaving beats any single device** (the §6/Fig. 10
ordering).  Uniform round-robin serializes on the slowest device;
membind leaves the other links idle.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import perfmodel
from repro.core.interleave import InterleavedTensor
from repro.core.mover import BulkMover
from repro.core.policy import MemPolicy
from repro.core.telemetry import Telemetry
from repro.core.tiers import (CXL_A, CXL_B, DDR5_L8, OpClass, TierTopology,
                              paper_topology)

# stage profiles: (compute_ms, db_dependent_accesses)
WORKLOADS = {
    "compose_post": {"compute_ms": 1.2, "db_hops": 4000, "db_bytes": 64 << 10},
    "read_user_timeline": {"compute_ms": 3.0, "db_hops": 400, "db_bytes": 16 << 10},
}
MIX = (("read_user_timeline", 0.9), ("compose_post", 0.1))  # home~user tl.


def request_ms(topo, wl: dict, storage_tier) -> float:
    chase_ms = wl["db_hops"] * storage_tier.chase_latency_ns * 1e-6
    read_ms = wl["db_bytes"] / storage_tier.load_bw * 1e3
    return wl["compute_ms"] + chase_ms + read_ms


# ---------------------------------------------------------------------------
# Part 2: weighted-interleave bandwidth expansion on a device mix.
# The fast tier is the SNC-clipped DDR node (the paper's saturated-DRAM
# regime — expansion only pays once the fast tier is the bottleneck).
# ---------------------------------------------------------------------------
def expansion_topology() -> TierTopology:
    snc = dataclasses.replace(DDR5_L8, name="snc-2ch", load_bw=55e9,
                              load_peak_streams=12)
    return TierTopology(fast=snc, slows=(CXL_A, CXL_B))


def _device_bw(tier) -> float:
    """Saturated streaming bandwidth of one device (its own channel)."""
    return perfmodel.stream_bandwidth(tier, OpClass.LOAD,
                                      tier.load_peak_streams)


def aggregate_bw(topo: TierTopology, weights: tuple[float, ...]) -> float:
    """Effective streaming bandwidth of a page-interleave weight vector.

    Devices stream concurrently; total time for B bytes is set by the
    device that takes longest on its share, so the effective bandwidth is
    ``1 / max_i(w_i / bw_i)`` — maximized when w_i tracks bw_i (the
    paper's best static ratio)."""
    shares = (1.0 - sum(weights),) + tuple(weights)
    devs = (topo.fast,) + topo.slows
    worst = max(w / _device_bw(d) for w, d in zip(shares, devs) if w > 0)
    return 1.0 / worst


def run_expansion() -> list[str]:
    rows = []
    topo = expansion_topology()
    devs = (topo.fast,) + topo.slows
    bws = [_device_bw(d) for d in devs]

    # Single-device baselines (membind each device).
    singles = {}
    for i, d in enumerate(devs):
        w = [0.0] * len(topo.slows)
        if i > 0:
            w[i - 1] = 1.0
        singles[d.name] = aggregate_bw(topo, tuple(w))
        rows.append(f"fig10/expansion/single/{d.name},0,"
                    f"bw={singles[d.name]/1e9:.1f}GB/s")
    best_single = max(singles.values())

    # Uniform round-robin (the numactl --interleave default).
    n = len(devs)
    uniform = aggregate_bw(topo, (1.0 / n,) * len(topo.slows))
    rows.append(f"fig10/expansion/uniform,0,bw={uniform/1e9:.1f}GB/s")

    # Interleave-ratio sweep: slide the slow share, split across the CXL
    # devices proportional to their bandwidth, and find the peak.
    bw_w = topo.bandwidth_weights()
    sweep_best, sweep_best_s = 0.0, 0.0
    for s in np.linspace(0.0, 0.8, 81):
        w = tuple(float(s) * x for x in bw_w)
        bw = aggregate_bw(topo, w)
        if bw > sweep_best:
            sweep_best, sweep_best_s = bw, float(s)
    rows.append(f"fig10/expansion/sweep_peak,0,slow_share={sweep_best_s:.2f}"
                f";bw={sweep_best/1e9:.1f}GB/s")

    # Bandwidth-proportional weights (the analytic optimum).
    total = sum(bws)
    prop = tuple(b / total for b in bws[1:])
    weighted = aggregate_bw(topo, prop)
    rows.append(f"fig10/expansion/weighted,0,w={','.join(f'{x:.2f}' for x in prop)}"
                f";bw={weighted/1e9:.1f}GB/s")

    # The paper's Fig. 10 ordering: weighted >= uniform >= best single.
    assert weighted >= uniform >= best_single, (weighted, uniform, best_single)
    # ... and the proportional point is (near) the sweep's peak, which
    # expands bandwidth to ~the sum of the devices.
    assert weighted >= 0.99 * sweep_best, (weighted, sweep_best)
    assert weighted >= 0.95 * total, (weighted, total)
    rows.append(f"fig10/claim/expansion_ordering,0,"
                f"weighted={weighted/1e9:.0f}>=uniform={uniform/1e9:.0f}"
                f">=single={best_single/1e9:.0f}GB/s")
    rows.extend(run_actuation_cost(topo, prop))
    return rows


def run_actuation_cost(topo: TierTopology,
                       weights: tuple[float, ...]) -> list[str]:
    """Reaching the weighted-interleave point on a REAL paged tensor:
    the uniform -> bandwidth-proportional reshape moves only the delta
    pages and drains O(runs) coalesced mover descriptors, so adopting
    the Fig. 10 optimum costs page-delta traffic, not a rebuild."""
    rng = np.random.default_rng(0)
    n_pages = 1024
    it = InterleavedTensor.from_array(
        jnp.asarray(rng.normal(size=(n_pages * 16, 16)), jnp.float32),
        MemPolicy.from_tier_fractions(
            topo.fast.name, tuple(t.name for t in topo.slows),
            (1.0 / 3, 1.0 / 3)),
        page_rows=16, headroom=n_pages // 4)
    tel = Telemetry()
    page_bytes = 16 * it.row_bytes
    with BulkMover(topo, asynchronous=True, batch_size=16,
                   telemetry=tel) as mover:
        before = np.asarray(it.page_device).copy()
        it = it.repartition_weights(weights, mover=mover)
        delta = int((np.asarray(it.page_device) != before).sum())
        descs = mover.descriptors_submitted
        moved = mover.bytes_submitted
    assert moved == delta * page_bytes, (moved, delta * page_bytes)
    assert 0 < descs < delta, (descs, delta)  # coalesced, not per page
    return [f"fig10/expansion/actuation,0,delta_pages={delta}"
            f";descriptors={descs};bytes_exact=1"]


def run() -> list[str]:
    rows = []
    topo = paper_topology()
    gaps = {}
    for name, wl in WORKLOADS.items():
        dram = request_ms(topo, wl, topo.fast)
        cxl = request_ms(topo, wl, topo.slow)
        gaps[name] = cxl / dram
        rows.append(f"fig10/sim/{name}/dram,{dram*1e3:.1f},ms={dram:.3f}")
        rows.append(f"fig10/sim/{name}/cxl,{cxl*1e3:.1f},ms={cxl:.3f}"
                    f";gap=x{gaps[name]:.3f}")
    # F8: db-heavy shows a gap; front-end-heavy is amortized to ~nothing
    assert gaps["compose_post"] > 1.25, gaps
    assert gaps["read_user_timeline"] < 1.10, gaps
    mixed_dram = sum(w * request_ms(topo, WORKLOADS[n], topo.fast)
                     for n, w in MIX)
    mixed_cxl = sum(w * request_ms(topo, WORKLOADS[n], topo.slow)
                    for n, w in MIX)
    mixed_gap = mixed_cxl / mixed_dram
    assert mixed_gap < 1.25
    rows.append(f"fig10/claim/compose_gap,0,x{gaps['compose_post']:.2f}")
    rows.append(f"fig10/claim/timeline_amortized,0,"
                f"x{gaps['read_user_timeline']:.3f}")
    rows.append(f"fig10/claim/mixed_saturation_similar,0,x{mixed_gap:.3f}")
    rows.extend(run_expansion())
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
