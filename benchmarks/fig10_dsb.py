"""Fig. 10 — DeathStarBench microservices on tiered memory.

Request = chain of compute stages (nginx/RPC/ML, ms-scale) + database
stages whose latency depends on where the storage/caching tier lives.
Validates F8: compose-post (db-heavy) shows a visible tail gap with
storage on CXL; read-user-timeline (front-end-heavy) shows ~none; the
mixed workload saturates at a similar point either way — so ms-latency
layered services are the right offloading candidates (§6).
"""
from __future__ import annotations

from repro.core.tiers import paper_topology

# stage profiles: (compute_ms, db_dependent_accesses)
WORKLOADS = {
    "compose_post": {"compute_ms": 1.2, "db_hops": 4000, "db_bytes": 64 << 10},
    "read_user_timeline": {"compute_ms": 3.0, "db_hops": 400, "db_bytes": 16 << 10},
}
MIX = (("read_user_timeline", 0.9), ("compose_post", 0.1))  # home~user tl.


def request_ms(topo, wl: dict, storage_tier) -> float:
    chase_ms = wl["db_hops"] * storage_tier.chase_latency_ns * 1e-6
    read_ms = wl["db_bytes"] / storage_tier.load_bw * 1e3
    return wl["compute_ms"] + chase_ms + read_ms


def run() -> list[str]:
    rows = []
    topo = paper_topology()
    gaps = {}
    for name, wl in WORKLOADS.items():
        dram = request_ms(topo, wl, topo.fast)
        cxl = request_ms(topo, wl, topo.slow)
        gaps[name] = cxl / dram
        rows.append(f"fig10/sim/{name}/dram,{dram*1e3:.1f},ms={dram:.3f}")
        rows.append(f"fig10/sim/{name}/cxl,{cxl*1e3:.1f},ms={cxl:.3f}"
                    f";gap=x{gaps[name]:.3f}")
    # F8: db-heavy shows a gap; front-end-heavy is amortized to ~nothing
    assert gaps["compose_post"] > 1.25, gaps
    assert gaps["read_user_timeline"] < 1.10, gaps
    mixed_dram = sum(w * request_ms(topo, WORKLOADS[n], topo.fast)
                     for n, w in MIX)
    mixed_cxl = sum(w * request_ms(topo, WORKLOADS[n], topo.slow)
                    for n, w in MIX)
    mixed_gap = mixed_cxl / mixed_dram
    assert mixed_gap < 1.25
    rows.append(f"fig10/claim/compose_gap,0,x{gaps['compose_post']:.2f}")
    rows.append(f"fig10/claim/timeline_amortized,0,"
                f"x{gaps['read_user_timeline']:.3f}")
    rows.append(f"fig10/claim/mixed_saturation_similar,0,x{mixed_gap:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
