"""Fig. 4 — bulk data-movement efficiency (movdir64B / DSA analogue).

(a) route comparison D2D/D2C/C2D/C2C and (b) engine-offloaded movement:
sync vs async x batch {1,16,128} at page granularity, via the BulkMover
cost model; validates F4 orderings.  Also times the real stream_copy
Pallas kernel (cache-bypass path) on this host.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import memo
from repro.core.tiers import paper_topology


def run() -> list[str]:
    rows = []
    topo = paper_topology()
    sim = memo.simulate_movement(topo, nbytes=1 << 28, page_bytes=4 << 10)
    for r in sim:
        rows.append(f"fig4/sim/{r['route']}/{r['mode']}/batch{r['batch']},"
                    f"0,GBps={r['GBps']:.2f}")
    def g(route, mode, batch):
        return next(r["GBps"] for r in sim
                    if (r["route"], r["mode"], r["batch"]) == (route, mode, batch))
    # F4: async >= sync; batching amortizes; mixed routes beat C2C
    assert g("C2D", "async", 128) >= g("C2D", "sync", 1)
    assert g("C2D", "sync", 128) >= g("C2D", "sync", 1)
    assert g("C2D", "sync", 1) > g("C2C", "sync", 1)
    assert g("D2C", "sync", 1) > g("C2C", "sync", 1)
    rows.append(f"fig4/claim/async_beats_sync,0,"
                f"{g('C2D','async',128):.2f}>={g('C2D','sync',1):.2f}")
    rows.append(f"fig4/claim/c2c_slowest,0,"
                f"C2C={g('C2C','sync',1):.2f};C2D={g('C2D','sync',1):.2f}")
    # real cache-bypass kernel on this host
    from repro.kernels.stream_copy import ops
    x = jnp.ones((4096, 1024), jnp.float32)
    out = jax.block_until_ready(ops.stream_copy(x, block_rows=256))
    t0 = time.perf_counter()
    for _ in range(3):
        out = jax.block_until_ready(ops.stream_copy(x, block_rows=256))
    dt = (time.perf_counter() - t0) / 3
    rows.append(f"fig4/measured/stream_copy_16MiB,{dt*1e6:.1f},"
                f"GBps={2*x.nbytes/dt/1e9:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
