"""Fig. 11 — Caption: dynamic page allocation converging from cold start.

The paper's §7 result: a counter-sampling controller that tunes the
slow-tier page fraction online converges to (within a few points of)
the best *static* weighted-interleave split — without knowing the
workload in advance — and never ends below the membind-fast default.

Scenario A reproduces the positive regime on the paper's testbed with
the SNC-clipped fast tier (the Fig. 9 setup where ~20% CXL RAISES DLRM
throughput ~11%): the controller starts at 0% slow and climbs the
measured-throughput hill to the static optimum.

Scenario B runs the same loop on the TPU v5e topology where HBM has
bandwidth headroom: the correct answer is "stay fast", and Caption's
guardrails keep it there (Fig. 7 discipline: interleaving never helps
an unsaturated fast tier).

Finally the actuation path is audited end-to-end: re-tiering a real
``InterleavedTensor`` moves ONLY the delta pages (byte-for-byte checked
against BulkMover telemetry) and is numerically a no-op.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.fig8_dlrm import BYTES_PER_INFER, throughput, throughput_nd
from repro.core.arbiter import ArbiterConfig, CaptionArbiter
from repro.core.caption import CaptionConfig, CaptionController, EpochMetrics
from repro.core.interleave import InterleavedTensor
from repro.core.mover import BulkMover
from repro.core.policy import MemPolicy
from repro.core.telemetry import EpochWindow, Telemetry
from repro.core.tiers import (CXL_A, CXL_B, DDR5_L8, TierTopology,
                              paper_topology, tpu_v5e_topology)
from repro.core.warmstart import WarmStartMemo

THREADS = 32
EPOCHS = 64

# -- multi-buffer mode: three tiered buffers share one slow tier ------------
#: per-buffer thread counts (weights-, KV- and opt-state-shaped demand).
MB_BUFFERS = {"weights": 32, "kv": 24, "opt": 16}
#: shared slow-tier byte budget (< the CXL 20 GB/s peak: link headroom).
MB_BUDGET = 12e9
#: §3 contention: an oversubscribed far-memory controller serves *less*
#: than its budget (Fig. 3 collapse), so blowing it hurts everyone.
MB_COLLAPSE = 0.65


def snc_topology() -> TierTopology:
    """Paper testbed with the fast tier clipped to 2 channels (Fig. 9)."""
    snc = dataclasses.replace(DDR5_L8, name="snc-2ch", load_bw=55e9,
                              load_peak_streams=12)
    return TierTopology(fast=snc, slow=paper_topology().slow)


def three_device_topology() -> TierTopology:
    """The SNC fast node + two of the paper's CXL devices (Table 1 mix)."""
    snc = dataclasses.replace(DDR5_L8, name="snc-2ch", load_bw=55e9,
                              load_peak_streams=12)
    return TierTopology(fast=snc, slows=(CXL_A, CXL_B))


def run_three_device() -> list[str]:
    """Caption on a 3-device topology: the controller walks a WEIGHT
    VECTOR on the simplex (coordinate descent per device) and must land
    within 5pp per device of the best static sweep point — the N-device
    generalization of the paper's Fig. 11 convergence claim."""
    rows = []
    topo = three_device_topology()

    def tput(w) -> float:
        return throughput_nd(topo.fast, topo.slows, tuple(w), THREADS)

    # Exhaustive static sweep over the weight simplex (the Fig. 10 grid).
    grid = np.linspace(0.0, 0.5, 51)
    best_w, best_t = (0.0, 0.0), 0.0
    for a in grid:
        for b in grid:
            if a + b > 0.8:
                continue
            t = tput((float(a), float(b)))
            if t > best_t:
                best_w, best_t = (float(a), float(b)), t
    membind = tput((0.0, 0.0))

    ctl = CaptionController(
        topo, CaptionConfig(probe_epochs=2, step=0.05, min_step=0.01,
                            hysteresis=0.01))
    trace = []
    for epoch in range(256):
        t = tput(ctl.weights)
        trace.append((epoch, tuple(ctl.weights), t))
        ctl.observe(EpochMetrics(throughput=t))
        if ctl.converged:
            break
    for epoch, w, t in trace[:: max(1, len(trace) // 8)]:
        rows.append(f"fig11/3dev/epoch{epoch:03d},0,"
                    f"w=({w[0]:.3f},{w[1]:.3f});inf_s={t:.0f}")
    final_t = tput(ctl.weights)
    rows.append(
        f"fig11/3dev/converged,0,"
        f"w=({ctl.weights[0]:.3f},{ctl.weights[1]:.3f})"
        f";best=({best_w[0]:.3f},{best_w[1]:.3f})"
        f";tput={final_t:.0f};static_best={best_t:.0f};membind={membind:.0f}")
    # Acceptance: converged; each device's weight within 5pp of the best
    # static sweep point; throughput at least membind-fast and within 5%
    # of the best static split.
    assert ctl.converged, ctl.phase
    for w, b in zip(ctl.weights, best_w):
        assert abs(w - b) <= 0.05, (tuple(ctl.weights), best_w)
    assert final_t >= membind, (final_t, membind)
    assert final_t >= 0.95 * best_t, (final_t, best_t)
    return rows


def _static_sweep(topo: TierTopology) -> tuple[float, float]:
    """Best static weighted-interleave split by exhaustive sweep."""
    best_f, best_t = 0.0, throughput(topo.fast, topo.slow, 0.0, THREADS)
    for f in np.linspace(0.0, 0.6, 121):
        t = throughput(topo.fast, topo.slow, float(f), THREADS)
        if t > best_t:
            best_f, best_t = float(f), t
    return best_f, best_t


def _run_loop(topo: TierTopology, cfg: CaptionConfig
              ) -> tuple[CaptionController, list[tuple[int, float, float]]]:
    """Cold start (0% slow) closed loop: modeled epoch -> counters -> adjust."""
    ctl = CaptionController(topo, cfg, initial_fraction=0.0)
    trace = []
    for epoch in range(EPOCHS):
        t = throughput(topo.fast, topo.slow, ctl.fraction, THREADS)
        trace.append((epoch, ctl.fraction, t))
        ctl.observe(EpochMetrics(throughput=t))  # DLRM inference: read-only
    return ctl, trace


def _shared_throughput(topo: TierTopology, fracs: dict[str, float]
                       ) -> tuple[dict[str, float], float]:
    """Per-buffer inference rates when all buffers share the slow tier.

    Each buffer runs the Fig. 8 closed-loop model in isolation; if their
    combined slow-tier traffic oversubscribes MB_BUDGET, the controller
    collapses (Fig. 3) and every buffer slows in proportion to its slow
    dependence.  Returns (rates, achieved slow-tier bytes/s)."""
    fast, slow = topo.fast, topo.slow
    xs = {n: throughput(fast, slow, fracs[n], th)
          for n, th in MB_BUFFERS.items()}
    offered = sum(xs[n] * fracs[n] * BYTES_PER_INFER for n in xs)
    if offered <= MB_BUDGET:
        return xs, offered
    eff = MB_BUDGET * MB_COLLAPSE
    xs = {n: xs[n] / (1 + fracs[n] * (offered / eff - 1)) for n in xs}
    return xs, sum(xs[n] * fracs[n] * BYTES_PER_INFER for n in xs)


def run_multibuffer(topo: TierTopology) -> list[str]:
    """Three buffers under one CaptionArbiter vs uncoordinated greed.

    The uncoordinated baseline gives each buffer its per-buffer greedy
    optimum (the best static split computed as if it owned the whole slow
    tier — exactly what N independent Caption loops converge to); their
    summed traffic blows the budget and the controller collapse drags
    aggregate throughput below even membind-fast.  The arbiter gates and
    clips growth against the shared budget, so the fleet lands under it
    and beats the greedy configuration."""
    rows = []
    fast, slow = topo.fast, topo.slow

    # Uncoordinated greedy: per-buffer static sweep assuming sole ownership.
    greedy = {}
    for n, th in MB_BUFFERS.items():
        grid = np.linspace(0.0, 0.6, 121)
        greedy[n] = float(grid[int(np.argmax(
            [throughput(fast, slow, float(f), th) for f in grid]))])
    xs_greedy, off_greedy = _shared_throughput(topo, greedy)
    agg_greedy = sum(xs_greedy.values())
    membind = sum(throughput(fast, slow, 0.0, th)
                  for th in MB_BUFFERS.values())

    # Coordinated: one arbiter, three registered controllers, telemetry
    # source attribution billing each buffer's slow traffic.
    tel = Telemetry()
    arb = CaptionArbiter(topo, ArbiterConfig(slow_bw_budget=MB_BUDGET,
                                             starvation_floor=0.1))
    ccfg = CaptionConfig(probe_epochs=2, step=0.05, min_step=0.01,
                         hysteresis=0.01)
    ctls = {n: arb.register(n, CaptionController(topo, ccfg))
            for n in MB_BUFFERS}
    wins = {n: EpochWindow(tel) for n in MB_BUFFERS}
    for epoch in range(96):
        fracs = {n: c.fraction for n, c in ctls.items()}
        xs, _ = _shared_throughput(topo, fracs)
        for n in MB_BUFFERS:
            tel.record_move("engine", slow.name,
                            int(xs[n] * fracs[n] * BYTES_PER_INFER), 0.0,
                            source=n)
            arb.observe_window(n, wins[n], xs[n], slow_name=slow.name,
                               seconds=1.0)

    fracs = {n: c.fraction for n, c in ctls.items()}
    xs_arb, off_arb = _shared_throughput(topo, fracs)
    agg_arb = sum(xs_arb.values())
    for n in MB_BUFFERS:
        rows.append(f"fig11/multibuffer/{n},0,f={fracs[n]:.3f}"
                    f";tput={xs_arb[n]:.0f};grant={arb.grants()[n]:.3g}")
    rows.append(
        f"fig11/multibuffer/aggregate,0,arb={agg_arb:.0f}"
        f";greedy={agg_greedy:.0f};membind={membind:.0f}"
        f";slow_bw={off_arb:.3g};budget={MB_BUDGET:.3g}")
    # Acceptance: combined slow traffic within budget; aggregate throughput
    # at least the best uncoordinated (per-buffer greedy) configuration;
    # nobody starved below the floor share.
    assert off_arb <= MB_BUDGET * 1.05, (off_arb, MB_BUDGET)
    assert agg_arb >= agg_greedy, (agg_arb, agg_greedy)
    assert agg_arb >= membind, (agg_arb, membind)
    floor = arb.cfg.starvation_floor * MB_BUDGET
    assert all(g >= floor * 0.99 for g in arb.grants().values()), arb.grants()
    return rows


def run() -> list[str]:
    rows = []
    cfg = CaptionConfig(probe_epochs=2, step=0.05, min_step=0.01,
                        hysteresis=0.01)

    # --- Scenario A: bandwidth-bound fast tier (paper SNC, Fig. 9/11) ------
    topo = snc_topology()
    best_f, best_t = _static_sweep(topo)
    baseline = throughput(topo.fast, topo.slow, 0.0, THREADS)  # membind fast
    ctl, trace = _run_loop(topo, cfg)
    for epoch, f, t in trace[:: max(1, EPOCHS // 16)]:
        rows.append(f"fig11/snc/epoch{epoch:02d},0,f={f:.3f};inf_s={t:.0f}")
    final_t = throughput(topo.fast, topo.slow, ctl.fraction, THREADS)
    rows.append(
        f"fig11/snc/converged,0,f={ctl.fraction:.3f};best_static={best_f:.3f}"
        f";tput={final_t:.0f};static_best={best_t:.0f};membind={baseline:.0f}")
    # Acceptance: within 5 points of the best static split, and at least as
    # good as the static default (membind fast).
    assert abs(ctl.fraction - best_f) <= 0.05, (ctl.fraction, best_f)
    assert final_t >= baseline, (final_t, baseline)
    assert final_t >= 0.95 * best_t, (final_t, best_t)

    # --- Scenario B: fast tier has headroom (TPU v5e) -----------------------
    tpu = tpu_v5e_topology()
    tbest_f, _ = _static_sweep(tpu)
    tctl, ttrace = _run_loop(tpu, cfg)
    tfinal = throughput(tpu.fast, tpu.slow, tctl.fraction, THREADS)
    tbase = throughput(tpu.fast, tpu.slow, 0.0, THREADS)
    rows.append(f"fig11/tpu/converged,0,f={tctl.fraction:.3f}"
                f";best_static={tbest_f:.3f};tput={tfinal:.0f}")
    assert abs(tctl.fraction - tbest_f) <= 0.05, (tctl.fraction, tbest_f)
    assert tfinal >= 0.95 * tbase

    # --- Actuation audit: repartition moves ONLY the delta pages ------------
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(4096, 64)), jnp.float32)
    page_rows = 64
    it = InterleavedTensor.from_array(table, MemPolicy.membind("fast"),
                                      page_rows=page_rows)
    ref = np.asarray(it.to_array())
    page_bytes = page_rows * it.row_bytes
    tel = Telemetry()
    with BulkMover(topo, asynchronous=True, batch_size=16,
                   telemetry=tel) as mover:
        pol1 = MemPolicy.from_slow_fraction("fast", "slow", ctl.fraction)
        expect1 = int(pol1.page_is_slow(it.n_pages).sum())  # 0 -> f: delta =
        it = it.repartition(pol1, mover=mover, fast_tier=topo.fast.name,
                            slow_tier=topo.slow.name)
        moved1 = tel.route(topo.fast.name, topo.slow.name).bytes_moved
        assert moved1 == expect1 * page_bytes, (moved1, expect1 * page_bytes)
        # a small controller adjustment flips only the page-count delta
        f2 = ctl.fraction + 0.05
        cur_slow = int(np.asarray(it.page_tier).sum())
        delta12 = abs(round(f2 * it.n_pages) - cur_slow)
        descs_before = mover.descriptors_submitted
        it = it.repartition_fraction(f2, mover=mover,
                                     fast_tier=topo.fast.name,
                                     slow_tier=topo.slow.name)
        descs12 = mover.descriptors_submitted - descs_before
        moved2 = (tel.route(topo.fast.name, topo.slow.name).bytes_moved
                  + tel.route(topo.slow.name, topo.fast.name).bytes_moved
                  - moved1)
        assert moved2 == delta12 * page_bytes, (moved2, delta12 * page_bytes)
        assert delta12 < it.n_pages  # strictly less than a rebuild
        # run-coalesced movement: O(delta-runs) descriptors, not one per
        # page — the billed bytes above stayed exact regardless
        assert descs12 < delta12, (descs12, delta12)
    assert np.allclose(np.asarray(it.to_array()), ref)  # numerical no-op
    rows.append(f"fig11/repartition/audit,0,pages={it.n_pages}"
                f";delta1={expect1};delta2={delta12};descs2={descs12}"
                f";bytes_ok=1")

    # --- Retrace-free actuation: probe epochs never retrace the consumer ----
    ctl_w = CaptionController(
        snc_topology(), CaptionConfig(probe_epochs=1, step=0.05,
                                      min_step=0.01, hysteresis=0.01))
    n_pages = 256
    walk_it = InterleavedTensor.from_array(
        jnp.asarray(rng.normal(size=(n_pages * 16, 8)), jnp.float32),
        MemPolicy.membind("fast"), page_rows=16,
        headroom=ctl_w.headroom_pages(n_pages))
    traces = [0]

    def _step(t, i):
        traces[0] += 1
        return t.gather_rows(i)

    step_fn = jax.jit(_step)
    idx = jnp.asarray(rng.integers(0, n_pages * 16, size=32))
    epochs = 0
    for _ in range(16):
        jax.block_until_ready(step_fn(walk_it, idx))
        tput = throughput(topo.fast, topo.slow, ctl_w.fraction, THREADS)
        d = ctl_w.observe(EpochMetrics(throughput=tput))
        walk_it = walk_it.repartition_fraction(d.fraction,
                                               telemetry=Telemetry())
        ctl_w.actuated(walk_it.slow_fraction())
        epochs += 1
    assert epochs >= 10 and traces[0] == 1, (epochs, traces[0])
    rows.append(f"fig11/repartition/retrace_free,0,epochs={epochs}"
                f";jit_traces={traces[0]}")

    # --- N-device: weight-vector convergence on a 3-device pool -------------
    rows.extend(run_three_device())

    # --- Multi-buffer: one arbiter, one shared slow-tier budget -------------
    rows.extend(run_multibuffer(topo))
    return rows


# -- control plane: dueling probes, warm-start memo, joint moves -------------
#: injected relative telemetry noise (std) for the regret comparison.
NOISE_STD = 0.06
#: paired duels per candidate point in the noise-robust configuration.
DUEL_COUNT = 3
#: seeds averaged by the regret gate (smoke uses the first 3).
REGRET_SEEDS = (0, 1, 2, 3, 4)
REGRET_EPOCHS = 280


def _control_cfg(duels: int = 0) -> CaptionConfig:
    return CaptionConfig(probe_epochs=2, step=0.05, min_step=0.01,
                         hysteresis=0.01, duel_count=duels)


def _sweep_threads(topo: TierTopology, threads: int) -> tuple[float, float]:
    best_f, best_t = 0.0, throughput(topo.fast, topo.slow, 0.0, threads)
    for f in np.linspace(0.0, 0.6, 121):
        t = throughput(topo.fast, topo.slow, float(f), threads)
        if t > best_t:
            best_f, best_t = float(f), t
    return best_f, best_t


def _noisy_regret(topo: TierTopology, best_t: float, seed: int,
                  duels: int, epochs: int) -> tuple[float, float]:
    """Closed loop on the SNC hill with multiplicative telemetry noise;
    returns (final fraction, cumulative relative regret vs the best
    static split).  Regret is charged on the TRUE throughput at each
    operating point — the controller only ever sees the noisy signal."""
    rng = np.random.default_rng(seed)
    ctl = CaptionController(topo, _control_cfg(duels), initial_fraction=0.0)
    regret = 0.0
    for _ in range(epochs):
        t_true = throughput(topo.fast, topo.slow, ctl.fraction, THREADS)
        regret += (best_t - t_true) / best_t
        ctl.observe(EpochMetrics(
            throughput=t_true * (1.0 + rng.normal(0.0, NOISE_STD))))
    return ctl.fraction, regret


def _regret_section(topo: TierTopology, smoke: bool,
                    rows: list[str]) -> dict:
    """Dueling probes vs single-sample hill-climb under injected noise.

    The single-sample climb is bimodal under noise: one unlucky window
    at cold start rejects the first (real) gradient and parks the walk
    at f=0 for the whole run.  Paired duels average the noise down and
    retry before shrinking, so every seed converges near the optimum —
    the seed-averaged cumulative regret must be strictly lower."""
    seeds = REGRET_SEEDS[:3] if smoke else REGRET_SEEDS
    epochs = 200 if smoke else REGRET_EPOCHS
    best_f, best_t = _static_sweep(topo)
    single, duel = {}, {}
    for seed in seeds:
        sf, sr = _noisy_regret(topo, best_t, seed, 0, epochs)
        df, dr = _noisy_regret(topo, best_t, seed, DUEL_COUNT, epochs)
        single[seed] = {"final_f": sf, "regret": sr}
        duel[seed] = {"final_f": df, "regret": dr}
        rows.append(f"fig11/control/regret/seed{seed},0,"
                    f"single_f={sf:.3f};single_regret={sr:.1f}"
                    f";duel_f={df:.3f};duel_regret={dr:.1f}")
    s_mean = sum(v["regret"] for v in single.values()) / len(seeds)
    d_mean = sum(v["regret"] for v in duel.values()) / len(seeds)
    rows.append(f"fig11/control/regret/mean,0,single={s_mean:.1f}"
                f";duel={d_mean:.1f};noise={NOISE_STD};epochs={epochs}")
    # Acceptance: dueling cumulative regret strictly below the
    # single-sample baseline in the same run, and the dueling walk lands
    # near the true optimum on EVERY seed (no stuck-at-zero runs).
    assert d_mean < s_mean, (d_mean, s_mean)
    for seed, v in duel.items():
        assert abs(v["final_f"] - best_f) <= 0.05, (seed, v, best_f)
    return {"noise": NOISE_STD, "epochs": epochs, "best_f": best_f,
            "seeds": list(seeds), "duel_count": DUEL_COUNT,
            "single": single, "duel": duel,
            "single_mean_regret": s_mean, "duel_mean_regret": d_mean}


def _warmstart_section(topo: TierTopology, rows: list[str]) -> dict:
    """Cold walk records its converged weights under the workload
    fingerprint; a rerun of the same workload must warm-start from the
    memo — at the remembered optimum from the first decision, converged
    within one confirmation stint instead of re-walking the hill."""
    cfg = _control_cfg()
    memo = WarmStartMemo()
    cold = CaptionController(topo, cfg, initial_fraction=0.0)
    cold.attach_memo(memo)
    cold_epochs = None
    for epoch in range(4 * REGRET_EPOCHS):
        t = throughput(topo.fast, topo.slow, cold.fraction, THREADS)
        cold.observe(EpochMetrics(throughput=t))
        if cold.converged:
            cold_epochs = epoch + 1
            break
    assert cold.converged and len(memo) == 1, (cold.phase, len(memo))

    # The rerun loads the memo through a JSON roundtrip (what --memo-path
    # persists to disk between driver invocations).
    memo2 = WarmStartMemo.from_json(memo.to_json())
    warm = CaptionController(topo, cfg, initial_fraction=0.0)
    warm.attach_memo(memo2)
    reach_epoch = None
    warm_epochs = None
    for epoch in range(cold_epochs):
        t = throughput(topo.fast, topo.slow, warm.fraction, THREADS)
        warm.observe(EpochMetrics(throughput=t))
        gap = max(abs(a - b) for a, b in zip(warm.weights, cold.weights))
        if reach_epoch is None and gap <= 0.02:
            reach_epoch = epoch + 1
        if warm.converged:
            warm_epochs = epoch + 1
            break
    gap_pp = 100 * max(abs(a - b)
                       for a, b in zip(warm.weights, cold.weights))
    rows.append(f"fig11/control/warmstart,0,cold_epochs={cold_epochs}"
                f";warm_epochs={warm_epochs};reach_epoch={reach_epoch}"
                f";gap_pp={gap_pp:.2f};hits={memo2.hits}")
    # Acceptance: the warm-started rerun is within 2pp per device of the
    # cold walk's converged weights within 2 probe epochs (it lands
    # there on the memo-hit decision), holds converged after one
    # confirmation stint, and beats the cold walk outright.
    assert warm.converged, warm.phase
    assert memo2.hits == 1, (memo2.hits, memo2.misses)
    assert reach_epoch is not None and reach_epoch <= 2, reach_epoch
    assert gap_pp <= 2.0, gap_pp
    assert warm_epochs <= 2 * cfg.probe_epochs, (warm_epochs, cold_epochs)
    assert warm_epochs < cold_epochs, (warm_epochs, cold_epochs)
    return {"cold_epochs": cold_epochs, "warm_epochs": warm_epochs,
            "reach_epoch": reach_epoch, "gap_pp": gap_pp,
            "memo_hits": memo2.hits}


def _drift_section(topo: TierTopology, smoke: bool,
                   rows: list[str]) -> dict:
    """Drifting workload: after the dueling walk converges on workload A
    (32 threads), the app shifts to a write-heavier, lower-parallelism
    phase (B).  The slow-route bandwidth at the held point shifts with
    it, the drift detector re-opens the walk, and the controller
    re-converges near B's own static optimum."""
    threads_b = 16  # B's static optimum is ~0.09: nonzero AND != A's
    demand_scale_b = 3.0  # B pushes 3x the slow-tier bytes per inference
    best_f_a, _ = _sweep_threads(topo, THREADS)
    best_f_b, _ = _sweep_threads(topo, threads_b)
    ctl = CaptionController(topo, _control_cfg(DUEL_COUNT),
                            initial_fraction=0.0)
    reopen_epoch = None
    switch_epoch = None
    epochs = 360 if smoke else 600
    for epoch in range(epochs):
        if switch_epoch is None and ctl.converged:
            switch_epoch = epoch + 8  # hold a few epochs, then drift
        on_b = switch_epoch is not None and epoch >= switch_epoch
        threads = threads_b if on_b else THREADS
        scale = demand_scale_b if on_b else 1.0
        t = throughput(topo.fast, topo.slow, ctl.fraction, threads)
        d = ctl.observe(EpochMetrics(
            throughput=t,
            slow_bw=scale * t * ctl.fraction * BYTES_PER_INFER))
        if on_b and reopen_epoch is None and "drift" in d.reason:
            reopen_epoch = epoch
    rows.append(f"fig11/control/drift,0,switch={switch_epoch}"
                f";reopen={reopen_epoch};final_f={ctl.fraction:.3f}"
                f";best_a={best_f_a:.3f};best_b={best_f_b:.3f}")
    # Acceptance: converged on A near A's optimum, re-opened after the
    # shift, re-converged near B's optimum (which must actually differ).
    assert switch_epoch is not None  # converged on A at all
    assert abs(best_f_a - best_f_b) > 0.02, (best_f_a, best_f_b)
    assert reopen_epoch is not None and reopen_epoch >= switch_epoch
    assert ctl.converged, ctl.phase
    assert abs(ctl.fraction - best_f_b) <= 0.05, (ctl.fraction, best_f_b)
    return {"switch_epoch": switch_epoch, "reopen_epoch": reopen_epoch,
            "final_f": ctl.fraction, "best_f_a": best_f_a,
            "best_f_b": best_f_b}


def _joint_section(topo: TierTopology, smoke: bool,
                   rows: list[str]) -> dict:
    """Arbiter joint moves: growth is frozen locally and granted through
    utility-per-cost-ordered propose/commit rounds against the shared
    budget — coordination by allocation instead of clip-the-greedy."""
    fast, slow = topo.fast, topo.slow
    greedy = {}
    for n, th in MB_BUFFERS.items():
        grid = np.linspace(0.0, 0.6, 121)
        greedy[n] = float(grid[int(np.argmax(
            [throughput(fast, slow, float(f), th) for f in grid]))])
    xs_greedy, _ = _shared_throughput(topo, greedy)
    agg_greedy = sum(xs_greedy.values())
    membind = sum(throughput(fast, slow, 0.0, th)
                  for th in MB_BUFFERS.values())

    tel = Telemetry()
    arb = CaptionArbiter(topo, ArbiterConfig(slow_bw_budget=MB_BUDGET,
                                             starvation_floor=0.1,
                                             joint_moves=True))
    ctls = {n: arb.register(n, CaptionController(topo, _control_cfg()))
            for n in MB_BUFFERS}
    wins = {n: EpochWindow(tel) for n in MB_BUFFERS}
    rounds = 0
    granted_total = 0.0
    epochs = 64 if smoke else 96
    for epoch in range(epochs):
        fracs = {n: c.fraction for n, c in ctls.items()}
        xs, _ = _shared_throughput(topo, fracs)
        for n in MB_BUFFERS:
            tel.record_move("engine", slow.name,
                            int(xs[n] * fracs[n] * BYTES_PER_INFER), 0.0,
                            source=n)
            arb.observe_window(n, wins[n], xs[n], slow_name=slow.name,
                               seconds=1.0)
        grants = arb.joint_move()
        if grants:
            rounds += 1
            granted_total += sum(grants.values())

    fracs = {n: c.fraction for n, c in ctls.items()}
    xs_arb, off_arb = _shared_throughput(topo, fracs)
    agg_arb = sum(xs_arb.values())
    for n in MB_BUFFERS:
        rows.append(f"fig11/control/joint/{n},0,f={fracs[n]:.3f}"
                    f";tput={xs_arb[n]:.0f}")
    rows.append(f"fig11/control/joint/aggregate,0,arb={agg_arb:.0f}"
                f";greedy={agg_greedy:.0f};membind={membind:.0f}"
                f";slow_bw={off_arb:.3g};budget={MB_BUDGET:.3g}"
                f";rounds={rounds};granted={granted_total:.3f}")
    # Acceptance: growth happened ONLY through committed joint grants,
    # the fleet lands under budget, and coordinated allocation does at
    # least as well as uncoordinated greed (and membind-fast).
    assert rounds > 0 and granted_total > 0
    assert abs(sum(fracs.values()) - granted_total) <= granted_total + 1e-9
    assert off_arb <= MB_BUDGET * 1.05, (off_arb, MB_BUDGET)
    assert agg_arb >= membind, (agg_arb, membind)
    assert agg_arb >= agg_greedy, (agg_arb, agg_greedy)
    return {"fractions": fracs, "aggregate": agg_arb, "greedy": agg_greedy,
            "membind": membind, "slow_bw": off_arb, "budget": MB_BUDGET,
            "rounds": rounds, "granted_total": granted_total}


def run_control(smoke: bool = False) -> tuple[list[str], dict]:
    """Convergence-time + cumulative-regret gate for the control plane
    (noisy and drifting workloads), emitted as BENCH_control.json."""
    rows: list[str] = []
    topo = snc_topology()
    bench = {
        "bench": "control",
        "smoke": smoke,
        "regret": _regret_section(topo, smoke, rows),
        "warmstart": _warmstart_section(topo, rows),
        "drift": _drift_section(topo, smoke, rows),
        "joint": _joint_section(topo, smoke, rows),
    }
    return rows, bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--control", action="store_true",
                    help="run the control-plane gate (dueling regret, "
                         "warm-start, drift re-probe, joint moves) instead "
                         "of the legacy Fig. 11 sections")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized control-plane gate (implies --control)")
    ap.add_argument("--out", default=None,
                    help="write the control-plane results as JSON "
                         "(BENCH_control.json)")
    args = ap.parse_args(argv)
    if args.control or args.smoke:
        rows, bench = run_control(smoke=args.smoke)
        print("\n".join(rows))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(bench, f, indent=2, sort_keys=True)
            print(f"wrote {args.out}")
        return
    print("\n".join(run()))


if __name__ == "__main__":
    main()
