"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod 16x16 mesh:
  compute term    = HLO_FLOPs_per_dev / 197 TF/s
  memory term     = HLO_bytes_per_dev / 819 GB/s
  collective term = ICI_wire/50 GB/s + DCN_wire/(12.5/8 GB/s per chip)
  tier term       = host<->HBM staged bytes (paging + amortized Caption
                    repartition migration) / 32 GB/s (PCIe) — the paper's
                    subject, reported alongside the required three
plus MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (inference), the
useful-compute ratio, the dominant term, and the roofline fraction
(model-flops time / dominant-term time).

HLO numbers come from the loop-corrected analyzer (launch/hlo_analysis);
offload-micro cells aggregate n_micro micro-programs + the paged update.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9  # per link
DCN_BW_PER_CHIP = 12.5e9 / 8
PCIE_BW = 32e9


def load_records(dryrun_dir: str = "experiments/dryrun", mesh="pod16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def terms(rec: dict) -> dict | None:
    if "error" in rec or "skipped" in rec or "hlo" not in rec:
        return None
    chips = rec["chips"]
    mult = rec.get("n_micro", 0) if rec.get("offload_micro_step") else 1
    mult = max(mult, 1)
    flops = rec["hlo"]["flops_per_device"] * mult
    hbm = rec["hlo"]["hbm_bytes_per_device"] * mult
    ici = rec["hlo"]["ici_bytes_per_device"] * mult
    dcn = rec["hlo"]["dcn_bytes_per_device"] * mult
    tier_bytes = rec.get("offload_traffic_bytes_per_step_per_chip", 0.0)
    # Caption repartition traffic (amortized page migration, recorded by
    # the dry run): migration shares the same PCIe path as paging.
    tier_bytes += rec.get("migration_bytes_per_step_per_chip", 0.0)
    if rec.get("offload_micro_step"):
        # bf16 grads stream host-ward every micro step
        tier_bytes += rec["params"] * 2 * mult / chips
    t = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm / HBM_BW,
        "collective_s": ici / ICI_BW + dcn / DCN_BW_PER_CHIP,
        "tier_s": tier_bytes / PCIE_BW,
    }
    model_flops_dev = rec["model_flops_total"] / chips
    t["model_compute_s"] = model_flops_dev / PEAK_FLOPS
    t["useful_ratio"] = model_flops_dev / flops if flops else 0.0
    dom = max(("compute_s", "memory_s", "collective_s", "tier_s"),
              key=lambda k: t[k])
    t["dominant"] = dom.replace("_s", "")
    bound = max(t["compute_s"], t["memory_s"], t["collective_s"], t["tier_s"])
    t["roofline_fraction"] = t["model_compute_s"] / bound if bound else 0.0
    return t


_LEVERS = {
    "compute": ("cut remat recompute / pad-free attention heads "
                "(raise useful-flops ratio toward 1)"),
    "memory": ("fuse/flash the attention + larger operand reuse per HBM "
               "pass (raise arithmetic intensity)"),
    "collective": ("reshard to cut all-gathers (overlap grad sync with "
                   "backward; int8-compress the DCN hop)"),
    "tier": ("raise BulkMover batch size / overlap paging with compute; "
             "drop master-weight precision to bf16"),
}


def table(recs) -> str:
    rows = []
    header = ("| cell | dom | compute s | memory s | coll s | tier s | "
              "useful | roofline frac |")
    sep = "|" + "---|" * 8
    for rec in recs:
        name = f"{rec['arch']} x {rec['shape']}"
        if "skipped" in rec:
            rows.append(f"| {name} | SKIP ({rec['skipped'][:40]}...) "
                        f"| | | | | | |")
            continue
        t = terms(rec)
        if t is None:
            rows.append(f"| {name} | ERROR | | | | | | |")
            continue
        rows.append(
            f"| {name} | **{t['dominant']}** | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['tier_s']:.4f} | {t['useful_ratio']:.2f} | "
            f"{t['roofline_fraction']:.2%} |")
    return "\n".join([header, sep] + rows)


def csv_rows(recs) -> list[str]:
    out = []
    for rec in recs:
        if "skipped" in rec or "error" in rec:
            continue
        t = terms(rec)
        step_s = max(t["compute_s"], t["memory_s"], t["collective_s"],
                     t["tier_s"])
        out.append(
            f"roofline/{rec['arch']}/{rec['shape']},{step_s*1e6:.1f},"
            f"dom={t['dominant']};frac={t['roofline_fraction']:.3f};"
            f"useful={t['useful_ratio']:.2f}")
    return out


def main():
    recs = load_records()
    print(table(recs))
    print()
    for row in csv_rows(recs):
        print(row)
    # machine-readable dump for EXPERIMENTS.md tooling
    out = []
    for rec in recs:
        e = {"arch": rec.get("arch"), "shape": rec.get("shape")}
        if "skipped" in rec:
            e["skipped"] = rec["skipped"]
        else:
            e.update(terms(rec) or {"error": rec.get("error", "?")})
        out.append(e)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.json", "w") as f:
        json.dump(out, f, indent=1, default=float)


if __name__ == "__main__":
    main()
