"""Hot-path perf harness: tier actuation + routed access (ISSUE 5).

The paper's Caption loop (§7) only pays off if actuation is off the
critical path — CXL-DMSim and emucxl both stress that the emulation/
accounting layer must not stall the workload it studies.  This harness
measures the three hot paths the actuation/access stack runs every
probe epoch, **against the pre-change reference implementations in the
same run** (the per-page Python planner and the masked N-pass routed
access, preserved below as ``_legacy_*``), and emits
``BENCH_hotpaths.json`` so the perf trajectory is tracked run over run:

* ``repartition`` — vectorized O(Δ) planner + run-coalesced descriptors
  vs the per-page Python loop (asserts the >= 3x speedup acceptance
  bar, and that a 1-point weight shift on a 4096-page tensor issues
  O(delta-runs) descriptors, not one per page);
* ``gather`` / ``scatter`` — single-pass sort-bucketed routed access vs
  the masked one-full-pass-per-device formulation (bit-exact);
* ``traces`` — a jitted step function across a >= 10-epoch Caption walk
  on a capacity-padded (``headroom``) tensor traces exactly once;
* ``actuation`` (ISSUE 7) — a write-heavy Caption-style loop
  (repartition + row scatter per epoch) through the donated in-place
  path vs the PR 5 copy-on-write baseline: the donated stable path must
  perform ZERO full receiving-shard copies (asserted in the smoke lane
  too) and win >= 2x at full size.  Smoke-lane actuation TIMING is
  informational only (``"gated": false`` in the JSON): the shrunken
  tensor is noise-bound, so only the full size gates the >= 2x claim —
  the zero-copy invariant is still asserted in both lanes.

``--smoke`` shrinks the tensor for the CI tier-1 lane; the nightly
workflow runs the full size and uploads the JSON artifact next to the
fig10/fig11 results.  The resolved shard backend
(modeled / staged / memory_kind) is recorded in the JSON config.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.caption import CaptionConfig, CaptionController, EpochMetrics
from repro.core.interleave import InterleavedTensor, device_page_map
from repro.core.mover import BulkMover
from repro.core.policy import MemPolicy
from repro.core.telemetry import Telemetry
from repro.core.tiers import TierTopology, paper_three_device_topology

# full-size problem: 4096 pages x 64 rows x 16 features fp32 (64 MiB)
N_PAGES = 4096
PAGE_ROWS = 64
FEATURE = 16
GATHER_BATCH = 4096
REPEATS = 5
WALK_EPOCHS = 12


# ---------------------------------------------------------------------------
# Pre-change reference implementations (the PR 4 hot paths, verbatim
# structure): per-page Python repartition planner + masked N-pass access.
# They live HERE, not in the library, so the speedup is measured against
# the real baseline in the same run on the same machine.
# ---------------------------------------------------------------------------
def _legacy_minimal_delta_weights(current, weights, n_devices):
    from repro.core.interleave import _round_targets
    cur = np.asarray(current, np.int8)
    n = len(cur)
    targets = _round_targets(tuple(weights), n)
    targets += [0] * (n_devices - 1 - len(targets))
    counts = np.bincount(cur, minlength=n_devices)
    target_all = [n - sum(targets)] + list(targets)
    if all(int(counts[d]) == target_all[d] for d in range(n_devices)):
        return None
    out = cur.copy()
    pool: list[int] = []
    for d in range(n_devices):
        surplus = int(counts[d]) - target_all[d]
        if surplus <= 0:
            continue
        cands = np.nonzero(cur == d)[0]
        pick = cands[(np.arange(surplus) * len(cands)) // surplus]
        pool.extend(int(p) for p in pick)
    pool.sort()
    deficits = [(d, target_all[d] - int(counts[d]))
                for d in range(n_devices) if target_all[d] > int(counts[d])]
    k = nxt = 0
    while nxt < len(pool):
        d, need = deficits[k % len(deficits)]
        if need > 0:
            out[pool[nxt]] = d
            nxt += 1
            deficits[k % len(deficits)] = (d, need - 1)
        else:
            deficits.pop(k % len(deficits))
            continue
        k += 1
    return out


def _legacy_repartition_fraction(it: InterleavedTensor, fraction: float,
                                 telemetry: Telemetry, mover=None,
                                 names=None) -> InterleavedTensor:
    """The pre-change actuation path: per-page Python loops end to end
    (plan one page at a time, ship/bill ONE descriptor per page, rebuild
    shards by stacking one page at a time)."""
    import dataclasses
    new_dev = _legacy_minimal_delta_weights(
        np.asarray(it.page_device), (float(fraction),), len(it.parts))
    if new_dev is None:
        return it
    n = it.n_pages
    names = tuple(names) if names else it.device_names
    old_dev = np.asarray(it.page_device)
    old_local = np.asarray(it.page_local)
    delta = np.nonzero(new_dev != old_dev)[0]
    feature = it.parts[0].shape[1:]
    paged = [np.asarray(p).reshape((-1, it.page_rows) + feature)
             for p in it.parts]

    def old_page(p):
        return paged[old_dev[p]][old_local[p]]

    page_bytes = it.page_rows * it.row_bytes
    moved = {}
    if mover is not None and delta.size:
        from repro.core.mover import Descriptor
        descs = [
            Descriptor(
                src_tier=names[int(old_dev[p])],
                dst_tier=names[int(new_dev[p])],
                payload=jnp.asarray(old_page(p)),
                on_done=lambda r, p=int(p): moved.__setitem__(p, r),
            )
            for p in delta
        ]
        mover.submit(descs)
        if mover.asynchronous:
            mover.wait_all()
    else:
        for p in delta:
            telemetry.record_move(names[int(old_dev[p])],
                                  names[int(new_dev[p])],
                                  page_bytes, 0.0)
            moved[int(p)] = old_page(p)
    new_dev2, new_local, _ = device_page_map(new_dev, len(it.parts))
    groups: list[list[np.ndarray]] = [[] for _ in range(len(it.parts))]
    for p in range(n):
        groups[int(new_dev2[p])].append(
            np.asarray(moved[p]) if p in moved else old_page(p))

    def stack(pages):
        if not pages:
            return jnp.zeros((0,) + feature, it.parts[0].dtype)
        return jnp.asarray(np.stack(pages).reshape((-1,) + feature),
                           it.parts[0].dtype)

    return dataclasses.replace(
        it,
        parts=tuple(stack(g) for g in groups),
        page_device=jnp.asarray(new_dev2, jnp.int8),
        page_local=jnp.asarray(new_local, jnp.int32),
    )


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------
def _make(n_pages: int, headroom: int = 0) -> tuple[InterleavedTensor, np.ndarray]:
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n_pages * PAGE_ROWS, FEATURE)).astype(np.float32)
    it = InterleavedTensor.from_array(
        jnp.asarray(x), MemPolicy.from_slow_fraction("fast", "slow", 0.3),
        page_rows=PAGE_ROWS, headroom=headroom)
    return it, x


def bench_repartition(n_pages: int, repeats: int) -> dict:
    """Full actuation path — plan, ship through the BulkMover, rebuild —
    new (capacity-padded shards, vectorized planner, run-coalesced slab
    descriptors) vs pre-change (per-page Python planning, one descriptor
    per page, per-page stacking rebuild), same weight shifts, same
    machine, same run."""
    topo = paper_three_device_topology()
    fast, slow = topo.fast.name, topo.slows[0].name
    it, x = _make(n_pages)
    # headroom sized for the walk's excursion (what the Caption engine
    # does via CaptionController.headroom_pages, scaled to this sweep)
    it_padded, _ = _make(n_pages, headroom=max(16, n_pages // 16))
    shifts = [0.35, 0.3] * repeats  # alternate so every call moves pages

    with BulkMover(topo, asynchronous=True, batch_size=16,
                   telemetry=Telemetry()) as mover:
        t0 = time.perf_counter()
        legacy = it
        for f in shifts:
            legacy = _legacy_repartition_fraction(
                legacy, f, Telemetry(), mover=mover, names=(fast, slow))
        jax.block_until_ready(legacy.parts)
        t_legacy = time.perf_counter() - t0
        legacy_descs = mover.descriptors_submitted

    with BulkMover(topo, asynchronous=True, batch_size=16,
                   telemetry=Telemetry()) as mover:
        t0 = time.perf_counter()
        new = it_padded
        for f in shifts:
            new = new.repartition_fraction(f, mover=mover, fast_tier=fast,
                                           slow_tier=slow)
        jax.block_until_ready(new.parts)
        t_new = time.perf_counter() - t0
        new_descs = mover.descriptors_submitted

    assert np.allclose(np.asarray(new.to_array()), x)
    assert np.allclose(np.asarray(legacy.to_array()), x)
    speedup = t_legacy / max(t_new, 1e-9)
    delta_pages = abs(round(0.35 * n_pages) - round(0.3 * n_pages))
    return {
        "n_pages": n_pages,
        "repartitions": len(shifts),
        "legacy_s": t_legacy,
        "new_s": t_new,
        "speedup": speedup,
        "legacy_pages_per_s": len(shifts) * n_pages / max(t_legacy, 1e-9),
        "new_pages_per_s": len(shifts) * n_pages / max(t_new, 1e-9),
        "legacy_descriptors": legacy_descs,
        "new_descriptors": new_descs,
        "delta_pages_per_shift": delta_pages,
    }


def bench_descriptors(n_pages: int) -> dict:
    """1-point weight shift: O(delta-runs) descriptors, exact bytes."""
    topo = paper_three_device_topology()
    it, _ = _make(n_pages)
    tel = Telemetry()
    page_bytes = PAGE_ROWS * it.row_bytes
    cur_slow = int(np.asarray(it.page_tier).sum())
    delta = abs(round(0.31 * n_pages) - cur_slow)
    with BulkMover(topo, asynchronous=True, batch_size=16,
                   telemetry=tel) as mover:
        it = it.repartition_fraction(0.31, mover=mover,
                                     fast_tier=topo.fast.name,
                                     slow_tier=topo.slows[0].name)
        descs = mover.descriptors_submitted
        moved_bytes = mover.bytes_submitted
    assert moved_bytes == delta * page_bytes, (moved_bytes, delta * page_bytes)
    assert descs < delta, (descs, delta)  # coalesced: not one per page
    return {
        "delta_pages": delta,
        "descriptors": descs,
        "billed_bytes": moved_bytes,
        "page_bytes": page_bytes,
    }


def bench_gather_scatter(n_pages: int, repeats: int) -> dict:
    """Routed access, with the ISSUE 8 crossover fix audited: the auto
    path (``gather_rows``) picks masked vs bucketed per call, so the
    measured auto time is the CHOSEN path's own sample and the reported
    ``gather_speedup`` (masked / auto) can only dip below 1.0 if the
    crossover picked the slower path — the regression this section used
    to show (0.73x: bucketed forced unconditionally at batch 4096)."""
    it, x = _make(n_pages)
    rng = np.random.default_rng(1)
    idx_np = rng.integers(0, x.shape[0], size=GATHER_BATCH)
    idx = jnp.asarray(idx_np)
    vals = jnp.asarray(rng.normal(size=(GATHER_BATCH, FEATURE)), jnp.float32)

    # correctness first: the two formulations are value-identical
    ref = np.asarray(it._gather_rows_masked(idx))
    assert np.array_equal(ref, np.asarray(it._gather_rows_bucketed(idx_np)))
    assert np.array_equal(ref, np.asarray(it.gather_rows(idx)))

    def timed(fn):
        fn()  # warm
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return min(ts)  # min-of-repeats: stable under scheduler noise

    t_masked = timed(lambda: it._gather_rows_masked(idx))
    t_bucket = timed(lambda: it._gather_rows_bucketed(idx_np))
    path = it.choose_gather_path(GATHER_BATCH)
    t_auto = t_bucket if path == "bucketed" else t_masked
    s_masked = timed(lambda: it._scatter_masked(idx, vals, "set").parts)
    s_bucket = timed(lambda: it._scatter_bucketed(idx_np, vals, "set").parts)

    # The regime the bucketed single-pass exists for: many shards (masked
    # pays one full pass per device) at mid batch.  The crossover must
    # keep routing that case to the bucketed path and keep its win.
    pol3 = MemPolicy.from_tier_fractions(
        "fast", ["cxl-a", "cxl-b", "cxl-c"], [0.15, 0.15, 0.15])
    it3 = InterleavedTensor.from_array(jnp.asarray(x), pol3,
                                       page_rows=PAGE_ROWS)
    mid = min(512, x.shape[0])
    idx3_np = rng.integers(0, x.shape[0], size=mid)
    idx3 = jnp.asarray(idx3_np)
    assert np.array_equal(np.asarray(it3._gather_rows_masked(idx3)),
                          np.asarray(it3.gather_rows(idx3)))
    t3_masked = timed(lambda: it3._gather_rows_masked(idx3))
    t3_bucket = timed(lambda: it3._gather_rows_bucketed(idx3_np))
    path3 = it3.choose_gather_path(mid)
    t3_auto = t3_bucket if path3 == "bucketed" else t3_masked
    return {
        "batch": GATHER_BATCH,
        "gather_masked_rows_per_s": GATHER_BATCH / max(t_masked, 1e-9),
        "gather_bucketed_rows_per_s": GATHER_BATCH / max(t_bucket, 1e-9),
        "gather_auto_rows_per_s": GATHER_BATCH / max(t_auto, 1e-9),
        "gather_path": path,
        "gather_speedup": t_masked / max(t_auto, 1e-9),
        "gather_multidev_batch": mid,
        "gather_multidev_path": path3,
        "gather_multidev_speedup": t3_masked / max(t3_auto, 1e-9),
        "scatter_masked_rows_per_s": GATHER_BATCH / max(s_masked, 1e-9),
        "scatter_bucketed_rows_per_s": GATHER_BATCH / max(s_bucket, 1e-9),
        "scatter_speedup": s_masked / max(s_bucket, 1e-9),
    }


def bench_actuation(n_pages: int, repeats: int) -> dict:
    """Donated in-place shard actuation (ISSUE 7): a write-heavy loop —
    one repartition plus one row-scatter per epoch, the Caption probe
    pattern — through ``donate=True`` vs the PR 5 copy-on-write
    baseline, same shapes, same machine, same run.  Both paths are
    bit-exact; the donated stable path must leave the full-shard copy
    counter at ZERO (the CoW baseline pays one per receiving shard per
    epoch)."""
    from repro.core.donation import FULL_SHARD_COPIES

    headroom = max(16, n_pages // 16)
    shifts = [0.35, 0.3] * (repeats * 2)
    writes_per_epoch = 4
    rows_per_write = 256  # small frequent writes: the probe-epoch pattern
    rng = np.random.default_rng(3)
    # distinct rows within each write (set semantics); same batch size
    # across writes so the donated path stays within one jit bucket
    idxs = [np.unique(rng.integers(0, n_pages * PAGE_ROWS,
                                   size=rows_per_write))[:rows_per_write - 8]
            for _ in range(writes_per_epoch)]
    vals = [jnp.asarray(rng.normal(size=(ix.size, FEATURE)), jnp.float32)
            for ix in idxs]

    def loop(donate: bool):
        it, _ = _make(n_pages, headroom=headroom)
        # steady-state timing: warm the jit caches (donated scatters
        # compile once per bucket) and the CoW mirrors before the clock
        for f in (0.35, 0.3):
            it = it.repartition_fraction(f, telemetry=Telemetry(),
                                         donate=donate)
            it = it.update_rows(idxs[0], vals[0], donate=donate)
        jax.block_until_ready(it.parts)
        FULL_SHARD_COPIES.reset()
        t0 = time.perf_counter()
        for f in shifts:
            it = it.repartition_fraction(f, telemetry=Telemetry(),
                                         donate=donate)
            for ix, v in zip(idxs, vals):
                it = it.update_rows(ix, v, donate=donate)
        jax.block_until_ready(it.parts)
        return time.perf_counter() - t0, FULL_SHARD_COPIES.reset(), it

    t_cow, copies_cow, it_cow = loop(False)
    t_don, copies_don, it_don = loop(True)
    # acceptance: the donated stable path performs zero full
    # receiving-shard copies (smoke lane asserts this too)
    assert copies_don == 0, copies_don
    assert copies_cow > 0, copies_cow
    assert np.array_equal(np.asarray(it_cow.to_array()),
                          np.asarray(it_don.to_array()))
    epochs = len(shifts)
    return {
        "epochs": epochs,
        "cow_s": t_cow,
        "donated_s": t_don,
        "speedup": t_cow / max(t_don, 1e-9),
        "cow_full_shard_copies": copies_cow,
        "donated_full_shard_copies": copies_don,
        "cow_epochs_per_s": epochs / max(t_cow, 1e-9),
        "donated_epochs_per_s": epochs / max(t_don, 1e-9),
        "writes_per_epoch": writes_per_epoch,
        "scatter_rows_per_write": int(idxs[0].size),
    }


def bench_trace_stability(n_pages: int) -> dict:
    """A jitted step across a Caption walk: exactly one trace."""
    topo = TierTopology(fast=paper_three_device_topology().fast,
                        slow=paper_three_device_topology().slows[0])
    ctl = CaptionController(topo, CaptionConfig(probe_epochs=1, step=0.05),
                            initial_fraction=0.2)
    it, x = _make(n_pages, headroom=ctl.headroom_pages(n_pages))
    it = it.repartition_fraction(0.2, telemetry=Telemetry())
    traces = [0]

    def step(t, i):
        traces[0] += 1
        return t.bag_reduce(i.reshape(8, -1))

    fn = jax.jit(step)
    rng = np.random.default_rng(2)
    idx = jnp.asarray(rng.integers(0, x.shape[0], size=64))
    epochs = 0
    for _ in range(WALK_EPOCHS):
        out = np.asarray(fn(it, idx))
        d = ctl.observe(EpochMetrics(throughput=1.0 + ctl.fraction))
        it = it.repartition_weights(d.weights, telemetry=Telemetry())
        ctl.actuated_weights(it.weights())
        epochs += 1
        assert np.isfinite(out).all()
    assert epochs >= 10 and traces[0] == 1, (epochs, traces[0])
    return {"walk_epochs": epochs, "jit_traces": traces[0]}


def run(smoke: bool = False) -> tuple[list[str], dict]:
    from repro.core.interleave import resolve_backend

    n_pages = 512 if smoke else N_PAGES
    repeats = 2 if smoke else REPEATS
    out = {
        "config": {"n_pages": n_pages, "page_rows": PAGE_ROWS,
                   "feature": FEATURE, "smoke": smoke,
                   "backend": resolve_backend("auto")},
        "repartition": bench_repartition(n_pages, repeats),
        "descriptors": bench_descriptors(n_pages),
        "gather_scatter": bench_gather_scatter(n_pages, repeats),
        "actuation": bench_actuation(n_pages, repeats),
        "trace_stability": bench_trace_stability(n_pages),
    }
    rep = out["repartition"]
    # Acceptance bar: >= 3x over the pre-change baseline, same run.
    assert rep["speedup"] >= 3.0, rep
    gs = out["gather_scatter"]
    # ISSUE 8: the crossover-chosen gather path never loses to masked
    # (and keeps the bucketed win in the many-shard regime it serves).
    assert gs["gather_speedup"] >= 1.0, gs
    assert gs["gather_multidev_speedup"] >= 1.0, gs
    act = out["actuation"]
    # Smoke timing is informational: the perf-trajectory consumer must
    # not regress-gate on an ungated sample (zero-copy asserts always).
    act["gated"] = not smoke
    if not smoke:
        # ISSUE 7 acceptance: donated >= 2x over the CoW baseline on the
        # write-heavy loop at full size (smoke sizes are noise-bound; the
        # zero-copy invariant is asserted inside bench_actuation always).
        assert act["speedup"] >= 2.0, act
    rows = [
        f"hotpaths/repartition,0,speedup=x{rep['speedup']:.1f}"
        f";new={rep['new_pages_per_s']:.3g}pages/s"
        f";legacy={rep['legacy_pages_per_s']:.3g}pages/s",
        f"hotpaths/descriptors,0,delta={out['descriptors']['delta_pages']}"
        f";descs={out['descriptors']['descriptors']}"
        f";bytes_exact=1",
        f"hotpaths/gather,0,speedup=x{gs['gather_speedup']:.2f}"
        f";path={gs['gather_path']}"
        f";rows_per_s={gs['gather_auto_rows_per_s']:.3g}"
        f";multidev=x{gs['gather_multidev_speedup']:.2f}"
        f"@{gs['gather_multidev_path']}",
        f"hotpaths/scatter,0,speedup=x{out['gather_scatter']['scatter_speedup']:.2f}"
        f";rows_per_s={out['gather_scatter']['scatter_bucketed_rows_per_s']:.3g}",
        f"hotpaths/actuation,0,speedup=x{act['speedup']:.2f}"
        f";donated_copies={act['donated_full_shard_copies']}"
        f";cow_copies={act['cow_full_shard_copies']}"
        f";epochs_per_s={act['donated_epochs_per_s']:.3g}",
        f"hotpaths/traces,0,epochs={out['trace_stability']['walk_epochs']}"
        f";jit_traces={out['trace_stability']['jit_traces']}",
    ]
    return rows, out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small problem size (CI tier-1 lane)")
    ap.add_argument("--out", default="BENCH_hotpaths.json")
    args = ap.parse_args()
    rows, payload = run(smoke=args.smoke)
    payload["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print("\n".join(rows))
    print(f"hotpaths/json,0,wrote={args.out}")


if __name__ == "__main__":
    main()
