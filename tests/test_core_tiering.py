"""Core tiered-memory library tests: policy, interleave, planner, mover,
classifier, ledger — including hypothesis property tests on the system's
invariants (interleave addressing is a bijection; bag-reduce equals the
untiered reduction; planner never overflows capacity)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis, with fallback

from repro.core import (
    AccessProfile, Boundedness, BufferClass, BufferReq, BulkMover,
    CapacityError, Descriptor, InterleavedTensor, MemPolicy, OpClass,
    TierLedger, classify, paper_topology, plan, tpu_v5e_topology,
)
from repro.core import perfmodel
from repro.core.mover import double_buffer


# -- MemPolicy ---------------------------------------------------------------
@given(st.integers(1, 63), st.integers(1, 200))
@settings(max_examples=50, deadline=None)
def test_weighted_interleave_ratio(m, n_pages):
    """N:M page assignment hits the requested ratio within one cycle."""
    pol = MemPolicy.weighted(("fast", "slow"), (64 - m, m))
    assign = pol.assign_pages(n_pages)
    assert assign.shape == (n_pages,)
    frac = (assign == 1).mean()
    assert abs(frac - m / 64) <= 64 / max(n_pages, 64)


@given(st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_from_slow_fraction_roundtrip(f):
    pol = MemPolicy.from_slow_fraction("fast", "slow", f)
    assert abs(pol.slow_fraction("fast") - f) < 1 / 32


def test_preferred_slow_fraction_capacity_aware():
    """PREFERRED overflow lands on the fallback tier: the reported slow
    fraction must account for how much actually fits the preferred tier."""
    topo = tpu_v5e_topology()  # hbm 16 GiB fast, host slow
    pol = MemPolicy.preferred("hbm", "host")
    # optimistic answer without capacity info: nothing beyond fast
    assert pol.slow_fraction("hbm") == 0.0
    led = TierLedger(topo)
    led.register("other", "hbm", 12 << 30)  # 4 GiB left on hbm
    page = 2 << 20
    n_pages = (8 << 30) // page  # an 8 GiB buffer: only half fits
    f = pol.slow_fraction("hbm", n_pages=n_pages, page_bytes=page, ledger=led)
    assert f == pytest.approx(0.5)
    # preferring the slow tier: the fitting half is slow, overflow is fast
    pol_rev = MemPolicy.preferred("host", "hbm")
    assert pol_rev.slow_fraction("hbm") == 1.0
    led2 = TierLedger(topo)
    led2.register("other", "host", led2.free("host") - (4 << 30))
    f_rev = pol_rev.slow_fraction("hbm", n_pages=n_pages, page_bytes=page,
                                  ledger=led2)
    assert f_rev == pytest.approx(0.5)
    # everything fits -> the optimistic answer is exact
    led3 = TierLedger(topo)
    assert pol.slow_fraction("hbm", n_pages=16, page_bytes=page,
                             ledger=led3) == 0.0


def test_paper_ratios():
    """The paper's 30:1 (3.23%) and 9:1 (10%) interleave ratios."""
    p = MemPolicy.weighted(("dram", "cxl"), (30, 1))
    assert abs(p.slow_fraction("dram") - 0.0323) < 1e-3
    p = MemPolicy.weighted(("dram", "cxl"), (9, 1))
    assert abs(p.slow_fraction("dram") - 0.10) < 1e-9


# -- InterleavedTensor --------------------------------------------------------
@given(st.integers(1, 7), st.integers(1, 7), st.integers(2, 16),
       st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_interleave_gather_bijection(wf, ws, page_rows, seed):
    """gather(update(x)) round-trips for any N:M policy and page size."""
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(page_rows, 6 * page_rows))
    x = jnp.asarray(rng.normal(size=(rows, 4)), jnp.float32)
    it = InterleavedTensor.from_array(
        x, MemPolicy.weighted(("fast", "slow"), (wf, ws)), page_rows)
    assert np.allclose(it.to_array(), x)
    idx = jnp.asarray(rng.integers(0, rows, size=8))
    assert np.allclose(it.gather_rows(idx), x[np.asarray(idx)])
    vals = jnp.ones((8, 4)) * 7.0
    it2 = it.update_rows(idx, vals)
    assert np.allclose(it2.gather_rows(idx), vals)


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_interleave_bag_reduce_exact(seed):
    """Tiered embedding-bag == untiered reduction (DLRM §5.2 invariant)."""
    rng = np.random.default_rng(seed)
    V, D, B, K = 64, 8, 4, 6
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, size=(B, K)))
    w = jnp.asarray(rng.uniform(size=(B, K)), jnp.float32)
    ref = jnp.einsum("bkd,bk->bd", table[idx], w)
    for weights in [(1, 1), (3, 1), (1, 3)]:
        it = InterleavedTensor.from_array(
            table, MemPolicy.weighted(("fast", "slow"), weights), page_rows=4)
        out = it.bag_reduce(idx, w)
        assert np.allclose(out, ref, atol=1e-5)


def test_interleave_with_kernel_reduce():
    """The Pallas embedding_reduce kernel slots into the tiered container."""
    from repro.kernels.embedding_reduce import ops
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 64, size=(4, 8)))
    w = jnp.asarray(rng.uniform(size=(4, 8)), jnp.float32)
    it = InterleavedTensor.from_array(
        table, MemPolicy.weighted(("fast", "slow"), (1, 1)), page_rows=8)
    out = it.bag_reduce(idx, w, reduce_fn=lambda t, i, ww:
                        ops.embedding_reduce(t, i, ww))
    ref = jnp.einsum("bkd,bk->bd", table[idx], w)
    assert np.allclose(out, ref, atol=1e-4)


def test_migrate_pages():
    x = jnp.arange(80.0).reshape(20, 4)
    it = InterleavedTensor.from_array(x, MemPolicy.membind("fast"), page_rows=4)
    assert it.slow_fraction() == 0.0
    it2 = it.migrate_pages(np.array([1, 3]), to_slow=True)
    assert 0.3 < it2.slow_fraction() < 0.5
    assert np.allclose(it2.to_array(), x)


# -- classifier ---------------------------------------------------------------
def test_classifier_redis_vs_dlrm():
    """§6.1: Redis-like access is latency-bound; DLRM-like is bandwidth-bound."""
    topo = paper_topology()
    redis = AccessProfile(
        bytes_read_per_step=4096, bytes_written_per_step=512,
        dependent_chain=32, parallelism=1, granularity=64,
        compute_seconds=2e-6, deadline_seconds=50e-6)
    dlrm = AccessProfile(
        bytes_read_per_step=2e9, bytes_written_per_step=0,
        dependent_chain=1, parallelism=1024, granularity=256,
        compute_seconds=0.01)
    assert classify(redis, topo.slow) == Boundedness.LATENCY_BOUND
    assert classify(dlrm, topo.slow) == Boundedness.BANDWIDTH_BOUND


# -- planner -------------------------------------------------------------------
def _req(name, klass, nbytes, rps, wps=0.0, chain=1, par=1024):
    return BufferReq(name, klass, int(nbytes), AccessProfile(
        rps, wps, chain, par, 2 << 20, 0.05))


def test_planner_pins_latency_bound():
    topo = tpu_v5e_topology()
    reqs = [
        _req("state", BufferClass.RECURRENT_STATE, 1 << 20, 1e6, 1e6, chain=64, par=1),
        _req("opt", BufferClass.OPT_STATE, 30 << 30, 30e9, 30e9),
    ]
    p = plan(reqs, topo, compute_seconds=0.05)
    assert p.slow_fraction("state") == 0.0
    # must spill the ~14 GiB overflow (30 GiB demand vs 16 GiB HBM)
    assert 0.40 < p.slow_fraction("opt") < 0.55


def test_planner_never_overflows_fast_tier():
    topo = tpu_v5e_topology()
    reqs = [_req(f"b{i}", BufferClass.OPT_STATE, 4 << 30, 4e9) for i in range(6)]
    p = plan(reqs, topo, compute_seconds=0.05, reserve_fast_bytes=2 << 30)
    p.ledger.check()
    used = p.ledger.used("hbm")
    assert used <= topo.fast.capacity_bytes


def test_planner_infeasible_raises():
    topo = tpu_v5e_topology()
    reqs = [_req("huge", BufferClass.OPT_STATE, 200 << 30, 1e9)]
    with pytest.raises(MemoryError):
        plan(reqs, topo, compute_seconds=0.05)


@given(st.integers(1, 6), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_planner_capacity_property(n, seed):
    """Whatever the workload, a feasible plan never overflows any tier."""
    rng = np.random.default_rng(seed)
    topo = tpu_v5e_topology()
    reqs = [
        _req(f"b{i}", BufferClass.OPT_STATE,
             int(rng.uniform(0.1, 8) * 2**30), rng.uniform(1e8, 1e10))
        for i in range(n)
    ]
    try:
        p = plan(reqs, topo, compute_seconds=0.05)
    except MemoryError:
        return
    p.ledger.check()


# -- ledger --------------------------------------------------------------------
def test_ledger_capacity_error():
    topo = tpu_v5e_topology()
    led = TierLedger(topo)
    led.register("a", "hbm", 10 << 30)
    with pytest.raises(CapacityError):
        led.register("b", "hbm", 10 << 30)
    led.release("a")
    led.register("b", "hbm", 10 << 30)


# -- mover ----------------------------------------------------------------------
def test_mover_sync_async_equivalence():
    topo = tpu_v5e_topology()
    payloads = [jnp.full((128,), i, jnp.float32) for i in range(12)]
    with BulkMover(topo, asynchronous=False, batch_size=4) as sync_m:
        outs = sync_m.submit([Descriptor("host", "hbm", p) for p in payloads])
        sync_res = [c.result for c in outs]
    with BulkMover(topo, asynchronous=True, batch_size=4) as async_m:
        async_m.submit([Descriptor("host", "hbm", p) for p in payloads])
        comps = async_m.wait_all()
    assert len(comps) == 12
    for p, r in zip(payloads, sync_res):
        assert np.allclose(p, r)


def test_mover_modeled_cost_prefers_batching():
    """Fig. 4b ordering: async >= sync; batched sync >= unbatched sync."""
    topo = paper_topology()
    small_pages = [Descriptor("cxl-agilex", "ddr5-l8", jnp.zeros((1024,)))
                   for _ in range(64)]
    t_sync1 = BulkMover(topo, asynchronous=False, batch_size=1).modeled_cost(small_pages)
    t_sync128 = BulkMover(topo, asynchronous=False, batch_size=128).modeled_cost(small_pages)
    t_async = BulkMover(topo, asynchronous=True, batch_size=128).modeled_cost(small_pages)
    assert t_sync128 <= t_sync1
    assert t_async <= t_sync128 * 1.01


def test_double_buffer_order():
    out = list(double_buffer(range(7), lambda i: i * i))
    assert out == [i * i for i in range(7)]


# -- perfmodel calibration (paper's headline numbers) ---------------------------
def test_perfmodel_reproduces_paper_facts():
    topo = paper_topology()
    l8, cxl = topo.fast, topo.slow
    # F1: latency ratios
    assert abs(cxl.load_latency_ns / l8.load_latency_ns - 2.2) < 0.05
    assert abs(cxl.chase_latency_ns / l8.chase_latency_ns - 3.7) < 0.05
    # F2: CXL load bw collapses past 12 threads
    bw8 = perfmodel.stream_bandwidth(cxl, OpClass.LOAD, 8)
    bw16 = perfmodel.stream_bandwidth(cxl, OpClass.LOAD, 16)
    assert bw16 < bw8
    assert abs(bw16 / 1e9 - 16.8) < 3.0  # paper: drops to ~16.8 GB/s
    # nt-store peaks at 2 threads near DDR4-2666 theoretical max
    nt2 = perfmodel.stream_bandwidth(cxl, OpClass.NT_STORE, 2)
    assert abs(nt2 / 1e9 - 22) < 2.0
    assert perfmodel.stream_bandwidth(cxl, OpClass.NT_STORE, 8) < nt2
    # F3: RFO makes temporal stores to CXL cost 2x the traffic
    assert perfmodel.store_traffic_bytes(cxl, 1000, OpClass.STORE) == 2000
    assert perfmodel.store_traffic_bytes(cxl, 1000, OpClass.NT_STORE) == 1000
    # F5: random block bw converges to sequential with block size
    r1k = perfmodel.random_block_bandwidth(cxl, OpClass.LOAD, 1024, 4)
    r64k = perfmodel.random_block_bandwidth(cxl, OpClass.LOAD, 65536, 4)
    seq = perfmodel.stream_bandwidth(cxl, OpClass.LOAD, 4)
    assert r1k < r64k <= seq
