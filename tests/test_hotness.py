"""Hotness-driven semantic tiering (ISSUE 10): ledger, semantic
assignment, SemanticTensor re-tier invariants, MoE dispatch counts,
Caption hot-set coordination, and serving-pool ledger registration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.caption import CaptionConfig, CaptionController, EpochMetrics
from repro.core.hotness import (HotnessLedger, HotSetCoordinator,
                                SemanticTensor, semantic_assignment)
from repro.core.mover import BulkMover
from repro.core.telemetry import Telemetry
from repro.core.tiers import paper_three_device_topology

TOPO = paper_three_device_topology()
NAMES = (TOPO.fast.name,) + tuple(t.name for t in TOPO.slows)


def _zipf(n, rng, alpha=1.1, scale=1e4):
    s = np.zeros(n)
    s[rng.permutation(n)] = 1.0 / (1.0 + np.arange(n)) ** alpha
    return s * scale


# -- HotnessLedger -----------------------------------------------------------
def test_ledger_ewma_decay():
    led = HotnessLedger(4, decay=0.5)
    led.record([8, 0, 0, 0])
    led.tick()
    led.record([0, 8, 0, 0])
    led.tick()
    # key 0 decayed one epoch (8 * 0.5), key 1 fresh
    assert led.scores()[0] == pytest.approx(4.0)
    assert led.scores()[1] == pytest.approx(8.0)
    assert list(led.rank()[:2]) == [1, 0]
    # a key that stops being touched decays toward cold
    for _ in range(20):
        led.tick()
    assert led.scores()[0] < 1e-4


def test_ledger_record_rows_and_keys():
    led = HotnessLedger(4, decay=0.5)
    led.record_rows([0, 1, 7, 8, 9], rows_per_key=4)  # keys 0,0,1,2,2
    s = led.scores()
    assert list(s) == [2, 1, 2, 0]
    led.record_keys([3, 3], weights=[5, 5])
    assert led.scores()[3] == 10
    with pytest.raises(ValueError):
        led.record_keys([4])
    with pytest.raises(ValueError):
        led.record([1, 2])


def test_ledger_topk_split_and_traffic():
    led = HotnessLedger(6, decay=0.5)
    led.record([0, 10, 5, 0, 20, 1])
    hot, cold = led.topk_split(2)
    assert list(hot) == [4, 1]
    assert set(cold) == {0, 2, 3, 5}
    assert led.traffic_share(hot) == pytest.approx(30 / 36)
    # clipping
    h_all, c_none = led.topk_split(99)
    assert len(h_all) == 6 and len(c_none) == 0


def test_ledger_mark_drift():
    led = HotnessLedger(8, decay=0.5)
    led.record([10, 9, 8, 7, 0, 0, 0, 0])
    led.mark(4)
    assert led.drift() == 0.0
    led.record([0, 0, 0, 0, 100, 100, 0, 0])
    # two of the four marked keys fell out of the top-4
    assert led.drift() == pytest.approx(0.5)


# -- semantic_assignment -----------------------------------------------------
def test_semantic_assignment_contiguous_keys_and_quotas():
    hot = np.array([5, 2])
    cold = np.array([0, 1, 3, 4, 6, 7])
    assign = semantic_assignment(8, 4, hot, cold, (0.5, 0.5))
    assert assign.shape == (32,)
    # every key's pages are contiguous on one device
    for k in range(8):
        assert len(set(assign[k * 4:(k + 1) * 4])) == 1
    assert (assign[5 * 4] == 0) and (assign[2 * 4] == 0)
    dev_of_key = assign[::4]
    counts = np.bincount(dev_of_key, minlength=3)
    assert counts[0] == 2 and counts[1] == 3 and counts[2] == 3
    # consecutive-rank cold keys alternate devices (interleave, not blocks)
    cold_devs = [dev_of_key[k] for k in cold]
    assert cold_devs != sorted(cold_devs) or len(set(cold_devs)) == 1


# -- SemanticTensor ----------------------------------------------------------
def _mk(n_keys=64, rpk=8, page_rows=2, dim=4, seed=0, placement="blind",
        weights=(0.25, 0.25, 0.25)):
    rng = np.random.default_rng(seed)
    arr = jnp.asarray(rng.normal(size=(n_keys * rpk, dim)), jnp.float32)
    led = HotnessLedger(n_keys, decay=0.5)
    led.record(_zipf(n_keys, rng))
    st = SemanticTensor.from_array(
        arr, rows_per_key=rpk, weights=weights, device_names=NAMES,
        page_rows=page_rows, ledger=led, headroom=n_keys * rpk // page_rows,
        placement=placement)
    return st, np.asarray(arr)


def test_semantic_tensor_roundtrip_bitexact_across_retier():
    st, ref = _mk()
    assert np.array_equal(np.asarray(st.to_array()), ref)
    st2 = st.retier((0.25, 0.25, 0.25), telemetry=Telemetry())
    assert np.array_equal(np.asarray(st2.to_array()), ref)
    assert st2.last_retier["moved_pages"] > 0
    assert st2.hot_traffic_share() > st.hot_traffic_share()


def test_semantic_tensor_noop_retier_returns_self():
    st, _ = _mk(placement="semantic")
    st2 = st.retier((0.25, 0.25, 0.25), telemetry=Telemetry())
    assert st2 is st


def test_semantic_retier_o_moved_keys_descriptors():
    st, ref = _mk(placement="semantic")
    rng = np.random.default_rng(9)
    for _ in range(8):
        st.ledger.record(_zipf(st.n_keys, rng))
        st.ledger.tick()
    mover = BulkMover(TOPO)
    try:
        d0 = mover.descriptors_submitted
        st2 = st.retier((0.25, 0.25, 0.25), mover=mover,
                        telemetry=Telemetry())
        descs = mover.descriptors_submitted - d0
    finally:
        mover.close()
    r = st2.last_retier
    assert r["moved_pages"] > 0
    # run coalescing: each moved key's 4 contiguous pages ship as <= 1
    # descriptor per key, never one per page
    assert descs <= r["moved_keys"] < r["moved_pages"]
    assert np.array_equal(np.asarray(st2.to_array()), ref)


def test_semantic_tensor_records_access_and_telemetry():
    st, ref = _mk()
    idx = jnp.asarray([0, 1, 2, 3] * 5)  # rows of keys 0..? rpk=8 -> key 0
    st.gather_rows(idx)
    assert st.ledger.scores()[0] > 0
    telem = Telemetry()
    st2 = st.retier((0.25, 0.25, 0.25), telemetry=telem, source="t")
    c = telem.snapshot()["counters"]
    assert c["semantic_promoted_pages"] == c["semantic_promoted_pages|t"] > 0
    assert c["semantic_demoted_pages"] > 0
    assert np.array_equal(np.asarray(st2.to_array()), ref)


def test_semantic_tensor_padding_and_validation():
    arr = jnp.arange(30, dtype=jnp.float32).reshape(10, 3)
    st = SemanticTensor.from_array(arr, rows_per_key=4, weights=(0.5,),
                                   device_names=("fast", "slow"))
    assert st.n_keys == 3  # 10 rows pad to 12
    assert np.array_equal(np.asarray(st.to_array()), np.asarray(arr))
    with pytest.raises(ValueError):
        SemanticTensor.from_array(arr, rows_per_key=4, page_rows=3,
                                  weights=(0.5,))
    with pytest.raises(ValueError):
        SemanticTensor.from_array(arr, rows_per_key=4, weights=(0.5,),
                                  placement="nope")


def test_zero_retrace_across_hotness_flip():
    st, _ = _mk(placement="semantic")
    traces = [0]

    def step(t, i):
        traces[0] += 1
        return t.gather_rows(i)

    fn = jax.jit(step)
    idx = jnp.arange(16)
    fn(st.it, idx)
    rng = np.random.default_rng(11)
    for _ in range(8):
        st.ledger.record(_zipf(st.n_keys, rng))
        st.ledger.tick()
    st = st.retier((0.25, 0.25, 0.25), telemetry=Telemetry())
    assert st.last_retier["moved_pages"] > 0
    fn(st.it, idx)
    assert traces[0] == 1


# -- MoE dispatch counts -----------------------------------------------------
def test_moe_expert_counts_feed_ledger():
    from repro.models import moe, registry
    arch = registry.get("deepseek-moe-16b").tiny()
    cfg = arch.cfg
    params = moe.init(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_padded, size=(2, 8)))
    _, aux = moe.forward_with_aux(cfg, params, tokens)
    counts = np.asarray(aux["expert_counts"])
    E = cfg.moe.n_experts
    assert counts.shape == (E,)
    # kept dispatch slots: <= B*S*top_k per MoE unit, > 0 overall
    n_units = params["units"]["moe"]["router"].shape[0]
    assert 0 < counts.sum() <= 2 * 8 * cfg.moe.top_k * n_units
    led = HotnessLedger(E)
    led.record(counts)
    assert led.scores().sum() == counts.sum()


# -- Caption integration -----------------------------------------------------
def _walk(coord, skew, epochs, topo=TOPO):
    for _ in range(epochs):
        coord.st.ledger.record(skew)
        dev, sc = coord.st.key_device(), coord.st.ledger.scores()
        total = max(float(sc.sum()), 1e-12)
        shares = tuple(float(sc[dev == i + 1].sum()) / total
                       for i in range(len(topo.slows)))
        from benchmarks.fig8_dlrm import throughput_nd
        coord.epoch(EpochMetrics(
            throughput=throughput_nd(topo.fast, topo.slows, shares, 32)))


def test_hot_set_coordinator_reopens_on_drift():
    rng = np.random.default_rng(5)
    n_keys, rpk = 64, 8
    arr = jnp.asarray(rng.normal(size=(n_keys * rpk, 4)), jnp.float32)
    led = HotnessLedger(n_keys, decay=0.5)
    skew = _zipf(n_keys, rng, scale=1e6)
    led.record(skew)
    cfg = CaptionConfig(epoch_steps=1, probe_epochs=1, step=0.1,
                        min_step=0.02, hysteresis=0.005,
                        drift_threshold=0.0, write_damp=False)
    ctl = CaptionController(TOPO, cfg, initial_fraction=0.9,
                            min_fraction=0.75)
    st = SemanticTensor.from_array(
        arr, rows_per_key=rpk, weights=ctl.weights, device_names=NAMES,
        page_rows=2, ledger=led, headroom=n_keys * rpk // 2,
        placement="semantic")
    coord = HotSetCoordinator(st, ctl, drift_threshold=0.5)
    _walk(coord, skew, 20)
    assert ctl.converged and coord.reopens == 0
    assert coord.drift() == 0.0
    # workload shift: a brand-new hot set re-opens the converged walk
    flipped = _zipf(n_keys, rng, scale=1e6)
    _walk(coord, flipped, 20)
    assert coord.reopens >= 1
    assert ctl.converged  # and re-converges
    assert coord.st.hot_traffic_share() > 0.5
    # the re-converged hot set is the NEW skew's, pinned fast
    assert np.array_equal(np.asarray(coord.st.to_array()),
                          np.asarray(arr))


def test_caption_reopen_resets_phase():
    from repro.core.caption import Phase
    ctl = CaptionController(TOPO, CaptionConfig(
        epoch_steps=1, probe_epochs=1, hysteresis=0.0, write_damp=False),
        initial_fraction=0.5)
    for _ in range(60):
        ctl.observe(EpochMetrics(throughput=100.0))
        if ctl.converged:
            break
    assert ctl.converged
    d = ctl.reopen("test shift")
    assert ctl.phase == Phase.MEASURE
    assert "re-opened" in d.reason


def test_planner_hot_set_seed():
    from repro.core.planner import hot_set_seed
    scores = np.concatenate([np.full(10, 100.0), np.full(90, 0.1)])
    w = hot_set_seed(scores, TOPO, fast_budget_fraction=0.5,
                     target_hot_traffic=0.8)
    assert len(w) == len(TOPO.slows)
    # 10 hot keys cover >80% of traffic: hot fraction ~0.1, the rest slow
    assert sum(w) == pytest.approx(0.9, abs=0.02)
    # cold start: no signal -> fall back to the full budget
    w0 = hot_set_seed(np.zeros(100), TOPO, fast_budget_fraction=0.3)
    assert sum(w0) == pytest.approx(0.7, abs=0.02)


# -- serving pools in the TierLedger ----------------------------------------
def test_kv_pools_register_in_ledger():
    from repro.core.ledger import TierLedger
    from repro.core.policy import MemPolicy
    from repro.models.registry import get
    from repro.serving.engine import ServingEngine
    arch = get("qwen2.5-32b").tiny()
    params = arch.module.init(arch.cfg, jax.random.PRNGKey(0))
    bw = TOPO.bandwidth_weights()
    pol = MemPolicy.from_tier_fractions(
        TOPO.fast.name, TOPO.slow_names, [0.5 * w for w in bw])
    led = TierLedger(TOPO)
    eng = ServingEngine(arch.cfg, params, max_batch=2, max_len=32,
                        policy=pol, topology=TOPO, page_t=8,
                        prefix_pages=8, ledger=led)
    per = led.per_buffer()["kv"]
    pool = eng.cache.pool_bytes_per_device()
    # every pool byte is billed to a real topology tier, prefix included
    assert per[TOPO.fast.name] == pool[TOPO.fast.name] > 0
    assert sum(per.values()) == sum(pool.values())
    pb = eng.cache.prefix.page_bytes()
    assert pool[TOPO.fast.name] >= eng.cache.prefix.pool_pages * pb
    # re-registering refreshes, never double-bills
    eng.register_pools()
    assert led.per_buffer()["kv"] == per


def test_kv_pool_bytes_tracks_repartition():
    from repro.core.ledger import TierLedger
    from repro.core.policy import MemPolicy
    from repro.models.registry import get
    from repro.serving.kv_cache import TieredKVCache
    arch = get("qwen2.5-32b").tiny()
    pol = MemPolicy.from_slow_fraction("fast", "slow", 0.0)
    cache = TieredKVCache.create(arch.cfg, 2, 32, pol, page_t=8,
                                 slow_headroom=4)
    led = TierLedger(TOPO)
    billed = cache.register_in_ledger(
        led, "kv", device_names=(TOPO.fast.name, TOPO.slows[0].name))
    assert billed[TOPO.fast.name] > 0
    cache2 = cache.repartition_fraction(0.5)
    billed2 = cache2.register_in_ledger(
        led, "kv", device_names=(TOPO.fast.name, TOPO.slows[0].name))
    # half the pages moved out: the slow pool is now billed too
    assert billed2.get(TOPO.slows[0].name, 0) > 0
    assert led.used(TOPO.slows[0].name) == billed2[TOPO.slows[0].name]
