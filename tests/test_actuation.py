"""Donated in-place actuation, shard backends, and per-device KV pools
(ISSUE 7).

Covers the donation contract end to end: the three scatter
formulations (masked N-pass, bucketed numpy, donated jit) are bit-exact
on the same inputs — including duplicate indices under ``add`` and
aliased value buffers — the donated stable-path repartition performs
ZERO full receiving-shard copies and genuinely reuses the shard buffers
(``unsafe_buffer_pointer``), the mover bills post-cast bytes for
fused-cast descriptors, and the per-device KV pools keep storage equal
to the ``read_bytes_per_device`` accounting with drain/retile decode
bit-exactness on 3-device topologies.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.donation import FULL_SHARD_COPIES, donated_update, pad_to_bucket
from repro.core.interleave import (BACKENDS, InterleavedTensor,
                                   resolve_backend, supports_memory_kinds)
from repro.core.mover import BulkMover, Descriptor, stream_executor
from repro.core.policy import MemPolicy
from repro.core.telemetry import Telemetry
from repro.core.tiers import TierTopology, paper_three_device_topology
from repro.testing import given, settings, st  # hypothesis, with fallback

FEAT = 4
PAGE_ROWS = 8


def _tensor(rng, rows=256, weights=(3, 1), headroom=4, backend="modeled"):
    x = jnp.asarray(rng.normal(size=(rows, FEAT)), jnp.float32)
    it = InterleavedTensor.from_array(
        x, MemPolicy.weighted(("fast", "slow"), weights), PAGE_ROWS,
        headroom=headroom, backend=backend)
    return it, np.asarray(x)


# -- scatter equivalence: donated == masked == bucketed -----------------------
@given(st.integers(0, 200), st.integers(1, 48))
@settings(max_examples=25, deadline=None)
def test_scatter_set_equivalence(seed, n_idx):
    """set with distinct rows: all three formulations bit-exact."""
    rng = np.random.default_rng(seed)
    it, x = _tensor(rng)
    idx = rng.choice(x.shape[0], size=min(n_idx, x.shape[0]), replace=False)
    vals = rng.normal(size=(idx.size, FEAT)).astype(np.float32)
    ref = x.copy()
    ref[idx] = vals
    masked = it._scatter_masked(jnp.asarray(idx), jnp.asarray(vals), "set")
    bucketed = it._scatter_bucketed(idx, jnp.asarray(vals), "set")
    donated = it.update_rows(idx, jnp.asarray(vals), donate=True)  # it dies
    assert np.array_equal(np.asarray(masked.to_array()), ref)
    assert np.array_equal(np.asarray(bucketed.to_array()), ref)
    assert np.array_equal(np.asarray(donated.to_array()), ref)


@given(st.integers(0, 200), st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_scatter_add_duplicates_equivalence(seed, n_idx):
    """add with DUPLICATE rows: duplicates must accumulate identically
    through the masked jax path, the numpy ufunc path, and the donated
    jit scatter."""
    rng = np.random.default_rng(seed)
    it, x = _tensor(rng)
    idx = rng.integers(0, x.shape[0], size=n_idx)  # duplicates likely
    vals = rng.normal(size=(idx.size, FEAT)).astype(np.float32)
    ref = x.copy()
    np.add.at(ref, idx, vals)
    masked = it._scatter_masked(jnp.asarray(idx), jnp.asarray(vals), "add")
    donated = it.add_rows(idx, jnp.asarray(vals), donate=True)  # it dies
    np.testing.assert_allclose(np.asarray(masked.to_array()), ref,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(donated.to_array()), ref,
                               atol=1e-5)
    # scatter-add accumulation order is formulation-dependent in float;
    # the two jax paths must still agree to rounding
    np.testing.assert_allclose(np.asarray(masked.to_array()),
                               np.asarray(donated.to_array()), atol=1e-6)


def test_scatter_aliased_values(key):
    """Values aliasing the shard's own storage (a read-modify-write
    through the gather) stay correct under donation: staging must be
    copied before the in-place write."""
    rng = np.random.default_rng(0)
    it, x = _tensor(rng)
    idx = np.arange(0, 64)
    # values gathered FROM the tensor itself (aliased source)
    vals = it.gather_rows(idx + 64)
    ref = x.copy()
    ref[idx] = x[idx + 64]
    out = it.update_rows(idx, vals, donate=True)
    assert np.array_equal(np.asarray(out.to_array()), ref)


def test_donated_update_under_jit_bucketing():
    """Varying delta sizes reuse a bounded set of jit traces (power-of-2
    buckets) and stay bit-exact."""
    rng = np.random.default_rng(1)
    part = jnp.asarray(rng.normal(size=(128, FEAT)), jnp.float32)
    ref = np.asarray(part).copy()
    for n in (1, 3, 5, 9, 17):
        rows = rng.choice(128, size=n, replace=False)
        vals = rng.normal(size=(n, FEAT)).astype(np.float32)
        ref[rows] = vals
        part = donated_update(part, rows, vals, "set")
    assert np.array_equal(np.asarray(part), ref)
    # bucket padding points one-past-the-end and is dropped
    rows_p, vals_p = pad_to_bucket(np.array([2, 5, 7]),
                                   np.ones((3, FEAT), np.float32), 128)
    assert rows_p.shape[0] == 4 and rows_p[-1] == 128


# -- donated repartition: zero copies, buffer reuse, bit-exact ----------------
def test_donated_repartition_zero_copies_and_aliasing():
    rng = np.random.default_rng(2)
    cur, x = _tensor(rng, rows=512, headroom=8)
    ptrs = [p.unsafe_buffer_pointer() for p in cur.parts]
    FULL_SHARD_COPIES.reset()
    # excursions stay within the headroom cap (slow starts at 16/64
    # pages, cap 24) so every step takes the donated stable path.  No
    # reference to any ancestor may survive the call (the donation
    # contract): a live ancestor pins its host mirror views, which
    # blocks the buffer alias.
    for f in (0.375, 0.25, 0.3125, 0.125, 0.25):
        cur = cur.repartition_fraction(f, telemetry=Telemetry(),
                                       donate=True)
    assert FULL_SHARD_COPIES.reset() == 0
    # the walk reused the original buffers in place throughout
    assert [p.unsafe_buffer_pointer() for p in cur.parts] == ptrs
    assert np.array_equal(np.asarray(cur.to_array()), x)


def test_donated_vs_cow_repartition_bit_exact():
    rng = np.random.default_rng(3)
    it, x = _tensor(rng, rows=512, headroom=8)
    cow = it.repartition_fraction(0.375, telemetry=Telemetry())
    FULL_SHARD_COPIES.reset()
    don = it.repartition_fraction(0.375, telemetry=Telemetry(),
                                  donate=True)  # it dies here
    assert FULL_SHARD_COPIES.reset() == 0
    for pc, pd in zip(cow.parts, don.parts):
        assert np.array_equal(np.asarray(pc), np.asarray(pd))
    assert np.array_equal(np.asarray(don.to_array()), x)


def test_donation_deletes_parent_buffers():
    rng = np.random.default_rng(4)
    it, _ = _tensor(rng)
    idx = np.arange(8)
    out = it.update_rows(idx, jnp.zeros((8, FEAT)), donate=True)
    # the receiving shard's parent buffer is genuinely gone
    assert any(p.is_deleted() for p in it.parts)
    assert not any(p.is_deleted() for p in out.parts)


# -- backends -----------------------------------------------------------------
def test_backend_resolution():
    assert resolve_backend("modeled") == "modeled"
    assert resolve_backend("staged") == "staged"
    # auto falls back to modeled when the platform lacks pinned_host
    expected = "memory_kind" if supports_memory_kinds() else "modeled"
    assert resolve_backend("auto") == expected
    assert resolve_backend("memory_kind") == expected
    with pytest.raises(ValueError):
        resolve_backend("nope")
    assert set(BACKENDS) == {"modeled", "staged", "memory_kind"}


def test_staged_backend_equivalence():
    """The staged backend (jax-slab descriptors, device-resident shards)
    produces the same arrays as the modeled backend across a
    repartition + scatter sequence."""
    rng = np.random.default_rng(5)
    a, x = _tensor(rng, backend="modeled")
    rng = np.random.default_rng(5)
    b, _ = _tensor(rng, backend="staged")
    assert b.backend == "staged"
    idx = np.arange(16)
    vals = jnp.ones((16, FEAT), jnp.float32)
    for f in (0.375, 0.25):
        a = a.repartition_fraction(f, telemetry=Telemetry())
        b = b.repartition_fraction(f, telemetry=Telemetry())
    a = a.update_rows(idx, vals)
    b = b.update_rows(idx, vals)
    assert np.array_equal(np.asarray(a.to_array()), np.asarray(b.to_array()))


# -- mover: post-cast byte billing + pipelined executor -----------------------
def test_mover_bills_post_cast_bytes():
    """A fused-cast descriptor's wire bytes are the POST-cast size: a
    bf16 -> fp32 migration bills 4 bytes/element, not 2 (regression for
    the compressed-staging upcast path)."""
    topo = paper_three_device_topology()
    payload = jnp.ones((64, 16), jnp.bfloat16)
    d = Descriptor(topo.fast.name, topo.slows[0].name, payload,
                   out_dtype=jnp.float32)
    assert d.nbytes == 64 * 16 * 4
    plain = Descriptor(topo.fast.name, topo.slows[0].name, payload)
    assert plain.nbytes == 64 * 16 * 2
    with BulkMover(topo, telemetry=Telemetry()) as mover:
        mover.submit([d])
        assert mover.bytes_submitted == 64 * 16 * 4


def test_stream_executor_casts_and_copies():
    """The double-buffered migration executor moves and casts payloads
    through the Pallas kernel (interpret mode on CPU)."""
    topo = paper_three_device_topology()
    src = jnp.asarray(np.random.default_rng(6).normal(size=(100, 8)),
                      jnp.float32)
    got = {}
    with BulkMover(topo, execute=stream_executor(block_rows=32),
                   telemetry=Telemetry()) as mover:
        assert mover.pipelined
        mover.submit([Descriptor(topo.fast.name, topo.slows[0].name, src,
                                 out_dtype=jnp.bfloat16,
                                 on_done=lambda r: got.setdefault("x", r))])
    out = got["x"]
    assert out.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(src.astype(jnp.bfloat16)))


# -- KV cache: per-device pools -----------------------------------------------
@pytest.fixture(scope="module")
def tiny_model(key):
    from repro.models import registry
    arch = registry.get("internvl2-2b").tiny()
    return arch.cfg, arch.module.init(arch.cfg, jax.random.PRNGKey(0))


def _decode_n(cfg, params, cache, toks, n):
    from repro.serving.kv_cache import tiered_decode_step
    logits = None
    for _ in range(n):
        logits, cache = tiered_decode_step(cfg, params, cache, toks)
    return logits, cache


def test_kv_storage_matches_read_accounting(tiny_model):
    """ISSUE 7 invariant: with per-device physical pools, the bytes each
    device actually stores equal the read-accounting bytes per device
    (modulo the fast tier's >= 1-page billing floor, avoided here by
    keeping a fast page in every slot)."""
    from repro.serving.kv_cache import TieredKVCache
    cfg, params = tiny_model
    pol = MemPolicy.from_tier_fractions("fast", ("cxl-a", "cxl-b"),
                                        (0.25, 0.25))
    cache = TieredKVCache.create(cfg, 3, 32, pol, page_t=4, slow_headroom=2)
    assert len(cache.k_parts) == 3  # one physical pool pair per device
    assert cache.storage_bytes_per_device() == cache.read_bytes_per_device()
    # still equal after a weight shift (stable path)
    cache = cache.repartition_weights((0.375, 0.125),
                                      telemetry=Telemetry())
    assert cache.storage_bytes_per_device() == cache.read_bytes_per_device()


def test_kv_donated_retile_bit_exact(tiny_model):
    from repro.serving.kv_cache import TieredKVCache
    cfg, params = tiny_model
    toks = jnp.asarray([3, 9], jnp.int32)
    pol = MemPolicy.from_slow_fraction("fast", "slow", 0.0)
    a = TieredKVCache.create(cfg, 2, 32, pol, page_t=4, slow_headroom=4)
    _, a = _decode_n(cfg, params, a, toks, 4)
    cow = a.repartition_fraction(0.5, telemetry=Telemetry())
    l_cow, _ = _decode_n(cfg, params, cow, toks, 4)
    FULL_SHARD_COPIES.reset()
    slow_ptr = a.k_parts[1].unsafe_buffer_pointer()
    don = a.repartition_fraction(0.5, telemetry=Telemetry(),
                                 donate=True)  # a dies here
    assert FULL_SHARD_COPIES.reset() == 0
    assert don.k_parts[1].unsafe_buffer_pointer() == slow_ptr
    assert a.k_parts[1].is_deleted()
    l_don, _ = _decode_n(cfg, params, don, toks, 4)
    assert np.array_equal(np.asarray(l_cow), np.asarray(l_don))


def test_kv_three_device_drain_bit_exact(tiny_model):
    """Draining a slow device (donated) leaves decode bit-exact vs the
    same drain through the copy-on-write path."""
    from repro.serving.kv_cache import TieredKVCache
    cfg, params = tiny_model
    toks = jnp.asarray([3, 9], jnp.int32)
    pol = MemPolicy.from_tier_fractions("fast", ("cxl-a", "cxl-b"),
                                        (0.25, 0.25))

    def build():
        c = TieredKVCache.create(cfg, 2, 32, pol, page_t=4, slow_headroom=8)
        _, c = _decode_n(cfg, params, c, toks, 4)
        return c

    ref = build().drain_device("cxl-a")
    l_ref, _ = _decode_n(cfg, params, ref, toks, 4)
    don = build().drain_device("cxl-a", donate=True)
    assert don.weights()[0] == 0.0
    l_don, _ = _decode_n(cfg, params, don, toks, 4)
    assert np.array_equal(np.asarray(l_ref), np.asarray(l_don))
    # the drained cache keeps per-device storage == accounting
    assert don.storage_bytes_per_device()["cxl-a"] == 0


def test_kv_retile_roundtrip_bit_exact(tiny_model):
    """Rebuild path (headroom=0) round-trips through a mixed placement
    and back, matching a never-retiled cache exactly."""
    from repro.serving.kv_cache import TieredKVCache
    cfg, params = tiny_model
    toks = jnp.asarray([3, 9], jnp.int32)
    pol = MemPolicy.from_slow_fraction("fast", "slow", 0.0)
    a = TieredKVCache.create(cfg, 2, 32, pol, page_t=4)
    _, a = _decode_n(cfg, params, a, toks, 4)
    a = a.repartition_fraction(0.5, telemetry=Telemetry())
    a = a.repartition_fraction(0.0, telemetry=Telemetry())
    l_a, _ = _decode_n(cfg, params, a, toks, 4)
    b = TieredKVCache.create(cfg, 2, 32, pol, page_t=4)
    _, b = _decode_n(cfg, params, b, toks, 4)
    l_b, _ = _decode_n(cfg, params, b, toks, 4)
    assert np.array_equal(np.asarray(l_a), np.asarray(l_b))


def test_engine_donated_actuation(tiny_model):
    """The engine's Caption/pin actuations run donated by default and
    keep the full-pool copy counter at zero across a served workload."""
    from repro.core.caption import CaptionConfig, CaptionController
    from repro.core.tiers import tpu_v5e_topology
    from repro.serving.engine import ServingEngine
    cfg, params = tiny_model
    topo = tpu_v5e_topology()
    ctl = CaptionController(topo, CaptionConfig(epoch_steps=2,
                                                probe_epochs=1, step=0.1),
                            initial_fraction=0.1)
    eng = ServingEngine(cfg, params, max_batch=2, max_len=16,
                        topology=topo, page_t=4, caption=ctl)
    assert eng.donate_kv
    eng.submit([1, 2, 3], max_new_tokens=6)
    eng.submit([4, 5], max_new_tokens=6, slo="latency")
    FULL_SHARD_COPIES.reset()
    eng.run_until_drained(max_steps=64)
    assert FULL_SHARD_COPIES.reset() == 0
    assert eng.decode_traces == 1
    assert len(eng.done) == 2
