"""§6.1 classifier edges: pool-worst-case classification and skewed
AccessProfile corners (zero parallelism, µs deadlines, compute-bound
crossover)."""
import dataclasses

from repro.core import AccessProfile, Boundedness, classify
from repro.core.classifier import classify_pool, tolerates_slow_tier
from repro.core.tiers import (TierTopology, paper_three_device_topology,
                              paper_topology)

TOPO3 = paper_three_device_topology()
SLOW = paper_topology().slows[0]
FAST = paper_topology().fast

STREAMING = AccessProfile(
    bytes_read_per_step=1 << 30, bytes_written_per_step=0,
    dependent_chain=1, parallelism=64, granularity=4096)
CHASE = AccessProfile(
    bytes_read_per_step=64 * 1000, bytes_written_per_step=0,
    dependent_chain=1000, parallelism=1, granularity=64)


def test_zero_parallelism_treated_as_serial():
    """parallelism=0 must not divide by zero; it means one stream."""
    p0 = dataclasses.replace(CHASE, parallelism=0)
    p1 = dataclasses.replace(CHASE, parallelism=1)
    assert classify(p0, SLOW) == classify(p1, SLOW) \
        == Boundedness.LATENCY_BOUND


def test_us_deadline_flags_any_far_chase():
    """Redis case: µs SLO + even a short dependent chain on a far tier."""
    short = AccessProfile(
        bytes_read_per_step=4096, bytes_written_per_step=0,
        dependent_chain=16, parallelism=1, granularity=64,
        compute_seconds=1.0,  # plenty of compute to hide it on average
        deadline_seconds=50e-6)
    assert classify(short, SLOW) == Boundedness.LATENCY_BOUND
    # the same access shape with an ms-level deadline amortizes fine
    ms = dataclasses.replace(short, deadline_seconds=5e-3)
    assert classify(ms, SLOW) == Boundedness.COMPUTE_BOUND
    # µs deadline alone is not a verdict: trivial latency exposure passes
    tiny = dataclasses.replace(short, dependent_chain=1, parallelism=256)
    assert classify(tiny, SLOW) != Boundedness.LATENCY_BOUND


def test_compute_bound_crossover():
    """Sweep compute per step: bandwidth-bound until compute dominates."""
    stream_time = STREAMING.bytes_per_step / SLOW.load_bw
    below = dataclasses.replace(STREAMING, compute_seconds=stream_time / 2)
    above = dataclasses.replace(STREAMING, compute_seconds=stream_time * 2)
    assert classify(below, SLOW) == Boundedness.BANDWIDTH_BOUND
    assert classify(above, SLOW) == Boundedness.COMPUTE_BOUND


def test_tolerates_slow_tier():
    assert tolerates_slow_tier(STREAMING, SLOW)
    assert not tolerates_slow_tier(CHASE, SLOW)


def test_classify_pool_worst_case_over_slows():
    """One latency-bound device in the pool taints the whole verdict."""
    assert classify_pool(STREAMING, TOPO3) == Boundedness.BANDWIDTH_BOUND
    assert classify_pool(CHASE, TOPO3) == Boundedness.LATENCY_BOUND
    # a pool mixing a benign and a high-latency device: worst case wins
    borderline = AccessProfile(
        bytes_read_per_step=1 << 20, bytes_written_per_step=0,
        dependent_chain=32, parallelism=8, granularity=64)
    laggard = dataclasses.replace(
        TOPO3.slows[-1], chase_latency_ns=200_000.0)
    mixed = TierTopology(fast=TOPO3.fast, slows=(TOPO3.slows[0], laggard))
    per_dev = [classify(borderline, t) for t in mixed.slows]
    assert Boundedness.LATENCY_BOUND in per_dev
    assert per_dev[0] != Boundedness.LATENCY_BOUND
    assert classify_pool(borderline, mixed) == Boundedness.LATENCY_BOUND


def test_classify_pool_empty_slow_pool_falls_back_to_fast():
    solo = TierTopology(fast=FAST, slows=())
    assert classify_pool(STREAMING, solo) == classify(STREAMING, FAST)
    # even a pointer chase is fine against local DRAM's chase latency
    local_chase = dataclasses.replace(CHASE, compute_seconds=1e-3)
    assert classify_pool(local_chase, solo) != Boundedness.LATENCY_BOUND
