"""Warm-start memo tests: JSON persistence roundtrip, fingerprint
stability across equivalent windows, structural invalidation (topology
signature + drift signature), the CaptionController warm-start flow,
and the elastic remove/add_device interaction."""
import dataclasses

import pytest

from repro.core.caption import CaptionConfig, CaptionController, EpochMetrics
from repro.core.telemetry import EpochWindow, Telemetry
from repro.core.tiers import CXL_A, CXL_B, TierTopology, paper_topology
from repro.core.warmstart import (WarmStartMemo, WorkloadFingerprint,
                                  fingerprint_counters, fingerprint_metrics,
                                  topology_signature)

from benchmarks.fig8_dlrm import throughput as _fig8_throughput
from benchmarks.fig11_caption import snc_topology as _snc_topology

CFG = CaptionConfig(probe_epochs=2, step=0.05, min_step=0.01,
                    hysteresis=0.01)


def _tput(topo, f):
    return _fig8_throughput(topo.fast, topo.slow, f, 32)


def _converge(ctl, topo, epochs=256):
    for epoch in range(epochs):
        ctl.observe(EpochMetrics(throughput=_tput(topo, ctl.fraction)))
        if ctl.converged:
            return epoch + 1
    raise AssertionError(f"did not converge: {ctl.phase}")


# -- fingerprints --------------------------------------------------------------
def test_fingerprint_stable_across_equivalent_windows():
    """Sampling jitter within a quantization bucket maps to one key."""
    topo = paper_topology()
    a = fingerprint_metrics(
        EpochMetrics(throughput=1.0, write_ratio=0.24, slow_bw=100e9,
                     writer_concurrency=8), topo)
    b = fingerprint_metrics(
        EpochMetrics(throughput=2.0, write_ratio=0.26, slow_bw=120e9,
                     writer_concurrency=9), topo)
    assert a.key() == b.key()
    # ... and a genuinely different workload maps elsewhere
    c = fingerprint_metrics(
        EpochMetrics(throughput=1.0, write_ratio=0.9, slow_bw=1e9,
                     writer_concurrency=64), topo)
    assert c.key() != a.key()


def test_fingerprint_counters_matches_metrics_features():
    tel = Telemetry()
    win = EpochWindow(tel)
    tel.record_move("fast", "slow", 3000, 0.0)
    tel.record_move("slow", "fast", 1000, 0.0)
    win.gauge("writer_concurrency", 4)
    counters = win.tick(seconds=1.0)
    feats = counters.workload_features("slow")
    assert feats["write_ratio"] == pytest.approx(0.75)
    assert feats["slow_bw"] == pytest.approx(4000.0)
    assert feats["parallelism"] == 4
    fp = fingerprint_counters(counters, paper_topology(), slow="slow")
    assert fp.write_ratio == pytest.approx(0.75)
    assert fp.topology == topology_signature(paper_topology())


def test_memo_json_roundtrip(tmp_path):
    topo = paper_topology()
    fp = fingerprint_metrics(
        EpochMetrics(throughput=1.0, write_ratio=0.25, slow_bw=10e9,
                     writer_concurrency=8), topo)
    memo = WarmStartMemo(drift_threshold=0.4)
    memo.record(fp, (0.15, 0.05))
    path = tmp_path / "memo.json"
    memo.save(str(path))
    loaded = WarmStartMemo.load(str(path))
    assert loaded.drift_threshold == pytest.approx(0.4)
    assert len(loaded) == 1
    assert loaded.lookup(fp) == (0.15, 0.05)
    assert loaded.hits == 1
    # a missing file is an empty memo, never a crash
    empty = WarmStartMemo.load(str(tmp_path / "nope.json"))
    assert len(empty) == 0 and empty.lookup(fp) is None


def test_memo_invalidation_topology_and_drift():
    topo = TierTopology(fast=paper_topology().fast, slows=(CXL_A, CXL_B))
    fp = fingerprint_metrics(
        EpochMetrics(throughput=1.0, write_ratio=0.25, slow_bw=100e9,
                     writer_concurrency=8), topo)
    memo = WarmStartMemo(drift_threshold=0.2)
    memo.record(fp, (0.1, 0.1))
    # topology change (hot-remove) -> different signature -> miss
    fp_removed = dataclasses.replace(
        fp, topology=topology_signature(topo.remove_device(CXL_B.name)))
    assert memo.lookup(fp_removed) is None
    assert memo.misses == 1 and memo.drift_misses == 0
    # same quantization bucket but raw route bandwidth drifted -> miss
    fp_drift = dataclasses.replace(fp, slow_bw=130e9)
    assert fp_drift.key() == fp.key()
    assert memo.lookup(fp_drift) is None
    assert memo.drift_misses == 1
    # the undrifted workload still hits
    assert memo.lookup(fp) == (0.1, 0.1)


def test_memo_validation():
    with pytest.raises(ValueError):
        WarmStartMemo(drift_threshold=-0.1)


# -- controller warm-start flow ------------------------------------------------
def test_cold_walk_records_and_warm_run_skips_the_walk():
    topo = _snc_topology()
    memo = WarmStartMemo()
    cold = CaptionController(topo, CFG, initial_fraction=0.0)
    cold.attach_memo(memo)
    cold_epochs = _converge(cold, topo)
    assert len(memo) == 1
    (entry,) = memo.entries().values()
    assert entry["weights"] == pytest.approx(list(cold.weights))

    warm = CaptionController(topo, CFG, initial_fraction=0.0)
    warm.attach_memo(WarmStartMemo.from_json(memo.to_json()))
    d0 = warm.observe(EpochMetrics(throughput=_tput(topo, 0.0)))
    # first decision lands AT the remembered optimum (<= 2pp per device)
    assert "warm-start" in d0.reason
    assert all(abs(a - b) <= 0.02
               for a, b in zip(warm.weights, cold.weights))
    warm_epochs = 1 + _converge(warm, topo)
    # one confirmation stint, then hold — not a re-walk
    assert warm_epochs <= 2 * CFG.probe_epochs
    assert warm_epochs < cold_epochs


def test_memo_miss_walks_cold_and_different_workload_files_new_entry():
    topo = _snc_topology()
    memo = WarmStartMemo()
    ctl = CaptionController(topo, CFG, initial_fraction=0.0)
    ctl.attach_memo(memo)
    d0 = ctl.observe(EpochMetrics(throughput=_tput(topo, 0.0)))
    assert "warm-start" not in d0.reason  # nothing remembered yet
    _converge(ctl, topo)
    assert len(memo) == 1

    # a different workload (distinct fingerprint) walks cold and files a
    # SECOND entry instead of clobbering the first
    ctl2 = CaptionController(topo, CFG, initial_fraction=0.0)
    ctl2.attach_memo(memo)
    for _ in range(256):
        ctl2.observe(EpochMetrics(
            throughput=_tput(topo, ctl2.fraction),
            write_ratio=0.9, slow_bw=5e9, writer_concurrency=64))
        if ctl2.converged:
            break
    assert ctl2.converged and len(memo) == 2


def test_warm_start_respects_capacity_floor():
    """Remembered weights below the plan's floor are clamped up."""
    topo = _snc_topology()
    memo = WarmStartMemo()
    fp = fingerprint_metrics(EpochMetrics(throughput=1.0), topo)
    memo.record(fp, (0.05,))
    ctl = CaptionController(topo, CFG, initial_fraction=0.3,
                            min_fraction=0.2)
    ctl.attach_memo(memo)
    d = ctl.observe(EpochMetrics(throughput=_tput(topo, 0.3)))
    assert "warm-start" in d.reason
    assert ctl.fraction == pytest.approx(0.2)


# -- elastic interaction -------------------------------------------------------
def test_remove_device_reopens_and_refingerprints():
    topo = TierTopology(fast=_snc_topology().fast, slows=(CXL_A, CXL_B))
    memo = WarmStartMemo()
    fp = fingerprint_metrics(EpochMetrics(throughput=1.0), topo)
    memo.record(fp, (0.12, 0.08))
    ctl = CaptionController(topo, CFG, initial_fraction=0.0)
    ctl.attach_memo(memo)
    d = ctl.observe(EpochMetrics(throughput=1.0))
    assert "warm-start" in d.reason and ctl.weights == [0.12, 0.08]

    ctl.remove_device(CXL_B.name)
    assert not ctl.converged  # the walk re-opened
    # next epoch re-fingerprints against the SHRUNKEN topology: the old
    # entry's signature no longer matches, so no stale warm-start
    d2 = ctl.observe(EpochMetrics(throughput=1.0))
    assert "warm-start" not in d2.reason
    assert memo.misses >= 1
    # a converged walk on the new topology files under the new signature
    for _ in range(256):
        ctl.observe(EpochMetrics(
            throughput=_fig8_throughput(ctl.topology.fast,
                                        ctl.topology.slows[0],
                                        ctl.fraction, 32)))
        if ctl.converged:
            break
    assert ctl.converged and len(memo) == 2
    sigs = {e["topology"] for e in memo.entries().values()}
    assert topology_signature(ctl.topology) in sigs


def test_add_device_reopens_and_new_topology_can_warm_start():
    """After hot-add, the re-fingerprint may itself warm-start — if the
    GROWN pool was seen (and converged) before, its entry hits."""
    topo2 = TierTopology(fast=_snc_topology().fast, slows=(CXL_A,))
    topo3 = topo2.add_device(CXL_B)
    memo = WarmStartMemo()
    memo.record(fingerprint_metrics(EpochMetrics(throughput=1.0), topo3),
                (0.1, 0.1))
    ctl = CaptionController(topo2, CFG, initial_fraction=0.2)
    ctl.attach_memo(memo)
    ctl.observe(EpochMetrics(throughput=1.0))  # fingerprints topo2: miss
    assert memo.hits == 0
    ctl.add_device(CXL_B)
    assert not ctl.converged
    d = ctl.observe(EpochMetrics(throughput=1.0))
    assert "warm-start" in d.reason and memo.hits == 1
    assert ctl.weights == [0.1, 0.1]
