"""Elastic tier topology + fault injection tests (degraded-mode coverage).

Covers the hot-remove/hot-add path end to end (topology -> controller ->
arbiter -> KV cache -> serving engine), the perfmodel degradation
registry the FaultInjector drives, and the three resilience-runtime
fixes: ResilientLoop's scratch replay, HeartbeatMonitor deregistration,
and StragglerMitigator's failed-original / EWMA handling."""
import itertools
import time

import jax
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import perfmodel
from repro.core.arbiter import CaptionArbiter
from repro.core.caption import CaptionConfig, CaptionController, EpochMetrics
from repro.core.interleave import InterleavedTensor
from repro.core.mover import BulkMover
from repro.core.policy import MemPolicy
from repro.core.telemetry import Telemetry
from repro.core.tiers import (CXL_A, CXL_B, CXL_C, DDR5_L8, OpClass,
                              TierTopology, paper_three_device_topology)
from repro.runtime.elastic import FaultInjector
from repro.runtime.fault_tolerance import (HeartbeatMonitor, ResilientLoop,
                                           WorkerFailure)
from repro.runtime.straggler import StragglerMitigator


# -- ResilientLoop: scratch replay must be bit-exact ---------------------------
def test_resilient_loop_scratch_replay_bit_exact(tmp_path):
    """A failure BEFORE the first checkpoint replays from the pristine
    initial state — an in-place-mutating step function must not leak the
    partial run's mutations into the replay."""
    def step_fn(state, step):
        state["x"] += step + 1.0  # in-place numpy update: the hazard
        return state

    def run(sub, injector=None):
        loop = ResilientLoop(
            Checkpointer(str(tmp_path / sub), asynchronous=False),
            checkpoint_every=100)  # > n_steps: no checkpoint to restore
        return loop.run({"x": np.zeros(4), "step": 0}, step_fn, 6,
                        failure_injector=injector)

    clean = run("clean")
    fired = []

    def injector(step):
        if step == 3 and not fired:
            fired.append(step)
            raise WorkerFailure("node loss before any checkpoint")

    out = run("faulty", injector)
    assert fired == [3]
    np.testing.assert_array_equal(out["x"], clean["x"])
    assert out["step"] == clean["step"] == 6


def test_resilient_loop_leaves_callers_dict_alone(tmp_path):
    """run() must not pop keys out of (or otherwise mutate) the caller's
    state dict — resubmitting the same dict is the natural retry idiom."""
    state = {"x": np.float64(1.0), "step": 0}
    ResilientLoop(Checkpointer(str(tmp_path), asynchronous=False),
                  checkpoint_every=5).run(
        state, lambda s, i: {"x": s["x"] + 1.0}, 4)
    assert state == {"x": 1.0, "step": 0}


# -- HeartbeatMonitor: removal + recovery reset --------------------------------
def test_heartbeat_remove_unpoisons_monitor():
    """One dead worker must be removable; otherwise check() re-raises for
    it forever and recovery can never be acknowledged."""
    mon = HeartbeatMonitor(timeout=1.0)
    mon.beat("cxl-c", now=0.0)
    mon.beat("cxl-a", now=4.9)
    with pytest.raises(WorkerFailure):
        mon.check(now=5.0)
    assert mon.remove("cxl-c") is True
    mon.check(now=5.0)  # recovery acknowledged: no re-raise
    assert mon.remove("cxl-c") is False  # already deregistered


def test_heartbeat_forgive_restarts_window():
    mon = HeartbeatMonitor(timeout=1.0)
    mon.beat("w0", now=0.0)
    assert mon.dead_workers(now=2.0) == ["w0"]
    mon.forgive("w0", now=2.0)
    mon.check(now=2.5)
    assert mon.dead_workers(now=3.5) == ["w0"]  # the clock restarted


# -- StragglerMitigator: redispatch result + EWMA ------------------------------
def test_straggler_failed_original_does_not_shadow_backup():
    """When the stalled original dies and the backup succeeds, the backup's
    result must win — an arbitrary first-completed pick re-raises the
    original's exception over a perfectly good answer."""
    strag = StragglerMitigator(threshold=3.0, min_timeout=0.05)
    for _ in range(5):
        assert strag.run(lambda: 42) == 42  # prime the EWMA fast
    calls = itertools.count()

    def flaky():
        if next(calls) == 0:  # the original: stalls, then dies
            time.sleep(0.15)
            raise RuntimeError("original dispatch died mid-stall")
        time.sleep(0.3)  # the backup: slower, but healthy
        return 7

    assert strag.run(flaky) == 7
    assert strag.stats.redispatched == 1

    # Only when EVERY dispatch fails does the exception propagate.
    def doomed():
        time.sleep(0.25)
        raise ValueError("both dispatches fail")

    with pytest.raises(ValueError):
        strag.run(doomed)
    strag.close()


def test_straggler_ewma_tracks_winner_not_stall():
    """The latency estimate must reflect the winning dispatch's own
    runtime; folding the stall's wall clock (deadline wait + backup) into
    the EWMA inflates every later deadline."""
    strag = StragglerMitigator(threshold=3.0, alpha=1.0, min_timeout=0.05)
    strag.run(lambda: time.sleep(0.01) or 1)
    once = itertools.count()

    def stall_then_fast():
        if next(once) == 0:
            time.sleep(0.4)
        return 2

    assert strag.run(stall_then_fast) == 2
    assert strag.stats.redispatched == 1
    # alpha=1: the estimate IS the winner's own latency (near-instant
    # backup), not the >= 0.05 s stall wall clock.
    assert strag.stats.median_estimate < 0.04
    strag.close()


# -- topology: hot-remove / hot-add --------------------------------------------
def test_topology_remove_add_roundtrip():
    topo = paper_three_device_topology()
    shrunk = topo.remove_device("cxl-c")
    assert shrunk.slow_names == ("cxl-a", "cxl-b")
    # the departed device stays ledger-visible for queued descriptors
    assert [t.name for t in shrunk.extra] == ["cxl-c"]
    assert sum(shrunk.bandwidth_weights()) == pytest.approx(1.0)
    back = shrunk.add_device("cxl-c")  # promoted back from ``extra``
    assert back.slow_names == topo.slow_names
    assert back.extra == ()
    gone = topo.remove_device("cxl-b", keep_visible=False)
    assert all(t.name != "cxl-b" for t in gone.extra)
    # a registry name also resolves (fresh device, never seen before)
    wide = topo.add_device("ddr5-r1")
    assert wide.slow_names[-1] == "ddr5-r1"


def test_topology_remove_add_errors():
    topo = paper_three_device_topology()
    with pytest.raises(ValueError):
        topo.remove_device(topo.fast.name)
    with pytest.raises(KeyError):
        topo.remove_device("nope")
    with pytest.raises(ValueError):
        topo.add_device(CXL_A)  # already a placement target
    with pytest.raises(KeyError):
        topo.add_device("nope")


# -- perfmodel degradation registry --------------------------------------------
def test_perfmodel_degradation_scales_entry_points():
    base_bw = perfmodel.stream_bandwidth(CXL_A, OpClass.LOAD, 8)
    base_rnd = perfmodel.random_block_bandwidth(CXL_A, OpClass.LOAD, 64, 4)
    base_lat = perfmodel.chase_seconds(CXL_A, 1000)
    other = perfmodel.stream_bandwidth(CXL_B, OpClass.LOAD, 8)
    try:
        perfmodel.set_degradation("cxl-a", bw_scale=0.5, latency_scale=2.0)
        assert perfmodel.stream_bandwidth(CXL_A, OpClass.LOAD, 8) == \
            pytest.approx(base_bw * 0.5)
        assert perfmodel.random_block_bandwidth(
            CXL_A, OpClass.LOAD, 64, 4) < base_rnd
        assert perfmodel.chase_seconds(CXL_A, 1000) == \
            pytest.approx(base_lat * 2.0)
        # absolute multipliers, not compounding: re-setting is idempotent
        perfmodel.set_degradation("cxl-a", bw_scale=0.5, latency_scale=2.0)
        assert perfmodel.stream_bandwidth(CXL_A, OpClass.LOAD, 8) == \
            pytest.approx(base_bw * 0.5)
        # untouched devices see nothing
        assert perfmodel.stream_bandwidth(CXL_B, OpClass.LOAD, 8) == other
        # same-device transfers stay in the C2C class under degradation
        # (the paper's slowest route: both sides share one controller)
        same = perfmodel.bulk_move_cost(CXL_A, CXL_A, 1 << 20)
        cross = perfmodel.bulk_move_cost(CXL_A, CXL_B, 1 << 20)
        assert same.seconds > cross.seconds
        # unity multipliers clear the entry
        perfmodel.set_degradation("cxl-a", bw_scale=1.0, latency_scale=1.0)
        assert perfmodel.degradation("cxl-a") is None
    finally:
        perfmodel.clear_degradations()
    assert perfmodel.stream_bandwidth(CXL_A, OpClass.LOAD, 8) == base_bw
    with pytest.raises(ValueError):
        perfmodel.set_degradation("cxl-a", bw_scale=0.0)


# -- FaultInjector -------------------------------------------------------------
def test_fault_injector_kill_and_revive_via_heartbeats():
    mon = HeartbeatMonitor(timeout=1.0)
    inj = FaultInjector(mon)
    devs = ("cxl-a", "cxl-b", "cxl-c")
    inj.beat_alive(devs, now=0.0)
    mon.check(now=0.5)
    inj.kill("cxl-c")
    inj.beat_alive(devs, now=2.0)  # the dead device goes silent
    with pytest.raises(WorkerFailure) as ei:
        mon.check(now=2.0)
    assert "cxl-c" in str(ei.value)
    mon.remove("cxl-c")  # the elastic shrink path deregisters it
    mon.check(now=2.5)
    inj.revive("cxl-c")  # re-add: forgiven, beats resume
    inj.beat_alive(devs, now=3.0)
    mon.check(now=3.5)
    assert [a for _, a, _ in inj.log] == ["kill", "revive"]


def test_fault_injector_schedule_and_context_cleanup():
    base = perfmodel.stream_bandwidth(CXL_B, OpClass.LOAD, 4)
    with FaultInjector() as inj:
        inj.schedule(3, "degrade", "cxl-b", bw_scale=0.25) \
           .schedule(5, "restore", "cxl-b")
        assert inj.apply(0) == []
        assert [e.action for e in inj.apply(3)] == ["degrade"]
        assert perfmodel.stream_bandwidth(CXL_B, OpClass.LOAD, 4) == \
            pytest.approx(base * 0.25)
        assert inj.apply(3) == []  # events fire once
        inj.apply(5)
        assert perfmodel.stream_bandwidth(CXL_B, OpClass.LOAD, 4) == base
        inj.degrade("cxl-b", bw_scale=0.5)  # left dangling on purpose
    # context exit lifts every degradation this injector installed
    assert perfmodel.stream_bandwidth(CXL_B, OpClass.LOAD, 4) == base


# -- InterleavedTensor: drain conservation -------------------------------------
def test_interleaved_drain_conserves_pages_and_bits(key):
    topo = paper_three_device_topology()
    names = (topo.fast.name,) + topo.slow_names
    t = InterleavedTensor.from_array(
        jax.random.normal(key, (64, 4)),
        MemPolicy.weighted(names, (5, 1, 1, 1)), page_rows=4)
    before = np.asarray(t.to_array())
    counts = t.valid_page_counts()
    assert counts[3] > 0  # the departing device actually holds pages
    tel = Telemetry()
    with BulkMover(topo, asynchronous=False, telemetry=tel) as mover:
        drained = t.drain_device("cxl-c", mover=mover, telemetry=tel)
    assert drained.weights()[2] == 0.0
    assert drained.valid_page_counts()[3] == 0
    # page conservation: nothing lost, nothing invented
    assert sum(drained.valid_page_counts()) == sum(counts)
    np.testing.assert_array_equal(np.asarray(drained.to_array()), before)
    # the drain billed real dead->survivor routes, byte-for-byte
    moved = sum(tel.route("cxl-c", d).bytes_moved
                for d in ("cxl-a", "cxl-b", topo.fast.name))
    assert moved == counts[3] * 4 * 4 * before.dtype.itemsize
    with pytest.raises(KeyError):
        t.drain_device("nope")


# -- CaptionController: elastic walk -------------------------------------------
def _converge(ctl, tput_fn, epochs=256):
    for _ in range(epochs):
        ctl.observe(EpochMetrics(throughput=tput_fn(ctl.weights)))
        if ctl.converged:
            break
    return ctl


def test_caption_remove_reseeds_and_reopens():
    topo = paper_three_device_topology()
    ctl = CaptionController(topo, CaptionConfig(probe_epochs=1),
                            initial_weights=(0.1, 0.2, 0.3))
    _converge(ctl, lambda w: 100.0)  # flat landscape: fast convergence
    assert ctl.converged
    total = ctl.fraction
    ctl.remove_device("cxl-b")
    assert ctl.topology.slow_names == ("cxl-a", "cxl-c")
    assert ctl.n_slow == len(ctl.weights) == 2
    # total slow share preserved, re-seeded bandwidth-proportionally
    assert sum(ctl.weights) == pytest.approx(total)
    bw = ctl.topology.bandwidth_weights()
    assert list(ctl.weights) == pytest.approx([total * b for b in bw])
    assert not ctl.converged  # the walk re-opened on the survivors
    _converge(ctl, lambda w: 100.0)
    assert ctl.converged  # ... and re-converges on the shrunken simplex
    with pytest.raises(KeyError):
        ctl.remove_device("nope")
    ctl.remove_device("cxl-a")
    with pytest.raises(ValueError):
        ctl.remove_device("cxl-c")  # never remove the last slow device


def test_caption_add_probes_new_coordinate_first():
    topo = TierTopology(fast=DDR5_L8, slows=(CXL_A, CXL_B))
    ctl = CaptionController(topo, CaptionConfig(probe_epochs=1),
                            initial_weights=(0.2, 0.1))
    # peaked objective: the walk holds an interior optimum (total ~0.3),
    # leaving simplex headroom for the newcomer to climb into
    _converge(ctl, lambda w: 100.0 - abs(sum(w) - 0.3) * 100.0)
    held = tuple(ctl.weights)
    ctl.add_device(CXL_C)
    assert ctl.topology.slow_names == ("cxl-a", "cxl-b", "cxl-c")
    # survivors keep their converged point; the newcomer enters at zero
    assert tuple(ctl.weights) == held + (0.0,)
    assert ctl.active_slow_device == "cxl-c"
    assert not ctl.converged
    d = ctl.observe(EpochMetrics(throughput=100.0))
    assert d.weights[2] > 0.0  # the next probe climbs the new coordinate


def test_degradation_drift_reopens_converged_walk():
    """A bandwidth fault the injector installs shows up in the slow-route
    counters; the EWMA drift detector must re-open a converged walk."""
    topo = TierTopology(fast=DDR5_L8, slows=(CXL_A, CXL_B))
    ctl = CaptionController(
        topo, CaptionConfig(probe_epochs=1, drift_threshold=0.3))
    _converge(ctl, lambda w: 100.0)
    assert ctl.converged

    def slow_bw():
        return sum(perfmodel.stream_bandwidth(d, OpClass.LOAD, 4)
                   for d in topo.slows)

    base = slow_bw()
    for _ in range(3):  # establish the drift reference at the hold point
        d = ctl.observe(EpochMetrics(throughput=100.0, slow_bw=base))
        assert ctl.converged
    with FaultInjector() as inj:
        inj.degrade("cxl-a", bw_scale=0.2)
        d = ctl.observe(EpochMetrics(throughput=60.0, slow_bw=slow_bw()))
    assert "drift" in d.reason
    assert not ctl.converged


# -- CaptionArbiter: elastic budgets -------------------------------------------
def test_arbiter_elastic_budgets():
    topo = paper_three_device_topology()
    arb = CaptionArbiter(topo)  # defaults to per-device nt-store budgets
    assert set(arb.cfg.device_budgets) == {"cxl-a", "cxl-b", "cxl-c"}
    arb.register("kv", CaptionController(topo, CaptionConfig(probe_epochs=1)))
    # a dead device's billed demand must not keep gating the survivors
    arb._entries["kv"].demand_dev.update({"cxl-a": 1e9, "cxl-c": 2e9})
    arb.remove_device("cxl-c")
    assert arb.topology.slow_names == ("cxl-a", "cxl-b")
    assert "cxl-c" not in (arb.cfg.device_budgets or {})
    assert "cxl-c" not in arb._entries["kv"].demand_dev
    arb.add_device("cxl-c")
    assert arb.topology.slow_names == ("cxl-a", "cxl-b", "cxl-c")
    assert arb.cfg.device_budgets["cxl-c"] == pytest.approx(CXL_C.nt_store_bw)


# -- ServingEngine: kill -> drain -> recover -> re-add -------------------------
def _tiny_engine(key, topo, tel, mover=None, caption=None):
    from repro.models import registry
    from repro.serving.engine import ServingEngine
    arch = registry.get("internvl2-2b").tiny()
    params = arch.module.init(arch.cfg, key)
    names = (topo.fast.name,) + topo.slow_names
    return ServingEngine(
        arch.cfg, params, max_batch=2, max_len=32,
        policy=MemPolicy.weighted(names, (5, 1, 1, 1)), topology=topo,
        page_t=4, caption=caption, mover=mover, telemetry=tel)


def test_engine_drain_keeps_latency_slot_fast(key):
    """Hot-removing a device mid-run: the latency-SLO slot stays all-fast,
    the dead device empties, billed drain bytes equal its page population,
    and every request still completes (zero drops, zero timeouts)."""
    topo = paper_three_device_topology()
    tel = Telemetry()
    with BulkMover(topo, asynchronous=False, telemetry=tel) as mover:
        eng = _tiny_engine(key, topo, tel, mover=mover)
        eng.submit([5, 6, 7], max_new_tokens=10, slo="latency")
        eng.submit([5, 6, 7], max_new_tokens=10)
        for _ in range(3):
            eng.step()
        assert eng.pinned_slots == {0}
        dev = np.asarray(eng.cache.page_device)
        assert (dev[0] == 0).all()  # SLO slot pinned fast
        dead_pages = int((dev[1] == 3).sum())
        assert dead_pages > 0
        item = eng.cache.k_fast.dtype.itemsize
        L = eng.cache.k_fast.shape[0]
        K, hd = eng.cache.k_fast.shape[3:]
        page_kv_bytes = 2 * L * eng.cache.page_t * K * hd * item
        # route totals include the SLO pin's earlier migration: the drain
        # audit below is the DELTA billed from the dead device
        routes = ("cxl-a", "cxl-b", topo.fast.name)
        pre = {d: tel.route("cxl-c", d).bytes_moved for d in routes}

        eng.remove_device("cxl-c")
        dev = np.asarray(eng.cache.page_device)
        assert (dev[0] == 0).all()      # the drain never touched the pin
        assert not (dev == 3).any()     # the dead device is empty
        assert dev.shape == (2, 8)      # page population conserved
        billed = sum(tel.route("cxl-c", d).bytes_moved - pre[d]
                     for d in routes)
        assert billed == dead_pages * page_kv_bytes
        assert eng.topology.slow_names == ("cxl-a", "cxl-b")
        assert mover.topology.slow_names == ("cxl-a", "cxl-b")
        with pytest.raises(KeyError):
            eng.remove_device("nope")

        done = eng.run_until_drained()
        assert sorted(r.rid for r in done) == [0, 1]
        assert all(len(r.generated) == 10 for r in done)

        eng.add_device("cxl-c")  # hot re-add restores the placement target
        assert eng.topology.slow_names == ("cxl-a", "cxl-b", "cxl-c")
        assert eng._device_names == (topo.fast.name,) + topo.slow_names


def test_engine_kill_drain_recover_same_tokens(key):
    """Full degraded-mode path: a FaultInjector kill silences a device's
    heartbeats, the monitor flags it, recovery drains it through the
    elastic path, the controller re-seeds on the survivors, and the
    generated tokens are identical to a run with no kill at all."""
    topo = paper_three_device_topology()

    def run(kill: bool):
        tel = Telemetry()
        mon = HeartbeatMonitor(timeout=1.5)
        ctl = CaptionController(
            topo, CaptionConfig(epoch_steps=2, probe_epochs=1))
        with BulkMover(topo, asynchronous=False, telemetry=tel) as mover, \
                FaultInjector(mon) as inj:
            eng = _tiny_engine(key, topo, tel, mover=mover, caption=ctl)
            for _ in range(3):
                eng.submit([5, 6, 7], max_new_tokens=8)
            steps, recovered = 0, []
            while eng.queue or any(eng.slots):
                steps += 1
                now = float(steps)
                eng.step()
                inj.beat_alive(topo.slow_names, now=now)
                if kill and steps == 4:
                    inj.kill("cxl-c")
                try:
                    mon.check(now=now)
                except WorkerFailure:
                    for name in mon.dead_workers(now=now):
                        eng.remove_device(name, monitor=mon)
                        recovered.append(name)
            mon.check(now=float(steps))  # the monitor is not poisoned
            if kill:
                inj.revive("cxl-c")
                eng.add_device("cxl-c")
            return (eng, recovered,
                    sorted((r.rid, tuple(r.generated)) for r in eng.done))

    eng_kill, recovered, toks_kill = run(kill=True)
    _, none_recovered, toks_clean = run(kill=False)
    assert recovered == ["cxl-c"] and none_recovered == []
    assert toks_kill == toks_clean  # zero dropped requests, exact tokens
    assert len(toks_kill) == 3
    # the control plane healed: controller and engine span 3 devices again
    assert eng_kill.caption.topology.slow_names == topo.slow_names
    assert eng_kill.caption.active_slow_device == "cxl-c"
    assert eng_kill.topology.slow_names == topo.slow_names


def test_kv_cache_drain_rejects_bad_targets(key):
    from repro.models import registry
    from repro.serving.kv_cache import TieredKVCache
    arch = registry.get("internvl2-2b").tiny()
    topo = paper_three_device_topology()
    names = (topo.fast.name,) + topo.slow_names
    cache = TieredKVCache.create(
        arch.cfg, 2, 32, MemPolicy.weighted(names, (5, 1, 1, 1)), page_t=4)
    with pytest.raises(ValueError):
        cache.drain_device("cxl-b", weights=(0.2, 0.2, 0.0),
                           telemetry=Telemetry())
    with pytest.raises(KeyError):
        cache.drain_device("nope", telemetry=Telemetry())
    with pytest.raises(KeyError):
        cache.drain_device(0, telemetry=Telemetry())  # fast is not drainable
    drained = cache.drain_device("cxl-b", telemetry=Telemetry())
    assert drained.weights()[1] == 0.0
    assert sum(drained.weights()) == pytest.approx(sum(cache.weights()))
