"""Per-architecture smoke tests: reduced config, one forward + train step
on CPU, asserting output shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry

ARCHS = list(registry.ARCH_IDS)


def _batch(cfg, key, B=2, S=32):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_padded)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_ctx, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.vision.n_prefix_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCHS)
def test_forward_shapes_and_finite(arch_id, key):
    arch = registry.get(arch_id).tiny()
    cfg, mod = arch.cfg, arch.module
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    params = mod.init(cfg, key)
    kwargs = {}
    if cfg.family == "audio":
        kwargs["frames"] = batch["frames"]
    if cfg.family == "vlm":
        kwargs["prefix_embeds"] = batch["prefix_embeds"]
    logits = mod.forward(cfg, params, batch["tokens"], **kwargs)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ARCHS)
def test_one_train_step(arch_id, key):
    """Gradients are finite and a step changes the loss deterministically."""
    from repro.optim import adamw
    arch = registry.get(arch_id).tiny()
    cfg, mod = arch.cfg, arch.module
    batch = _batch(cfg, key)
    params = mod.init(cfg, key)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    state = adamw.init_state(params)
    loss0, grads = jax.value_and_grad(
        lambda p: mod.loss(cfg, p, batch, remat=True))(params)
    gnorm = adamw.global_norm(grads)
    assert np.isfinite(float(loss0)) and np.isfinite(float(gnorm))
    params2, state2, metrics = adamw.apply(opt_cfg, params, grads, state)
    loss1 = mod.loss(cfg, params2, batch)
    assert np.isfinite(float(loss1))
    assert float(loss1) < float(loss0)  # one step on the same batch improves


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ARCHS)
def test_decode_matches_forward(arch_id, key):
    """KV-cache/recurrent decode replay is numerically identical to the
    parallel forward (the core serving invariant)."""
    arch = registry.get(arch_id).tiny()
    cfg, mod = arch.cfg, arch.module
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, min(cfg.vocab_padded, 200))
    if cfg.family == "audio":
        frames = jax.random.normal(key, (B, cfg.encoder.n_ctx, cfg.d_model))
        full = mod.forward(cfg, params := mod.init(cfg, key), toks, frames=frames)
        enc = mod.encode(cfg, params, frames)
        xk, xv = mod.prepare_cross(cfg, params, enc)
        cache = mod.init_cache(cfg, B, S)
        cache["xk"], cache["xv"] = xk, xv
    else:
        params = mod.init(cfg, key)
        kwargs = {}
        if cfg.family == "vlm":
            kwargs = {}
        full = mod.forward(cfg, params, toks)
        cache = mod.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = mod.decode_step(cfg, params, cache, toks[:, t])
        outs.append(lg)
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full)))
    assert err < 5e-3, f"{arch_id}: decode diverges from forward by {err}"


@pytest.mark.parametrize("arch_id", ARCHS)
def test_param_count_matches_claim(arch_id):
    """Analytical param count lands within 12% of the advertised size."""
    cfg = registry.get_config(arch_id)
    claimed = {
        "qwen2.5-32b": 32e9, "starcoder2-3b": 3e9, "qwen1.5-32b": 32e9,
        "stablelm-12b": 12e9, "recurrentgemma-9b": 9e9, "internvl2-2b": 2e9,
        "rwkv6-7b": 7e9, "llama4-maverick-400b-a17b": 400e9,
        "deepseek-moe-16b": 16e9, "whisper-large-v3": 1.5e9,
    }[arch_id]
    assert abs(cfg.param_count() - claimed) / claimed < 0.12


def test_moe_active_params():
    cfg = registry.get_config("llama4-maverick-400b-a17b")
    assert abs(cfg.active_param_count() - 17e9) / 17e9 < 0.1
    cfg = registry.get_config("deepseek-moe-16b")
    assert abs(cfg.active_param_count() - 2.8e9) / 2.8e9 < 0.15
