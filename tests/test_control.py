"""Control-plane tests: noise-robust dueling probes (paired comparisons,
shrink patience, adaptive step sizing), arbiter joint propose/commit
rounds, and classifier-seeded controller construction (§6.1 taxonomy on
the seeding path)."""
import numpy as np
import pytest

from repro.core.arbiter import ArbiterConfig, CaptionArbiter
from repro.core.caption import CaptionConfig, CaptionController, EpochMetrics
from repro.core.classifier import AccessProfile, Boundedness
from repro.core.tiers import paper_topology, tpu_v5e_topology
from repro.serving.engine import kv_access_profile
from repro.models import registry

from benchmarks.fig8_dlrm import throughput as _fig8_throughput
from benchmarks.fig11_caption import snc_topology as _snc_topology

DUEL_CFG = CaptionConfig(probe_epochs=2, step=0.05, min_step=0.01,
                         hysteresis=0.01, duel_count=3)


def _tput(topo, f, threads=32):
    return _fig8_throughput(topo.fast, topo.slow, f, threads)


# -- config validation ---------------------------------------------------------
def test_duel_config_validation():
    with pytest.raises(ValueError):
        CaptionConfig(duel_count=-1)
    with pytest.raises(ValueError):
        CaptionConfig(step_expand=0.5)
    with pytest.raises(ValueError):
        CaptionConfig(max_step=0.0)


# -- dueling probes ------------------------------------------------------------
def test_dueling_converges_on_clean_hill():
    """Without noise the dueling walk lands where the legacy walk does."""
    topo = _snc_topology()
    ctl = CaptionController(topo, DUEL_CFG, initial_fraction=0.0)
    for _ in range(256):
        ctl.observe(EpochMetrics(throughput=_tput(topo, ctl.fraction)))
        if ctl.converged:
            break
    assert ctl.converged
    assert abs(ctl.fraction - 0.205) <= 0.05, ctl.fraction


def test_dueling_stays_fast_when_fast_tier_has_headroom():
    """TPU regime: the candidate loses every duel, the walk reverses
    into the bound and holds at zero — dueling keeps the Fig. 7 answer."""
    topo = tpu_v5e_topology()
    ctl = CaptionController(topo, DUEL_CFG, initial_fraction=0.0)
    for _ in range(256):
        ctl.observe(EpochMetrics(throughput=_tput(topo, ctl.fraction)))
        if ctl.converged:
            break
    assert ctl.converged and ctl.fraction == pytest.approx(0.0)


def test_dueling_beats_single_sample_under_noise():
    """The tentpole claim at test scale: seed-averaged cumulative regret
    of the dueling walk is strictly below the single-sample climb on the
    same noisy hill (one unlucky window parks the single-sample walk at
    f=0; paired duels average the noise down and retry)."""
    topo = _snc_topology()
    best_t = max(_tput(topo, f) for f in np.linspace(0, 0.6, 121))

    def regret(seed, duels):
        rng = np.random.default_rng(seed)
        cfg = CaptionConfig(probe_epochs=2, step=0.05, min_step=0.01,
                            hysteresis=0.01, duel_count=duels)
        ctl = CaptionController(topo, cfg, initial_fraction=0.0)
        total = 0.0
        for _ in range(220):
            t = _tput(topo, ctl.fraction)
            total += (best_t - t) / best_t
            ctl.observe(EpochMetrics(
                throughput=t * (1 + rng.normal(0, 0.06))))
        return total, ctl.fraction

    seeds = (0, 1, 2)
    single = [regret(s, 0) for s in seeds]
    duel = [regret(s, 3) for s in seeds]
    assert (sum(r for r, _ in duel) / len(seeds)
            < sum(r for r, _ in single) / len(seeds)), (duel, single)
    # and the dueling walk never gets stuck away from the optimum
    for _, f in duel:
        assert abs(f - 0.205) <= 0.05, f


def test_dueling_adaptive_step_expands_on_win_streak():
    """Consecutive accepted duels expand the probe step (bounded by
    max_step); a monotone hill makes every duel a clean win."""
    topo = _snc_topology()
    cfg = CaptionConfig(probe_epochs=1, step=0.05, min_step=0.01,
                        hysteresis=0.01, duel_count=1, step_expand=2.0,
                        max_step=0.2, max_fraction=0.95)
    ctl = CaptionController(topo, cfg, initial_fraction=0.0)
    expanded = []
    for _ in range(64):
        # strictly increasing in f: every candidate wins its duel
        d = ctl.observe(EpochMetrics(throughput=1.0 + ctl.fraction))
        if "step up to" in d.reason:
            expanded.append(d.reason)
    assert expanded, "win streak never expanded the step"
    # the expansion respects the cap
    assert ctl._step <= cfg.max_step + 1e-12


def test_dueling_shrink_patience_retries_before_halving():
    """A single tied duel does not halve the step: the decision log
    shows a retry at the same step before any shrink."""
    topo = _snc_topology()
    ctl = CaptionController(topo, DUEL_CFG, initial_fraction=0.0)
    rng = np.random.default_rng(5)
    reasons = []
    for _ in range(220):
        t = _tput(topo, ctl.fraction) * (1 + rng.normal(0, 0.06))
        reasons.append(ctl.observe(EpochMetrics(throughput=t)).reason)
        if ctl.converged:
            break
    assert ctl.converged
    joined = "\n".join(reasons)
    assert "reject (retry)" in joined


# -- arbiter joint moves -------------------------------------------------------
def _joint_arbiter(budget=10e9):
    topo = _snc_topology()
    arb = CaptionArbiter(topo, ArbiterConfig(slow_bw_budget=budget,
                                             joint_moves=True))
    cfg = CaptionConfig(probe_epochs=1, step=0.05, min_step=0.01,
                        hysteresis=0.01)
    a = arb.register("a", CaptionController(topo, cfg))
    b = arb.register("b", CaptionController(topo, cfg))
    return topo, arb, a, b


def test_joint_moves_freeze_unilateral_growth():
    topo, arb, a, b = _joint_arbiter()
    d = None
    for _ in range(3):
        d = arb.observe("a", EpochMetrics(throughput=1.0 + a.fraction),
                        slow_bw=1e9)
    assert a.fraction == pytest.approx(0.0)  # growth is gated off
    assert "joint-move round" in d.reason
    # ... until a joint round grants it
    grants = arb.joint_move()
    assert grants.get("a", 0.0) > 0.0
    assert a.fraction == pytest.approx(grants["a"])


def test_joint_move_respects_budget_headroom():
    """Grants are sized so granted_fraction x cost never exceeds the
    remaining budget headroom."""
    topo, arb, a, b = _joint_arbiter(budget=10e9)
    # bill demand so cost estimates are real: a at 9.5e9 of 10e9 budget
    arb.observe("a", EpochMetrics(throughput=1.0), slow_bw=9.5e9)
    arb.observe("b", EpochMetrics(throughput=1.0), slow_bw=0.0)
    # force fractions so cost = demand/fraction is defined
    a.actuated(0.1)
    b.actuated(0.1)
    grants = arb.joint_move()
    headroom = 10e9 - arb.aggregate_demand_bw()
    cost_a = 9.5e9 / 0.1
    spent = sum(g * (cost_a if n == "a" else cost_a) for n, g in grants.items())
    # cold b borrows the fleet-average cost (= a's), so both price the same
    assert spent <= headroom * (1 + 1e-9) + 1e-6
    assert arb.history[-1]["joint_grants"] == grants


def test_joint_move_orders_by_utility_per_cost():
    """With equal costs, the scarce headroom goes to the buffer whose
    marginal utility is higher; the loser gets the remainder."""
    topo, arb, a, b = _joint_arbiter(budget=10e9)
    arb.observe("a", EpochMetrics(throughput=1.0), slow_bw=4.0e9)
    arb.observe("b", EpochMetrics(throughput=1.0), slow_bw=4.0e9)
    a.actuated(0.2)
    b.actuated(0.2)
    # headroom 2e9; cost 2e10/point each -> only 0.1 points to grant;
    # both propose 0.05 -> high-utility buffer is served first in full
    grants = arb.joint_move(utilities={"a": 1.0, "b": 100.0})
    assert grants["b"] == pytest.approx(0.05)
    assert grants["a"] == pytest.approx(0.05)  # remainder still affords it
    # tighter headroom: only the high-utility buffer is served
    topo2, arb2, a2, b2 = _joint_arbiter(budget=10e9)
    arb2.observe("a", EpochMetrics(throughput=1.0), slow_bw=4.7e9)
    arb2.observe("b", EpochMetrics(throughput=1.0), slow_bw=4.7e9)
    a2.actuated(0.2)
    b2.actuated(0.2)
    grants2 = arb2.joint_move(utilities={"a": 1.0, "b": 100.0})
    assert grants2["b"] > 0.0
    assert grants2.get("a", 0.0) < grants2["b"]


def test_joint_move_skips_converged_and_latency_bound():
    topo, arb, a, b = _joint_arbiter()
    # converge a (no growth appetite), keep b eligible
    a._move_to(tuple(a.weights), type(a.phase).CONVERGED, "test hold")
    grants = arb.joint_move()
    assert "a" not in grants
    prof = AccessProfile(1e6, 1e6, dependent_chain=64, parallelism=1,
                         granularity=64, deadline_seconds=50e-6)
    lat = arb.register("lat", CaptionController.from_profile(
        prof, topo, CaptionConfig(probe_epochs=1)))
    assert lat.latency_bound
    assert "lat" not in arb.joint_move()


def test_commit_joint_restores_step_and_local_shrink_reverts_bad_grants():
    """A grant restores the probe step (walk stays alive while grants
    flow); a grant that lands past the optimum is walked back by the
    ungated local climb."""
    topo = tpu_v5e_topology()  # any slow share hurts: worst-case grant
    cfg = CaptionConfig(probe_epochs=1, step=0.05, min_step=0.01,
                        hysteresis=0.01)
    ctl = CaptionController(topo, cfg, initial_fraction=0.0)
    ctl._step = 0.011  # nearly annealed out
    d = ctl.commit_joint(0.1)
    assert d.changed and ctl.fraction == pytest.approx(0.1)
    assert ctl._step >= cfg.step  # restored
    for _ in range(128):
        ctl.observe(EpochMetrics(throughput=_tput(topo, ctl.fraction)))
        if ctl.converged:
            break
    assert ctl.converged
    assert ctl.fraction <= 0.05, ctl.fraction  # bad grant reverted


# -- classifier-seeded construction (§6.1 on the seeding path) -----------------
def test_from_profile_pins_latency_bound_buffers_fast():
    topo = paper_topology()
    # µs-deadline dependent chain: Redis-shaped, latency-bound vs CXL
    prof = AccessProfile(1e6, 1e6, dependent_chain=64, parallelism=1,
                         granularity=64, deadline_seconds=50e-6)
    ctl = CaptionController.from_profile(prof, topo,
                                         initial_fraction=0.5)
    assert ctl.boundedness == Boundedness.LATENCY_BOUND
    assert ctl.latency_bound
    assert ctl.fraction == pytest.approx(0.0)  # fast-pin seeding
    assert ctl.min_fraction == pytest.approx(0.0)
    # the guardrail keeps it monotone-fast afterwards
    for _ in range(8):
        ctl.observe(EpochMetrics(throughput=1.0))
    assert ctl.fraction == pytest.approx(0.0)


def test_from_profile_keeps_prior_for_bandwidth_bound():
    topo = paper_topology()
    prof = AccessProfile(100e9, 0, dependent_chain=1, parallelism=1024,
                         granularity=4 << 20, compute_seconds=0.1)
    ctl = CaptionController.from_profile(prof, topo,
                                         initial_fraction=0.3,
                                         min_fraction=0.1)
    assert ctl.boundedness == Boundedness.BANDWIDTH_BOUND
    assert not ctl.latency_bound
    assert ctl.fraction == pytest.approx(0.3)
    assert ctl.min_fraction == pytest.approx(0.1)


def test_kv_access_profile_shape():
    """The serving driver's KV profile: streaming reads dominate, writes
    are one row per step, parallelism is batch x kv heads."""
    cfg = registry.get("starcoder2-3b").tiny().cfg
    prof = kv_access_profile(cfg, max_batch=4, max_len=64, page_t=16)
    row = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.resolved_head_dim * 4
    assert prof.bytes_written_per_step == pytest.approx(row * 4)
    assert prof.bytes_read_per_step == pytest.approx(row * 4 * 64)
    assert prof.dependent_chain == 1
    assert prof.parallelism == 4 * cfg.n_kv_heads
    assert prof.granularity >= 16 * cfg.resolved_head_dim * 4
