"""Substrate tests: data pipeline, optimizer (+offload), checkpointing,
fault tolerance (bit-exact recovery), straggler mitigation, elastic
re-mesh, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis, with fallback

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.policy import MemPolicy
from repro.core.tiers import paper_topology, tpu_v5e_topology
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw, compression, offload, schedules
from repro.runtime.elastic import choose_mesh, replan
from repro.runtime.fault_tolerance import (HeartbeatMonitor, ResilientLoop,
                                           WorkerFailure)
from repro.runtime.straggler import StragglerMitigator


# -- data ---------------------------------------------------------------------
def test_pipeline_deterministic():
    cfg = DataConfig(vocab=100, batch=4, seq=16, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    for s in (0, 7, 123):
        np.testing.assert_array_equal(p1.batch_at(s)["tokens"],
                                      p2.batch_at(s)["tokens"])


def test_pipeline_shards_differ():
    a = TokenPipeline(DataConfig(100, 4, 16, seed=3, shard_id=0, num_shards=2))
    b = TokenPipeline(DataConfig(100, 4, 16, seed=3, shard_id=1, num_shards=2))
    assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])


def test_pipeline_file_backed(tmp_path):
    toks = np.arange(10_000, dtype=np.uint32)
    f = tmp_path / "tokens.bin"
    toks.tofile(f)
    p = TokenPipeline(DataConfig(vocab=50_000, batch=2, seq=32, path=str(f)))
    b = p.batch_at(0)
    assert b["tokens"].shape == (2, 32)
    # labels are next-token shifted views of the same stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_prefetch_matches():
    p = TokenPipeline(DataConfig(100, 2, 8, seed=1))
    it = p.iter_from(0, prefetch=True)
    for s in range(4):
        np.testing.assert_array_equal(next(it)["tokens"], p.batch_at(s)["tokens"])


# -- optimizer -----------------------------------------------------------------
def test_adamw_decreases_loss(key):
    w = jax.random.normal(key, (16, 4))
    x = jax.random.normal(jax.random.fold_in(key, 1), (64, 16))
    y = x @ jax.random.normal(jax.random.fold_in(key, 2), (16, 4))
    params = {"w": w}
    cfg = adamw.AdamWConfig(lr=3e-2, weight_decay=0.0)
    state = adamw.init_state(params)
    loss = lambda p: jnp.mean((x @ p["w"] - y) ** 2)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply(cfg, params, g, state)
    assert float(loss(params)) < 0.05 * l0


def test_tiered_adamw_matches_fused(key):
    params = {"big": jax.random.normal(key, (3_000_000,), jnp.float32),
              "small": jax.random.normal(key, (64,), jnp.float32)}
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(key, p.shape) * 0.01, params)
    cfg = adamw.AdamWConfig(lr=1e-3, schedule=schedules.constant())
    p1, s1, _ = adamw.apply(cfg, params, grads, adamw.init_state(params))
    opt = offload.TieredAdamW(cfg, slow_fraction=0.9)
    st = opt.init(params)
    assert list(st["slow"]) and opt.host_bytes(st) > 0
    p2, st2, m = opt.step(params, grads, st)
    for k in params:
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                   atol=1e-6)
    assert m["offload_bytes"] > 0


@given(st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_compression_error_feedback_property(seed):
    """quant + residual carries the full signal: recon + new_r == g + r."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=200) * rng.uniform(0.01, 100), jnp.float32)
    r = jnp.asarray(rng.normal(size=200) * 0.01, jnp.float32)
    q, s, new_r = compression.compress_with_feedback(g, r)
    recon = compression.dequantize_int8(q, s) + new_r
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g + r),
                               rtol=1e-5, atol=1e-5)


def test_compression_converges_with_feedback():
    """Repeated compressed steps track the true sum (no bias accumulation)."""
    rng = np.random.default_rng(0)
    total_true, total_q = np.zeros(64), np.zeros(64)
    r = jnp.zeros(64)
    for _ in range(100):
        g = jnp.asarray(rng.normal(size=64), jnp.float32)
        q, s, r = compression.compress_with_feedback(g, r)
        total_q += np.asarray(compression.dequantize_int8(q, s))
        total_true += np.asarray(g)
    assert np.abs(total_q - total_true).max() < np.abs(total_true).max() * 0.05 + 0.5


# -- checkpointing ---------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"a": jax.random.normal(key, (8, 8)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32)}}
    ck = Checkpointer(str(tmp_path), keep=2, asynchronous=True)
    ck.save(10, tree, metadata={"rng": 7})
    ck.save(20, tree)
    ck.wait()
    step, restored, meta = ck.restore(tree)
    assert step == 20
    np.testing.assert_array_equal(restored["a"], tree["a"])
    step, restored, meta = ck.restore(tree, step=10)
    assert meta["rng"] == 7


def test_checkpoint_gc(tmp_path, key):
    tree = {"a": jnp.ones((4,))}
    ck = Checkpointer(str(tmp_path), keep=2, asynchronous=False)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.available_steps() == [3, 4]


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": jnp.ones((16,))}
    ck = Checkpointer(str(tmp_path), asynchronous=False)
    ck.save(1, tree)
    # flip bytes in the stored leaf
    d = os.path.join(str(tmp_path), "step_1")
    fname = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, fname), "r+b") as f:
        f.seek(-4, 2)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(IOError):
        ck.restore(tree)


# -- fault tolerance ---------------------------------------------------------------
def test_heartbeat_monitor():
    mon = HeartbeatMonitor(timeout=1.0)
    mon.beat("w0", now=0.0)
    mon.beat("w1", now=0.5)
    assert mon.dead_workers(now=1.2) == ["w0"]
    with pytest.raises(WorkerFailure):
        mon.check(now=5.0)


def test_resilient_loop_bit_exact_recovery(tmp_path):
    """A mid-run failure + restore replays to the exact same final state."""
    def make_loop():
        return ResilientLoop(Checkpointer(str(tmp_path), asynchronous=False),
                             checkpoint_every=5)

    def step_fn(state, step):
        x = state["x"]
        return {"x": x * 1.5 + step}

    clean = {"x": np.float64(1.0), "step": 0}
    expect = ResilientLoop(
        Checkpointer(str(tmp_path / "clean"), asynchronous=False),
        checkpoint_every=5).run(dict(clean), step_fn, 20)

    fired = []
    def injector(step):
        if step == 13 and not fired:
            fired.append(step)
            raise WorkerFailure("injected node loss at step 13")

    out = make_loop().run({"x": np.float64(1.0), "step": 0}, step_fn, 20,
                          failure_injector=injector)
    assert fired == [13]
    np.testing.assert_allclose(float(out["x"]), float(expect["x"]))


def test_straggler_redispatch():
    import itertools
    strag = StragglerMitigator(threshold=3.0, min_timeout=0.05)
    calls = itertools.count()
    def fast():
        next(calls)
        return 42
    for _ in range(5):
        assert strag.run(fast) == 42
    import time as _t
    slow_first = iter([0.5, 0.0])
    def sometimes_slow():
        _t.sleep(next(slow_first, 0.0))
        return 7
    assert strag.run(sometimes_slow) == 7
    assert strag.stats.redispatched >= 1
    strag.close()


# -- elastic -----------------------------------------------------------------------
def test_choose_mesh_divisibility():
    m = choose_mesh(512, model_parallel_hint=16, pods=2)
    assert m.shape == (2, 16, 16)
    m = choose_mesh(448, model_parallel_hint=16, pods=1)
    assert m.data * m.model == 448


def test_replan_shrink_spills_to_slow():
    """Losing chips shrinks fast-tier budget; the planner absorbs it by
    re-weighting pages toward the slow tier (the paper's N:M knob)."""
    from repro.core.classifier import AccessProfile
    from repro.core.planner import BufferReq
    from repro.core.policy import BufferClass
    old = choose_mesh(512, pods=2)
    reqs = [BufferReq("opt", BufferClass.OPT_STATE, 10 << 30, AccessProfile(
        10e9, 10e9, 1, 1024, 2 << 20, 0.05))]
    ep = replan(old, 448, reqs, tpu_v5e_topology(), compute_seconds=0.05,
                reserve_fast_bytes=8 << 30)
    assert ep.new_mesh.n_chips == 448
    assert ep.placement.ledger.used("hbm") <= tpu_v5e_topology().fast.capacity_bytes
    assert any(m.kind == "repartition" for m in ep.moves)


# -- serving ------------------------------------------------------------------------
def test_engine_tiered_vs_fast_same_tokens(key):
    """Token outputs are identical whatever the tier split (exact merge)."""
    from repro.models import registry
    from repro.serving.engine import ServingEngine
    arch = registry.get("internvl2-2b").tiny()
    params = arch.module.init(arch.cfg, key)
    outs = []
    for frac in (0.0, 0.5):
        eng = ServingEngine(arch.cfg, params, max_batch=2, max_len=32,
                            policy=MemPolicy.from_slow_fraction("fast", "slow", frac),
                            topology=paper_topology(), page_t=8)
        for _ in range(3):
            eng.submit([5, 6, 7], max_new_tokens=5)
        done = eng.run_until_drained()
        outs.append(sorted((r.rid, tuple(r.generated)) for r in done))
    assert outs[0] == outs[1]
    # and the slow split models a higher per-step cost
    assert len(outs[0]) == 3


def test_tiered_adamw_int8_moments_converge():
    """8-bit-Adam-style moment paging (sqrt-domain nu) still optimizes and
    halves tier traffic (llama4 §Perf iteration)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (256, 64))
    y = x @ jax.random.normal(key, (64, 4))
    params = {"w": jnp.zeros((64 * 4,), jnp.float32)}
    cfg = adamw.AdamWConfig(lr=3e-2, weight_decay=0.0,
                            schedule=schedules.constant())
    opt = offload.TieredAdamW(cfg, slow_fraction=1.0, min_offload_bytes=64,
                              quantize_moments=True)
    st = opt.init(params)
    assert list(st["slow"].values())[0].quantized
    loss = lambda p: jnp.mean((x @ p["w"].reshape(64, 4) - y) ** 2)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, st, _ = opt.step(params, g, st)
    assert float(loss(params)) < 0.05 * l0
    t8 = opt.traffic_per_step_bytes(st)
    opt32 = offload.TieredAdamW(cfg, slow_fraction=1.0, min_offload_bytes=64)
    t32 = opt32.traffic_per_step_bytes(
        opt32.init({"w": jnp.zeros((64 * 4,), jnp.float32)}))
    assert t8 < 0.55 * t32


def test_wkv_chunked_matches_exact():
    """Chunked (TPU-blocked) WKV == exact scan across the decay range."""
    from repro.models import rwkv
    key = jax.random.PRNGKey(0)
    B, T, H, hd = 2, 64, 2, 16
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd))
    r, k, v = mk(0) * 0.5, mk(1) * 0.5, mk(2)
    w = jnp.exp(-jnp.exp(jax.random.uniform(key, (B, T, H, hd),
                                            minval=-8.0, maxval=1.5)))
    u = mk(4)[0, 0] * 0.1
    s0 = jax.random.normal(jax.random.fold_in(key, 9), (B, H, hd, hd)) * 0.1
    y1, s1 = rwkv.wkv_scan(r, k, v, w, u, s0)
    y2, s2 = rwkv.wkv_chunked(r, k, v, w, u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
