"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles
(interpret=True executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis, with fallback

from repro.kernels.decode_attention import ops as da_ops, ref as da_ref
from repro.kernels.embedding_reduce import ops as er_ops, ref as er_ref
from repro.kernels.stream_copy import ops as sc_ops, ref as sc_ref
from repro.kernels.wkv6 import ops as wkv_ops, ref as wkv_ref


@pytest.mark.parametrize("V,D,B,K", [(32, 64, 2, 4), (128, 128, 8, 16),
                                     (256, 256, 4, 32), (64, 512, 1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_reduce_sweep(V, D, B, K, dtype, key):
    table = jax.random.normal(key, (V, D), jnp.float32).astype(dtype)
    idx = jax.random.randint(key, (B, K), 0, V)
    w = jax.random.uniform(key, (B, K), jnp.float32)
    out = er_ops.embedding_reduce(table, idx, w)
    ref = er_ref.embedding_reduce(table, idx, w)
    tol = 1e-4 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=0.05)


@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_embedding_reduce_property(seed):
    """Kernel == oracle for arbitrary index multisets incl. duplicates."""
    rng = np.random.default_rng(seed)
    V, D = 64, 128
    B, K = int(rng.integers(1, 6)), int(rng.integers(1, 12))
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, size=(B, K)))
    w = jnp.asarray(rng.uniform(size=(B, K)), jnp.float32)
    np.testing.assert_allclose(
        er_ops.embedding_reduce(table, idx, w),
        er_ref.embedding_reduce(table, idx, w), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape,block", [((256, 64), 64), ((512, 128), 256),
                                         ((128, 32), 128)])
@pytest.mark.parametrize("dtype,out_dtype", [
    (jnp.float32, None), (jnp.float32, jnp.bfloat16), (jnp.bfloat16, None)])
def test_stream_copy_sweep(shape, block, dtype, out_dtype, key):
    x = jax.random.normal(key, shape, jnp.float32).astype(dtype)
    out = sc_ops.stream_copy(x, out_dtype=out_dtype, block_rows=block)
    ref = sc_ref.stream_copy(x, out_dtype)
    assert out.dtype == ref.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("shape", [(1, 8), (7, 16), (100, 16), (300, 8),
                                   (513, 4), (5, 1), (64, 3), (1024, 128)])
@pytest.mark.parametrize("block", [4, 64, 256])
def test_stream_copy_ragged_sweep(shape, block, key):
    """Row counts need not divide ``block_rows``: the double-buffered
    migration kernel ships the ragged tail through its dedicated staging
    slot, overlapped with the full-chunk pipeline (ISSUE 7)."""
    x = jax.random.normal(key, shape, jnp.float32)
    out = sc_ops.stream_copy(x, block_rows=block)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    # fused dtype casts on the same ragged shapes, both directions
    down = sc_ops.stream_copy(x, out_dtype=jnp.bfloat16, block_rows=block)
    np.testing.assert_array_equal(
        np.asarray(down), np.asarray(sc_ref.stream_copy(x, jnp.bfloat16)))
    xb = x.astype(jnp.bfloat16)
    up = sc_ops.stream_copy(xb, out_dtype=jnp.float32, block_rows=block)
    np.testing.assert_array_equal(
        np.asarray(up), np.asarray(sc_ref.stream_copy(xb, jnp.float32)))


@pytest.mark.parametrize("B,H,K,hd,T,block", [
    (2, 8, 2, 32, 128, 32), (1, 4, 4, 64, 256, 64),
    (3, 8, 1, 16, 64, 64), (2, 16, 16, 32, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, K, hd, T, block, dtype, key):
    q = jax.random.normal(key, (B, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, K, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, K, hd),
                          jnp.float32).astype(dtype)
    lengths = jnp.asarray(np.random.default_rng(0).integers(1, T + 1, size=B))
    out = da_ops.decode_attention(q, k, v, lengths, block_t=block)
    ref = da_ref.decode_attention(q, k, v, lengths)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=tol, rtol=tol * 10)


def test_decode_attention_ragged_lengths(key):
    """Blocks past each row's length contribute nothing (skip correctness)."""
    B, H, K, hd, T = 4, 4, 2, 16, 256
    q = jax.random.normal(key, (B, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, K, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, K, hd))
    lengths = jnp.array([1, 17, 100, 256])
    out = da_ops.decode_attention(q, k, v, lengths, block_t=64)
    ref = da_ref.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("B,T,H,hd,block", [
    (2, 64, 2, 16, 16), (1, 128, 4, 32, 64), (2, 32, 1, 64, 32)])
def test_wkv6_sweep(B, T, H, hd, block, key):
    r = jax.random.normal(key, (B, T, H, hd)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, hd)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 3),
                                         (B, T, H, hd))) * 0.5 + 0.5
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))
    y1, s1 = wkv_ops.wkv6(r, k, v, w, u, s0, block_t=block)
    y2, s2 = wkv_ref.wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_wkv6_state_carry(key):
    """Chunked kernel with carried state == one long exact scan."""
    B, T, H, hd = 1, 64, 2, 16
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd))
    r, k, v = mk(0) * 0.5, mk(1) * 0.5, mk(2)
    w = jax.nn.sigmoid(mk(3)) * 0.4 + 0.6
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))
    # two kernel calls of T/2 with carried state
    y_a, s_a = wkv_ops.wkv6(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u, s0,
                            block_t=16)
    y_b, s_b = wkv_ops.wkv6(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, s_a,
                            block_t=16)
    y_full, s_full = wkv_ref.wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_b), np.asarray(s_full), atol=1e-4)
