"""Shared-prefix paged KV (ISSUE 8): reference sharing, copy-on-write
divergence, survival across re-tiering, refcount-safe eviction, and the
cost-model admission / migration-overlap engine paths."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.caption import CaptionConfig, CaptionController
from repro.core.policy import MemPolicy
from repro.models import registry
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import _INT32_MAX, TieredKVCache
from repro.serving.prefix_cache import PrefixCache


def _setup(arch_id="starcoder2-3b", seed=0):
    arch = registry.get(arch_id).tiny()
    cfg = arch.cfg
    params = arch.module.init(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _engine(cfg, params, *, prefix_pages=16, slow=0.5, **kw):
    policy = MemPolicy.from_slow_fraction("fast", "slow", slow)
    return ServingEngine(cfg, params, max_batch=3, max_len=64,
                         policy=policy, page_t=8,
                         prefix_pages=prefix_pages, **kw)


def _prompts(cfg, n=6, pre_len=24, suf_len=5, seed=7):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_padded, size=pre_len).tolist()
    return [pre + rng.integers(0, cfg.vocab_padded, size=suf_len).tolist()
            for _ in range(n)]


def test_identical_prompts_share_pages_with_refcounts():
    cfg, params = _setup()
    eng = _engine(cfg, params)
    prompt = _prompts(cfg, n=1)[0]
    eng.submit(prompt, max_new_tokens=4)
    eng.run_until_drained()
    assert eng.prefix_index.allocated_pages() == 3  # 24 prefix tokens / 8
    assert eng.prefill_tokens_avoided == 0  # first request seeds the pool

    # two identical prompts in flight: both reference the SAME pool pages
    eng.submit(prompt, max_new_tokens=8)
    eng.submit(prompt, max_new_tokens=8)
    eng.step()
    sp = np.asarray(eng.cache.prefix.slot_pages)
    refs0 = sorted(int(p) for p in sp[0] if p >= 0)
    refs1 = sorted(int(p) for p in sp[1] if p >= 0)
    assert refs0 == refs1 and len(refs0) == 3
    rc = eng.prefix_index.page_refcounts()
    assert all(rc[p] == 2 for p in refs0)
    assert eng.prefix_index.dedup_pages() == 3  # one stored, one saved
    eng.run_until_drained()
    assert all(c == 0 for c in eng.prefix_index.page_refcounts().values())
    assert eng.prefill_tokens_avoided >= 2 * 24


def test_sharing_and_cow_match_unshared_decode():
    """Shared / CoW attention must reproduce the no-sharing engine's
    generated tokens exactly, including prompts diverging mid-page."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    pre = rng.integers(0, cfg.vocab_padded, size=20).tolist()  # 2.5 pages
    prompts = [pre + rng.integers(0, cfg.vocab_padded, size=7).tolist()
               for _ in range(5)]

    def run(prefix_pages):
        eng = _engine(cfg, params, prefix_pages=prefix_pages)
        for p in prompts:
            eng.submit(p, max_new_tokens=6)
        done = eng.run_until_drained()
        return eng, {r.rid: r.generated for r in done}

    e0, base = run(0)
    e1, shared = run(16)
    assert base == shared
    assert e1.prefill_tokens_avoided > 0
    # prompts share 20 tokens but full pages cover only 16: the tail 4
    # rows arrive by copy-on-write into each diverging slot's own tier
    assert e1.prefix_index.cow_copies >= 1
    assert e1.decode_traces == 1  # attach/detach never change the treedef


def test_shared_pages_survive_repartition_and_drain():
    cfg, params = _setup()
    policy = MemPolicy.from_tier_fractions("fast", ["cxl-a", "cxl-b"],
                                           [0.25, 0.25])
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, policy=policy,
                        page_t=8, prefix_pages=8)
    prompt = _prompts(cfg, n=1, pre_len=16, suf_len=4)[0]
    eng.submit(prompt, max_new_tokens=4)
    eng.run_until_drained()
    eng.submit(prompt, max_new_tokens=12)
    eng.step()
    assert int(np.asarray(eng.cache.prefix.slot_shared)[0]) == 16

    def no_revived_rows(cache):
        shared = np.asarray(cache.prefix.slot_shared)
        for p in cache.pos_parts:
            pn = np.asarray(p)
            assert not ((pn < shared[:, None]) & (pn != _INT32_MAX)).any()

    tok_before = list(eng.slots[0].generated)
    eng.cache = eng.cache.repartition_fraction(0.75, telemetry=None)
    no_revived_rows(eng.cache)
    eng.step()
    eng.cache = eng.cache.drain_device("cxl-a", telemetry=None)
    no_revived_rows(eng.cache)
    done = eng.run_until_drained()
    # decode across both re-tierings matches the undisturbed engine
    ref = _engine(cfg, params, prefix_pages=0, slow=0.5)
    ref.submit(prompt, max_new_tokens=4)
    ref.run_until_drained()
    ref.submit(prompt, max_new_tokens=12)
    ref_done = ref.run_until_drained()
    assert done[-1].generated == ref_done[-1].generated
    assert done[-1].generated[:len(tok_before)] == tok_before


def test_eviction_never_frees_referenced_pages():
    idx = PrefixCache(pool_pages=4, page_t=4)
    live = list(range(0, 12))  # 3 pages
    nodes = idx.insert(live + [99], [])
    assert len(nodes) == 3
    idx.acquire([n for _, n in nodes])
    referenced = {n.page for _, n in nodes}
    # a fourth page fills the pool; further inserts must only ever evict
    # refcount-zero leaves — the referenced chain survives every attempt
    for seed in range(5):
        other = [1000 + seed * 16 + i for i in range(17)]
        idx.insert(other, [])
        assert referenced <= set(idx.nodes.keys())
    assert idx.evictions > 0
    m, _, _ = idx.match(live + [99])
    assert [n.page for n in m] == [n.page for _, n in nodes]
    idx.release(m)


def test_prefix_storage_deduplicated_reads_per_reference():
    cfg, params = _setup()
    eng = _engine(cfg, params, slow=0.0)
    prompt = _prompts(cfg, n=1)[0]
    eng.submit(prompt, max_new_tokens=4)
    eng.run_until_drained()
    page_b = eng.cache._page_kv_bytes()
    store0 = eng.cache.storage_bytes_per_device()["fast"]
    reads0 = eng.cache.read_bytes_per_device()["fast"]
    eng.submit(prompt, max_new_tokens=8)
    eng.submit(prompt, max_new_tokens=8)
    eng.step()
    sp = np.asarray(eng.cache.prefix.slot_pages)
    assert (sp >= 0).sum() == 6  # 2 slots x 3 shared pages, by reference
    # reads bill PER REFERENCE (every reader streams the shared rows)...
    reads1 = eng.cache.read_bytes_per_device()["fast"]
    assert reads1 - reads0 == 6 * page_b
    # ...but storage bills each shared page ONCE: the referencing slots'
    # own rows below the boundary are sentineled holes, so attaching two
    # 3-page references REMOVES 6 private pages from occupied storage.
    store1 = eng.cache.storage_bytes_per_device()["fast"]
    assert store0 - store1 == 6 * page_b
    pdev = np.asarray(eng.cache.prefix.page_device)
    assert (pdev >= 0).sum() == 3  # the pool holds each page exactly once


def test_admission_defers_batch_requests_under_pin_pressure():
    from repro.core.tiers import paper_topology
    cfg, params = _setup()
    topo = paper_topology()
    item = 2 * cfg.n_layers * 64 * cfg.n_kv_heads * cfg.resolved_head_dim * 4
    eng = ServingEngine(
        cfg, params, max_batch=3, max_len=64,
        policy=MemPolicy.from_slow_fraction("fast", "slow", 0.0),
        page_t=8, topology=topo, admission="cost",
        admission_capacity_bytes=int(item * 1.5), admission_max_defer=6)
    prompts = _prompts(cfg, n=4, pre_len=8, suf_len=4)
    eng.submit(prompts[0], max_new_tokens=16, slo="latency")
    for p in prompts[1:]:
        eng.submit(p, max_new_tokens=4, slo="batch")
    done = eng.run_until_drained()
    assert len(done) == 4  # starvation bound: everyone completes
    assert eng.admission_deferrals > 0


def test_overlap_engine_accounts_hidden_migration_time():
    from repro.core.mover import BulkMover
    from repro.core.telemetry import Telemetry
    from repro.core.tiers import paper_topology
    cfg, params = _setup()
    topo = paper_topology()
    mover = BulkMover(topo, asynchronous=True, batch_size=16)
    tel = Telemetry()
    try:
        eng = ServingEngine(
            cfg, params, max_batch=3, max_len=64,
            policy=MemPolicy.from_slow_fraction(topo.fast.name,
                                                topo.slow.name, 0.5),
            page_t=8, topology=topo, mover=mover, telemetry=tel,
            prefix_pages=8, overlap=True)
        for p in _prompts(cfg, n=3, pre_len=16, suf_len=4):
            eng.submit(p, max_new_tokens=8)
        eng.step()
        # actuate a re-tier WITHOUT fencing (the overlap issue path)...
        b0 = mover.bytes_submitted
        eng.cache = eng.cache.repartition_fraction(
            0.25, pinned_slots=eng.pinned_slots, mover=mover,
            telemetry=tel, fast_tier=topo.fast.name,
            slow_tier=topo.slow.name, wait=False)
        eng._account_actuation(mover.bytes_submitted - b0, 0.0)
        assert eng._inflight_move_bytes > 0
        # ...decode keeps running while the drain pool streams the copy
        for _ in range(4):
            eng.step()
        assert eng._inflight_compute_s > 0
        eng._drain_migrations()
        assert eng.migration_hidden_s > 0  # move time hid under decode
        assert mover.pending == 0
        counters = tel.snapshot()["counters"]
        assert counters.get("migration_hidden_s", 0) > 0
        # generated tokens are unaffected by the unfenced migration
        done = eng.run_until_drained()
        ref = _engine(cfg, params, prefix_pages=8)
        for p in _prompts(cfg, n=3, pre_len=16, suf_len=4):
            ref.submit(p, max_new_tokens=8)
        ref_done = ref.run_until_drained()
        assert ([r.generated for r in done]
                == [r.generated for r in ref_done])
    finally:
        mover.close()
