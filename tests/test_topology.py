"""N-device tier topology tests: the TierTopology type and presets, the
page->device map invariants of InterleavedTensor under repeated weight-
vector repartitions, mover route purity and per-device writer tracking
with >= 3 devices, arbiter per-device budget enforcement, planner
per-device fractions + arbiter-aware seeding, Caption's weight-vector
walk + workload-shift re-probing, the minimal-delta no-op guarantee,
and the two-device back-compat shim."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.testing import given, settings, st  # hypothesis, with fallback

from repro.core.arbiter import ArbiterConfig, CaptionArbiter
from repro.core.caption import CaptionConfig, CaptionController, EpochMetrics
from repro.core.classifier import AccessProfile
from repro.core.interleave import (InterleavedTensor, minimal_delta_weights,
                                   tier_page_map)
from repro.core.mover import BulkMover, Descriptor
from repro.core.planner import BufferReq, plan
from repro.core.policy import BufferClass, MemPolicy
from repro.core.telemetry import EpochWindow, Telemetry
from repro.core.tiers import (CXL_A, CXL_B, CXL_C, DDR5_L8, TierTopology,
                              paper_three_device_topology, topology_from_spec,
                              tpu_v5e_topology)


def three_dev() -> TierTopology:
    return TierTopology(fast=DDR5_L8, slows=(CXL_A, CXL_B))


# -- TierTopology ---------------------------------------------------------------
def test_topology_two_device_back_compat():
    """The historical TierTopology(fast=..., slow=...) shape keeps working:
    .slow is the first slow device and .tiers includes extras."""
    topo = tpu_v5e_topology()
    assert topo.slow is not None and topo.slow.name == "host"
    assert topo.n_slow == 1
    assert [t.name for t in topo.tiers] == ["hbm", "host"]
    # sequence form
    topo3 = paper_three_device_topology()
    assert topo3.slow_names == ("cxl-a", "cxl-b", "cxl-c")
    assert topo3.slow.name == "cxl-a"  # primary = first
    assert topo3.device_index("cxl-b") == 2
    with pytest.raises(ValueError):
        TierTopology(fast=DDR5_L8, slow=CXL_A, slows=(CXL_A,))
    with pytest.raises(ValueError):  # duplicate names
        TierTopology(fast=DDR5_L8, slows=(CXL_A, CXL_A))


def test_topology_bandwidth_weights_and_spec():
    topo = paper_three_device_topology()
    w = topo.bandwidth_weights()
    assert len(w) == 3 and abs(sum(w) - 1.0) < 1e-9
    assert w[0] > w[1] > w[2]  # cxl-a is the fastest device
    t2 = topology_from_spec("ddr5-l8+cxl-a+cxl-b")
    assert t2.fast.name == "ddr5-l8" and t2.slow_names == ("cxl-a", "cxl-b")
    assert topology_from_spec("paper3").n_slow == 3
    with pytest.raises(ValueError, match="unknown device"):
        topology_from_spec("ddr5-l8+nope")


# -- page->device map invariants ------------------------------------------------
@given(st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_interleave_device_map_invariants_under_repartition(seed):
    """Under repeated random weight vectors: the device map matches the
    shard sizes, local indices are a bijection, values are preserved, and
    the realized weights hit the targets to page rounding."""
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(24, 96))
    x = jnp.asarray(rng.normal(size=(rows, 3)), jnp.float32)
    pol = MemPolicy.from_tier_fractions(
        "fast", ("cxl-a", "cxl-b", "cxl-c"), (0.2, 0.2, 0.1))
    it = InterleavedTensor.from_array(x, pol, page_rows=4)
    n = it.n_pages
    for _ in range(4):
        w = rng.uniform(0, 0.3, size=3)
        it = it.repartition_weights(tuple(w), telemetry=Telemetry())
        dev = np.asarray(it.page_device)
        local = np.asarray(it.page_local)
        # shard sizes match the map
        for i, part in enumerate(it.parts):
            count = int((dev == i).sum())
            assert part.shape[0] == count * it.page_rows
            # local indices within a device are 0..count-1, each once
            assert sorted(local[dev == i]) == list(range(count))
        # realized weights == targets after page rounding
        total_target = round(min(sum(w), 1.0) * n)
        assert int((dev >= 1).sum()) == total_target
        # numerical no-op
        assert np.allclose(np.asarray(it.to_array()), np.asarray(x))


def test_minimal_delta_weights_noop_and_counts():
    """The no-op guarantee: a weight vector that rounds to the current
    per-device counts returns None (no page churn, no mover work)."""
    cur = np.array([0, 1, 0, 2, 0, 1, 0, 2], np.int8)  # 4 fast, 2+2 slow
    assert minimal_delta_weights(cur, (0.25, 0.25), 3) is None
    out = minimal_delta_weights(cur, (0.5, 0.25), 3)
    assert out is not None
    counts = np.bincount(out, minlength=3)
    assert list(counts) == [2, 4, 2]
    # minimal moves: only the deficit count changes device
    assert int((out != cur).sum()) == 2


def test_repartition_weights_noop_enqueues_no_mover_work():
    x = jnp.arange(64.0).reshape(16, 4)
    pol = MemPolicy.from_tier_fractions("fast", ("cxl-a", "cxl-b"),
                                        (0.25, 0.25), denominator=4)
    it = InterleavedTensor.from_array(x, pol, page_rows=4)  # 4 pages
    tel = Telemetry()
    topo = three_dev()
    with BulkMover(topo, asynchronous=True, telemetry=tel) as mover:
        it2 = it.repartition_weights((0.25, 0.25), mover=mover,
                                     fast_tier="ddr5-l8")
    assert it2 is it  # same object: true no-op
    assert not tel.routes  # nothing moved, nothing billed
    # scalar shim: fraction that rounds to the current count is also free
    it3 = InterleavedTensor.from_array(x, MemPolicy.membind("fast"),
                                       page_rows=4)
    it4 = it3.repartition_fraction(0.1, telemetry=tel)  # rounds to 0 pages
    assert it4 is it3


def test_interleave_two_device_shim():
    """slow_fraction/page_tier/fast/slow keep their two-device semantics."""
    x = jnp.arange(64.0).reshape(16, 4)
    it = InterleavedTensor.from_array(x, MemPolicy.membind("fast"),
                                      page_rows=4)
    assert it.device_names == ("fast", "slow")
    it = it.repartition_fraction(0.5, telemetry=Telemetry())
    assert it.slow_fraction() == pytest.approx(0.5)
    assert int(np.asarray(it.page_tier).sum()) == 2
    assert it.fast.shape[0] == it.slow.shape[0] == 8
    # a 3-device tensor refuses the ambiguous .slow accessor
    it3 = InterleavedTensor.from_array(
        x, MemPolicy.from_tier_fractions("fast", ("a", "b"), (0.25, 0.25),
                                         denominator=4), page_rows=4)
    with pytest.raises(AttributeError):
        _ = it3.slow


# -- mover: route purity + per-device writers with >= 3 devices -----------------
def test_mover_route_purity_three_devices():
    """One submission across 3 slow devices: every batch is route-pure
    (per-route batch counts cover every descriptor) and per-device writer
    watermarks track independently."""
    topo = paper_three_device_topology()
    tel = Telemetry()
    with BulkMover(topo, asynchronous=True, batch_size=4, max_writers=2,
                   drain_workers=3, telemetry=tel) as mover:
        descs = []
        for dst in ("cxl-a", "cxl-b", "cxl-c"):
            descs += [Descriptor("ddr5-l8", dst, jnp.zeros((32,)))
                      for _ in range(6)]
        mover.submit(descs)
        mover.wait_all()
        for dst in ("cxl-a", "cxl-b", "cxl-c"):
            r = tel.route("ddr5-l8", dst)
            assert r.descriptors == 6
            assert r.batches == 2  # ceil(6/4) route-pure batches
            assert mover.take_peak_writers(dst) >= 1
        # per-device watermarks reset independently
        assert mover.take_peak_writers("cxl-a") == 0


def test_mover_writer_limit_is_per_device():
    """max_writers bounds concurrency PER slow device, not across the
    pool: three devices can have 3 concurrent writers total."""
    import threading
    topo = paper_three_device_topology()
    barrier = threading.Barrier(3)

    def rendezvous(payload):
        barrier.wait(timeout=10)
        return payload

    with BulkMover(topo, asynchronous=True, batch_size=1, max_writers=1,
                   drain_workers=3, telemetry=Telemetry(),
                   execute=rendezvous) as mover:
        mover.submit([Descriptor("ddr5-l8", dst, jnp.zeros((8,)))
                      for dst in ("cxl-a", "cxl-b", "cxl-c")])
        mover.wait_all()
        assert mover.take_peak_writers() == 3  # one per device, concurrent
        for dst in ("cxl-a", "cxl-b", "cxl-c"):
            assert mover.peak_by_dev[dst] == 1  # but never 2 on one device


# -- arbiter: per-device budgets ------------------------------------------------
def test_arbiter_default_multi_device_budgets():
    arb = CaptionArbiter(paper_three_device_topology())
    assert arb.cfg.device_budgets is not None
    assert set(arb.cfg.device_budgets) == {"cxl-a", "cxl-b", "cxl-c"}
    assert arb.cfg.slow_bw_budget == pytest.approx(
        sum(arb.cfg.device_budgets.values()))


def test_arbiter_per_device_budget_gates_only_saturated_device():
    """A buffer growing onto a saturated device is frozen; the same walk
    on a device with headroom still grows."""
    topo = three_dev()
    budgets = {"cxl-a": 1e9, "cxl-b": 50e9}
    arb = CaptionArbiter(topo, ArbiterConfig(
        slow_bw_budget=100e9, device_budgets=budgets))
    ctl = arb.register("buf", CaptionController(
        topo, CaptionConfig(probe_epochs=1, step=0.1)))
    assert ctl.active_slow_device == "cxl-a"  # coordinate 0 first
    # cxl-a saturated: growth on it must freeze
    for _ in range(6):
        d = arb.observe("buf", EpochMetrics(throughput=1.0),
                        slow_bw=2e9, device_bw={"cxl-a": 2e9})
    assert ctl.weights[0] == 0.0
    assert any("cxl-a at budget" in h["reason"] for h in arb.history)
    # force the walk onto cxl-b (headroom): growth proceeds
    ctl._coord = 1
    grew = False
    for _ in range(6):
        arb.observe("buf", EpochMetrics(throughput=1.0 + ctl.fraction),
                    slow_bw=2e9, device_bw={"cxl-a": 2e9})
        grew = grew or ctl.weights[1] > 0
    assert grew, ctl.weights


def test_arbiter_device_clip_pulls_back_saturated_share():
    topo = three_dev()
    arb = CaptionArbiter(topo, ArbiterConfig(
        slow_bw_budget=100e9, device_budgets={"cxl-a": 1e9, "cxl-b": 50e9},
        slack=0.0))
    ctl = arb.register("buf", CaptionController(
        topo, CaptionConfig(probe_epochs=1),
        initial_weights=(0.4, 0.2)))
    for _ in range(4):
        d = arb.observe("buf", EpochMetrics(throughput=1.0), slow_bw=8e9,
                        device_bw={"cxl-a": 8e9, "cxl-b": 0.5e9})
    assert ctl.weights[0] < 0.4  # the saturated device's share was cut
    assert ctl.weights[1] == pytest.approx(0.2)  # headroom share untouched
    assert any("device clip" in h["reason"] for h in arb.history)


# -- planner: per-device fractions + arbiter-aware seeding ----------------------
def _bw_req(name, nbytes, rps, wps=0.0):
    return BufferReq(name, BufferClass.OPT_STATE, int(nbytes),
                     AccessProfile(rps, wps, 1, 1024, 2 << 20, 0.05))


def test_planner_emits_device_fractions_bandwidth_proportional():
    snc = dataclasses.replace(DDR5_L8, name="snc-2ch", load_bw=55e9,
                              load_peak_streams=12, capacity_bytes=96 << 30)
    topo = TierTopology(fast=snc, slows=(CXL_A, CXL_B))
    p = plan([_bw_req("emb", 8 << 30, 55e9 * 1.3)], topo,
             compute_seconds=1.0)
    d = p.decisions["emb"]
    assert d.slow_fraction > 0.05
    assert set(d.device_fractions) <= {"cxl-a", "cxl-b"}
    assert sum(d.device_fractions.values()) == pytest.approx(
        d.slow_fraction)
    # bandwidth-proportional: the faster device carries the larger share
    assert d.device_fractions["cxl-a"] > d.device_fractions["cxl-b"]
    # capacity ledger accounts per device
    assert p.ledger.used("cxl-a") > 0 and p.ledger.used("cxl-b") > 0


def test_planner_multi_device_capacity_spill_order():
    """Overflow fills slow devices in declaration order, capacity-capped."""
    small_a = dataclasses.replace(CXL_A, capacity_bytes=4 << 30)
    topo = TierTopology(fast=tpu_v5e_topology().fast,
                        slows=(small_a, CXL_B))
    p = plan([_bw_req("opt", 28 << 30, 1e9, 1e9)], topo,
             compute_seconds=0.05)
    d = p.decisions["opt"]
    # 12 GiB overflow: 4 GiB fills cxl-a, the rest lands on cxl-b
    assert d.min_slow_fraction > 0.4
    assert p.ledger.used("cxl-a") <= small_a.capacity_bytes
    assert p.ledger.used("cxl-b") > 0


def test_planner_arbiter_aware_seeding_scales_under_budget():
    """When aggregate slow write demand exceeds the arbiter budget, the
    voluntary slow share is scaled under it at plan time; capacity floors
    are untouched."""
    snc = dataclasses.replace(DDR5_L8, name="snc-2ch", load_bw=55e9,
                              load_peak_streams=12, capacity_bytes=96 << 30)
    topo = TierTopology(fast=snc, slow=CXL_C)
    reqs = [_bw_req("a", 8 << 30, 40e9, 40e9),
            _bw_req("b", 8 << 30, 40e9, 40e9)]
    free = plan(reqs, topo, compute_seconds=0.5)
    budget = 30e9
    capped = plan(reqs, topo, compute_seconds=0.5, write_budget_bw=budget)
    assert any("arbiter-aware seeding" in n for n in capped.notes)
    assert sum(capped.slow_fraction(n) for n in ("a", "b")) < \
        sum(free.slow_fraction(n) for n in ("a", "b"))
    for n in ("a", "b"):
        assert capped.slow_fraction(n) <= free.slow_fraction(n) + 1e-9
        assert capped.slow_fraction(n) >= \
            capped.decisions[n].min_slow_fraction - 1e-9
    # seeded demand actually fits the budget, to one N:M round-up quantum
    # per buffer (1/64 of the write rate each)
    quantum = (1 / 64) * 40e9 * CXL_C.rfo_traffic_multiplier / 0.5
    rate = sum(capped.slow_fraction(n)
               * reqs[i].profile.bytes_written_per_step
               * CXL_C.rfo_traffic_multiplier / 0.5
               for i, n in enumerate(("a", "b")))
    assert rate <= budget + 2 * quantum
    # a budget that nothing exceeds changes nothing
    roomy = plan(reqs, topo, compute_seconds=0.5, write_budget_bw=1e15)
    for n in ("a", "b"):
        assert roomy.slow_fraction(n) == pytest.approx(free.slow_fraction(n))


# -- caption: weight vector + re-probing ----------------------------------------
def test_caption_weight_vector_respects_simplex_and_floor():
    topo = three_dev()
    ctl = CaptionController(topo, CaptionConfig(probe_epochs=1, step=0.2,
                                                max_fraction=0.5),
                            initial_weights=(0.2, 0.1), min_fraction=0.25)
    for _ in range(40):
        # always-improving signal tries to push the sum past the ceiling
        ctl.observe(EpochMetrics(throughput=1.0 + ctl.fraction))
    assert ctl.fraction <= 0.5 + 1e-9
    ctl2 = CaptionController(topo, CaptionConfig(probe_epochs=1, step=0.2),
                             initial_weights=(0.2, 0.1), min_fraction=0.25)
    for _ in range(40):
        # always-degrading signal tries to shrink below the capacity floor
        ctl2.observe(EpochMetrics(throughput=1.0 / (1.0 + ctl2.fraction)))
    assert ctl2.fraction >= 0.25 - 1e-9


def test_caption_two_device_scalar_shim():
    """On a single-slow topology the weight vector degenerates to the
    scalar walk: Decision.weights mirrors Decision.fraction."""
    topo = tpu_v5e_topology()
    ctl = CaptionController(topo, CaptionConfig(probe_epochs=1),
                            initial_fraction=0.3)
    d = ctl.observe(EpochMetrics(throughput=1.0))
    assert d.weights == (pytest.approx(d.fraction),)
    ctl.actuated(0.25)
    assert ctl.weights == [pytest.approx(0.25)]


def test_caption_drift_reopens_converged_walk():
    """Workload-shift re-probing: a converged controller whose slow-route
    EWMA bandwidth drifts past the threshold resets and re-converges to
    the new optimum."""
    topo = snc = None
    from benchmarks.fig11_caption import snc_topology
    topo = snc_topology()
    from benchmarks.fig8_dlrm import throughput as tp
    cfg = CaptionConfig(probe_epochs=2, step=0.05, min_step=0.01,
                        hysteresis=0.01, drift_threshold=0.3)
    ctl = CaptionController(topo, cfg)

    def optimum(phase2: bool) -> float:
        grid = np.linspace(0, 0.6, 121)
        f = [tp(topo.fast, topo.slow, float(x), 8 if phase2 else 32)
             for x in grid]
        return float(grid[int(np.argmax(f))])

    def run_epochs(n, threads, bw):
        for _ in range(n):
            t = tp(topo.fast, topo.slow, ctl.fraction, threads)
            ctl.observe(EpochMetrics(throughput=t, slow_bw=bw))

    run_epochs(64, 32, 10e9)  # phase 1: bandwidth-hungry, steady route bw
    assert ctl.converged
    f1 = ctl.fraction
    assert abs(f1 - optimum(False)) <= 0.05
    # phase 2: the workload shifts (fewer threads, route bw collapses)
    run_epochs(2, 8, 1e9)
    assert not ctl.converged  # drift re-opened the walk
    assert any("workload shift" in d.reason for d in ctl.history[-3:])
    run_epochs(96, 8, 1e9)  # steady again: re-converges to the new point
    assert ctl.converged
    assert abs(ctl.fraction - optimum(True)) <= 0.07
    # control: with drift detection disabled the controller never re-opens
    ctl3 = CaptionController(topo, dataclasses.replace(
        cfg, drift_threshold=0.0))
    for _ in range(64):
        ctl3.observe(EpochMetrics(
            throughput=tp(topo.fast, topo.slow, ctl3.fraction, 32),
            slow_bw=10e9))
    assert ctl3.converged
    for _ in range(8):
        ctl3.observe(EpochMetrics(throughput=1.0, slow_bw=1e9))
    assert ctl3.converged


# -- kv cache: weight-vector retile + device routes -----------------------------
def test_kv_cache_repartition_weights_and_device_routes(key):
    from repro.models import registry
    from repro.serving.kv_cache import TieredKVCache, tiered_decode_step
    arch = registry.get("internvl2-2b").tiny()
    cfg = arch.cfg
    params = arch.module.init(cfg, key)
    pol = MemPolicy.from_tier_fractions(
        "fast", ("cxl-a", "cxl-b"), (0.0, 0.0))
    cache = TieredKVCache.create(cfg, 2, 32, pol, page_t=8)
    # all-fast, but the device vocabulary survives the zero vector
    assert cache.device_names == ("fast", "cxl-a", "cxl-b")
    assert cache.slow_fraction() == 0.0
    # decode a few tokens, then re-tier onto two devices mid-sequence
    toks = jnp.asarray([3, 9], jnp.int32)
    cache_b = cache
    tel = Telemetry()
    outs = []
    for t in range(6):
        la, cache = tiered_decode_step(cfg, params, cache, toks)
        lb, cache_b = tiered_decode_step(cfg, params, cache_b, toks)
        if t == 2:
            cache_b = cache_b.repartition_weights(
                (0.25, 0.25), telemetry=tel)
        outs.append((np.asarray(la), np.asarray(lb)))
    for a, b in outs:
        np.testing.assert_allclose(a, b, atol=1e-4)
    dev = np.asarray(cache_b.page_device)
    assert (dev == 1).sum() == (dev == 2).sum() == 2  # 1 page/dev/slot
    # traffic billed on real device routes
    assert tel.route("fast", "cxl-a").bytes_moved > 0
    assert tel.route("fast", "cxl-b").bytes_moved > 0
    # no-op weights: same object, no new traffic
    before = dict(tel.routes)
    again = cache_b.repartition_weights((0.25, 0.25), telemetry=tel)
    assert again is cache_b
    assert dict(tel.routes) == before


def test_tier_page_map_collapses_devices_to_storage():
    assign = np.array([0, 1, 2, 3, 1, 0], np.int8)
    a01, local, counts = tier_page_map(assign)
    assert list(a01) == [0, 1, 1, 1, 1, 0]
    assert counts == [2, 4]
