"""Multi-worker BulkMover + CaptionArbiter tests: real writer concurrency
(the §6 semaphore exercised live, not synthetically), priority lanes,
lifecycle bugs (submit-after-close, mixed-route telemetry), the global
slow-tier bandwidth budget (convergence, latency priority, starvation
floor, capacity-floor clipping, per-source billing), and the serving
engine's per-request SLO classes."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.arbiter import ArbiterConfig, CaptionArbiter
from repro.core.caption import (CaptionConfig, CaptionController,
                                EpochMetrics)
from repro.core.classifier import Boundedness
from repro.core.mover import (LANE_BULK, LANE_LATENCY, BulkMover,
                              Descriptor)
from repro.core.policy import MemPolicy
from repro.core.telemetry import EpochWindow, Telemetry
from repro.core.tiers import tpu_v5e_topology


# -- multi-worker drain pool ---------------------------------------------------
def test_drain_pool_real_writer_concurrency():
    """With drain_workers=4 a barrier-based execute forces >= 2 descriptors
    in flight into the slow tier at once, so take_peak_writers() reports
    REAL concurrency — and the §6 writer-limit guardrail then freezes
    slow-fraction growth on those real (not synthetic) metrics."""
    topo = tpu_v5e_topology()
    barrier = threading.Barrier(2)

    def rendezvous(payload):
        barrier.wait(timeout=10)  # needs a second concurrent writer
        return payload

    tel = Telemetry()
    win = EpochWindow(tel)
    with BulkMover(topo, asynchronous=True, batch_size=1, max_writers=4,
                   drain_workers=4, telemetry=tel,
                   execute=rendezvous) as mover:
        descs = [Descriptor("hbm", "host", jnp.zeros((16,)))
                 for _ in range(8)]
        mover.submit(descs)
        mover.wait_all()
        peak = mover.peak_writers
        assert peak >= 2, peak

        # The guardrail fires from the mover's own watermark: growth of the
        # slow fraction is frozen while writers exceed the limit.
        ctl = CaptionController(
            topo, CaptionConfig(probe_epochs=1, step=0.1, writer_limit=1))
        d = ctl.observe_window(win, throughput=1.0, mover=mover)
        assert ctl.fraction == 0.0
        assert "writers" in d.reason, d.reason


def test_drain_pool_single_worker_serializes():
    """Control: one drain worker can never exceed one concurrent writer."""
    topo = tpu_v5e_topology()
    with BulkMover(topo, asynchronous=True, batch_size=2,
                   drain_workers=1) as mover:
        mover.submit([Descriptor("hbm", "host", jnp.zeros((64,)))
                      for _ in range(8)])
        mover.wait_all()
        assert mover.take_peak_writers() == 1


def test_priority_lane_jumps_bulk_traffic():
    """A latency-lane descriptor submitted after bulk traffic drains before
    the queued bulk batches (the lane is a real scheduling property)."""
    topo = tpu_v5e_topology()
    release = threading.Event()
    started = threading.Event()
    order = []

    def execute(payload):
        if not started.is_set():  # the first descriptor blocks the worker
            started.set()
            release.wait(timeout=10)
        return payload

    mover = BulkMover(topo, asynchronous=True, batch_size=1,
                      drain_workers=1, telemetry=Telemetry(),
                      execute=execute)
    try:
        # Occupy the single worker, then queue bulk, then a latency jumper.
        mover.submit([Descriptor("hbm", "host", jnp.zeros((8,)))])
        started.wait(timeout=10)
        mover.submit([Descriptor(
            "hbm", "host", jnp.zeros((8,)), lane=LANE_BULK,
            on_done=lambda r: order.append("bulk")) for _ in range(3)])
        mover.submit([Descriptor(
            "hbm", "host", jnp.zeros((8,)), lane=LANE_LATENCY,
            on_done=lambda r: order.append("latency"))])
        release.set()
        mover.wait_all()
    finally:
        release.set()
        mover.close()
    assert order[0] == "latency", order


def test_submit_after_close_raises():
    topo = tpu_v5e_topology()
    mover = BulkMover(topo, asynchronous=True)
    mover.close()
    with pytest.raises(RuntimeError, match="close"):
        mover.submit([Descriptor("hbm", "host", jnp.zeros((4,)))])


def test_mixed_route_batches_attribute_per_route():
    """Each route in one submission sees its own batch count — the old
    code billed every batch to batch[0]'s route."""
    topo = tpu_v5e_topology()
    tel = Telemetry()
    with BulkMover(topo, asynchronous=False, batch_size=8,
                   telemetry=tel) as mover:
        mover.submit(
            [Descriptor("hbm", "host", jnp.zeros((4,))) for _ in range(2)]
            + [Descriptor("host", "hbm", jnp.zeros((4,))) for _ in range(2)])
    assert tel.route("hbm", "host").batches == 1
    assert tel.route("host", "hbm").batches == 1
    assert tel.route("hbm", "host").descriptors == 2
    assert tel.route("host", "hbm").descriptors == 2


def test_sync_submit_preserves_submission_order():
    topo = tpu_v5e_topology()
    payloads = [jnp.full((8,), i, jnp.float32) for i in range(6)]
    routes = [("hbm", "host"), ("host", "hbm")] * 3  # interleaved routes
    with BulkMover(topo, asynchronous=False, batch_size=2,
                   telemetry=Telemetry()) as mover:
        comps = mover.submit([Descriptor(s, d, p)
                              for (s, d), p in zip(routes, payloads)])
    for p, c in zip(payloads, comps):
        assert np.allclose(p, c.result)


# -- arbiter: the global slow-tier bandwidth budget ----------------------------
def _greedy_metrics(ctl):
    """A workload whose modeled throughput always improves with more slow
    pages — an uncoordinated controller would climb forever."""
    return EpochMetrics(throughput=1.0 + ctl.fraction)


def test_arbiter_keeps_fleet_under_budget():
    topo = tpu_v5e_topology()
    budget = 10e9
    bw_per_fraction = 40e9  # each buffer's slow traffic scales with fraction
    arb = CaptionArbiter(topo, ArbiterConfig(slow_bw_budget=budget))
    ctls = [arb.register(f"buf{i}", CaptionController(
        topo, CaptionConfig(probe_epochs=1, step=0.1))) for i in range(3)]
    for _ in range(24):
        for i, c in enumerate(ctls):
            arb.observe(f"buf{i}", _greedy_metrics(c),
                        slow_bw=c.fraction * bw_per_fraction)
    assert arb.aggregate_demand_bw() <= budget * 1.05
    # ... and no controller was starved to zero: everyone got slow pages.
    assert all(c.fraction > 0 for c in ctls), [c.fraction for c in ctls]
    assert sum(arb.grants().values()) <= budget * 1.001


def test_arbiter_latency_bound_priority_and_floor():
    """Latency-bound demand is served first in full (Fig. 7); bandwidth
    buffers split the remainder but a quiet buffer keeps the floor share."""
    topo = tpu_v5e_topology()
    budget = 10e9
    arb = CaptionArbiter(topo, ArbiterConfig(slow_bw_budget=budget,
                                             starvation_floor=0.1))
    lat = arb.register("lat", CaptionController(
        topo, CaptionConfig(probe_epochs=1), initial_fraction=0.2,
        min_fraction=0.2, boundedness=Boundedness.LATENCY_BOUND))
    arb.register("loud", CaptionController(topo, CaptionConfig(probe_epochs=1)))
    arb.register("quiet", CaptionController(topo, CaptionConfig(probe_epochs=1)))
    arb.observe("lat", EpochMetrics(throughput=1.0), slow_bw=2e9)
    arb.observe("loud", EpochMetrics(throughput=1.0), slow_bw=50e9)
    arb.observe("quiet", EpochMetrics(throughput=1.0), slow_bw=0.1e9)
    g = arb.grants()
    assert g["lat"] == pytest.approx(2e9)  # served first, in full
    assert g["quiet"] >= 0.1 * budget * 0.999  # starvation floor
    assert g["loud"] + g["quiet"] <= budget - 2e9 + 1e-6
    assert g["loud"] > g["quiet"]  # proportional beyond the floor


def test_arbiter_clip_never_below_capacity_floor():
    """An over-budget buffer is clipped toward its grant but never below
    the planner's capacity floor (the spill minimum must stay resident)."""
    topo = tpu_v5e_topology()
    arb = CaptionArbiter(topo, ArbiterConfig(slow_bw_budget=1e9,
                                             starvation_floor=0.0))
    ctl = arb.register("opt", CaptionController(
        topo, CaptionConfig(probe_epochs=1), initial_fraction=0.5,
        min_fraction=0.4))
    arb.register("other", CaptionController(topo, CaptionConfig(probe_epochs=1)))
    arb.observe("other", EpochMetrics(throughput=1.0), slow_bw=0.9e9)
    for _ in range(8):  # way over budget: would clip to ~0 without the floor
        arb.observe("opt", EpochMetrics(throughput=1.0), slow_bw=20e9)
    assert ctl.fraction >= 0.4 - 1e-9
    assert ctl.fraction < 0.5  # but it WAS clipped


def test_arbiter_source_billing_from_window():
    """observe_window bills only the caller's source-attributed slow-tier
    bytes, so co-tenant traffic in a shared Telemetry is not double-billed."""
    topo = tpu_v5e_topology()
    tel = Telemetry()
    arb = CaptionArbiter(topo, ArbiterConfig(slow_bw_budget=10e9))
    arb.register("a", CaptionController(topo, CaptionConfig(probe_epochs=1)))
    arb.register("b", CaptionController(topo, CaptionConfig(probe_epochs=1)))
    arb.register("quiet", CaptionController(topo,
                                            CaptionConfig(probe_epochs=1)))
    win_a, win_b = EpochWindow(tel), EpochWindow(tel)
    win_q = EpochWindow(tel)
    tel.record_move("engine", "host", 3_000, 0.0, source="a")
    tel.record_move("engine", "host", 1_000, 0.0, source="b")
    arb.observe_window("a", win_a, throughput=1.0, slow_name="host",
                       seconds=1.0)
    arb.observe_window("b", win_b, throughput=1.0, slow_name="host",
                       seconds=1.0)
    # A buffer with no attributed traffic in a window that DID see others'
    # attribution must be billed zero, not its co-tenants' total.
    arb.observe_window("quiet", win_q, throughput=1.0, slow_name="host",
                       seconds=1.0)
    d = arb.demands()
    assert d["a"] == pytest.approx(3_000.0)
    assert d["b"] == pytest.approx(1_000.0)
    assert d["quiet"] == pytest.approx(0.0)


def test_arbiter_legacy_fallback_ignores_stale_source_keys():
    """Unattributed traffic still bills via the raw-route fallback even
    after some PAST window carried attribution (zero-delta source keys
    must not disable the legacy path)."""
    topo = tpu_v5e_topology()
    tel = Telemetry()
    arb = CaptionArbiter(topo, ArbiterConfig(slow_bw_budget=10e9))
    arb.register("legacy", CaptionController(topo,
                                             CaptionConfig(probe_epochs=1)))
    win = EpochWindow(tel)
    tel.record_move("engine", "host", 500, 0.0, source="other")
    win.tick(seconds=1.0)  # the attributed epoch closes
    tel.record_move("engine", "host", 2_000, 0.0)  # no source attribution
    arb.observe_window("legacy", win, throughput=1.0, slow_name="host",
                       seconds=1.0)
    assert arb.demands()["legacy"] == pytest.approx(2_000.0)


def test_arbiter_register_rejects_duplicates():
    topo = tpu_v5e_topology()
    arb = CaptionArbiter(topo)
    arb.register("kv", CaptionController(topo))
    with pytest.raises(ValueError, match="registered"):
        arb.register("kv", CaptionController(topo))


# -- serving engine SLO classes ------------------------------------------------
def test_kv_cache_pin_slot_excluded_from_repartition():
    from repro.models import registry
    from repro.serving.kv_cache import TieredKVCache
    arch = registry.get("internvl2-2b").tiny()
    cache = TieredKVCache.create(arch.cfg, 4, 32, MemPolicy.membind("fast"),
                                 page_t=8)
    shape_before = cache.k_fast.shape
    cache = cache.pin_slot(1, telemetry=Telemetry())
    # pinning rewrites index maps, never the fast part's shape (no jit
    # retrace / reallocation on the latency admission path)
    assert cache.k_fast.shape == shape_before
    cache = cache.repartition_fraction(0.5, pinned_slots={1},
                                       telemetry=Telemetry())
    tiers = np.asarray(cache.page_tier)
    assert tiers[1].sum() == 0  # pinned slot stays all-fast
    for b in (0, 2, 3):
        assert tiers[b].mean() == pytest.approx(0.5)
    # the reported operating point covers only the tunable population
    assert cache.slow_fraction(pinned_slots={1}) == pytest.approx(0.5)
    # unpinned again: the slot rejoins the repartition population
    cache = cache.repartition_fraction(0.5, telemetry=Telemetry())
    assert np.asarray(cache.page_tier)[1].mean() == pytest.approx(0.5)


def test_kv_cache_pin_slot_preserves_decode(key):
    """Pinning a slot mid-sequence is numerically a no-op for attention."""
    from repro.models import registry
    from repro.serving.kv_cache import TieredKVCache, tiered_decode_step
    arch = registry.get("internvl2-2b").tiny()
    cfg = arch.cfg
    params = arch.module.init(cfg, key)
    cache = TieredKVCache.create(
        cfg, 2, 32, MemPolicy.from_slow_fraction("fast", "slow", 0.5),
        page_t=8)
    cache_b = cache
    toks = jnp.asarray([3, 9], jnp.int32)
    for t in range(6):
        la, cache = tiered_decode_step(cfg, params, cache, toks)
        lb, cache_b = tiered_decode_step(cfg, params, cache_b, toks)
        if t == 2:
            cache_b = cache_b.pin_slot(1, telemetry=Telemetry())
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-4)
    assert np.asarray(cache_b.page_tier)[1].sum() == 0


def test_engine_latency_slo_pins_and_batch_tolerates_slow(key):
    from repro.models import registry
    from repro.serving.engine import ServingEngine
    arch = registry.get("internvl2-2b").tiny()
    params = arch.module.init(arch.cfg, key)
    eng = ServingEngine(arch.cfg, params, max_batch=2, max_len=32,
                        policy=MemPolicy.from_slow_fraction(
                            "fast", "slow", 0.5),
                        topology=tpu_v5e_topology(), page_t=8,
                        telemetry=Telemetry())
    eng.submit([5, 6, 7], max_new_tokens=6, slo="latency")
    eng.submit([5, 6, 7], max_new_tokens=6, slo="batch")
    eng.step()
    tiers = np.asarray(eng.cache.page_tier)
    assert eng.pinned_slots == {0}
    assert tiers[0].sum() == 0  # latency slot pinned fast
    assert tiers[1].sum() > 0  # batch slot keeps slow pages
    done = eng.run_until_drained()
    assert len(done) == 2
    assert not eng.pinned_slots  # unpinned on completion


def test_engine_rejects_unknown_slo():
    from repro.serving.engine import Request
    with pytest.raises(ValueError, match="slo"):
        Request(0, [1], 4, slo="best-effort")
