"""Hot-path tests (ISSUE 5): routed-access old-vs-new equivalence
(bit-exact), repartition descriptor coalescing + billed-byte invariance,
shape-stable capacity-padded shards, and jit trace-count assertions
across multi-epoch Caption walks."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.testing import given, settings, st  # hypothesis, with fallback

from repro.core.caption import CaptionConfig, CaptionController, EpochMetrics
from repro.core.interleave import (InterleavedTensor, contiguous_runs,
                                   device_page_map, minimal_delta_weights)
from repro.core.mover import BulkMover
from repro.core.policy import MemPolicy
from repro.core.telemetry import Telemetry
from repro.core.tiers import (TierTopology, paper_three_device_topology,
                              tpu_v5e_topology)
from repro.serving.kv_cache import _INT32_MAX, TieredKVCache, _kv_layout_rows


def _tensor(rng, rows=100, feat=4, page_rows=8, weights=(3, 1), headroom=0):
    x = jnp.asarray(rng.normal(size=(rows, feat)), jnp.float32)
    it = InterleavedTensor.from_array(
        x, MemPolicy.weighted(("fast", "slow"), weights), page_rows,
        headroom=headroom)
    return it, np.asarray(x)


# -- routed access: single-pass bucketed == masked N-pass (bit-exact) ---------
@given(st.integers(0, 500), st.integers(0, 20))
@settings(max_examples=25, deadline=None)
def test_gather_bucketed_equals_masked_bit_exact(seed, headroom):
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(8, 120))
    it, x = _tensor(rng, rows=rows,
                    weights=(int(rng.integers(1, 6)), int(rng.integers(1, 6))),
                    headroom=headroom)
    if headroom:  # exercise the free-slot (non-rank) local layout too
        it = it.repartition_fraction(float(rng.uniform(0, 1)),
                                     telemetry=Telemetry())
    idx = rng.integers(0, rows, size=(2, 7))
    got = it._gather_rows_bucketed(idx)
    ref = it._gather_rows_masked(jnp.asarray(idx))
    assert np.array_equal(np.asarray(got), np.asarray(ref))
    # the public entry with concrete indices routes through the bucketed
    # path and still equals the source array
    assert np.array_equal(np.asarray(it.gather_rows(jnp.asarray(idx))),
                          x[idx])


@given(st.integers(0, 500))
@settings(max_examples=25, deadline=None)
def test_scatter_bucketed_equals_masked(seed):
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(16, 120))
    it, x = _tensor(rng, rows=rows)
    # "set" with distinct indices (duplicate-set order is unspecified in
    # both formulations); "add" with duplicates must accumulate equally
    idx_set = rng.permutation(rows)[:8]
    vals = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    a = it._scatter_bucketed(idx_set, vals, "set")
    b = it._scatter_masked(jnp.asarray(idx_set), vals, "set")
    assert np.array_equal(np.asarray(a.to_array()), np.asarray(b.to_array()))
    idx_add = rng.integers(0, rows, size=8)
    c = it._scatter_bucketed(idx_add, vals, "add")
    d = it._scatter_masked(jnp.asarray(idx_add), vals, "add")
    np.testing.assert_allclose(np.asarray(c.to_array()),
                               np.asarray(d.to_array()), atol=1e-6)


def test_routed_access_traced_falls_back_to_masked():
    """Inside jit the masked formulation runs (static shapes) and agrees
    with the host path."""
    rng = np.random.default_rng(0)
    it, x = _tensor(rng)
    idx = jnp.asarray(rng.integers(0, 100, size=8))
    f = jax.jit(lambda t, i: t.gather_rows(i))
    assert np.array_equal(np.asarray(f(it, idx)),
                          np.asarray(it.gather_rows(idx)))


# -- vectorized bookkeeping == reference loops --------------------------------
@given(st.integers(0, 300))
@settings(max_examples=30, deadline=None)
def test_device_page_map_matches_reference_loop(seed):
    rng = np.random.default_rng(seed)
    n_devices = int(rng.integers(1, 5))
    assign = rng.integers(0, n_devices, size=int(rng.integers(1, 64)))
    dev, local, counts = device_page_map(assign.astype(np.int8), n_devices)
    # reference: the pre-change per-page counter walk
    ref_local = np.zeros(len(assign), np.int32)
    counters = [0] * n_devices
    for p, d in enumerate(assign):
        ref_local[p] = counters[d]
        counters[d] += 1
    assert np.array_equal(local, ref_local)
    assert counts == counters
    assert np.array_equal(dev, assign)


@given(st.integers(0, 300))
@settings(max_examples=30, deadline=None)
def test_kv_layout_rows_matches_reference_loop(seed):
    from repro.core.interleave import tier_page_map
    rng = np.random.default_rng(seed)
    B, P = int(rng.integers(1, 5)), int(rng.integers(1, 10))
    pt = int(rng.integers(1, 6))
    assign = rng.integers(0, 3, size=(B, P)).astype(np.int8)
    a01, local, Tf, Ts, pf, ps = _kv_layout_rows(assign, pt)
    # reference: the pre-change per-slot B x P python walk
    assign01 = np.minimum(assign, 1).astype(np.int8)
    rl = np.zeros((B, P), np.int32)
    n_slow = np.zeros(B, np.int64)
    for b in range(B):
        _, loc, counters = tier_page_map(assign01[b])
        rl[b] = loc
        n_slow[b] = counters[1]
    rTs = int(n_slow.max()) * pt
    rpf = np.full((B, P * pt), _INT32_MAX, np.int32)
    rps = (np.full((B, rTs), _INT32_MAX, np.int32) if rTs
           else np.zeros((B, 0), np.int32))
    for b in range(B):
        fpos, spos = [], []
        for p in range(P):
            (spos if assign01[b, p] else fpos).extend(
                range(p * pt, (p + 1) * pt))
        rpf[b, : len(fpos)] = fpos
        if rTs and spos:
            rps[b, : len(spos)] = spos
    assert np.array_equal(a01, assign01) and np.array_equal(local, rl)
    assert (Tf, Ts) == (P * pt, rTs)
    assert np.array_equal(pf, rpf) and np.array_equal(ps, rps)


# -- repartition: coalescing + billed-byte invariance -------------------------
def test_one_point_shift_issues_run_coalesced_descriptors():
    """The acceptance bar: a 1-point weight shift on a 4096-page tensor
    issues O(delta-runs) mover descriptors, not one per page, while the
    billed bytes stay exactly delta * page_bytes."""
    topo = paper_three_device_topology()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4096 * 4, 8)), jnp.float32)
    it = InterleavedTensor.from_array(
        x, MemPolicy.from_slow_fraction("fast", "slow", 0.3), page_rows=4,
        headroom=512)
    page_bytes = 4 * it.row_bytes
    cur_slow = int(np.asarray(it.page_tier).sum())
    delta = abs(round(0.31 * it.n_pages) - cur_slow)
    tel = Telemetry()
    with BulkMover(topo, asynchronous=True, batch_size=16,
                   telemetry=tel) as mover:
        it2 = it.repartition_fraction(0.31, mover=mover,
                                      fast_tier=topo.fast.name,
                                      slow_tier=topo.slows[0].name)
        descs = mover.descriptors_submitted
        moved = mover.bytes_submitted
    assert delta >= 40  # a real 1-point shift on 4096 pages
    assert moved == delta * page_bytes
    assert descs < delta / 2, (descs, delta)  # coalesced runs
    assert np.array_equal(np.asarray(it2.to_array()), np.asarray(x))


def test_telemetry_path_billed_bytes_invariant():
    """Mover-less actuation bills identical bytes per route as the
    per-page accounting did (run records just aggregate)."""
    rng = np.random.default_rng(1)
    it, x = _tensor(rng, rows=512, page_rows=4)
    tel = Telemetry()
    before = int(np.asarray(it.page_tier).sum())
    it2 = it.repartition_fraction(0.5, telemetry=tel)
    after = int(np.asarray(it2.page_tier).sum())
    page_bytes = 4 * it.row_bytes
    total = sum(r.bytes_moved for r in tel.routes.values())
    assert total == abs(after - before) * page_bytes
    assert np.array_equal(np.asarray(it2.to_array()), x)


@given(st.integers(0, 300), st.integers(1, 32))
@settings(max_examples=25, deadline=None)
def test_minimal_delta_weights_run_pages_invariants(seed, run_pages):
    """For any run length: exact per-device counts, minimal move count,
    the no-op guarantee, and picks clustered into at most
    ceil(surplus/run) runs per surplus device."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 200))
    n_devices = int(rng.integers(2, 5))
    cur = rng.integers(0, n_devices, size=n).astype(np.int8)
    w = tuple(float(x) for x in rng.dirichlet(np.ones(n_devices))[1:])
    out = minimal_delta_weights(cur, w, n_devices, run_pages=run_pages)
    counts = np.bincount(cur, minlength=n_devices)
    if out is None:
        # no-op only when targets round to current counts
        again = minimal_delta_weights(cur, w, n_devices, run_pages=1)
        assert again is None
        return
    new_counts = np.bincount(out, minlength=n_devices)
    # page-count conservation + minimal moves
    assert new_counts.sum() == n
    moves = int((out != cur).sum())
    surplus = np.maximum(counts - new_counts, 0).sum()
    assert moves == surplus  # every move fills a real deficit
    # same targets as the page-at-a-time planner
    ref = minimal_delta_weights(cur, w, n_devices, run_pages=1)
    assert np.array_equal(np.bincount(ref, minlength=n_devices), new_counts)


def test_contiguous_runs():
    assert contiguous_runs(np.array([], np.int64)) == []
    assert contiguous_runs(np.array([3])) == [(0, 1)]
    assert contiguous_runs(np.array([1, 2, 3, 7, 8, 11])) == [
        (0, 3), (3, 2), (5, 1)]


# -- capacity-padded shards ---------------------------------------------------
def test_headroom_keeps_shapes_and_values_until_exhausted():
    rng = np.random.default_rng(2)
    it, x = _tensor(rng, rows=256, page_rows=8, weights=(1, 0), headroom=8)
    shapes = [p.shape for p in it.parts]
    cur = it
    for f in (0.1, 0.25, 0.05, 0.2):  # all fit 8 pages of headroom (32 pages)
        cur = cur.repartition_fraction(f, telemetry=Telemetry())
        assert [p.shape for p in cur.parts] == shapes
        assert np.allclose(np.asarray(cur.to_array()), x)
        dev = np.asarray(cur.page_device)
        local = np.asarray(cur.page_local)
        caps = cur.capacity_pages
        counts = cur.valid_page_counts()
        assert sum(counts) == cur.n_pages
        for i in range(cur.n_devices):  # locals valid + unique per device
            mine = np.sort(local[dev == i])
            assert counts[i] == mine.size <= caps[i]
            assert len(np.unique(mine)) == len(mine)
            assert mine.size == 0 or mine[-1] < caps[i]
    # exhaust the slow headroom: the shard grows (retrace by design)...
    grown = cur.repartition_fraction(0.9, telemetry=Telemetry())
    assert grown.parts[1].shape[0] > shapes[1][0]
    assert np.allclose(np.asarray(grown.to_array()), x)
    # ... and carries fresh headroom for the next walk
    assert grown.capacity_pages[1] >= round(0.9 * grown.n_pages) + 8


def test_headroom_zero_keeps_exact_legacy_shapes():
    rng = np.random.default_rng(3)
    it, x = _tensor(rng, rows=128, page_rows=4)
    it2 = it.repartition_fraction(0.4, telemetry=Telemetry())
    dev = np.asarray(it2.page_device)
    for i, part in enumerate(it2.parts):
        assert part.shape[0] == int((dev == i).sum()) * 4
    assert np.allclose(np.asarray(it2.to_array()), x)


# -- jit trace counts across Caption walks ------------------------------------
def test_interleave_walk_traces_once_across_epochs():
    """A jitted consumer over a capacity-padded tensor traces exactly
    once across >= 10 Caption probe epochs (the retrace-free acceptance
    bar)."""
    topo = tpu_v5e_topology()
    ctl = CaptionController(topo, CaptionConfig(probe_epochs=1, step=0.05),
                            initial_fraction=0.1)
    rng = np.random.default_rng(4)
    n_pages = 128
    x = jnp.asarray(rng.normal(size=(n_pages * 8, 4)), jnp.float32)
    it = InterleavedTensor.from_array(
        x, MemPolicy.from_slow_fraction("fast", "slow", 0.1), page_rows=8,
        headroom=ctl.headroom_pages(n_pages))
    traces = [0]

    def step(t, i):
        traces[0] += 1
        return t.bag_reduce(i)

    fn = jax.jit(step)
    idx = jnp.asarray(rng.integers(0, x.shape[0], size=(4, 8)))
    epochs = 0
    for _ in range(12):
        jax.block_until_ready(fn(it, idx))
        d = ctl.observe(EpochMetrics(throughput=1.0 + ctl.fraction))
        it = it.repartition_weights(d.weights, telemetry=Telemetry())
        ctl.actuated(it.slow_fraction())
        epochs += 1
    assert epochs >= 10
    assert traces[0] == 1, traces[0]
    assert np.allclose(np.asarray(it.to_array()), np.asarray(x))


def test_kv_decode_traces_once_across_walk(key):
    """The jitted decode step over a slow_headroom cache keeps its shapes
    (and its single trace) across repeated Caption repartitions."""
    from repro.models import registry
    from repro.serving.kv_cache import tiered_decode_step
    arch = registry.get("internvl2-2b").tiny()
    cfg = arch.cfg
    params = arch.module.init(cfg, key)
    pol = MemPolicy.from_tier_fractions("fast", ("cxl-a", "cxl-b"),
                                        (0.0, 0.0))
    cache = TieredKVCache.create(cfg, 2, 32, pol, page_t=4,
                                 slow_headroom=8)
    assert cache.k_slow.shape[2] == 8 * 4
    traces = [0]

    def decode(p, c, t):
        traces[0] += 1
        return tiered_decode_step(cfg, p, c, t)

    fn = jax.jit(decode)
    toks = jnp.asarray([3, 9], jnp.int32)
    fracs = [(0.125, 0.125), (0.25, 0.25), (0.125, 0.0), (0.25, 0.125),
             (0.0, 0.25), (0.375, 0.125), (0.125, 0.375), (0.25, 0.0),
             (0.0, 0.0), (0.375, 0.375)]
    for w in fracs:
        _, cache = fn(params, cache, toks)
        cache = cache.repartition_weights(w, telemetry=Telemetry())
    assert len(fracs) >= 10
    assert traces[0] == 1, traces[0]


def test_kv_decode_equivalence_with_headroom(key):
    """Headroom-padded caches decode identically to exact-size caches
    under a mid-sequence retile."""
    from repro.models import registry
    from repro.serving.kv_cache import tiered_decode_step
    arch = registry.get("internvl2-2b").tiny()
    cfg = arch.cfg
    params = arch.module.init(cfg, key)
    pol = MemPolicy.from_slow_fraction("fast", "slow", 0.0)
    a = TieredKVCache.create(cfg, 2, 32, pol, page_t=4)
    b = TieredKVCache.create(cfg, 2, 32, pol, page_t=4, slow_headroom=4)
    toks = jnp.asarray([3, 9], jnp.int32)
    for t in range(6):
        la, a = tiered_decode_step(cfg, params, a, toks)
        lb, b = tiered_decode_step(cfg, params, b, toks)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-5)
        if t == 2:
            a = a.repartition_fraction(0.5, telemetry=Telemetry())
            b = b.repartition_fraction(0.5, telemetry=Telemetry())
            # the retile fits the held capacity: shape unchanged
            assert b.k_slow.shape[2] == 4 * 4
            assert a.k_slow.shape[2] == 4 * 4  # exact-size (legacy) grows


def test_kv_retile_coalesces_descriptors(key):
    from repro.models import registry
    arch = registry.get("internvl2-2b").tiny()
    cfg = arch.cfg
    topo = TierTopology(fast=paper_three_device_topology().fast,
                        slow=paper_three_device_topology().slows[0])
    pol = MemPolicy.from_slow_fraction("fast", "slow", 0.0)
    cache = TieredKVCache.create(cfg, 3, 64, pol, page_t=4,
                                 slow_headroom=8)
    tel = Telemetry()
    with BulkMover(topo, asynchronous=True, batch_size=16,
                   telemetry=tel) as mover:
        cache = cache.repartition_fraction(
            0.5, mover=mover, fast_tier=topo.fast.name,
            slow_tier=topo.slow.name)
        descs = mover.descriptors_submitted
    moved_pages = int(np.asarray(cache.page_tier).sum())  # 8/slot, 1 group
    assert moved_pages == 3 * 8
    # one slot-group, fast->slow, consecutive locals: ~1 run, not 24
    assert descs <= 2, descs


def test_engine_headroom_and_trace_counter(key):
    """The serving engine sizes the KV slow pool for the Caption walk and
    exposes the decode trace counter."""
    from repro.models import registry
    from repro.serving.engine import ServingEngine
    arch = registry.get("internvl2-2b").tiny()
    cfg = arch.cfg
    params = arch.module.init(cfg, key)
    topo = tpu_v5e_topology()
    ctl = CaptionController(topo, CaptionConfig(epoch_steps=2,
                                                probe_epochs=1, step=0.1),
                            initial_fraction=0.0)
    eng = ServingEngine(cfg, params, max_batch=2, max_len=16,
                        topology=topo, page_t=4, caption=ctl)
    n_pages = 16 // 4
    assert eng.cache.slow_headroom == ctl.headroom_pages(n_pages)
    assert eng.cache.k_slow.shape[2] == ctl.headroom_pages(n_pages) * 4
    eng.submit([1, 2, 3], max_new_tokens=6)
    eng.submit([4, 5], max_new_tokens=6)
    eng.run_until_drained(max_steps=64)
    assert eng.decode_traces == 1, eng.decode_traces
