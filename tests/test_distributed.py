"""Distributed-semantics tests (subprocess: each needs its own XLA
virtual-device count, which must be set before JAX initializes)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8, timeout: int = 560):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys; sys.path.insert(0, {repr(os.path.join(REPO, 'src'))})
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=timeout)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


def test_ep_shard_map_matches_reference():
    """EP all_to_all dispatch == single-device routing (fwd, loss, grads)."""
    _run("""
        import jax, jax.numpy as jnp
        from repro.models import registry
        from repro.models.common import activation_sharding
        from repro.launch import shardings as shmod
        from repro.launch.mesh import make_mesh, mesh_context
        mesh = make_mesh((4, 2), ("data", "model"))
        arch = registry.get("deepseek-moe-16b").tiny()
        cfg, mod = arch.cfg, arch.module
        key = jax.random.PRNGKey(0)
        params = mod.init(cfg, key)
        toks = jax.random.randint(key, (8, 16), 0, 200)
        ref = mod.forward(cfg, params, toks)
        with mesh_context(mesh):
            with activation_sharding(shmod.activation_policy(mesh)):
                out = jax.jit(lambda p, t: mod.forward(cfg, p, t))(params, toks)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 5e-3, err
    """)


def test_sharded_train_step_matches_single_device():
    """The full production train step on a 2x2x2 mesh computes the same
    loss as the single-device step (same batch, same init)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch import shardings as shmod, steps as steps_mod
        from repro.launch.mesh import make_mesh, mesh_context
        from repro.launch.shapes import ShapeSpec
        from repro.models import registry
        from repro.optim import adamw
        arch = registry.get("starcoder2-3b").tiny()
        cfg, mod = arch.cfg, arch.module
        key = jax.random.PRNGKey(0)
        params = mod.init(cfg, key)
        toks = jax.random.randint(key, (8, 32), 0, 200)
        batch = {"tokens": toks, "labels": toks}
        opt_cfg = adamw.AdamWConfig(lr=1e-3)
        opt_state = adamw.init_state(params)
        # single device
        fn1 = steps_mod.make_train_step(arch, opt_cfg, n_micro=1)
        p1, o1, m1 = jax.jit(fn1)(params, opt_state, batch)
        # 2x2x2 mesh with microbatching
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        scfg = shmod.ShardingConfig(fsdp=True)
        psh = shmod.param_shardings(jax.eval_shape(lambda: params), cfg, mesh, scfg)
        act = shmod.activation_policy(mesh)
        fn8 = steps_mod.make_train_step(arch, opt_cfg, n_micro=2,
                                        act_policy=act, mesh=mesh,
                                        grad_shardings=psh)
        with mesh_context(mesh):
            p8, o8, m8 = jax.jit(fn8, in_shardings=(psh, None, None),
                                 out_shardings=(psh, None, None))(
                params, opt_state, batch)
        assert abs(float(m1["loss"]) - float(m8["loss"])) < 5e-3, (
            float(m1["loss"]), float(m8["loss"]))
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p8)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=5e-2)
    """)


def test_dryrun_machinery_small_mesh():
    """lower_cell compiles a small train cell end-to-end on a 2x4 mesh and
    produces memory/cost/collective records."""
    _run("""
        import jax
        from repro.launch import dryrun
        from repro.launch.mesh import make_mesh
        from repro.launch.shapes import ShapeSpec
        mesh = make_mesh((2, 4), ("data", "model"))
        # seq must cover the VLM's 256 prefix-embedding tokens
        shape = ShapeSpec("train_tiny", seq=512, batch=8, kind="train")
        rec, compiled = dryrun.lower_cell("internvl2-2b", shape, mesh, n_micro=2)
        assert rec["hlo"]["flops_per_device"] > 0
        assert rec["memory"]["peak_per_device"] > 0
        assert rec["hlo"]["collective_counts"]
    """, devices=8)


def test_collective_permute_and_groups_decode():
    """HLO analyzer's replica-group decoding on iota formats."""
    from repro.launch.hlo_analysis import decode_replica_groups
    g = decode_replica_groups("replica_groups=[32,16]<=[512]", 512)
    assert len(g) == 32 and len(g[0]) == 16 and g[0] == list(range(16))
    g = decode_replica_groups("replica_groups=[16,32]<=[32,16]T(1,0)", 512)
    assert len(g) == 16 and len(g[0]) == 32
    # transpose layout: group 0 collects one element from each 16-block
    assert g[0][:3] == [0, 16, 32]
    g = decode_replica_groups("replica_groups={{0,1},{2,3}}", 4)
    assert g == [[0, 1], [2, 3]]
