"""Caption control loop tests: telemetry epoch windows, hill-climbing
convergence against the planner's analytic optimum, the §6 guardrails,
and the delta-page repartition paths (InterleavedTensor, TieredKVCache,
TieredAdamW) — including the numerical no-op property."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.testing import given, settings, st  # hypothesis, with fallback

from repro.core.caption import (CaptionConfig, CaptionController,
                                EpochMetrics)
from repro.core.classifier import AccessProfile, Boundedness
from repro.core.interleave import InterleavedTensor, minimal_delta_assignment
from repro.core.mover import BulkMover
from repro.core.planner import BufferReq, plan
from repro.core.policy import BufferClass, MemPolicy
from repro.core.telemetry import EpochWindow, Telemetry
from repro.core.tiers import TierTopology, tpu_v5e_topology

# The benchmark modules ARE the modeled workloads under test: import the
# SNC topology and DLRM throughput model from them so the test and the
# Fig. 9/11 benchmarks can never drift apart.
from benchmarks.fig8_dlrm import throughput as _fig8_throughput
from benchmarks.fig11_caption import snc_topology as _snc_topology


def _dlrm_throughput(topo, f_slow: float, threads: int = 32) -> float:
    return _fig8_throughput(topo.fast, topo.slow, f_slow, threads)


# -- telemetry epoch windows ---------------------------------------------------
def test_epoch_window_deltas_and_ewma():
    tel = Telemetry()
    win = EpochWindow(tel, ewma_alpha=0.5)
    tel.record_move("fast", "slow", 1000, 1.0)
    win.gauge("writer_concurrency", 3)
    s0 = win.tick(seconds=1.0)
    assert s0.route_bytes["fast->slow"] == 1000
    assert s0.route_bw["fast->slow"] == pytest.approx(1000.0)
    assert s0.gauges["writer_concurrency"] == 3
    # second epoch sees only the delta, EWMA smooths across windows
    tel.record_move("fast", "slow", 3000, 1.0)
    s1 = win.tick(seconds=1.0)
    assert s1.route_bytes["fast->slow"] == 3000
    assert s1.route_bw_ewma["fast->slow"] == pytest.approx(2000.0)
    assert s1.gauges == {}  # gauges do not leak across epochs
    assert s1.bytes_into("slow") == 3000 and s1.bytes_from("slow") == 0


# -- controller convergence ----------------------------------------------------
def test_caption_converges_to_planner_optimum():
    """On a synthetic bandwidth-bound workload the closed loop lands within
    tolerance of the planner's analytic optimum (the Fig. 9/11 regime)."""
    topo = _snc_topology()
    # analytic optimum from the static planner (x* balance equation)
    reads = 55e9 * 1.3
    p = plan([BufferReq("emb", BufferClass.EMBEDDING, 8 << 30,
                        AccessProfile(reads, 0, 1, 1024, 256, 1.0))],
             TierTopology(fast=dataclasses.replace(topo.fast,
                                                   capacity_bytes=96 << 30),
                          slow=topo.slow),
             compute_seconds=1.0)
    f_planner = p.slow_fraction("emb")

    ctl = CaptionController(
        topo, CaptionConfig(probe_epochs=2, step=0.05, min_step=0.01,
                            hysteresis=0.01))
    for _ in range(64):
        t = _dlrm_throughput(topo, ctl.fraction)
        ctl.observe(EpochMetrics(throughput=t))
    assert ctl.converged
    # converges into the planner's neighborhood AND beats membind-fast
    assert abs(ctl.fraction - f_planner) <= 0.12, (ctl.fraction, f_planner)
    assert (_dlrm_throughput(topo, ctl.fraction)
            >= _dlrm_throughput(topo, 0.0))
    # ... and within 5 points of the empirically best static split
    grid = np.linspace(0, 0.5, 101)
    best = float(grid[np.argmax([_dlrm_throughput(topo, float(f))
                                 for f in grid])])
    assert abs(ctl.fraction - best) <= 0.05, (ctl.fraction, best)


def test_caption_never_grows_latency_bound():
    """Guideline 5: a latency-bound profile only ever walks toward fast."""
    topo = tpu_v5e_topology()
    ctl = CaptionController(topo, CaptionConfig(probe_epochs=1),
                            initial_fraction=0.4,
                            boundedness=Boundedness.LATENCY_BOUND)
    fracs = [ctl.fraction]
    for i in range(40):
        # even when a (noisy) sample claims slow is better, growth is pinned
        t = 1.0 + 0.5 * ctl.fraction + (0.1 if i % 3 else -0.1)
        ctl.observe(EpochMetrics(throughput=t))
        fracs.append(ctl.fraction)
    assert all(b <= a + 1e-12 for a, b in zip(fracs, fracs[1:]))


def test_caption_writer_limit_and_pressure_guardrails():
    topo = tpu_v5e_topology()
    ctl = CaptionController(topo, CaptionConfig(probe_epochs=1, step=0.1))
    for _ in range(6):
        d = ctl.observe(EpochMetrics(throughput=1.0, writer_concurrency=8))
    assert ctl.fraction == 0.0  # growth frozen above the writer limit
    # high fast pressure freezes shrink steps
    ctl2 = CaptionController(topo, CaptionConfig(probe_epochs=1, step=0.1),
                             initial_fraction=0.5,
                             boundedness=Boundedness.LATENCY_BOUND)
    for _ in range(6):
        ctl2.observe(EpochMetrics(throughput=1.0, fast_pressure=0.99))
    assert ctl2.fraction == pytest.approx(0.5)


def test_caption_respects_capacity_floor_from_plan():
    """from_plan seeds fraction/floor/boundedness; the controller can never
    tune below the capacity spill minimum."""
    topo = tpu_v5e_topology()
    reqs = [BufferReq("opt", BufferClass.OPT_STATE, 30 << 30,
                      AccessProfile(30e9, 30e9, 1, 1024, 2 << 20, 0.05))]
    p = plan(reqs, topo, compute_seconds=0.05)
    d = p.decisions["opt"]
    assert d.min_slow_fraction > 0.4  # 30 GiB demand vs 16 GiB HBM
    ctl = CaptionController.from_plan(p, "opt", topo,
                                      CaptionConfig(probe_epochs=1))
    assert ctl.fraction == pytest.approx(d.slow_fraction)
    for _ in range(50):
        # throughput always "prefers" less slow; floor must still hold
        ctl.observe(EpochMetrics(throughput=1.0 / (1.0 + ctl.fraction)))
    assert ctl.fraction >= d.min_slow_fraction - 1e-9


# -- repartition: numerical no-op + delta-only traffic -------------------------
@given(st.integers(1, 7), st.integers(1, 7), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_repartition_is_numerical_noop(wf, ws, seed):
    """reduce(before) == reduce(after) for any policy change (property)."""
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(8, 100))
    x = jnp.asarray(rng.normal(size=(rows, 4)), jnp.float32)
    it = InterleavedTensor.from_array(
        x, MemPolicy.weighted(("fast", "slow"), (wf, ws)), page_rows=8)
    idx = jnp.asarray(rng.integers(0, rows, size=(3, 5)))
    w = jnp.asarray(rng.uniform(size=(3, 5)), jnp.float32)
    before = it.bag_reduce(idx, w)
    target = float(rng.uniform(0, 1))
    it2 = it.repartition_fraction(target, telemetry=Telemetry())
    assert np.allclose(np.asarray(it2.to_array()), np.asarray(x))
    assert np.allclose(np.asarray(it2.bag_reduce(idx, w)),
                       np.asarray(before), atol=1e-5)


def test_repartition_moves_only_delta_pages():
    x = jnp.arange(64.0 * 4).reshape(64, 4)
    it = InterleavedTensor.from_array(x, MemPolicy.membind("fast"),
                                      page_rows=4)  # 16 pages
    tel = Telemetry()
    topo = tpu_v5e_topology()
    with BulkMover(topo, asynchronous=True, batch_size=4,
                   telemetry=tel) as mover:
        it2 = it.repartition_fraction(0.25, mover=mover, fast_tier="hbm",
                                      slow_tier="host")
        it3 = it2.repartition_fraction(0.5, mover=mover, fast_tier="hbm",
                                       slow_tier="host")
    page_bytes = 4 * it.row_bytes
    assert tel.route("hbm", "host").bytes_moved == 8 * page_bytes  # 4 + 4
    assert tel.route("host", "hbm").bytes_moved == 0
    assert it3.slow_fraction() == pytest.approx(0.5)
    assert np.allclose(np.asarray(it3.to_array()), np.asarray(x))


def test_minimal_delta_assignment_properties():
    cur = np.array([0, 1, 0, 0, 1, 0, 0, 0], np.int8)
    out = minimal_delta_assignment(cur, 0.5)
    assert int(out.sum()) == 4
    assert int((out != cur).sum()) == 2  # exactly the delta
    back = minimal_delta_assignment(out, 0.0)
    assert int(back.sum()) == 0


# -- serving: engine rebalances mid-decode, tokens unchanged -------------------
def test_engine_caption_rebalances_same_tokens(key):
    from repro.models import registry
    from repro.serving.engine import ServingEngine
    arch = registry.get("internvl2-2b").tiny()
    params = arch.module.init(arch.cfg, key)

    def run(caption):
        eng = ServingEngine(arch.cfg, params, max_batch=2, max_len=32,
                            policy=MemPolicy.membind("fast"),
                            topology=_snc_topology(), page_t=8,
                            caption=caption, telemetry=Telemetry())
        for _ in range(3):
            eng.submit([5, 6, 7], max_new_tokens=6)
        done = eng.run_until_drained()
        return eng, sorted((r.rid, tuple(r.generated)) for r in done)

    ctl = CaptionController(
        _snc_topology(), CaptionConfig(epoch_steps=2, probe_epochs=1))
    eng_dyn, toks_dyn = run(ctl)
    _, toks_static = run(None)
    assert toks_dyn == toks_static  # re-tiering never changes outputs
    assert len(eng_dyn.caption_trace) >= 2  # the loop actually ran


def test_engine_caption_mover_uses_topology_tier_names(key):
    """The engine's mover path must address the mover's REAL tier names
    (hbm/host on v5e), and migrations must flow through it batched."""
    from repro.models import registry
    from repro.serving.engine import ServingEngine
    arch = registry.get("internvl2-2b").tiny()
    params = arch.module.init(arch.cfg, key)
    topo = tpu_v5e_topology()
    tel = Telemetry()
    with BulkMover(topo, asynchronous=True, batch_size=4,
                   telemetry=tel) as mover:
        ctl = CaptionController(
            topo, CaptionConfig(epoch_steps=2, probe_epochs=1, step=0.25))
        eng = ServingEngine(arch.cfg, params, max_batch=2, max_len=32,
                            policy=MemPolicy.membind("fast"), topology=topo,
                            page_t=4, caption=ctl, mover=mover, telemetry=tel)
        for _ in range(2):
            eng.submit([5, 6, 7], max_new_tokens=6)
        done = eng.run_until_drained()
    assert len(done) == 2
    assert any(f > 0 for _, f in eng.caption_trace)  # the loop moved pages
    r = tel.route("hbm", "host")
    assert r.bytes_moved > 0  # migrations metered under real tier names
    assert r.batches <= r.descriptors  # batched submission, not per-page


# -- optimizer: repartition preserves training trajectory ----------------------
def test_tiered_adamw_repartition_preserves_trajectory():
    """Re-tiering opt state mid-training must not change the math: training
    with a mid-run repartition matches the fused optimizer."""
    from repro.optim import adamw, offload, schedules
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (64, 16))
    y = x @ jax.random.normal(key, (16, 4))
    params0 = {"a": jnp.zeros((16 * 4,), jnp.float32),
               "b": jnp.zeros((16 * 4,), jnp.float32)}
    cfg = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0,
                            schedule=schedules.constant())

    def loss(p):
        w = (p["a"] + p["b"]).reshape(16, 4)
        return jnp.mean((x @ w - y) ** 2)

    # fused reference
    pf, sf = params0, adamw.init_state(params0)
    for _ in range(8):
        pf, sf, _ = adamw.apply(cfg, pf, jax.grad(loss)(pf), sf)

    tel = Telemetry()
    opt = offload.TieredAdamW(cfg, slow_fraction=1.0, min_offload_bytes=64,
                              telemetry=tel)
    pt, st_ = params0, opt.init(params0)
    assert len(st_["slow"]) == 2
    for i in range(8):
        pt, st_, _ = opt.step(pt, jax.grad(loss)(pt), st_)
        if i == 3:  # mid-run: reclaim everything to the fast tier
            up_before = tel.route("host", "hbm").bytes_moved
            down_before = tel.route("hbm", "host").bytes_moved
            st_ = opt.repartition(pt, st_, 0.0)
            assert not st_["slow"]
            assert tel.route("host", "hbm").bytes_moved > up_before
            # delta only: reclaiming adds no device->host traffic (the
            # hbm->host bytes so far are step()'s own paging writebacks)
            assert tel.route("hbm", "host").bytes_moved == down_before
    for a, b in zip(jax.tree_util.tree_leaves(pf),
                    jax.tree_util.tree_leaves(pt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-6)


def test_tiered_adamw_repartition_partial_delta():
    """Moving 0 -> 0.5 offloads only the picked leaves; 0.5 -> 0.5 is free."""
    from repro.optim import adamw, offload, schedules
    params = {"a": jnp.ones((64,), jnp.float32),
              "b": jnp.ones((64,), jnp.float32)}
    cfg = adamw.AdamWConfig(lr=1e-2, schedule=schedules.constant())
    tel = Telemetry()
    opt = offload.TieredAdamW(cfg, slow_fraction=0.0, min_offload_bytes=64,
                              telemetry=tel)
    st_ = opt.init(params)
    assert not st_["slow"]
    st_ = opt.repartition(params, st_, 0.5)
    assert len(st_["slow"]) == 1
    down = tel.route("hbm", "host").bytes_moved
    assert down > 0
    st_ = opt.repartition(params, st_, 0.5)  # no transition -> no traffic
    assert tel.route("hbm", "host").bytes_moved == down


# -- KV cache repartition ------------------------------------------------------
def test_kv_cache_repartition_preserves_decode(key):
    """Attention partitions are invariant under re-tiering mid-sequence."""
    from repro.models import registry
    from repro.serving.kv_cache import TieredKVCache, tiered_decode_step
    arch = registry.get("internvl2-2b").tiny()
    cfg = arch.cfg
    params = arch.module.init(cfg, key)
    cache = TieredKVCache.create(cfg, 2, 32, MemPolicy.membind("fast"),
                                 page_t=8)
    toks = jnp.asarray([3, 9], jnp.int32)
    outs_a, outs_b = [], []
    cache_b = cache
    for t in range(6):
        la, cache = tiered_decode_step(cfg, params, cache, toks)
        lb, cache_b = tiered_decode_step(cfg, params, cache_b, toks)
        if t == 2:
            cache_b = cache_b.repartition_fraction(0.5, telemetry=Telemetry())
        outs_a.append(np.asarray(la))
        outs_b.append(np.asarray(lb))
    for a, b in zip(outs_a, outs_b):
        np.testing.assert_allclose(a, b, atol=1e-4)
