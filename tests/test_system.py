"""End-to-end behaviour tests: the training driver learns, survives a
restart bit-exactly, and the tiered optimizer trains equivalently."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import registry
from repro.optim import adamw, offload, schedules


def _tiny_setup(arch_id="starcoder2-3b", seed=0, batch=4, seq=32):
    arch = registry.get(arch_id).tiny()
    cfg, mod = arch.cfg, arch.module
    params = mod.init(cfg, jax.random.PRNGKey(seed))
    data = TokenPipeline(DataConfig(vocab=cfg.vocab_padded, batch=batch,
                                    seq=seq, seed=11))
    return cfg, mod, params, data


@pytest.mark.slow
def test_training_reduces_loss():
    cfg, mod, params, data = _tiny_setup()
    opt_cfg = adamw.AdamWConfig(lr=3e-3, schedule=schedules.constant(),
                                weight_decay=0.01)
    state = adamw.init_state(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: mod.loss(cfg, p, batch))(params)
        params, state, m = adamw.apply(opt_cfg, params, grads, state)
        return params, state, loss

    losses = []
    for s in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3, losses[::10]


@pytest.mark.slow
def test_training_restart_is_bit_exact(tmp_path):
    """Kill at step 12, restore the step-10 checkpoint, finish at 20:
    identical params to the uninterrupted run (deterministic pipeline)."""
    from repro.checkpoint.checkpointer import Checkpointer
    cfg, mod, params0, data = _tiny_setup()
    opt_cfg = adamw.AdamWConfig(lr=1e-3, schedule=schedules.constant())

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: mod.loss(cfg, p, batch))(params)
        params, state, _ = adamw.apply(opt_cfg, params, grads, state)
        return params, state

    def run(n_steps, ckpt=None, resume=False):
        params, state = params0, adamw.init_state(params0)
        start = 0
        if resume:
            start, (params, state), _ = ckpt.restore((params, state))
        for s in range(start, n_steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
            params, state = step(params, state, batch)
            if ckpt and (s + 1) % 10 == 0:
                ckpt.save(s + 1, (params, state))
                ckpt.wait()
        return params

    clean = run(20)
    ck = Checkpointer(str(tmp_path), asynchronous=False)
    run(12, ckpt=ck)  # "crashes" after step 12; last checkpoint at 10
    recovered = run(20, ckpt=ck, resume=True)
    for p1, p2 in zip(jax.tree_util.tree_leaves(clean),
                      jax.tree_util.tree_leaves(recovered)):
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_tiered_optimizer_training_equivalence():
    """Training with host-offloaded moments tracks the fused optimizer."""
    cfg, mod, params, data = _tiny_setup(seed=1)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, schedule=schedules.constant())
    pf, sf = params, adamw.init_state(params)
    opt = offload.TieredAdamW(opt_cfg, slow_fraction=1.0, min_offload_bytes=1024)
    pt, st = params, opt.init(params)
    loss_grad = jax.jit(jax.value_and_grad(lambda p, b: mod.loss(cfg, p, b)))
    for s in range(3):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        _, gf = loss_grad(pf, batch)
        pf, sf, _ = adamw.apply(opt_cfg, pf, gf, sf)
        _, gt = loss_grad(pt, batch)
        pt, st, _ = opt.step(pt, gt, st)
    for a, b in zip(jax.tree_util.tree_leaves(pf),
                    jax.tree_util.tree_leaves(pt)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-5)


def test_train_driver_main_runs():
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "internvl2-2b", "--tiny", "--steps", "12", "--batch", "2",
        "--seq", "16", "--ckpt-every", "100", "--log-every", "6",
        "--offload-fraction", "0.0",
    ])
    assert len(losses) == 12 and np.isfinite(losses).all()


def test_serve_driver_main_runs():
    from repro.launch import serve as serve_mod
    done = serve_mod.main([
        "--arch", "internvl2-2b", "--tiny", "--requests", "4",
        "--max-batch", "2", "--max-len", "32", "--new-tokens", "4",
        "--slow-fraction", "0.5", "--page-t", "8",
    ])
    assert len(done) == 4
