"""Quickstart: the paper's pipeline end-to-end in ~40 lines.

1. Characterize the tiers (MEMO), 2. classify a workload, 3. let the
planner place buffers, 4. run a tiered embedding reduction and a tiered
optimizer step — the CXL-paper loop: characterize -> classify -> place.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax, jax.numpy as jnp, numpy as np

from repro.core import (AccessProfile, BufferClass, BufferReq,
                        InterleavedTensor, MemPolicy, memo, plan,
                        tpu_v5e_topology)
from repro.kernels.embedding_reduce import ops as er

topo = tpu_v5e_topology()

# 1) characterize (measured on this host + modeled for the target tiers)
print("== MEMO (Fig. 2/3 analogue) ==")
print(" measured ptr-chase:", memo.measure_pointer_chase(1 << 18, 1 << 13).row())
for r in memo.simulate_latency(topo):
    print(" modeled:", r)

# 2-3) plan placement for a training step's buffers
reqs = [
    BufferReq("kv_cache", BufferClass.KV_CACHE, 6 << 30,
              AccessProfile(6e9, 1e6, 1, 512, 1 << 16, 0.02)),
    BufferReq("opt_state", BufferClass.OPT_STATE, 24 << 30,
              AccessProfile(24e9, 24e9, 1, 1024, 4 << 20, 0.02)),
    BufferReq("wkv_state", BufferClass.RECURRENT_STATE, 64 << 20,
              AccessProfile(1e8, 1e8, 4096, 1, 4096, 0.02)),
]
p = plan(reqs, topo, compute_seconds=0.02, reserve_fast_bytes=4 << 30)
print("\n== placement plan ==\n" + p.report())

# 4) tiered embedding-bag with the Pallas kernel (exact across tiers)
table = jnp.asarray(np.random.default_rng(0).normal(size=(1024, 64)), jnp.float32)
frac = p.slow_fraction("opt_state")  # reuse a planner-chosen ratio
it = InterleavedTensor.from_array(
    table, MemPolicy.from_slow_fraction("fast", "slow", 0.25), page_rows=64)
idx = jnp.asarray(np.random.default_rng(1).integers(0, 1024, (8, 16)))
w = jnp.ones((8, 16), jnp.float32)
out = it.bag_reduce(idx, w, reduce_fn=er.embedding_reduce)
ref = jnp.einsum("bkd,bk->bd", table[idx], w)
print(f"\ntiered embedding-bag max err vs dense: {float(jnp.max(jnp.abs(out-ref))):.2e}")
print("quickstart OK")
