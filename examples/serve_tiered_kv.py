"""Serve a small LM with the KV cache page-interleaved across memory
tiers (the paper's Redis experiment, §5.1, as a serving engine demo).

Run:  PYTHONPATH=src python examples/serve_tiered_kv.py
"""
from repro.launch import serve as serve_mod

for frac in (0.0, 0.5, 1.0):
    print(f"\n== slow-tier fraction {frac:.0%} ==")
    serve_mod.main([
        "--arch", "internvl2-2b", "--tiny", "--requests", "8",
        "--max-batch", "4", "--max-len", "64", "--new-tokens", "8",
        "--slow-fraction", str(frac), "--page-t", "8",
    ])

# Shared-prefix batch: every request repeats the same 24-token system
# prompt, so after the first request seeds the pool the rest attach the
# prefix pages by reference and replay only their 4-token suffixes.
print("\n== shared-prefix batch (prefix pool + cost admission) ==")
serve_mod.main([
    "--arch", "internvl2-2b", "--tiny", "--requests", "8",
    "--max-batch", "4", "--max-len", "64", "--new-tokens", "8",
    "--slow-fraction", "0.5", "--page-t", "8",
    "--shared-prefix", "24", "--prefix-pages", "16",
    "--admission", "cost", "--latency-every", "4",
])
