"""DLRM embedding reduction (paper §5.2 / MERCI) over a tiered table:
sweeps the DRAM:CXL interleave ratio and reports modeled throughput +
real kernel wall time (reproduces the Fig. 8/9 shape).

Run:  PYTHONPATH=src python examples/dlrm_embedding.py
"""
from benchmarks import fig8_dlrm

for row in fig8_dlrm.run():
    print(row)
