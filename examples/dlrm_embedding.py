"""DLRM embedding table with hotness-driven semantic tiering (ISSUE 10).

A Zipf-skewed lookup stream hits an embedding table interleaved across
DRAM + three CXL devices (the paper's Fig. 10 multi-device setup).
The table starts hotness-BLIND — an address-order N:M interleave, so
the hot rows are scattered across the slow devices — then the ledger
the lookups feed for free drives one :meth:`SemanticTensor.retier`
that pins the hot rows fast and deals the cold tail across the CXL
devices bandwidth-proportionally.  The report shows the before/after
placement, the promoted/demoted page counts, and the modeled
throughput (Fig. 8 closed-loop model) at the identical page budget.

Run:  PYTHONPATH=src python examples/dlrm_embedding.py
      [--rows 4096] [--alpha 1.1] [--decay 0.5] [--budget 0.25]
"""
import argparse
import pathlib
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks.fig8_dlrm import throughput_nd  # noqa: E402
from repro.core.hotness import SemanticTensor
from repro.core.tiers import paper_three_device_topology
from repro.kernels.embedding_reduce import ops

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--rows", type=int, default=4096, help="table rows")
ap.add_argument("--alpha", type=float, default=1.1, help="Zipf exponent")
ap.add_argument("--decay", type=float, default=0.5,
                help="ledger EWMA decay per epoch")
ap.add_argument("--budget", type=float, default=0.25,
                help="fraction of pages the fast tier can hold")
ap.add_argument("--lookups", type=int, default=20000,
                help="Zipf lookups per epoch")
args = ap.parse_args()

topo = paper_three_device_topology()
names = (topo.fast.name,) + tuple(t.name for t in topo.slows)
rng = np.random.default_rng(0)
rows_per_key, page_rows = 8, 2
n_keys = args.rows // rows_per_key

# Zipf popularity over a random permutation: hot rows are scattered in
# address space, exactly where a blind interleave loses.
zipf = np.zeros(n_keys)
zipf[rng.permutation(n_keys)] = 1.0 / (1.0 + np.arange(n_keys)) ** args.alpha
row_p = np.repeat(zipf, rows_per_key)
row_p /= row_p.sum()

# integer-valued fp32 rows: bag sums are exact in any accumulation
# order, so the before/after comparison below is bitwise
table = jnp.asarray(rng.integers(-8, 9, size=(args.rows, 64)), jnp.float32)
weights = tuple((1.0 - args.budget) * b for b in topo.bandwidth_weights())
st = SemanticTensor.from_array(
    table, rows_per_key=rows_per_key, weights=weights, device_names=names,
    page_rows=page_rows, decay=args.decay,
    headroom=args.rows // page_rows, placement="blind")


def modeled(s: SemanticTensor) -> float:
    dev, sc = s.key_device(), s.ledger.scores()
    total = max(float(sc.sum()), 1e-12)
    shares = tuple(float(sc[dev == i + 1].sum()) / total
                   for i in range(len(topo.slows)))
    return throughput_nd(topo.fast, topo.slows, shares, 32)


# one epoch of Zipf lookups; bag_reduce feeds the ledger for free
idx = jnp.asarray(rng.choice(args.rows, p=row_p, size=(args.lookups // 80, 80)))
w = jnp.ones(idx.shape, jnp.float32)
out_before = st.bag_reduce(idx, w, reduce_fn=ops.embedding_reduce)
st.ledger.tick()

print("== hotness-blind placement (address-order N:M interleave) ==")
print(st.placement_report())
t_blind = modeled(st)
print(f"hot-row traffic on fast: {st.hot_traffic_share():.1%}   "
      f"modeled: {t_blind:,.0f} inf/s\n")

st = st.retier(weights)

print("== after one hotness-driven re-tier (same page budget) ==")
print(st.placement_report())
t_hot = modeled(st)
print(f"hot-row traffic on fast: {st.hot_traffic_share():.1%}   "
      f"modeled: {t_hot:,.0f} inf/s   (x{t_hot / t_blind:.2f})")
r = st.last_retier
print(f"moved: {r['moved_keys']} keys / {r['moved_pages']} pages "
      f"(promoted {r['promoted_pages']}, demoted {r['demoted_pages']})")

out_after = st.bag_reduce(idx, w, reduce_fn=ops.embedding_reduce)
drift = float(np.max(np.abs(np.asarray(out_before) - np.asarray(out_after))))
print(f"bag-reduction max |before - after| = {drift:g}  (placement is "
      "invisible to the math)")
assert t_hot > t_blind and drift == 0.0
