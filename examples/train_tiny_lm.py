"""Train a ~100M-param starcoder2-family model for a few hundred steps on
CPU, with checkpoint/restart and (optionally) the tiered optimizer.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""
import argparse
import dataclasses

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--offload", type=float, default=0.0,
                    help="fraction of optimizer state paged to the slow tier")
    args = ap.parse_args()
    # a ~100M-param config: tiny() widened
    from repro.models import registry
    from repro.configs import base as cfgbase
    arch = registry.get("starcoder2-3b")
    cfg = dataclasses.replace(
        arch.cfg.tiny(), name="starcoder2-100m", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=2, d_ff=2048, vocab=32768, head_dim=64,
        max_seq=512)
    # registry-independent drive: reuse the launch driver with explicit args
    import repro.launch.train as T
    import repro.models.registry as R
    orig_get = R.get
    R.get = lambda a: dataclasses.replace(orig_get("starcoder2-3b"), cfg=cfg) \
        if a == "starcoder2-100m" else orig_get(a)
    try:
        losses = T.main([
            "--arch", "starcoder2-100m", "--steps", str(args.steps),
            "--batch", "4", "--seq", "256", "--lr", "6e-4",
            "--ckpt-dir", "/tmp/repro_100m", "--ckpt-every", "100",
            "--offload-fraction", str(args.offload), "--log-every", "20",
        ])
    finally:
        R.get = orig_get
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
