"""Batched serving engine with continuous batching over slot-based decode.

Requests occupy batch slots; each engine step decodes one token for
every active slot (ragged lengths handled by the cache's valid masks).
Per-request latency is tracked both as measured wall time and as
*modeled* time on the target tier topology (compute + per-tier KV
streaming via the calibrated perfmodel), which is what the Redis-
analogue benchmark (Figs. 6-7) reports.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import perfmodel
from repro.core.caption import CaptionController
from repro.core.classifier import AccessProfile
from repro.core.policy import MemPolicy
from repro.core.telemetry import GLOBAL_TELEMETRY, EpochWindow
from repro.core.tiers import OpClass, TierTopology
from repro.serving.kv_cache import TieredKVCache, tiered_decode_step
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import sample_greedy


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    #: SLO class — "latency" requests pin their KV pages fast (their slots
    #: leave the Caption repartition population); "batch" tolerate slow.
    slo: str = "batch"
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    generated: list[int] = dataclasses.field(default_factory=list)
    modeled_seconds: float = 0.0

    def __post_init__(self):
        if self.slo not in ("latency", "batch"):
            raise ValueError(f"slo must be 'latency' or 'batch': {self.slo!r}")

    @property
    def latency(self) -> float:
        return (self.finished_at or time.perf_counter()) - self.submitted_at


def kv_access_profile(cfg: ArchConfig, max_batch: int, max_len: int, *,
                      page_t: int = 64, item_bytes: int = 4,
                      compute_seconds: float = 0.0,
                      deadline_seconds: Optional[float] = None
                      ) -> AccessProfile:
    """AccessProfile of the tiered KV cache under steady decode.

    One decode step streams the whole live KV window once (attention
    reads every cached token) and appends one token row per sequence —
    massively parallel page gathers, shallow dependent chains.  The
    drivers feed this to :meth:`CaptionController.from_profile` so the
    §6.1 taxonomy drives controller seeding: against a latency-class
    deadline (µs SLO) the profile classifies LATENCY_BOUND and the KV
    controller is fast-pinned; the ordinary batch-serving shape
    classifies bandwidth-bound and keeps the planner's slow prior."""
    hd = cfg.resolved_head_dim
    row = 2 * cfg.n_layers * cfg.n_kv_heads * hd * item_bytes  # K+V, 1 tok
    return AccessProfile(
        bytes_read_per_step=float(row * max_len * max_batch),
        bytes_written_per_step=float(row * max_batch),
        dependent_chain=1,  # page gathers are independent across heads
        parallelism=max(max_batch * cfg.n_kv_heads, 1),
        granularity=max(page_t * hd * item_bytes, 1),
        compute_seconds=compute_seconds,
        deadline_seconds=deadline_seconds,
    )


class ServingEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: dict,
        *,
        max_batch: int = 8,
        max_len: int = 512,
        policy: Optional[MemPolicy] = None,
        topology: Optional[TierTopology] = None,
        page_t: int = 64,
        caption: Optional[CaptionController] = None,
        arbiter=None,
        buffer_name: str = "kv",
        mover=None,
        telemetry=GLOBAL_TELEMETRY,
        donate_kv: bool = True,
        prefix_pages: int = 0,
        admission: str = "none",
        admission_watermark: float = 0.9,
        admission_max_defer: int = 64,
        admission_capacity_bytes: Optional[int] = None,
        overlap: bool = False,
        ledger=None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.topology = topology
        policy = policy or MemPolicy.membind("fast")
        # With a Caption loop attached, size the KV slow pool for the
        # walk's ceiling up front (capacity padding): every repartition
        # the controller can request then fits the existing shapes, so
        # the jitted decode step traces exactly once across all probe
        # epochs instead of retracing on each actuation.
        n_pages = max_len // min(page_t, max_len)
        slow_headroom = (caption.headroom_pages(n_pages)
                         if caption is not None else 0)
        self.cache = TieredKVCache.create(
            cfg, max_batch, max_len, policy, page_t=page_t,
            slow_headroom=slow_headroom)
        # Shared-prefix paged KV (ISSUE 8): the pool is created up front
        # (pytree child — the jitted decode treedef must not change
        # mid-run) and indexed by a host-side refcounted radix trie.
        self.prefix_index: Optional[PrefixCache] = None
        if prefix_pages > 0:
            self.cache = self.cache.with_prefix(int(prefix_pages))
            self.prefix_index = PrefixCache(int(prefix_pages),
                                            min(page_t, max_len))
        self._slot_refs: dict[int, list] = {}
        self.prefill_tokens_total = 0
        self.prefill_tokens_avoided = 0
        # Cost-model admission (ISSUE 8): batch-class requests whose
        # predicted fast-tier footprint would pressure latency-class
        # pins are deferred (bounded by ``admission_max_defer`` steps).
        if admission not in ("none", "cost"):
            raise ValueError(f"admission must be 'none' or 'cost': "
                             f"{admission!r}")
        self.admission = admission
        self.admission_watermark = float(admission_watermark)
        self.admission_max_defer = int(admission_max_defer)
        self.admission_capacity_bytes = admission_capacity_bytes
        self.admission_deferrals = 0
        self._defer_steps = 0
        # Async migration/compute overlap (ISSUE 8): Caption actuations
        # submit mover descriptors WITHOUT fencing; decode keeps running
        # and completions drain at the next epoch boundary.  Hidden vs
        # exposed migration time is modeled via perfmodel.overlap_cost.
        self.overlap = bool(overlap)
        self.migration_stall_s = 0.0
        self.migration_hidden_s = 0.0
        self.migration_exposed_s = 0.0
        self._inflight_move_bytes = 0
        self._inflight_compute_s = 0.0
        # Engine-owned actuations (Caption repartitions, SLO pins, elastic
        # drains) always replace ``self.cache`` with the retiled cache, so
        # the parent provably dies — exactly the donation contract.  With
        # ``donate_kv`` those retiles patch the receiving pools in place
        # (zero full-pool copies on the stable path) instead of paying one
        # copy-on-write per receiving pool.  Direct ``cache.*`` calls made
        # by outside code keep the safe donate=False default.
        self.donate_kv = bool(donate_kv)
        # Trace accounting: the counter increments only when jit actually
        # retraces (the wrapped Python fn re-executes), so benchmarks and
        # tests can assert the walk stayed retrace-free.
        self.decode_traces = 0

        def _decode_traced(p, c, t):
            self.decode_traces += 1
            return tiered_decode_step(cfg, p, c, t)

        self._decode = jax.jit(_decode_traced)
        self.slots: list[Optional[Request]] = [None] * max_batch
        # Latency-SLO slots (request policy lives here, not in the cache):
        # excluded from Caption repartitions while their request is active.
        self.pinned_slots: set[int] = set()
        self.queue: list[Request] = []
        self._next_rid = 0
        self.done: list[Request] = []
        # modeled per-step seconds: per-tier KV streaming on the target HW
        self._step_model_cache: Optional[dict] = None
        # Caption control loop: between decode steps the controller reads
        # the epoch's modeled token throughput and re-tiers the KV pages.
        # When an arbiter spans several buffers, epochs route through it:
        # this engine's slow-tier traffic is billed to ``buffer_name`` and
        # growth is granted/clipped against the fleet budget.
        self.caption = caption
        self.arbiter = arbiter
        self.buffer_name = buffer_name
        if arbiter is not None and caption is not None:
            arbiter.register(buffer_name, caption)
        self.mover = mover
        self.telemetry = telemetry
        self._steps = 0
        self._epoch_tokens = 0
        self._epoch_modeled_s = 0.0
        self.caption_trace: list[tuple[int, float]] = []
        # One tier namespace for traffic accounting and migration: the
        # mover's topology names when a mover meters the moves, else the
        # generic fast/slow labels the modeled path uses.
        # Device-ordinal route labels (fast + every slow device): the
        # mover's real names when it meters the moves, else the names the
        # placement policy stamped onto the cache — repartitions reuse the
        # same labels, so device keys never churn mid-run.
        name_src = mover.topology if mover is not None else topology
        multi = (name_src is not None and name_src.n_slow > 1
                 and len(self.cache.device_names) > 2)
        if mover is not None:
            self._fast_name = mover.topology.fast.name
            self._slow_name = (mover.topology.slow or mover.topology.fast).name
        elif multi:
            self._fast_name = self.cache.device_names[0]
            self._slow_name = self.cache.device_names[1]
        else:
            self._fast_name, self._slow_name = "fast", "slow"
        if multi:
            self._device_names = ((self._fast_name,)
                                  + tuple(name_src.slow_names))
        else:
            self._device_names = (self._fast_name, self._slow_name)
        self._epoch_window = (EpochWindow(telemetry)
                              if caption is not None else None)
        # Capacity accounting (ISSUE 10 satellite): the serving plane's
        # framework-managed pools show up in the TierLedger report next
        # to the planner's buffers.  Registration refreshes whenever an
        # actuation can change pool shapes (Caption epochs, drains).
        self.ledger = ledger
        self.register_pools()

    def register_pools(self) -> dict[str, int]:
        """(Re-)register the KV + prefix pools in ``self.ledger``.

        No-op without a ledger.  Uses the engine's device-ordinal route
        labels, so generic ``fast/slow`` caches bill against the real
        topology tier names.  Safe to call after every re-tile: the
        previous registration is released first."""
        if self.ledger is None:
            return {}
        names = self._device_names[: len(self.cache.device_names)]
        if len(names) < len(self.cache.device_names):
            names = self.cache.device_names
        return self.cache.register_in_ledger(
            self.ledger, self.buffer_name, device_names=names,
            strict=False)

    # -- elastic topology (hot-remove / hot-add) -------------------------------
    def _active_slow_names(self) -> tuple[str, ...]:
        """Slow devices that are CURRENT placement targets.  The engine's
        ``_device_names`` is the union of every device ever seen (route
        labels never churn mid-run); the controller's weight vector spans
        only the live topology, so the two map by name."""
        if self.caption is not None and self.caption.topology.slows:
            return self.caption.topology.slow_names
        if self.topology is not None and self.topology.slows:
            return self.topology.slow_names
        return tuple(self._device_names[1:])

    def _expand_weights(self, weights) -> tuple[float, ...]:
        """Controller weight vector (live slow devices) -> cache device
        ordinals, zeros for devices that are no longer placement targets."""
        by_name = dict(zip(self._active_slow_names(), weights))
        n = len(self._device_names) - 1
        if not any(name in by_name for name in self._device_names[1:]):
            # Disjoint namespaces (a controller built on generic labels):
            # fall back to the positional alignment of the pre-elastic era.
            w = tuple(float(x) for x in weights)[:n]
            return w + (0.0,) * (n - len(w))
        return tuple(by_name.get(name, 0.0)
                     for name in self._device_names[1:])

    def _project_weights(self, kv_w) -> tuple[float, ...]:
        """Cache per-ordinal weights -> the controller's live-device order."""
        active = self._active_slow_names()
        by_name = dict(zip(self._device_names[1:], kv_w))
        if not any(name in by_name for name in active):
            w = tuple(float(x) for x in kv_w)[:len(active)]
            return w + (0.0,) * (len(active) - len(w))
        return tuple(by_name.get(name, 0.0) for name in active)

    def remove_device(self, name: str, *, monitor=None) -> None:
        """Elastic hot-remove of slow device ``name``.

        Drains the departing device's KV pages through the mover's bulk
        lane (run-coalesced descriptors billed on real dead->survivor
        routes) without touching in-flight requests, then rebuilds the
        control plane: topology and mover drop the device (it stays
        ledger-visible for queued descriptors), the arbiter forgets its
        budget and billed demand, and the Caption walk re-seeds on the
        survivors' bandwidth weights.  ``monitor`` (a HeartbeatMonitor)
        is deregistered so one dead device cannot poison every later
        health check."""
        if self.topology is None or name not in self.topology.slow_names:
            raise KeyError(name)
        new_topo = self.topology.remove_device(name)
        # Drain target: survivors keep the departing population's total
        # slow share, split bandwidth-proportionally — the same re-seed
        # the controller applies, so drain and walk agree on the new
        # operating point.
        if name in self.cache.device_names:
            total = sum(self.cache.weights(self.pinned_slots))
            by_name = dict(zip(new_topo.slow_names,
                               (total * b
                                for b in new_topo.bandwidth_weights())))
            target = tuple(by_name.get(n, 0.0)
                           for n in self._device_names[1:])
            self.cache = self.cache.drain_device(
                name, self.pinned_slots, weights=target, mover=self.mover,
                telemetry=self.telemetry, policy_names=self._device_names,
                source=self.buffer_name, donate=self.donate_kv)
            if self.cache.prefix is not None:
                # shared pool pages evacuate the dead device too — each
                # page ships once (refcount-deduplicated), to fast
                ord_ = self.cache.device_names.index(name)
                pdev = np.asarray(self.cache.prefix.page_device)
                if (pdev == ord_).any():
                    new = pdev.copy()
                    new[pdev == ord_] = 0
                    self.cache = self.cache.retile_prefix(
                        new, mover=self.mover, telemetry=self.telemetry,
                        policy_names=self._device_names,
                        source=self.buffer_name)
        self.topology = new_topo
        if self.mover is not None and name in self.mover.topology.slow_names:
            self.mover.update_topology(
                self.mover.topology.remove_device(name))
        if (self.arbiter is not None
                and name in self.arbiter.topology.slow_names):
            self.arbiter.remove_device(name)
        if (self.caption is not None
                and name in self.caption.topology.slow_names):
            self.caption.remove_device(name)
            self.caption.actuated_weights(self._project_weights(
                self.cache.weights(self.pinned_slots)))
        if monitor is not None:
            monitor.remove(name)
        self.register_pools()

    def add_device(self, spec) -> None:
        """Elastic hot-add: the device (TierSpec or name) joins the
        placement targets at weight zero and the Caption walk re-opens on
        its coordinate — pages climb onto it through the normal actuation
        path, so addition itself moves nothing."""
        if self.topology is None:
            raise ValueError("add_device needs a tier topology")
        self.topology = self.topology.add_device(spec)
        added = self.topology.slows[-1]
        if added.name not in self._device_names:
            self._device_names = self._device_names + (added.name,)
        if (self.mover is not None
                and added.name not in self.mover.topology.slow_names):
            self.mover.update_topology(
                self.mover.topology.add_device(added))
        if (self.arbiter is not None
                and added.name not in self.arbiter.topology.slow_names):
            self.arbiter.add_device(added)
        if (self.caption is not None
                and added.name not in self.caption.topology.slow_names):
            self.caption.add_device(added)

    # -- request management ---------------------------------------------------
    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               slo: str = "batch") -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, list(prompt), max_new_tokens, slo=slo,
                                  submitted_at=time.perf_counter()))
        return rid

    def _admit(self) -> None:
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                if not self._admission_ok(self.queue[0]):
                    # FIFO head deferred: later requests wait behind it
                    # (ordering preserved; starvation-bounded).
                    break
                req = self.queue.pop(0)
                self._defer_steps = 0
                self.slots[i] = req
                # Latency-SLO admission: pin the slot's pages fast before
                # prefill (migration rides the mover's latency lane).
                if req.slo == "latency":
                    self.cache = self.cache.pin_slot(
                        i, mover=self.mover, telemetry=self.telemetry,
                        fast_tier=self._fast_name, slow_tier=self._slow_name,
                        source=self.buffer_name, donate=self.donate_kv)
                    self.pinned_slots.add(i)
                self._reset_slot(i)
                # Shared-prefix fast path: attach the longest cached
                # prefix by reference and replay ONLY the suffix — the
                # decode-replay prefill (exact; slot-local) starts at
                # the shared boundary instead of token zero.
                shared = 0
                if self.prefix_index is not None:
                    shared = self._attach_prefix(i, req)
                for tok in req.prompt[shared:-1]:
                    self._step_slot_token(i, tok)
                if self.prefix_index is not None:
                    self._promote_prefix(i, req)
                self.prefill_tokens_total += max(len(req.prompt) - 1, 0)
                self.prefill_tokens_avoided += shared

    # -- shared-prefix attach / CoW / promotion --------------------------------
    def _attach_prefix(self, i: int, req: Request) -> int:
        """Match ``req``'s prompt in the prefix index; map fully-matched
        pages into slot ``i`` by reference and copy-on-write the head of
        a partially-matched page into the slot's own tier.  Returns the
        number of prompt tokens the replay loop can skip."""
        idx = self.prefix_index
        nodes, partial, plen = idx.match(req.prompt)
        Pm = self.cache.prefix.slot_pages.shape[1]
        nodes = nodes[:Pm]
        full_rows = len(nodes) * self.cache.page_t
        if nodes:
            idx.acquire(nodes)
            self._slot_refs[i] = nodes
            self.cache = self.cache.attach_prefix(
                i, [n.page for n in nodes])
        if partial is not None and plen > 0:
            # Copy-on-write at the divergence point: the writer gets a
            # PRIVATE copy of the matched head in its own tier-placed
            # pages; the shared page stays immutable for its readers.
            idx.touch(partial)
            idx.cow_copies += 1
            blk = self.cache.prefix
            k_rows = np.asarray(blk.k)[:, partial.page, :plen]
            v_rows = np.asarray(blk.v)[:, partial.page, :plen]
            self.cache = self.cache.write_token_rows(
                i, full_rows, k_rows, v_rows)
            src_ord = int(np.asarray(blk.page_device)[partial.page])
            dst_ord = int(self.cache._host_dev()[i][full_rows
                                                    // self.cache.page_t])
            names = self._device_names
            if src_ord != dst_ord and max(src_ord, dst_ord) < len(names):
                row_b = (self.cache._page_kv_bytes()
                         * plen // self.cache.page_t)
                self.telemetry.record_move(
                    names[src_ord], names[dst_ord], row_b, 0.0,
                    source=self.buffer_name)
        return full_rows + plen

    def _promote_prefix(self, i: int, req: Request) -> None:
        """After prefill, publish the prompt's novel full pages into the
        shared pool so the NEXT request with this prefix shares them."""
        placed = self.prefix_index.insert(req.prompt,
                                          self._slot_refs.get(i, []))
        if not placed:
            return
        pt = self.cache.page_t
        ks, vs = [], []
        for pno, _node in placed:
            k_pg, v_pg = self.cache.gather_token_rows(i, pno * pt, pt)
            ks.append(k_pg)
            vs.append(v_pg)
        self.cache = self.cache.write_prefix_pages(
            [n.page for _, n in placed],
            np.stack(ks, axis=1), np.stack(vs, axis=1), device=0)

    # -- cost-model admission ---------------------------------------------------
    def _admission_ok(self, req: Request) -> bool:
        """Admit unless the predicted fast-tier footprint (per-device KV
        bytes at the current operating point, plus this request's slot)
        would crowd latency-class pins AND the demotion migration that
        admission forces cannot hide inside an epoch of decode."""
        if (self.admission != "cost" or req.slo == "latency"
                or self.topology is None):
            return True
        if self._defer_steps >= self.admission_max_defer:
            return True  # starvation bound: the head request gets in
        item = self.cache.k_fast.dtype.itemsize
        L, B = self.cache.k_fast.shape[:2]
        K, hd = self.cache.k_fast.shape[3:]
        slot_bytes = 2 * L * self.max_len * K * hd * item
        f = self.cache.slow_fraction(self.pinned_slots)
        n_lat = len(self.pinned_slots)
        n_batch = sum(1 for j, r in enumerate(self.slots)
                      if r is not None and j not in self.pinned_slots) + 1
        pfx_fast = 0
        if self.cache.prefix is not None:
            pdev = np.asarray(self.cache.prefix.page_device)
            pfx_fast = (int((pdev == 0).sum())
                        * self.cache._page_kv_bytes())
        predicted = (n_lat * slot_bytes
                     + n_batch * slot_bytes * (1.0 - f) + pfx_fast)
        cap = (self.admission_capacity_bytes
               if self.admission_capacity_bytes is not None
               else self.topology.fast.capacity_bytes)
        cap *= self.admission_watermark
        if predicted <= cap or n_lat == 0 or self.topology.slow is None:
            return True
        # Over the watermark with live pins: admission would force the
        # excess fast bytes onto the slow tier.  Model that demotion as
        # a pipelined stream_copy and admit only if it hides entirely
        # under one epoch of decode compute.
        excess = int(predicted - cap)
        mc = perfmodel.pipelined_move_cost(
            self.topology.fast, self.topology.slow, excess,
            asynchronous=True)
        epoch_steps = (self.caption.cfg.epoch_steps
                       if self.caption is not None else 8)
        oc = perfmodel.overlap_cost(
            mc.seconds, self.modeled_step_seconds() * epoch_steps)
        if oc.exposed_s <= 0.0:
            return True
        self.admission_deferrals += 1
        self._defer_steps += 1
        return False

    def _reset_slot(self, i: int) -> None:
        self.cache = dataclasses.replace(
            self.cache, lengths=self.cache.lengths.at[i].set(0))

    # -- stepping ---------------------------------------------------------------
    def _current_tokens(self) -> jnp.ndarray:
        toks = np.zeros((self.max_batch,), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            toks[i] = (req.generated[-1] if req.generated else req.prompt[-1])
        return jnp.asarray(toks)

    def _step_slot_token(self, i: int, token: int) -> None:
        toks = np.zeros((self.max_batch,), np.int32)
        toks[i] = token
        logits, cache = self._decode(self.params, self.cache, jnp.asarray(toks))
        # only slot i advances; rebuild lengths so other slots are unchanged
        lengths = self.cache.lengths.at[i].add(1)
        self.cache = dataclasses.replace(cache, lengths=lengths)

    def modeled_step_seconds(self) -> float:
        """Per-decode-step time on the target topology (compute ignored on
        this CPU box; KV streaming dominates decode).  Devices stream on
        their own links, so the step pays the SLOWEST device, plus one
        dependent hop into every device holding pages."""
        if self.topology is None:
            return 0.0
        if self.topology.n_slow > 1 and len(self.cache.device_names) > 2:
            rbd = self.cache.read_bytes_per_device()
            times = [rbd.get(self.cache.device_names[0], 0)
                     / perfmodel.stream_bandwidth(
                         self.topology.fast, OpClass.LOAD, 8)]
            lat = self.topology.fast.chase_latency_ns * 1e-9
            for dev in self.topology.slows:
                # By name: a device the cache's policy rounded away holds
                # no pages and must not inherit a neighbor's bytes.
                b = rbd.get(dev.name, 0)
                if not b:
                    continue
                times.append(b / perfmodel.stream_bandwidth(
                    dev, OpClass.LOAD, 4))
                lat += dev.chase_latency_ns * 1e-9 * self.cfg.n_layers
            return max(times) + lat
        rb = self.cache.read_bytes_per_step()
        fast_t = rb["fast"] / perfmodel.stream_bandwidth(
            self.topology.fast, OpClass.LOAD, 8)
        slow = self.topology.slow
        slow_t = rb["slow"] / perfmodel.stream_bandwidth(
            slow, OpClass.LOAD, 4) if slow is not None and rb["slow"] else 0.0
        # decode also pays one dependent hop into each tier holding pages
        lat = self.topology.fast.chase_latency_ns * 1e-9
        if slow is not None and rb["slow"]:
            lat += slow.chase_latency_ns * 1e-9 * self.cfg.n_layers
        return max(fast_t, slow_t) + lat

    def step(self) -> int:
        """Decode one token for all active slots. Returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, self._current_tokens())
        step_model_s = self.modeled_step_seconds()
        now = time.perf_counter()
        toks = sample_greedy(logits)
        for i in active:
            req = self.slots[i]
            req.generated.append(int(toks[i]))
            req.modeled_seconds += step_model_s
            if req.first_token_at is None:
                req.first_token_at = now
            if len(req.generated) >= req.max_new_tokens:
                req.finished_at = now
                self.done.append(req)
                self.slots[i] = None
                if self.prefix_index is not None:
                    # drop the slot's shared-page references (refcounts
                    # fall; pages stay cached for the next match)
                    self.prefix_index.release(self._slot_refs.pop(i, []))
                    self.cache = self.cache.detach_prefix(i)
                self._reset_slot(i)
                # slot rejoins the batch-class repartition population
                self.pinned_slots.discard(i)
        self._steps += 1
        self._epoch_tokens += len(active)
        self._epoch_modeled_s += step_model_s
        if self._inflight_move_bytes:
            self._inflight_compute_s += step_model_s
        if (self.caption is not None
                and self._steps % self.caption.cfg.epoch_steps == 0):
            self._caption_epoch()
        return len(active)

    # -- async migration/compute overlap (ISSUE 8) ----------------------------
    def _modeled_move_seconds(self, nbytes: int) -> float:
        """Modeled duration of an in-flight bulk migration (fast<->slow
        pipelined stream_copy on the primary slow route)."""
        if nbytes <= 0 or self.topology is None or self.topology.slow is None:
            return 0.0
        return perfmodel.pipelined_move_cost(
            self.topology.fast, self.topology.slow, int(nbytes),
            asynchronous=True).seconds

    def _drain_migrations(self) -> None:
        """Epoch-boundary fence for overlap mode: collect completions of
        migrations issued without a fence, charge the wall time actually
        spent waiting as stall, and split the modeled move time into
        hidden (ran under decode compute) vs exposed."""
        if self._inflight_move_bytes == 0:
            return
        if self.mover is not None and self.mover.asynchronous:
            t0 = time.perf_counter()
            self.mover.wait_all()
            self.migration_stall_s += time.perf_counter() - t0
        oc = perfmodel.overlap_cost(
            self._modeled_move_seconds(self._inflight_move_bytes),
            self._inflight_compute_s)
        self.telemetry.record_overlap(oc.hidden_s, oc.exposed_s,
                                      source=self.buffer_name)
        self.migration_hidden_s += oc.hidden_s
        self.migration_exposed_s += oc.exposed_s
        self._inflight_move_bytes = 0
        self._inflight_compute_s = 0.0

    def _account_actuation(self, moved_bytes: int, stall_s: float) -> None:
        if moved_bytes <= 0:
            return
        if self.overlap and self.mover is not None \
                and self.mover.asynchronous:
            # unfenced: the move runs under the next epoch's decode
            self._inflight_move_bytes += moved_bytes
        else:
            # fenced: the whole move is exposed decode stall
            move_s = self._modeled_move_seconds(moved_bytes)
            self.telemetry.record_overlap(0.0, move_s,
                                          source=self.buffer_name)
            self.migration_exposed_s += move_s
            self.migration_stall_s += stall_s

    def _retier_prefix(self, fraction: float, *, wait: bool = True) -> None:
        """Tier-aware shared-page placement, actuated with the epoch's
        Caption decision: pages referenced by live slots are
        latency-critical and stay fast; unreferenced (cached-only) pages
        follow the batch population onto the slow tier.  Moves bill each
        page ONCE whatever its refcount — deduplicated traffic."""
        blk = self.cache.prefix
        if blk is None or self.prefix_index is None:
            return
        if len(self.cache.device_names) < 2:
            return
        pdev = np.asarray(blk.page_device)
        alloc = np.nonzero(pdev >= 0)[0]
        if alloc.size == 0:
            return
        rc = self.prefix_index.page_refcounts()
        new = pdev.copy()
        for pg in alloc:
            hot = rc.get(int(pg), 0) > 0
            new[pg] = 0 if (hot or fraction <= 0.0) else 1
        self.cache = self.cache.retile_prefix(
            new, mover=self.mover, telemetry=self.telemetry,
            policy_names=self._device_names, source=self.buffer_name,
            wait=wait)

    # -- Caption control loop (§7): sample -> decide -> re-tier ---------------
    def _caption_epoch(self) -> None:
        # Previous epoch's unfenced migrations ran under this epoch's
        # decode steps — drain them before issuing new movement.
        self._drain_migrations()
        # Surface this epoch's modeled KV traffic as route counters, then
        # close the observation window: the controller reads EpochCounters
        # (bandwidths, write share, gauges), not hand-rolled numbers.
        n = self.caption.cfg.epoch_steps
        rb = self.cache.read_bytes_per_step()
        item = self.cache.k_fast.dtype.itemsize
        L, B = self.cache.k_fast.shape[:2]
        K, hd = self.cache.k_fast.shape[3:]
        write_slot_b = 2 * L * K * hd * item  # one appended token, one slot
        write_b = write_slot_b * B
        # Only unpinned slots write to the slow tier: slow_fraction() is
        # the unpinned population's operating point, so bill it against
        # the unpinned slot count, not all B slots.
        n_unpinned = B - len(self.pinned_slots)
        dt = max(self._epoch_modeled_s, 1e-9)
        src = self.buffer_name
        multi = len(self._device_names) > 2
        self.telemetry.record_move(self._fast_name, "engine",
                                   rb["fast"] * n, dt, source=src)
        w_slow = int(write_slot_b * n_unpinned * n
                     * self.cache.slow_fraction(self.pinned_slots))
        self.telemetry.record_move("engine", self._fast_name,
                                   write_b * n - w_slow, 0.0, source=src)
        if multi:
            # Per-device billing: reads and appended-token writes land on
            # the real device routes, so the window (and the arbiter's
            # per-device budgets) see each device's own traffic.  Lookups
            # are by NAME — a device the cache's policy rounded away holds
            # no pages and must not be billed a neighbor's bytes.
            rbd = self.cache.read_bytes_per_device()
            w_by_name = dict(zip(self.cache.device_names[1:],
                                 self.cache.weights(self.pinned_slots)))
            total_w = sum(w_by_name.values())
            for dev in self._device_names[1:]:
                if rbd.get(dev):
                    self.telemetry.record_move(dev, "engine",
                                               rbd[dev] * n, dt, source=src)
                w_dev = w_by_name.get(dev, 0.0)
                if w_slow and total_w > 0 and w_dev > 0:
                    self.telemetry.record_move(
                        "engine", dev,
                        int(w_slow * w_dev / total_w), 0.0, source=src)
        else:
            if rb["slow"]:
                self.telemetry.record_move(self._slow_name, "engine",
                                           rb["slow"] * n, dt, source=src)
            if w_slow:
                self.telemetry.record_move("engine", self._slow_name, w_slow,
                                           0.0, source=src)
        pressure = None
        if self.topology is not None:
            kv_fast_bytes = (self.cache.k_fast.size + self.cache.v_fast.size) * item
            pressure = min(kv_fast_bytes / self.topology.fast.capacity_bytes,
                           1.0)
        before = self.caption.fraction
        tput = self._epoch_tokens / dt
        slo_names = (tuple(self._device_names[1:]) if multi
                     else self._slow_name)
        if self.arbiter is not None:
            decision = self.arbiter.observe_window(
                src, self._epoch_window, tput, mover=self.mover,
                fast_pressure=pressure, slow_name=slo_names, seconds=dt)
        else:
            decision = self.caption.observe_window(
                self._epoch_window, tput, mover=self.mover,
                fast_pressure=pressure, slow_name=slo_names, seconds=dt)
        self._epoch_tokens = 0
        self._epoch_modeled_s = 0.0
        if abs(decision.fraction - before) > 1e-9 or (
                multi and decision.changed):
            active = self._active_slow_names()
            b0 = self.mover.bytes_submitted if self.mover is not None else 0
            t0 = time.perf_counter()
            wait = not self.overlap
            if multi and (len(decision.weights) > 1
                          or (active and active[0] in self._device_names)):
                # Expand the controller's live-device weight vector onto
                # the cache's (union) device ordinals by name — after an
                # elastic remove the two differ, and a removed device
                # must actuate to exactly zero.
                self.cache = self.cache.repartition_weights(
                    self._expand_weights(decision.weights),
                    pinned_slots=self.pinned_slots,
                    mover=self.mover, telemetry=self.telemetry,
                    policy_names=self._device_names, source=src,
                    donate=self.donate_kv, wait=wait)
            else:
                self.cache = self.cache.repartition_fraction(
                    decision.fraction, pinned_slots=self.pinned_slots,
                    mover=self.mover,
                    telemetry=self.telemetry, fast_tier=self._fast_name,
                    slow_tier=self._slow_name, source=src,
                    donate=self.donate_kv, wait=wait)
            if self.cache.prefix is not None:
                self._retier_prefix(decision.fraction, wait=wait)
            moved = ((self.mover.bytes_submitted - b0)
                     if self.mover is not None else 0)
            self._account_actuation(moved, time.perf_counter() - t0)
            self.register_pools()
            # Page rounding may achieve less (or none) of the request: the
            # controller must continue from the real operating point.  With
            # zero tunable slots (everything SLO-pinned) there IS no
            # operating point to read back — feeding 0.0 would corrupt the
            # walk, so the decision stands until slots unpin.
            if n_unpinned > 0:
                if multi and self.caption.n_slow > 1:
                    self.caption.actuated_weights(self._project_weights(
                        self.cache.weights(self.pinned_slots)))
                else:
                    self.caption.actuated(
                        self.cache.slow_fraction(self.pinned_slots))
        self.caption_trace.append((self._steps, self.caption.fraction))

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        self._drain_migrations()
        return self.done
