"""Token samplers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_temperature(logits: jax.Array, key, temperature: float = 1.0):
    return jax.random.categorical(key, logits / max(temperature, 1e-6), axis=-1)


def sample_topk(logits: jax.Array, key, k: int = 40, temperature: float = 1.0):
    vals, idx = jax.lax.top_k(logits, k)
    choice = jax.random.categorical(key, vals / max(temperature, 1e-6), axis=-1)
    return jnp.take_along_axis(idx, choice[..., None], axis=-1)[..., 0]
