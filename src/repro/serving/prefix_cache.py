"""Shared-prefix KV page pool with a refcounted radix index (ISSUE 8).

Serving workloads repeat prompt prefixes (system prompts, few-shot
headers, chat history): under decode-replay prefill, every repeated
prefix token costs one full decode step.  This module stores the KV
pages of previously-seen prefixes ONCE, in a shared tier-placed pool,
and lets every later request whose prompt starts with the same tokens
attend those pages *by reference*:

* :class:`PrefixCache` is the host-side index — a radix trie keyed by
  full ``page_t``-token pages, each node owning one pool page with a
  refcount (live slot references) and an LRU tick.  Eviction reclaims
  only refcount-zero leaves, so a page shared by any active request
  can never be freed out from under it.
* :class:`PrefixBlock` is the device-side pool — ``(L, R, page_t, K,
  hd)`` K/V arrays plus per-slot page tables — registered as a pytree
  so it rides inside :class:`~repro.serving.kv_cache.TieredKVCache`
  through the jitted decode step with a stable treedef (attaching or
  releasing a prefix changes array values, never shapes).

Sharing is exact: K rows were written with rope applied at absolute
positions, and a shared prefix occupies the same absolute positions in
every referencing request, so the cached rows are valid verbatim.  The
pool contributes one extra attention partition per decode step, merged
with the per-device partials through the same log-sum-exp combine —
no attention math changes.

Divergence *inside* a page is copy-on-write: the matched head of the
page is copied into the diverging request's own tier-placed pages (its
private, writable storage) and the shared page stays immutable.  Pool
pages carry a per-page device label: migration and storage bill each
page ONCE regardless of how many slots reference it — the
deduplication the Caption controller and arbiter observe.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

#: slot_pages sentinel: no pool page attached at this logical page.
NO_PAGE = -1
#: page_device sentinel: pool page not allocated.
UNALLOCATED = -1


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PrefixBlock:
    """Device-side shared-prefix page pool, carried inside the KV cache.

    ``k``/``v`` are ``(L, R, page_t, K, hd)``: ``R`` pool pages of
    ``page_t`` token rows each.  ``slot_pages[b, j]`` is the pool page
    backing logical page ``j`` of slot ``b`` (``NO_PAGE`` when the slot
    owns that page privately), and ``slot_shared[b]`` the number of
    leading token positions served by references — the boundary below
    which the slot's own pool rows are sentineled out of attention.
    """

    k: jax.Array
    v: jax.Array
    slot_pages: jax.Array   # (B, P_max) int32
    slot_shared: jax.Array  # (B,) int32
    page_device: jax.Array  # (R,) int32; UNALLOCATED = free pool slot
    page_t: int

    def tree_flatten(self):
        return ((self.k, self.v, self.slot_pages, self.slot_shared,
                 self.page_device), (self.page_t,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        k, v, slot_pages, slot_shared, page_device = children
        return cls(k, v, slot_pages, slot_shared, page_device,
                   page_t=aux[0])

    @classmethod
    def create(cls, batch: int, pool_pages: int, max_pages: int,
               page_t: int, n_layers: int, n_kv_heads: int, head_dim: int,
               dtype) -> "PrefixBlock":
        return cls(
            k=jnp.zeros((n_layers, pool_pages, page_t, n_kv_heads,
                         head_dim), dtype),
            v=jnp.zeros((n_layers, pool_pages, page_t, n_kv_heads,
                         head_dim), dtype),
            slot_pages=jnp.full((batch, max_pages), NO_PAGE, jnp.int32),
            slot_shared=jnp.zeros((batch,), jnp.int32),
            page_device=jnp.full((pool_pages,), UNALLOCATED, jnp.int32),
            page_t=page_t)

    @property
    def pool_pages(self) -> int:
        return self.k.shape[1]

    def page_bytes(self) -> int:
        L, _, pt, K, hd = self.k.shape
        return 2 * L * pt * K * hd * self.k.dtype.itemsize

    def partition(self, layer: int):
        """(k, v, valid) attention partial over the referenced pool pages
        — one extra partition per decode step, exactly merged with the
        per-device partials.  Slots with no references contribute an
        all-invalid row, which the finite-NEG_INF merge weights to zero.
        """
        R = self.k.shape[1]
        pt = self.page_t
        B, Pm = self.slot_pages.shape
        K, hd = self.k.shape[3:]
        rows = jnp.clip(self.slot_pages, 0, R - 1).reshape(-1)
        k = jnp.take(self.k[layer], rows, axis=0).reshape(B, Pm * pt, K, hd)
        v = jnp.take(self.v[layer], rows, axis=0).reshape(B, Pm * pt, K, hd)
        valid = jnp.repeat(self.slot_pages >= 0, pt, axis=1)
        return k, v, valid


class _Node:
    """One trie node == one pool page holding one full token page."""

    __slots__ = ("page", "refcount", "tick", "children", "parent", "key")

    def __init__(self, page: int, parent: dict, key: tuple, tick: int):
        self.page = page
        self.refcount = 0
        self.tick = tick
        self.children: dict[tuple, "_Node"] = {}
        self.parent = parent  # the children-dict this node lives in
        self.key = key

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"_Node(page={self.page}, rc={self.refcount}, "
                f"children={len(self.children)})")


class PrefixCache:
    """Host-side radix index over full ``page_t``-token prompt pages.

    Pure bookkeeping: allocation, matching, refcounts, LRU eviction.
    The KV bytes live in the :class:`PrefixBlock`; callers copy rows in
    and out of the pool through the TieredKVCache helpers.
    """

    def __init__(self, pool_pages: int, page_t: int):
        self.page_t = int(page_t)
        self.pool_pages = int(pool_pages)
        self.root: dict[tuple, _Node] = {}
        self._free = list(range(pool_pages - 1, -1, -1))
        self._tick = 0
        self.nodes: dict[int, _Node] = {}  # pool page -> node
        # observability
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.cow_copies = 0

    # -- lookup ----------------------------------------------------------------
    def _page_key(self, prompt: Sequence[int], p: int) -> tuple:
        pt = self.page_t
        return tuple(int(t) for t in prompt[p * pt:(p + 1) * pt])

    def match(self, prompt: Sequence[int]
              ) -> tuple[list[_Node], Optional[_Node], int]:
        """Longest shared prefix of ``prompt`` in the index.

        Returns ``(nodes, partial, partial_len)``: ``nodes`` are the
        fully-matched page nodes (coverage capped at ``len(prompt) - 1``
        tokens — the last prompt token always replays so decode has a
        current-token activation), and ``partial`` the child whose page
        shares ``partial_len`` leading tokens with the remainder — the
        copy-on-write divergence point."""
        pt = self.page_t
        limit = max(len(prompt) - 1, 0) // pt
        children = self.root
        nodes: list[_Node] = []
        for p in range(limit):
            node = children.get(self._page_key(prompt, p))
            if node is None:
                break
            nodes.append(node)
            children = node.children
        rest = [int(t) for t in prompt[len(nodes) * pt: len(prompt) - 1]]
        partial, plen = None, 0
        if rest:
            for key, node in children.items():
                n = 0
                for a, b in zip(key, rest):
                    if a != b:
                        break
                    n += 1
                if n > plen:
                    partial, plen = node, n
        if nodes or plen:
            self.hits += 1
        else:
            self.misses += 1
        return nodes, partial, plen

    # -- reference management ---------------------------------------------------
    def touch(self, node: _Node) -> None:
        self._tick += 1
        node.tick = self._tick

    def acquire(self, nodes: Sequence[_Node]) -> None:
        for n in nodes:
            n.refcount += 1
            self.touch(n)

    def release(self, nodes: Sequence[_Node]) -> None:
        for n in nodes:
            assert n.refcount > 0, "release without matching acquire"
            n.refcount -= 1

    # -- insertion / eviction ---------------------------------------------------
    def _alloc(self) -> Optional[int]:
        if self._free:
            return self._free.pop()
        # LRU-evict: only refcount-zero LEAVES are reclaimable — a page
        # referenced by a live slot (or holding a live subtree) survives.
        victim = min((n for n in self.nodes.values()
                      if n.refcount == 0 and not n.children),
                     key=lambda n: n.tick, default=None)
        if victim is None:
            return None
        assert victim.refcount == 0, "evicting a referenced prefix page"
        del victim.parent[victim.key]
        del self.nodes[victim.page]
        self.evictions += 1
        return victim.page

    def insert(self, prompt: Sequence[int], matched: Sequence[_Node]
               ) -> list[tuple[int, _Node]]:
        """Extend the trie path ``matched`` with ``prompt``'s remaining
        full pages.  Returns ``[(page_no, node)]`` placements whose pool
        pages the caller must fill; stops early when the pool is
        exhausted of reclaimable pages."""
        pt = self.page_t
        limit = max(len(prompt) - 1, 0) // pt
        children = matched[-1].children if matched else self.root
        placed: list[tuple[int, _Node]] = []
        for p in range(len(matched), limit):
            key = self._page_key(prompt, p)
            node = children.get(key)
            if node is None:
                slot = self._alloc()
                if slot is None:
                    break
                self._tick += 1
                node = _Node(slot, children, key, self._tick)
                children[key] = node
                self.nodes[slot] = node
                placed.append((p, node))
            self.touch(node)
            children = node.children
        return placed

    # -- accounting -------------------------------------------------------------
    def page_refcounts(self) -> dict[int, int]:
        return {page: n.refcount for page, n in self.nodes.items()}

    def allocated_pages(self) -> int:
        return len(self.nodes)

    def dedup_pages(self) -> int:
        """Pool pages' worth of storage saved by sharing right now: each
        reference beyond storing the page once is a page the baseline
        would have materialized privately."""
        return sum(max(n.refcount - 1, 0) for n in self.nodes.values())
