"""Tiered, page-interleaved KV cache + decode step (the Redis §5.1 analogue).

The KV time axis is split into pages placed across (fast, slow...) devices
by a MemPolicy — the paper's N:M weighted interleave applied to serving
state.  Decode attends over every device partition and merges exactly via
log-sum-exp (attention.merge_partials); per-step per-tier byte counts
feed the perfmodel so benchmarks reproduce the paper's p99/QPS curves
on this CPU-only box.

Placement is **per slot**: each batch slot carries its own page->device
map, so a latency-SLO request can pin its pages fast (Fig. 7: any CXL
fraction hurts a µs-SLO app) while batch-class neighbors tolerate slow
pages.  Pinned slots are excluded from ``repartition_fraction`` — the
Caption loop only tunes the batch-class population.

Physical layout (ISSUE 7): storage is **per-device pools** — one
``(L, B, T_d, K, hd)`` K/V pool pair per device ordinal, so storage
bytes match the per-device accounting (``read_bytes_per_device``)
instead of collapsing every slow device onto one shared pool.  The fast
pool is sized for ALL pages (the fast tier is the home tier); each slow
pool holds its own pages plus ``slow_headroom`` pages of capacity.  A
retile whose per-device page counts fit the held capacities takes the
**O(Δ) stable path**: moved pages land in free slots of their
destination pool (gather-first, then write), unreceiving pools are
reused as-is, and with ``donate=True`` the receiving pools are patched
in place through the jitted donated scatter — zero full-pool copies.
Only when a pool outgrows its capacity (or the device set changes) does
the legacy full rebuild run, re-ranking locals and re-padding by the
headroom (jitted decode retraces once, by design).

Applies to the uniform-attention (dense/vlm/moe-attention) families;
recurrent state (rwkv/rglru) is latency-bound and planner-pinned fast.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.donation import FULL_SHARD_COPIES, donated_kv_update
from repro.core.interleave import (_policy_device_map, minimal_delta_weights,
                                   resolve_device_names, route_pure_runs)
from repro.core.mover import LANE_BULK, LANE_LATENCY
from repro.core.policy import MemPolicy
from repro.core.telemetry import GLOBAL_TELEMETRY
from repro.models import attention as attn
from repro.models.common import apply_norm, dtype_of, mlp_apply
from repro.serving.prefix_cache import NO_PAGE, UNALLOCATED, PrefixBlock

_INT32_MAX = np.iinfo(np.int32).max


def _kv_layout_rows(assign: np.ndarray, page_t: int):
    """LEGACY two-pool storage view of a (B, n_pages) page->tier map:
    local indices, shared part sizes, and per-slot per-part global
    positions (INT32_MAX pads never validate in the attention masks).

    Physical storage is per-device (:func:`_kv_device_layout_rows`)
    since ISSUE 7; this two-tier collapse remains the reference layout
    the per-device one generalizes (equivalence with the per-slot
    ``tier_page_map`` walk is asserted by tests/test_hotpaths.py).

    Fully vectorized (argsort/cumsum over the whole B x P map)."""
    assign = np.asarray(assign)
    B, P = assign.shape
    assign01 = np.minimum(assign, 1).astype(np.int8)
    is_slow = assign01.astype(bool)
    # local = rank of the page within its tier, in page order (the same
    # arrival-order discipline tier_page_map uses per slot)
    fast_rank = np.cumsum(~is_slow, axis=1) - 1
    slow_rank = np.cumsum(is_slow, axis=1) - 1
    local = np.where(is_slow, slow_rank, fast_rank).astype(np.int32)
    n_slow = is_slow.sum(axis=1).astype(np.int64)
    Tf = P * page_t
    Ts = int(n_slow.max(initial=0)) * page_t
    # global positions sorted by (tier, page): fast pages' spans first.
    order = np.argsort(assign01, axis=1, kind="stable")
    allpos = (order[:, :, None] * page_t
              + np.arange(page_t)).reshape(B, Tf).astype(np.int32)
    col = np.arange(Tf)
    fast_len = (P - n_slow)[:, None] * page_t
    pos_fast = np.where(col[None, :] < fast_len, allpos, _INT32_MAX)
    if Ts:
        cols = np.arange(Ts)
        gidx = np.minimum(fast_len + cols[None, :], Tf - 1)
        pos_slow = np.where(cols[None, :] < n_slow[:, None] * page_t,
                            np.take_along_axis(allpos, gidx, axis=1),
                            _INT32_MAX)
    else:
        pos_slow = np.zeros((B, 0), np.int32)
    return (assign01, local, Tf, Ts,
            pos_fast.astype(np.int32), pos_slow.astype(np.int32))


def _kv_device_layout_rows(assign: np.ndarray, page_t: int, n_devices: int):
    """Per-DEVICE physical layout for a (B, n_pages) page->device map.

    Returns ``(local, counts, pos_list)``: ``local[b, p]`` is page p's
    rank within its owning device (page order — the rank-order
    discipline every full rebuild restores), ``counts[d, b]`` the page
    count of device d in slot b, and ``pos_list[d]`` the
    ``(B, max_b counts[d, b] * page_t)`` global position held by each
    pool slot (INT32_MAX pads never validate in the attention masks).
    The two-device case reproduces :func:`_kv_layout_rows` exactly."""
    assign = np.asarray(assign)
    B, P = assign.shape
    local = np.zeros((B, P), np.int32)
    counts = np.zeros((n_devices, B), np.int64)
    pos_list = []
    at = np.arange(page_t)
    for d in range(n_devices):
        mask = assign == d
        counts[d] = mask.sum(axis=1)
        local = np.where(mask, np.cumsum(mask, axis=1) - 1, local).astype(
            np.int32)
        need = int(counts[d].max(initial=0))
        if need == 0:
            pos_list.append(np.zeros((B, 0), np.int32))
            continue
        # pages of d first (stable keeps page order), then the rest
        order = np.argsort(~mask, axis=1, kind="stable")[:, :need]
        allpos = (order[:, :, None] * page_t + at).reshape(
            B, need * page_t).astype(np.int32)
        cols = np.arange(need * page_t)
        pos_d = np.where(cols[None, :] < counts[d][:, None] * page_t,
                         allpos, _INT32_MAX)
        pos_list.append(pos_d.astype(np.int32))
    return local, counts, pos_list


def _pad_pos(pos: np.ndarray, T: int) -> np.ndarray:
    """Pad a (B, t) position map to (B, T) with never-valid sentinels."""
    if pos.shape[1] >= T:
        return pos
    pad = np.full((pos.shape[0], T - pos.shape[1]), _INT32_MAX, np.int32)
    return np.concatenate([pos, pad], axis=1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TieredKVCache:
    #: per-device K/V pools: ``k_parts[d]`` is ``(L, B, T_d, K, hd)``.
    #: ``T_0 = n_pages * page_t`` (the fast home tier never reallocates);
    #: slow pools hold their own pages plus ``slow_headroom`` pages.
    k_parts: tuple
    v_parts: tuple
    lengths: jax.Array  # (B,)
    # static addressing (per-slot page assignment)
    page_local: jax.Array  # (B, n_pages): page slot within its OWN device pool
    #: per-device (B, T_d) global position held by each pool slot.
    pos_parts: tuple
    #: per-page owning DEVICE ordinal (0 = fast, i >= 1 = slow device i-1);
    #: storage AND accounting are per device (ISSUE 7).
    page_device: jax.Array  # (B, n_pages) int8
    page_t: int
    #: route labels per device ordinal (telemetry/mover tier names).
    device_names: tuple = ("fast", "slow")
    #: slow-pool capacity padding, in pages per slot per device.  0 =
    #: each slow pool is sized exactly for its current worst slot (every
    #: retile that changes that resizes it — the legacy layout); > 0 =
    #: each slow pool keeps ``max_count + slow_headroom`` pages of
    #: capacity, so Caption repartitions and SLO pins that fit take the
    #: O(Δ) stable path and never change the decode step's shapes (zero
    #: retraces across probe epochs).
    slow_headroom: int = 0
    #: shared-prefix page pool (ISSUE 8) — ``None`` disables sharing and
    #: keeps the legacy treedef.  When set, decode attends one extra
    #: partition of referenced pool pages; a slot's own pool rows below
    #: its ``slot_shared`` boundary are pos-sentineled out of attention
    #: (the reference serves those positions instead).
    prefix: Optional[PrefixBlock] = None

    def tree_flatten(self):
        children = (tuple(self.k_parts), tuple(self.v_parts), self.lengths,
                    self.page_local, tuple(self.pos_parts), self.page_device,
                    self.prefix)
        return children, (self.page_t, self.device_names,
                          self.slow_headroom)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (k_parts, v_parts, lengths, page_local, pos_parts, page_device,
         prefix) = children
        return cls(tuple(k_parts), tuple(v_parts), lengths, page_local,
                   tuple(pos_parts), page_device, page_t=aux[0],
                   device_names=aux[1], slow_headroom=aux[2], prefix=prefix)

    # -- two-pool compatibility views ------------------------------------------
    @property
    def k_fast(self) -> jax.Array:
        return self.k_parts[0]

    @property
    def v_fast(self) -> jax.Array:
        return self.v_parts[0]

    @property
    def pos_fast(self) -> jax.Array:
        return self.pos_parts[0]

    @property
    def k_slow(self) -> jax.Array:
        """The FIRST slow device's pool (two-device compatibility view;
        on wider topologies index ``.k_parts`` directly)."""
        return self.k_parts[1]

    @property
    def v_slow(self) -> jax.Array:
        return self.v_parts[1]

    @property
    def pos_slow(self) -> jax.Array:
        return self.pos_parts[1]

    @property
    def page_tier(self) -> jax.Array:
        """(B, n_pages) int8 0/1 fast-vs-slow view of the device map."""
        return jnp.minimum(self.page_device, 1).astype(jnp.int8)

    # -- host-side map cache ----------------------------------------------------
    def _host_dev(self) -> np.ndarray:
        """Cached numpy page->device map: the Caption loop reads
        ``slow_fraction``/``weights`` every epoch and must not re-sync
        the device array each time."""
        cached = self.__dict__.get("_host_cache")
        if cached is None:
            cached = np.asarray(self.page_device)
            self.__dict__["_host_cache"] = cached
        return cached

    # -- construction ---------------------------------------------------------
    @classmethod
    def create(cls, cfg: ArchConfig, batch: int, max_len: int,
               policy: MemPolicy, *, page_t: int = 256, dtype=None,
               slow_headroom: int = 0) -> "TieredKVCache":
        dt = dtype or dtype_of(cfg.param_dtype)
        L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
        page_t = min(page_t, max_len)
        assert max_len % page_t == 0
        n_pages = max_len // page_t
        slow_headroom = min(max(int(slow_headroom), 0), n_pages)
        dev_row, names = _policy_device_map(policy, n_pages)
        dev = np.broadcast_to(dev_row.astype(np.int8), (batch, n_pages))
        n_devices = len(names)
        local, counts, pos_list = _kv_device_layout_rows(dev, page_t,
                                                         n_devices)
        caps = [n_pages * page_t]  # fast pool holds every page
        for d in range(1, n_devices):
            caps.append(min(int(counts[d].max(initial=0)) + slow_headroom,
                            n_pages) * page_t)
        out = cls(
            k_parts=tuple(jnp.zeros((L, batch, caps[d], K, hd), dt)
                          for d in range(n_devices)),
            v_parts=tuple(jnp.zeros((L, batch, caps[d], K, hd), dt)
                          for d in range(n_devices)),
            lengths=jnp.zeros((batch,), jnp.int32),
            page_local=jnp.asarray(local, jnp.int32),
            pos_parts=tuple(jnp.asarray(_pad_pos(pos_list[d], caps[d]))
                            for d in range(n_devices)),
            page_device=jnp.asarray(dev, jnp.int8),
            page_t=page_t,
            device_names=names,
            slow_headroom=slow_headroom,
        )
        out.__dict__["_host_cache"] = np.asarray(dev)
        return out

    # -- addressing -------------------------------------------------------------
    def _route(self, pos: jax.Array):
        """token position -> (owning device ordinal, flat pool row)."""
        page = pos // self.page_t
        page = jnp.minimum(page, self.page_device.shape[1] - 1)[:, None]
        dev = jnp.take_along_axis(self.page_device, page, axis=1)[:, 0]
        local = jnp.take_along_axis(self.page_local, page, axis=1)[:, 0]
        return dev, local * self.page_t + pos % self.page_t

    def slow_fraction(self, pinned_slots=()) -> float:
        """Slow-page share of the *tunable* slots (all slots minus
        ``pinned_slots``) — the operating point the Caption actuation
        feedback must report.  Pin state lives with the engine (request
        SLO policy), not in this data structure."""
        tiers = np.minimum(self._host_dev(), 1).astype(np.float32)
        pinned = set(pinned_slots)
        unpinned = [b for b in range(tiers.shape[0]) if b not in pinned]
        if not unpinned:
            return 0.0
        return float(tiers[unpinned].mean())

    def weights(self, pinned_slots=()) -> tuple[float, ...]:
        """Per-slow-device page shares of the tunable slots (the Caption
        weight-vector operating point on an N-device topology)."""
        dev = self._host_dev()
        pinned = set(pinned_slots)
        unpinned = [b for b in range(dev.shape[0]) if b not in pinned]
        n_slow = max(len(self.device_names) - 1, 1)
        if not unpinned:
            return (0.0,) * n_slow
        sub = dev[unpinned]
        return tuple(float((sub == i + 1).mean()) for i in range(n_slow))

    def device_fractions(self, pinned_slots=()) -> dict[str, float]:
        """Per-device page share of the tunable slots, keyed by name."""
        w = self.weights(pinned_slots)
        out = {self.device_names[0]: 1.0 - sum(w)}
        for i, share in enumerate(w):
            out[self.device_names[i + 1]] = share
        return out

    # -- per-step traffic (drives the latency/QPS simulation) ------------------
    def read_bytes_per_step(self) -> dict[str, int]:
        """Bytes streamed per decode step per tier (both K and V), from the
        per-slot page placement (pinned slots bill fast-only)."""
        item = self.k_parts[0].dtype.itemsize
        L = self.k_parts[0].shape[0]
        K, hd = self.k_parts[0].shape[3:]
        tiers = np.minimum(self._host_dev(), 1)
        n_pages = tiers.shape[1]
        slow_pages = tiers.sum(axis=1)
        fast_rows = int(np.maximum((n_pages - slow_pages), 1).sum()) * self.page_t
        slow_rows = int(slow_pages.sum()) * self.page_t
        out = {
            "fast": 2 * L * fast_rows * K * hd * item,
            "slow": 2 * L * slow_rows * K * hd * item,
        }
        # Prefix references READ per referencing slot (every reader
        # streams the rows), unlike storage/migration, billed once.
        for dev_ord, n_refs in self._prefix_ref_pages().items():
            key = "fast" if dev_ord == 0 else "slow"
            out[key] += n_refs * self._page_kv_bytes()
        return out

    def read_bytes_per_device(self) -> dict[str, int]:
        """Per-device decode-step read bytes, keyed by device name — the
        slow total splits across the real devices holding the pages (each
        device streams on its own link, so the modeled step time is the
        max, not the sum)."""
        item = self.k_parts[0].dtype.itemsize
        L = self.k_parts[0].shape[0]
        K, hd = self.k_parts[0].shape[3:]
        dev = self._host_dev()
        out = {}
        ref_pages = self._prefix_ref_pages()
        for i, name in enumerate(self.device_names):
            pages = (dev == i).sum(axis=1)
            if i == 0:
                pages = np.maximum(pages, 1)  # >= 1 fast page per slot
            out[name] = (2 * L * int(pages.sum()) * self.page_t * K * hd
                         * item
                         + ref_pages.get(i, 0) * self._page_kv_bytes())
        return out

    def storage_bytes_per_device(self) -> dict[str, int]:
        """Physically OCCUPIED bytes per device pool (valid page slots,
        K and V, all layers), read off the pos maps' sentinel structure.
        With per-device pools this equals the ``read_bytes_per_device``
        accounting (modulo the fast tier's >= 1-page billing floor) —
        the ISSUE 7 storage == accounting invariant."""
        item = self.k_parts[0].dtype.itemsize
        L = self.k_parts[0].shape[0]
        K, hd = self.k_parts[0].shape[3:]
        out = {}
        pfx_dev = (np.asarray(self.prefix.page_device)
                   if self.prefix is not None else None)
        for i, name in enumerate(self.device_names):
            rows = int((np.asarray(self.pos_parts[i]) != _INT32_MAX).sum())
            out[name] = 2 * L * rows * K * hd * item
            if pfx_dev is not None:
                # shared pool pages occupy storage ONCE, however many
                # slots reference them — the dedup Caption observes.
                out[name] += (int((pfx_dev == i).sum())
                              * self._page_kv_bytes())
        return out

    def pool_bytes_per_device(self) -> dict[str, int]:
        """ALLOCATED pool capacity per device, keyed by device name —
        what the :class:`~repro.core.ledger.TierLedger` should bill.

        Unlike :meth:`storage_bytes_per_device` (occupied page slots),
        this is the framework-RESERVED backing: the full K/V/pos pool
        arrays per device, plus the shared-prefix pool's pages billed to
        the device their label names.  Unallocated prefix pool slack is
        billed to the fast tier (the pool is materialized as one buffer
        and free slots have not been pushed over a CXL link yet)."""
        out = {}
        for i, name in enumerate(self.device_names):
            out[name] = int(
                (self.k_parts[i].size + self.v_parts[i].size)
                * self.k_parts[i].dtype.itemsize
                + self.pos_parts[i].size * self.pos_parts[i].dtype.itemsize)
        if self.prefix is not None:
            pdev = np.asarray(self.prefix.page_device)
            pb = self.prefix.page_bytes()
            for i, name in enumerate(self.device_names):
                out[name] += int((pdev == i).sum()) * pb
            out[self.device_names[0]] += (
                int((pdev == UNALLOCATED).sum()) * pb)
        return out

    def register_in_ledger(self, ledger, buffer: str = "kv_cache", *,
                           device_names=None, note: str = "serving KV pool",
                           strict: bool = False) -> dict[str, int]:
        """Register (or refresh) this cache's pools in a
        :class:`~repro.core.ledger.TierLedger` so ``report()`` covers
        the serving plane's framework-managed bytes.

        ``device_names`` maps this cache's device ordinals onto the
        ledger topology's tier names when the cache was built with the
        generic ``("fast", "slow")`` labels.  Re-registering under the
        same ``buffer`` releases the previous entries first, so epoch
        refreshes after a re-tile never double-bill."""
        names = tuple(device_names) if device_names else self.device_names
        if len(names) != len(self.device_names):
            raise ValueError(
                f"{len(names)} names for {len(self.device_names)} devices")
        pool = self.pool_bytes_per_device()
        ledger.release(buffer)
        billed = {}
        for cache_name, ledger_name in zip(self.device_names, names):
            nbytes = pool[cache_name]
            if not nbytes:
                continue
            try:
                ledger.register(buffer, ledger_name, nbytes, note,
                                strict=strict)
            except KeyError:
                # device outside the ledger topology (e.g. elastically
                # removed): its residual backing has no tier to bill
                continue
            billed[ledger_name] = nbytes
        return billed

    def _prefix_ref_pages(self) -> dict[int, int]:
        """Per-device ordinal count of prefix-page REFERENCES held by
        slots (a page referenced by r slots counts r times — every
        reader streams it each decode step)."""
        if self.prefix is None:
            return {}
        sp = np.asarray(self.prefix.slot_pages)
        pdev = np.asarray(self.prefix.page_device)
        refs = sp[sp >= 0]
        if refs.size == 0:
            return {}
        devs = pdev[refs]
        return {int(d): int((devs == d).sum()) for d in np.unique(devs)
                if d >= 0}

    def capacity_pages(self) -> tuple:
        """Per-device pool capacity in pages per slot."""
        return tuple(kp.shape[2] // self.page_t for kp in self.k_parts)

    # -- append + attend --------------------------------------------------------
    def append_layer(self, layer: jax.Array, k_new: jax.Array, v_new: jax.Array):
        """Scatter one token's K/V for one layer. k_new: (B, K, hd)."""
        B = k_new.shape[0]
        dev, local = self._route(self.lengths)
        bidx = jnp.arange(B)
        k_parts = list(self.k_parts)
        v_parts = list(self.v_parts)
        for d in range(len(k_parts)):
            T_d = k_parts[d].shape[2]
            if T_d == 0:
                continue
            # rows owned by another device are pushed out of bounds and
            # dropped — every pool sees one shape-static scatter.
            idx = jnp.where(dev == d, local, T_d)
            k_parts[d] = k_parts[d].at[layer, bidx, idx].set(
                k_new.astype(k_parts[d].dtype), mode="drop")
            v_parts[d] = v_parts[d].at[layer, bidx, idx].set(
                v_new.astype(v_parts[d].dtype), mode="drop")
        return dataclasses.replace(
            self, k_parts=tuple(k_parts), v_parts=tuple(v_parts))

    # -- SLO pinning (per-request latency class) --------------------------------
    def pin_slot(self, i: int, **kwargs) -> "TieredKVCache":
        """Move slot ``i``'s pages all-fast (latency-SLO admission) on the
        mover's latency lane.  The *exclusion* from future repartitions is
        the engine's job: it tracks the pinned-slot set (request policy)
        and passes it as ``pinned_slots`` — keeping SLO state out of this
        data structure keeps the jitted decode treedef stable."""
        new_dev = self._host_dev().copy()
        new_dev[i] = 0
        return self._retile(new_dev, lane=LANE_LATENCY, **kwargs)

    # -- dynamic re-tiering (Caption actuation path) ----------------------------
    def repartition(self, policy: MemPolicy, pinned_slots=(), **kwargs
                    ) -> "TieredKVCache":
        """Re-tier every unpinned slot's KV pages under ``policy``, moving
        only delta pages.

        Host-side (between decode steps).  Pages whose device is unchanged
        are sliced across; changed pages ship through the BulkMover (or
        are accounted to telemetry) on their real ``(src_device,
        dst_device)`` route, so inter-tier traffic is exactly
        ``delta_pages * page_kv_bytes``.  Attention output is invariant:
        the same (position, K, V) triples exist after the move, only
        their owning device changes.  Slots in ``pinned_slots``
        (latency-SLO) keep their all-fast rows.
        """
        n_pages = self.page_device.shape[1]
        row, names = _policy_device_map(policy, n_pages)
        pinned = set(pinned_slots)
        new_dev = self._host_dev().copy()
        for b in range(new_dev.shape[0]):
            if b not in pinned:
                new_dev[b] = row
        return self._retile(new_dev, policy_names=names, **kwargs)

    def repartition_fraction(self, fraction: float, pinned_slots=(),
                             **kwargs) -> "TieredKVCache":
        """Re-tier unpinned slots to ``fraction`` slow flipping the fewest
        KV pages per slot (two-device path)."""
        return self.repartition_weights((float(fraction),), pinned_slots,
                                        **kwargs)

    def repartition_weights(self, weights, pinned_slots=(), **kwargs
                            ) -> "TieredKVCache":
        """Re-tier unpinned slots to a per-slow-device weight vector,
        flipping the fewest KV pages per slot.  A vector that rounds to
        every slot's current per-device counts is a true no-op (``self``
        returned, no mover work enqueued)."""
        pinned = set(pinned_slots)
        n_devices = max(len(self.device_names), len(tuple(weights)) + 1)
        new_dev = self._host_dev().copy()
        changed = False
        for b in range(new_dev.shape[0]):
            if b in pinned:
                continue
            row = minimal_delta_weights(new_dev[b], tuple(weights),
                                        n_devices)
            if row is not None:
                new_dev[b] = row
                changed = True
        if not changed:
            return self
        return self._retile(new_dev, **kwargs)

    def drain_device(self, device, pinned_slots=(), *, weights=None,
                     **kwargs) -> "TieredKVCache":
        """Move every unpinned slot's pages off one slow device (elastic
        hot-remove drain).

        ``device`` is a slow-device ordinal (>= 1) or its name.  The
        departing share goes to the surviving slow devices proportionally
        to their current shares by default, or to an explicit per-device
        ``weights`` target (which must zero the departing device).  The
        move rides the normal minimal-delta repartition: run-coalesced
        LANE_BULK descriptors on real (dead device -> survivor) routes,
        so in-flight requests keep decoding — only page ownership moves.
        Pinned (latency-SLO) slots are already all-fast and untouched."""
        if isinstance(device, str):
            if device not in self.device_names:
                raise KeyError(device)
            i = self.device_names.index(device)
        else:
            i = int(device)
        if not 1 <= i < len(self.device_names):
            raise KeyError(device)
        if weights is None:
            cur = list(self.weights(pinned_slots))
            departing, cur[i - 1] = cur[i - 1], 0.0
            rest = sum(cur)
            if departing > 0 and rest > 0:
                cur = [w + departing * w / rest for w in cur]
            weights = tuple(cur)
        elif weights[i - 1] > 0:
            raise ValueError(
                f"drain target keeps weight on {self.device_names[i]!r}")
        return self.repartition_weights(weights, pinned_slots, **kwargs)

    def _route_names(self, n_devices: int,
                     policy_names: Optional[tuple] = None,
                     fast_tier: Optional[str] = None,
                     slow_tier: Optional[str] = None) -> tuple:
        return resolve_device_names(self.device_names, n_devices,
                                    policy_names, fast_tier, slow_tier)

    # -- retile internals -------------------------------------------------------
    def _page_kv_bytes(self) -> int:
        L = self.k_parts[0].shape[0]
        K, hd = self.k_parts[0].shape[3:]
        return 2 * L * self.page_t * K * hd * self.k_parts[0].dtype.itemsize

    def _slot_groups(self, old_dev, new_dev, old_local) -> dict:
        """Slots sharing (old row, new row, old locals) — the whole
        batch-class population after a repartition — move as ONE batched
        slice per run instead of per-slot-per-page.  The locals are part
        of the key because the stable path's free-slot allocation makes
        them history-dependent (equal device rows no longer imply equal
        physical layouts)."""
        groups: dict = {}
        for b in range(old_dev.shape[0]):
            key = (old_dev[b].tobytes() + new_dev[b].tobytes()
                   + old_local[b].tobytes())
            groups.setdefault(key, []).append(b)
        return groups

    def _ship_retile(self, groups, old_dev, new_dev, old_local, route, *,
                     mover, telemetry, source, lane, wait=True) -> None:
        """Movement metering on real device routes — including
        slow->slow hops (the paper's C2C class).  Moved pages coalesce
        into route-pure runs of consecutive source locals; each run is
        one contiguous slab of its source pool and ships as ONE batched
        descriptor (billed bytes identical to per-page).  Runs before
        any pool is written, so payloads slice pristine source data."""
        pt = self.page_t
        page_kv_bytes = self._page_kv_bytes()
        k_np = [np.asarray(kp) for kp in self.k_parts]
        v_np = [np.asarray(vp) for vp in self.v_parts]
        descs = []
        for slots in groups.values():
            b0, sl = slots[0], np.asarray(slots)
            od, nd = old_dev[b0].astype(np.int64), new_dev[b0].astype(np.int64)
            ol = old_local[b0].astype(np.int64)
            moved = np.nonzero(od != nd)[0]
            if moved.size == 0:
                continue
            order, starts, ends = route_pure_runs(
                od[moved], nd[moved], ol[moved])
            mv = moved[order]
            for s, e in zip(starts, ends):
                p0 = mv[s]
                d0, d1 = int(od[p0]), int(nd[p0])
                l0, run = int(ol[p0]), int(e - s)
                src, dst = route[d0], route[d1]
                if mover is not None:
                    from repro.core.mover import Descriptor
                    k_slab = k_np[d0][:, sl, l0 * pt:(l0 + run) * pt]
                    v_slab = v_np[d0][:, sl, l0 * pt:(l0 + run) * pt]
                    descs.append(Descriptor(
                        src, dst, (jnp.asarray(k_slab),
                                   jnp.asarray(v_slab)),
                        lane=lane, source=source))
                elif telemetry is not None:
                    telemetry.record_move(
                        src, dst, page_kv_bytes * len(slots) * run,
                        0.0, source=source)
        if mover is not None:
            # One submission: descriptors batch (§6).  ``wait=False`` is
            # the overlap path — descriptor payloads are fancy-indexed
            # copies, so the drain pool can stream them while the caller
            # keeps decoding; the engine drains completions at the next
            # epoch boundary and accounts hidden vs exposed time.
            mover.submit(descs)
            if wait and mover.asynchronous:
                mover.wait_all()

    def _retile(self, new_dev: np.ndarray, *, mover=None,
                fast_tier: Optional[str] = None,
                slow_tier: Optional[str] = None,
                policy_names: Optional[tuple] = None,
                telemetry=GLOBAL_TELEMETRY, source: Optional[str] = None,
                lane: int = LANE_BULK, donate: bool = False,
                wait: bool = True) -> "TieredKVCache":
        old_dev = self._host_dev()
        if np.array_equal(new_dev, old_dev):
            return self
        n_old = len(self.k_parts)
        n_devices = max(len(self.device_names),
                        int(new_dev.max(initial=0)) + 1,
                        len(policy_names or ()), n_old)
        route = self._route_names(n_devices, policy_names, fast_tier,
                                  slow_tier)
        old_local = np.asarray(self.page_local)
        groups = self._slot_groups(old_dev, new_dev, old_local)
        # Bill / ship the movement FIRST (payloads slice the CURRENT
        # pools — required for the donated in-place path too).
        self._ship_retile(groups, old_dev, new_dev, old_local, route,
                          mover=mover, telemetry=telemetry, source=source,
                          lane=lane, wait=wait)
        caps = self.capacity_pages()
        need = [int(max((new_dev == d).sum(axis=1).max(initial=0), 0))
                for d in range(n_devices)]
        stable = (self.slow_headroom > 0 and n_devices == n_old
                  and all(need[d] <= caps[d] for d in range(n_devices)))
        if stable:
            out = self._retile_stable(groups, old_dev, new_dev, old_local,
                                      donate=donate)
        else:
            out = self._retile_rebuild(groups, old_dev, new_dev, old_local,
                                       n_devices)
        # Stored names: the policy's, widened with the cache's EXISTING
        # names for higher ordinals (a narrower policy must not rename a
        # pinned slot's real device to a placeholder), without the legacy
        # fast/slow route overrides.
        out = dataclasses.replace(
            out, device_names=self._route_names(n_devices, policy_names,
                                                None, None))
        # Both retile paths recompute moved slots' pos rows from the page
        # layout, which revives own-pool rows a prefix reference serves —
        # re-sentinel everything below each slot's shared boundary.
        if out.prefix is not None:
            out = out._apply_prefix_sentinels()
        out.__dict__["_host_cache"] = np.asarray(new_dev)
        return out

    def _apply_prefix_sentinels(self) -> "TieredKVCache":
        shared = self.prefix.slot_shared[:, None]
        pos_new = tuple(jnp.where(p < shared, _INT32_MAX, p)
                        for p in self.pos_parts)
        return dataclasses.replace(self, pos_parts=pos_new)

    def _retile_stable(self, groups, old_dev, new_dev, old_local, *,
                       donate: bool = False) -> "TieredKVCache":
        """O(Δ) retile: every moved page lands in a free slot of its
        destination pool — pool shapes, the treedef, and every unmoved
        page's slot are untouched, so the jitted decode step keeps its
        trace.  Non-receiving pools are reused as-is; receiving pools
        are either copy-on-write (one full copy each) or — with
        ``donate`` — patched in place through the jitted donated scatter
        (zero full-pool copies; the caller must drop the parent cache).

        ORDERING HAZARD: a leaving page's old slot counts as free in its
        pool, so writes could clobber data another destination has not
        staged yet — every moved slab is gathered FIRST, then written."""
        pt = self.page_t
        at = np.arange(pt)
        L_idx = np.arange(self.k_parts[0].shape[0])
        caps = self.capacity_pages()
        n_devices = len(self.k_parts)
        new_local = old_local.copy()
        k_np = [np.asarray(kp) for kp in self.k_parts]   # pristine views
        v_np = [np.asarray(vp) for vp in self.v_parts]
        pos_np = [np.asarray(p).copy() for p in self.pos_parts]
        plan = []  # (dst_dev, slot ids, dst rows, k slab, v slab)
        for slots in groups.values():
            b0, sl = slots[0], np.asarray(slots)
            od, nd = old_dev[b0].astype(np.int64), new_dev[b0].astype(np.int64)
            ol = old_local[b0].astype(np.int64)
            moved = np.nonzero(od != nd)[0]
            if moved.size == 0:
                continue
            nl_row = ol.copy()
            for d in np.unique(nd[moved]):
                incoming = moved[nd[moved] == d]
                # free slots = capacity minus the slots kept by staying
                # pages (a leaving page's slot IS free — hence the
                # gather-first discipline)
                staying = (od == d) & (nd == d)
                used = np.zeros(caps[int(d)], bool)
                used[ol[staying]] = True
                free = np.nonzero(~used)[0]
                slots_free = free[: incoming.size]
                nl_row[incoming] = slots_free
                # stage the moved slabs per source pool, aligned with
                # their destination slots
                src_of = od[incoming]
                for s in np.unique(src_of):
                    sel = src_of == s
                    pages = incoming[sel]
                    src_rows = (ol[pages][:, None] * pt + at).ravel()
                    dst_rows = (slots_free[sel][:, None] * pt + at).ravel()
                    plan.append((int(d), sl, dst_rows,
                                 k_np[int(s)][np.ix_(L_idx, sl, src_rows)],
                                 v_np[int(s)][np.ix_(L_idx, sl, src_rows)]))
            new_local[np.ix_(sl, np.arange(nl_row.size))] = \
                nl_row[None, :].astype(np.int32)
            # recompute the group's pos rows for every device (cheap:
            # O(P) per group, pool widths unchanged)
            for d in range(n_devices):
                row = np.full(caps[d] * pt, _INT32_MAX, np.int32)
                pages_d = np.nonzero(nd == d)[0]
                if pages_d.size:
                    row[(nl_row[pages_d][:, None] * pt + at).ravel()] = (
                        pages_d[:, None] * pt + at).ravel().astype(np.int32)
                pos_np[d][sl] = row
        # All staging gathered (plan slabs are fancy-indexed copies) —
        # release the zero-copy host views BEFORE writing: a live view
        # blocks XLA aliasing and donation silently degrades to a full
        # copy (repro.core.donation VIEW HAZARD).
        k_np = v_np = None
        k_pools = list(self.k_parts)
        v_pools = list(self.v_parts)
        writable_k: dict = {}
        writable_v: dict = {}
        for d, sl, dst_rows, k_slab, v_slab in plan:
            if donate:
                k_pools[d] = donated_kv_update(k_pools[d], sl, dst_rows,
                                               k_slab)
                v_pools[d] = donated_kv_update(v_pools[d], sl, dst_rows,
                                               v_slab)
                continue
            if d not in writable_k:
                FULL_SHARD_COPIES.bump(2)  # one full CoW per K and V pool
                writable_k[d] = np.asarray(k_pools[d]).copy()
                writable_v[d] = np.asarray(v_pools[d]).copy()
            writable_k[d][np.ix_(L_idx, sl, dst_rows)] = k_slab
            writable_v[d][np.ix_(L_idx, sl, dst_rows)] = v_slab
        for d in writable_k:
            k_pools[d] = jnp.asarray(writable_k[d])
            v_pools[d] = jnp.asarray(writable_v[d])
        return dataclasses.replace(
            self,
            k_parts=tuple(k_pools), v_parts=tuple(v_pools),
            page_local=jnp.asarray(new_local, jnp.int32),
            pos_parts=tuple(jnp.asarray(p) for p in pos_np),
            page_device=jnp.asarray(new_dev, jnp.int8),
        )

    def _retile_rebuild(self, groups, old_dev, new_dev, old_local,
                        n_devices: int) -> "TieredKVCache":
        """Full rebuild: re-rank locals, reallocate every pool at its new
        capacity (plus headroom), and copy every page — the path that
        changes shapes, so the jitted decode retraces once, by design
        (a pool outgrew its capacity or the device set changed)."""
        pt = self.page_t
        at = np.arange(pt)
        L, B = self.k_parts[0].shape[:2]
        K, hd = self.k_parts[0].shape[3:]
        dt = self.k_parts[0].dtype
        L_idx = np.arange(L)
        P = old_dev.shape[1]
        old_caps = self.capacity_pages()
        new_local, counts, pos_list = _kv_device_layout_rows(
            new_dev, pt, n_devices)
        caps = [P]  # fast pool holds every page
        for d in range(1, n_devices):
            need = int(counts[d].max(initial=0))
            if (self.slow_headroom > 0 and d < len(old_caps)
                    and old_caps[d] >= need):
                caps.append(old_caps[d])  # held capacity: no retrace churn
            else:
                caps.append(min(need + self.slow_headroom, P))
        k_new = [np.zeros((L, B, caps[d] * pt, K, hd), dt)
                 for d in range(n_devices)]
        v_new = [np.zeros((L, B, caps[d] * pt, K, hd), dt)
                 for d in range(n_devices)]
        FULL_SHARD_COPIES.bump(2 * n_devices)
        k_np = [np.asarray(kp) for kp in self.k_parts]
        v_np = [np.asarray(vp) for vp in self.v_parts]
        n_old = len(self.k_parts)
        for slots in groups.values():
            b0, sl = slots[0], np.asarray(slots)
            od, nd = old_dev[b0].astype(np.int64), new_dev[b0].astype(np.int64)
            ol = old_local[b0].astype(np.int64)
            nl = new_local[b0].astype(np.int64)
            # one fancy-indexed copy per (source pool, dest pool) pair
            for s in range(n_old):
                sel_s = od == s
                if not sel_s.any():
                    continue
                for d in range(n_devices):
                    sel = np.nonzero(sel_s & (nd == d))[0]
                    if sel.size == 0:
                        continue
                    src_rows = (ol[sel][:, None] * pt + at).ravel()
                    dst_rows = (nl[sel][:, None] * pt + at).ravel()
                    k_new[d][np.ix_(L_idx, sl, dst_rows)] = \
                        k_np[s][np.ix_(L_idx, sl, src_rows)]
                    v_new[d][np.ix_(L_idx, sl, dst_rows)] = \
                        v_np[s][np.ix_(L_idx, sl, src_rows)]
        return dataclasses.replace(
            self,
            k_parts=tuple(jnp.asarray(k) for k in k_new),
            v_parts=tuple(jnp.asarray(v) for v in v_new),
            page_local=jnp.asarray(new_local, jnp.int32),
            pos_parts=tuple(
                jnp.asarray(_pad_pos(pos_list[d], caps[d] * pt))
                for d in range(n_devices)),
            page_device=jnp.asarray(new_dev, jnp.int8),
        )

    # -- shared-prefix pool (ISSUE 8) -------------------------------------------
    def with_prefix(self, pool_pages: int) -> "TieredKVCache":
        """Attach an (empty) shared-prefix page pool of ``pool_pages``
        pages.  Done once at engine construction: the pool is a pytree
        child, so creating it later would change the jitted decode
        treedef mid-run."""
        L, B = self.k_parts[0].shape[:2]
        K, hd = self.k_parts[0].shape[3:]
        blk = PrefixBlock.create(
            B, pool_pages, self.page_device.shape[1], self.page_t,
            L, K, hd, self.k_parts[0].dtype)
        return dataclasses.replace(self, prefix=blk)

    def attach_prefix(self, i: int, pages) -> "TieredKVCache":
        """Map shared pool pages into slot ``i`` BY REFERENCE: the slot's
        leading positions are served by the pool partition, its own pool
        rows below the boundary are sentineled out of attention (they
        hold no data — the dedup), and ``lengths`` jumps to the shared
        boundary so prefill replays only the suffix."""
        assert self.prefix is not None
        pages = [int(p) for p in pages]
        Pm = self.prefix.slot_pages.shape[1]
        assert len(pages) <= Pm
        full_rows = len(pages) * self.page_t
        row = np.full(Pm, NO_PAGE, np.int32)
        row[:len(pages)] = pages
        blk = dataclasses.replace(
            self.prefix,
            slot_pages=self.prefix.slot_pages.at[i].set(jnp.asarray(row)),
            slot_shared=self.prefix.slot_shared.at[i].set(full_rows))
        out = dataclasses.replace(
            self, prefix=blk, lengths=self.lengths.at[i].set(full_rows))
        pos_new = []
        for p in out.pos_parts:
            rowv = p[i]
            pos_new.append(p.at[i].set(
                jnp.where(rowv < full_rows, _INT32_MAX, rowv)))
        return dataclasses.replace(out, pos_parts=tuple(pos_new))

    def detach_prefix(self, i: int) -> "TieredKVCache":
        """Drop slot ``i``'s references (request finished) and restore
        its own-pool pos rows from the page layout, so the slot is
        reusable by a reference-free request."""
        if self.prefix is None:
            return self
        blk = dataclasses.replace(
            self.prefix,
            slot_pages=self.prefix.slot_pages.at[i].set(NO_PAGE),
            slot_shared=self.prefix.slot_shared.at[i].set(0))
        out = dataclasses.replace(self, prefix=blk)
        return out._restore_slot_pos(i)

    def _restore_slot_pos(self, i: int) -> "TieredKVCache":
        pt = self.page_t
        at = np.arange(pt)
        dev = self._host_dev()[i]
        loc = np.asarray(self.page_local)[i]
        pos_new = list(self.pos_parts)
        for d in range(len(self.k_parts)):
            T_d = self.k_parts[d].shape[2]
            row = np.full(T_d, _INT32_MAX, np.int32)
            pages_d = np.nonzero(dev == d)[0]
            if pages_d.size:
                row[(loc[pages_d][:, None] * pt + at).ravel()] = (
                    pages_d[:, None] * pt + at).ravel().astype(np.int32)
            pos_new[d] = pos_new[d].at[i].set(jnp.asarray(row))
        return dataclasses.replace(self, pos_parts=tuple(pos_new))

    def _slot_row_route(self, i: int, start: int, n: int):
        """(per-position device, own-pool row) for slot ``i`` positions
        ``[start, start + n)`` — host-side fancy-index plumbing for the
        CoW and promotion copies."""
        positions = np.arange(start, start + n)
        page = positions // self.page_t
        dev = self._host_dev()[i][page]
        rows = (np.asarray(self.page_local)[i][page] * self.page_t
                + positions % self.page_t)
        return dev, rows

    def gather_token_rows(self, i: int, start: int, n: int):
        """Copy slot ``i``'s own K/V rows for positions ``[start,
        start + n)`` out of the per-device pools: ``(L, n, K, hd)``
        numpy pair (promotion of freshly-prefilled pages into the shared
        pool)."""
        L = self.k_parts[0].shape[0]
        K, hd = self.k_parts[0].shape[3:]
        dev, rows = self._slot_row_route(i, start, n)
        out_k = np.zeros((L, n, K, hd), self.k_parts[0].dtype)
        out_v = np.zeros_like(out_k)
        for d in np.unique(dev):
            sel = np.nonzero(dev == d)[0]
            out_k[:, sel] = np.asarray(self.k_parts[d])[:, i, rows[sel]]
            out_v[:, sel] = np.asarray(self.v_parts[d])[:, i, rows[sel]]
        return out_k, out_v

    def write_token_rows(self, i: int, start: int, k_rows,
                         v_rows) -> "TieredKVCache":
        """Write ``(L, n, K, hd)`` rows into slot ``i``'s OWN pools at
        positions ``[start, ...)`` — the copy-on-write landing: a
        diverging request's private copy goes into whatever tier its
        own pages occupy."""
        n = k_rows.shape[1]
        dev, rows = self._slot_row_route(i, start, n)
        k_parts = list(self.k_parts)
        v_parts = list(self.v_parts)
        for d in np.unique(dev):
            sel = np.nonzero(dev == d)[0]
            idx = jnp.asarray(rows[sel])
            k_parts[d] = k_parts[d].at[:, i, idx].set(
                jnp.asarray(k_rows[:, sel], k_parts[d].dtype))
            v_parts[d] = v_parts[d].at[:, i, idx].set(
                jnp.asarray(v_rows[:, sel], v_parts[d].dtype))
        return dataclasses.replace(
            self, k_parts=tuple(k_parts), v_parts=tuple(v_parts),
            lengths=self.lengths.at[i].set(start + n))

    def write_prefix_pages(self, pool_slots, k_pages, v_pages, *,
                           device: int = 0) -> "TieredKVCache":
        """Fill shared pool pages (promotion): ``k_pages`` is
        ``(L, n, page_t, K, hd)`` for ``n`` pool slots, landing on
        device ordinal ``device`` (new prefixes are born fast; the
        epoch-level prefix retier demotes cold ones)."""
        assert self.prefix is not None
        idx = jnp.asarray(np.asarray(pool_slots, np.int32))
        blk = dataclasses.replace(
            self.prefix,
            k=self.prefix.k.at[:, idx].set(
                jnp.asarray(k_pages, self.prefix.k.dtype)),
            v=self.prefix.v.at[:, idx].set(
                jnp.asarray(v_pages, self.prefix.v.dtype)),
            page_device=self.prefix.page_device.at[idx].set(device))
        return dataclasses.replace(self, prefix=blk)

    def retile_prefix(self, new_dev, *, mover=None,
                      telemetry=GLOBAL_TELEMETRY,
                      policy_names: Optional[tuple] = None,
                      source: Optional[str] = None, lane: int = LANE_BULK,
                      wait: bool = True) -> "TieredKVCache":
        """Re-tier the shared pool's per-page placement.  Each moved page
        bills its bytes ONCE on its real route however many slots
        reference it — refcount-weighted (deduplicated) migration, vs
        the per-slot billing private pages pay in ``_retile``."""
        assert self.prefix is not None
        old = np.asarray(self.prefix.page_device)
        new_dev = np.asarray(new_dev, np.int32)
        moved = np.nonzero((old >= 0) & (new_dev >= 0)
                           & (old != new_dev))[0]
        if moved.size == 0:
            return self
        n_devices = max(len(self.device_names), int(new_dev.max()) + 1)
        route = self._route_names(n_devices, policy_names, None, None)
        page_b = self._page_kv_bytes()
        routes: dict[tuple, list] = {}
        for pg in moved:
            routes.setdefault((int(old[pg]), int(new_dev[pg])),
                              []).append(int(pg))
        if mover is not None:
            from repro.core.mover import Descriptor
            k_np = np.asarray(self.prefix.k)
            v_np = np.asarray(self.prefix.v)
            descs = [Descriptor(route[d0], route[d1],
                                (jnp.asarray(k_np[:, pages]),
                                 jnp.asarray(v_np[:, pages])),
                                lane=lane, source=source)
                     for (d0, d1), pages in routes.items()]
            mover.submit(descs)
            if wait and mover.asynchronous:
                mover.wait_all()
        elif telemetry is not None:
            for (d0, d1), pages in routes.items():
                telemetry.record_move(route[d0], route[d1],
                                      page_b * len(pages), 0.0,
                                      source=source)
        out = old.copy()
        out[moved] = new_dev[moved]
        blk = dataclasses.replace(self.prefix,
                                  page_device=jnp.asarray(out))
        return dataclasses.replace(self, prefix=blk)

    def partitions(self, layer: int):
        """[(k, v, valid)] per device pool for decode attention
        (post-append); zero-width pools contribute no partial.  With a
        shared-prefix pool attached, its referenced pages form one more
        partition — merged exactly, like any other device split."""
        upto = self.lengths[:, None] + 1
        parts = [(self.k_parts[d][layer], self.v_parts[d][layer],
                  self.pos_parts[d] < upto)
                 for d in range(len(self.k_parts))
                 if self.k_parts[d].shape[2]]
        if self.prefix is not None and self.prefix.pool_pages:
            parts.append(self.prefix.partition(layer))
        return parts


def tiered_decode_step(cfg: ArchConfig, params: dict, cache: TieredKVCache,
                       tokens: jax.Array) -> tuple[jax.Array, TieredKVCache]:
    """One decode step for the dense family over a tiered KV cache."""
    B = tokens.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache.lengths

    for li in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[li], params["layers"])
        h = apply_norm(x[:, None], lp["ln1"], cfg.norm)[:, 0]
        q = h @ lp["attn"]["wq"]
        k = h @ lp["attn"]["wk"]
        v = h @ lp["attn"]["wv"]
        if "bq" in lp["attn"]:
            q, k, v = (q + lp["attn"]["bq"], k + lp["attn"]["bk"],
                       v + lp["attn"]["bv"])
        q = q.reshape(B, H, hd)
        k = k.reshape(B, K, hd)
        v = v.reshape(B, K, hd)
        if cfg.rope:
            from repro.models.common import apply_rope
            q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta, cfg.rope_pct)[:, 0]
            k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta, cfg.rope_pct)[:, 0]
        cache = cache.append_layer(li, k, v)
        parts = [attn.attend_partial(q, kk, vv, valid)
                 for (kk, vv, valid) in cache.partitions(li)]
        o = attn.merge_partials(parts).astype(x.dtype)
        x = x + o.reshape(B, H * hd) @ lp["attn"]["wo"]
        h = apply_norm(x[:, None], lp["ln2"], cfg.norm)[:, 0]
        x = x + mlp_apply(h, lp["mlp"], cfg.act)

    x = apply_norm(x[:, None], params["final_norm"], cfg.norm)[:, 0]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, dataclasses.replace(cache, lengths=cache.lengths + 1)
