"""Tiered, page-interleaved KV cache + decode step (the Redis §5.1 analogue).

The KV time axis is split into pages placed across (fast, slow) tiers by
a MemPolicy — the paper's N:M weighted interleave applied to serving
state.  Decode attends over both partitions and merges exactly via
log-sum-exp (attention.merge_partials); per-step per-tier byte counts
feed the perfmodel so benchmarks reproduce the paper's p99/QPS curves
on this CPU-only box.

Placement is **per slot**: each batch slot carries its own page->tier
map, so a latency-SLO request can pin its pages fast (Fig. 7: any CXL
fraction hurts a µs-SLO app) while batch-class neighbors tolerate slow
pages.  Pinned slots are excluded from ``repartition_fraction`` — the
Caption loop only tunes the batch-class population.

Applies to the uniform-attention (dense/vlm/moe-attention) families;
recurrent state (rwkv/rglru) is latency-bound and planner-pinned fast.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.interleave import (_policy_device_map, minimal_delta_weights,
                                   resolve_device_names, route_pure_runs)
from repro.core.mover import LANE_BULK, LANE_LATENCY
from repro.core.policy import MemPolicy
from repro.core.telemetry import GLOBAL_TELEMETRY
from repro.models import attention as attn
from repro.models.common import apply_norm, dtype_of, mlp_apply

_INT32_MAX = np.iinfo(np.int32).max


def _kv_layout_rows(assign: np.ndarray, page_t: int):
    """Per-slot physical layout for a (B, n_pages) page->tier map: local
    indices, shared part sizes, and per-slot per-part global positions
    (INT32_MAX pads never validate in the attention masks).

    The fast part is sized for ALL pages (the fast tier is the home tier)
    so pinning a slot fast or shifting the interleave never reallocates
    it — repartition and SLO admission only rewrite index maps and the
    slow part, keeping the jitted decode step's shapes stable.

    Fully vectorized (argsort/cumsum over the whole B x P map — it runs
    on every retile and SLO pin); equivalence with the per-slot
    ``tier_page_map`` walk is asserted by tests/test_hotpaths.py."""
    assign = np.asarray(assign)
    B, P = assign.shape
    assign01 = np.minimum(assign, 1).astype(np.int8)
    is_slow = assign01.astype(bool)
    # local = rank of the page within its tier, in page order (the same
    # arrival-order discipline tier_page_map uses per slot)
    fast_rank = np.cumsum(~is_slow, axis=1) - 1
    slow_rank = np.cumsum(is_slow, axis=1) - 1
    local = np.where(is_slow, slow_rank, fast_rank).astype(np.int32)
    n_slow = is_slow.sum(axis=1).astype(np.int64)
    Tf = P * page_t
    Ts = int(n_slow.max(initial=0)) * page_t
    # global positions sorted by (tier, page): fast pages' spans first.
    order = np.argsort(assign01, axis=1, kind="stable")
    allpos = (order[:, :, None] * page_t
              + np.arange(page_t)).reshape(B, Tf).astype(np.int32)
    col = np.arange(Tf)
    fast_len = (P - n_slow)[:, None] * page_t
    pos_fast = np.where(col[None, :] < fast_len, allpos, _INT32_MAX)
    if Ts:
        cols = np.arange(Ts)
        gidx = np.minimum(fast_len + cols[None, :], Tf - 1)
        pos_slow = np.where(cols[None, :] < n_slow[:, None] * page_t,
                            np.take_along_axis(allpos, gidx, axis=1),
                            _INT32_MAX)
    else:
        pos_slow = np.zeros((B, 0), np.int32)
    return (assign01, local, Tf, Ts,
            pos_fast.astype(np.int32), pos_slow.astype(np.int32))


def _pad_pos(pos: np.ndarray, T: int) -> np.ndarray:
    """Pad a (B, t) position map to (B, T) with never-valid sentinels."""
    if pos.shape[1] >= T:
        return pos
    pad = np.full((pos.shape[0], T - pos.shape[1]), _INT32_MAX, np.int32)
    return np.concatenate([pos, pad], axis=1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TieredKVCache:
    k_fast: jax.Array  # (L, B, Tf, K, hd)
    v_fast: jax.Array
    k_slow: jax.Array  # (L, B, Ts, K, hd)
    v_slow: jax.Array
    lengths: jax.Array  # (B,)
    # static addressing (per-slot page assignment)
    page_tier: jax.Array  # (B, n_pages) int8: STORAGE tier (0 fast, 1 slow)
    page_local: jax.Array  # (B, n_pages)
    pos_fast: jax.Array  # (B, Tf) global position held by each fast slot
    pos_slow: jax.Array  # (B, Ts)
    #: per-page owning DEVICE ordinal (0 = fast, i >= 1 = slow device i-1).
    #: Physical storage keeps the shape-stable fast/slow pools (devices
    #: beyond the second share the slow pool on this modeled backend), but
    #: traffic routes and per-device accounting use the real device map.
    page_device: jax.Array  # (B, n_pages) int8
    page_t: int
    #: route labels per device ordinal (telemetry/mover tier names).
    device_names: tuple[str, ...] = ("fast", "slow")
    #: slow-pool capacity padding, in pages per slot.  0 = the slow part
    #: is sized exactly for the current worst slot (every retile that
    #: changes that resizes it — the legacy layout); > 0 = the slow part
    #: keeps ``max_slow + slow_headroom`` pages of capacity, so Caption
    #: repartitions and SLO pins that fit never change the decode step's
    #: shapes (zero retraces across probe epochs).
    slow_headroom: int = 0

    def tree_flatten(self):
        children = (self.k_fast, self.v_fast, self.k_slow, self.v_slow,
                    self.lengths, self.page_tier, self.page_local,
                    self.pos_fast, self.pos_slow, self.page_device)
        return children, (self.page_t, self.device_names,
                          self.slow_headroom)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, page_t=aux[0], device_names=aux[1],
                   slow_headroom=aux[2])

    # -- host-side map cache ----------------------------------------------------
    def _host_dev(self) -> np.ndarray:
        """Cached numpy page->device map: the Caption loop reads
        ``slow_fraction``/``weights`` every epoch and must not re-sync
        the device array each time."""
        cached = self.__dict__.get("_host_cache")
        if cached is None:
            cached = np.asarray(self.page_device)
            self.__dict__["_host_cache"] = cached
        return cached

    # -- construction ---------------------------------------------------------
    @classmethod
    def create(cls, cfg: ArchConfig, batch: int, max_len: int,
               policy: MemPolicy, *, page_t: int = 256, dtype=None,
               slow_headroom: int = 0) -> "TieredKVCache":
        dt = dtype or dtype_of(cfg.param_dtype)
        L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
        page_t = min(page_t, max_len)
        assert max_len % page_t == 0
        n_pages = max_len // page_t
        slow_headroom = min(max(int(slow_headroom), 0), n_pages)
        dev_row, names = _policy_device_map(policy, n_pages)
        dev = np.broadcast_to(dev_row.astype(np.int8), (batch, n_pages))
        assign, page_local, Tf, Ts, pos_fast, pos_slow = _kv_layout_rows(
            dev, page_t)
        Ts_cap = min(Ts + slow_headroom * page_t, n_pages * page_t)
        out = cls(
            k_fast=jnp.zeros((L, batch, Tf, K, hd), dt),
            v_fast=jnp.zeros((L, batch, Tf, K, hd), dt),
            k_slow=jnp.zeros((L, batch, max(Ts_cap, 0), K, hd), dt),
            v_slow=jnp.zeros((L, batch, max(Ts_cap, 0), K, hd), dt),
            lengths=jnp.zeros((batch,), jnp.int32),
            page_tier=jnp.asarray(assign, jnp.int8),
            page_local=jnp.asarray(page_local, jnp.int32),
            pos_fast=jnp.asarray(pos_fast),
            pos_slow=jnp.asarray(_pad_pos(pos_slow, Ts_cap)),
            page_device=jnp.asarray(dev, jnp.int8),
            page_t=page_t,
            device_names=names,
            slow_headroom=slow_headroom,
        )
        out.__dict__["_host_cache"] = np.asarray(dev)
        return out

    # -- addressing -------------------------------------------------------------
    def _route(self, pos: jax.Array):
        page = pos // self.page_t
        page = jnp.minimum(page, self.page_tier.shape[1] - 1)[:, None]
        tier = jnp.take_along_axis(self.page_tier, page, axis=1)[:, 0]
        local = jnp.take_along_axis(self.page_local, page, axis=1)[:, 0]
        return tier.astype(bool), local * self.page_t + pos % self.page_t

    def slow_fraction(self, pinned_slots=()) -> float:
        """Slow-page share of the *tunable* slots (all slots minus
        ``pinned_slots``) — the operating point the Caption actuation
        feedback must report.  Pin state lives with the engine (request
        SLO policy), not in this data structure."""
        tiers = np.minimum(self._host_dev(), 1).astype(np.float32)
        pinned = set(pinned_slots)
        unpinned = [b for b in range(tiers.shape[0]) if b not in pinned]
        if not unpinned:
            return 0.0
        return float(tiers[unpinned].mean())

    def weights(self, pinned_slots=()) -> tuple[float, ...]:
        """Per-slow-device page shares of the tunable slots (the Caption
        weight-vector operating point on an N-device topology)."""
        dev = self._host_dev()
        pinned = set(pinned_slots)
        unpinned = [b for b in range(dev.shape[0]) if b not in pinned]
        n_slow = max(len(self.device_names) - 1, 1)
        if not unpinned:
            return (0.0,) * n_slow
        sub = dev[unpinned]
        return tuple(float((sub == i + 1).mean()) for i in range(n_slow))

    def device_fractions(self, pinned_slots=()) -> dict[str, float]:
        """Per-device page share of the tunable slots, keyed by name."""
        w = self.weights(pinned_slots)
        out = {self.device_names[0]: 1.0 - sum(w)}
        for i, share in enumerate(w):
            out[self.device_names[i + 1]] = share
        return out

    # -- per-step traffic (drives the latency/QPS simulation) ------------------
    def read_bytes_per_step(self) -> dict[str, int]:
        """Bytes streamed per decode step per tier (both K and V), from the
        per-slot page placement (pinned slots bill fast-only)."""
        item = self.k_fast.dtype.itemsize
        L = self.k_fast.shape[0]
        K, hd = self.k_fast.shape[3:]
        tiers = np.minimum(self._host_dev(), 1)
        n_pages = tiers.shape[1]
        slow_pages = tiers.sum(axis=1)
        fast_rows = int(np.maximum((n_pages - slow_pages), 1).sum()) * self.page_t
        slow_rows = int(slow_pages.sum()) * self.page_t
        return {
            "fast": 2 * L * fast_rows * K * hd * item,
            "slow": 2 * L * slow_rows * K * hd * item,
        }

    def read_bytes_per_device(self) -> dict[str, int]:
        """Per-device decode-step read bytes, keyed by device name — the
        slow total splits across the real devices holding the pages (each
        device streams on its own link, so the modeled step time is the
        max, not the sum)."""
        item = self.k_fast.dtype.itemsize
        L = self.k_fast.shape[0]
        K, hd = self.k_fast.shape[3:]
        dev = self._host_dev()
        out = {}
        for i, name in enumerate(self.device_names):
            pages = (dev == i).sum(axis=1)
            if i == 0:
                pages = np.maximum(pages, 1)  # >= 1 fast page per slot
            out[name] = 2 * L * int(pages.sum()) * self.page_t * K * hd * item
        return out

    # -- append + attend --------------------------------------------------------
    def append_layer(self, layer: jax.Array, k_new: jax.Array, v_new: jax.Array):
        """Scatter one token's K/V for one layer. k_new: (B, K, hd)."""
        B = k_new.shape[0]
        is_slow, local = self._route(self.lengths)
        bidx = jnp.arange(B)
        f_idx = jnp.where(is_slow, self.k_fast.shape[2], local)
        s_idx = jnp.where(is_slow, local, self.k_slow.shape[2] or 1)
        k_fast = self.k_fast.at[layer, bidx, f_idx].set(
            k_new.astype(self.k_fast.dtype), mode="drop")
        v_fast = self.v_fast.at[layer, bidx, f_idx].set(
            v_new.astype(self.v_fast.dtype), mode="drop")
        if self.k_slow.shape[2]:
            k_slow = self.k_slow.at[layer, bidx, s_idx].set(
                k_new.astype(self.k_slow.dtype), mode="drop")
            v_slow = self.v_slow.at[layer, bidx, s_idx].set(
                v_new.astype(self.v_slow.dtype), mode="drop")
        else:
            k_slow, v_slow = self.k_slow, self.v_slow
        return dataclasses.replace(
            self, k_fast=k_fast, v_fast=v_fast, k_slow=k_slow, v_slow=v_slow)

    # -- SLO pinning (per-request latency class) --------------------------------
    def pin_slot(self, i: int, **kwargs) -> "TieredKVCache":
        """Move slot ``i``'s pages all-fast (latency-SLO admission) on the
        mover's latency lane.  The *exclusion* from future repartitions is
        the engine's job: it tracks the pinned-slot set (request policy)
        and passes it as ``pinned_slots`` — keeping SLO state out of this
        data structure keeps the jitted decode treedef stable."""
        new_dev = self._host_dev().copy()
        new_dev[i] = 0
        return self._retile(new_dev, lane=LANE_LATENCY, **kwargs)

    # -- dynamic re-tiering (Caption actuation path) ----------------------------
    def repartition(self, policy: MemPolicy, pinned_slots=(), **kwargs
                    ) -> "TieredKVCache":
        """Re-tier every unpinned slot's KV pages under ``policy``, moving
        only delta pages.

        Host-side (between decode steps).  Pages whose device is unchanged
        are sliced across; changed pages ship through the BulkMover (or
        are accounted to telemetry) on their real ``(src_device,
        dst_device)`` route, so inter-tier traffic is exactly
        ``delta_pages * page_kv_bytes``.  Attention output is invariant:
        the same (position, K, V) triples exist after the move, only
        their owning device changes.  Slots in ``pinned_slots``
        (latency-SLO) keep their all-fast rows.
        """
        n_pages = self.page_device.shape[1]
        row, names = _policy_device_map(policy, n_pages)
        pinned = set(pinned_slots)
        new_dev = self._host_dev().copy()
        for b in range(new_dev.shape[0]):
            if b not in pinned:
                new_dev[b] = row
        return self._retile(new_dev, policy_names=names, **kwargs)

    def repartition_fraction(self, fraction: float, pinned_slots=(),
                             **kwargs) -> "TieredKVCache":
        """Re-tier unpinned slots to ``fraction`` slow flipping the fewest
        KV pages per slot (two-device path)."""
        return self.repartition_weights((float(fraction),), pinned_slots,
                                        **kwargs)

    def repartition_weights(self, weights, pinned_slots=(), **kwargs
                            ) -> "TieredKVCache":
        """Re-tier unpinned slots to a per-slow-device weight vector,
        flipping the fewest KV pages per slot.  A vector that rounds to
        every slot's current per-device counts is a true no-op (``self``
        returned, no mover work enqueued)."""
        pinned = set(pinned_slots)
        n_devices = max(len(self.device_names), len(tuple(weights)) + 1)
        new_dev = self._host_dev().copy()
        changed = False
        for b in range(new_dev.shape[0]):
            if b in pinned:
                continue
            row = minimal_delta_weights(new_dev[b], tuple(weights),
                                        n_devices)
            if row is not None:
                new_dev[b] = row
                changed = True
        if not changed:
            return self
        return self._retile(new_dev, **kwargs)

    def drain_device(self, device, pinned_slots=(), *, weights=None,
                     **kwargs) -> "TieredKVCache":
        """Move every unpinned slot's pages off one slow device (elastic
        hot-remove drain).

        ``device`` is a slow-device ordinal (>= 1) or its name.  The
        departing share goes to the surviving slow devices proportionally
        to their current shares by default, or to an explicit per-device
        ``weights`` target (which must zero the departing device).  The
        move rides the normal minimal-delta repartition: run-coalesced
        LANE_BULK descriptors on real (dead device -> survivor) routes,
        so in-flight requests keep decoding — only page ownership moves.
        Pinned (latency-SLO) slots are already all-fast and untouched."""
        if isinstance(device, str):
            if device not in self.device_names:
                raise KeyError(device)
            i = self.device_names.index(device)
        else:
            i = int(device)
        if not 1 <= i < len(self.device_names):
            raise KeyError(device)
        if weights is None:
            cur = list(self.weights(pinned_slots))
            departing, cur[i - 1] = cur[i - 1], 0.0
            rest = sum(cur)
            if departing > 0 and rest > 0:
                cur = [w + departing * w / rest for w in cur]
            weights = tuple(cur)
        elif weights[i - 1] > 0:
            raise ValueError(
                f"drain target keeps weight on {self.device_names[i]!r}")
        return self.repartition_weights(weights, pinned_slots, **kwargs)

    def _route_names(self, n_devices: int,
                     policy_names: Optional[tuple] = None,
                     fast_tier: Optional[str] = None,
                     slow_tier: Optional[str] = None) -> tuple[str, ...]:
        return resolve_device_names(self.device_names, n_devices,
                                    policy_names, fast_tier, slow_tier)

    def _retile(self, new_dev: np.ndarray, *, mover=None,
                fast_tier: Optional[str] = None,
                slow_tier: Optional[str] = None,
                policy_names: Optional[tuple] = None,
                telemetry=GLOBAL_TELEMETRY, source: Optional[str] = None,
                lane: int = LANE_BULK) -> "TieredKVCache":
        old_dev = self._host_dev()
        if np.array_equal(new_dev, old_dev):
            return self
        pt = self.page_t
        n_devices = max(len(self.device_names),
                        int(new_dev.max(initial=0)) + 1,
                        len(policy_names or ()))
        route = self._route_names(n_devices, policy_names, fast_tier,
                                  slow_tier)
        new01, new_local, Tf, Ts, pos_fast, pos_slow = _kv_layout_rows(
            new_dev, pt)
        P = old_dev.shape[1]
        # Capacity-held slow pool: with headroom, a retile that fits the
        # existing capacity keeps the decode step's shapes (no retrace);
        # growing past it re-pads by the headroom so the NEXT walk fits.
        cap = self.k_slow.shape[2]
        if self.slow_headroom > 0:
            Ts_cap = cap if cap >= Ts else min(
                Ts + self.slow_headroom * pt, P * pt)
        else:
            Ts_cap = Ts
        old_local = np.asarray(self.page_local)
        k_parts = (np.asarray(self.k_fast), np.asarray(self.k_slow))
        v_parts = (np.asarray(self.v_fast), np.asarray(self.v_slow))

        L, B = self.k_fast.shape[:2]
        K, hd = self.k_fast.shape[3:]
        dt = self.k_fast.dtype
        new_k = (np.zeros((L, B, Tf, K, hd), dt),
                 np.zeros((L, B, Ts_cap, K, hd), dt))
        new_v = (np.zeros((L, B, Tf, K, hd), dt),
                 np.zeros((L, B, Ts_cap, K, hd), dt))
        page_kv_bytes = 2 * L * pt * K * hd * dt.itemsize  # one slot-page
        # Slots sharing a (old row, new row) pair — the whole batch-class
        # population after a repartition — copy as ONE batched slice per
        # tier combo instead of per-slot-per-page (locals are a function
        # of the row, so equal rows imply equal layouts).
        groups: dict[bytes, list[int]] = {}
        for b in range(B):
            key = old_dev[b].tobytes() + new_dev[b].tobytes()
            groups.setdefault(key, []).append(b)
        descs = []
        at = np.arange(pt)
        L_idx = np.arange(L)
        for slots in groups.values():
            b0, sl = slots[0], np.asarray(slots)
            od, nd = old_dev[b0].astype(np.int64), new_dev[b0].astype(np.int64)
            ot, nt = np.minimum(od, 1), np.minimum(nd, 1)
            ol, nl = old_local[b0].astype(np.int64), new_local[b0].astype(np.int64)
            # Vectorized data placement: one fancy-indexed copy per
            # (old storage tier, new storage tier) combination.
            for t0 in (0, 1):
                for t1 in (0, 1):
                    sel = np.nonzero((ot == t0) & (nt == t1))[0]
                    if sel.size == 0:
                        continue
                    src_rows = (ol[sel][:, None] * pt + at).ravel()
                    dst_rows = (nl[sel][:, None] * pt + at).ravel()
                    new_k[t1][np.ix_(L_idx, sl, dst_rows)] = \
                        k_parts[t0][np.ix_(L_idx, sl, src_rows)]
                    new_v[t1][np.ix_(L_idx, sl, dst_rows)] = \
                        v_parts[t0][np.ix_(L_idx, sl, src_rows)]
            # Movement metering on real device routes — including
            # slow->slow hops (the paper's C2C class), which the storage
            # tiers alone cannot distinguish.  Moved pages coalesce into
            # route-pure runs of consecutive source locals; each run is
            # one contiguous slab of its source pool and ships as ONE
            # batched descriptor (billed bytes identical to per-page).
            moved = np.nonzero(od != nd)[0]
            if moved.size:
                order, starts, ends = route_pure_runs(
                    od[moved], nd[moved], ol[moved])
                mv = moved[order]
                for s, e in zip(starts, ends):
                    p0 = mv[s]
                    d0, d1 = int(od[p0]), int(nd[p0])
                    t0 = min(d0, 1)
                    l0, run = int(ol[p0]), int(e - s)
                    src, dst = route[d0], route[d1]
                    if mover is not None:
                        from repro.core.mover import Descriptor
                        k_slab = k_parts[t0][:, sl,
                                             l0 * pt:(l0 + run) * pt]
                        v_slab = v_parts[t0][:, sl,
                                             l0 * pt:(l0 + run) * pt]
                        descs.append(Descriptor(
                            src, dst, (jnp.asarray(k_slab),
                                       jnp.asarray(v_slab)),
                            lane=lane, source=source))
                    elif telemetry is not None:
                        telemetry.record_move(
                            src, dst, page_kv_bytes * len(slots) * run,
                            0.0, source=source)
        if mover is not None:
            mover.submit(descs)  # one submission: descriptors batch (§6)
            if mover.asynchronous:
                mover.wait_all()
        # Stored names: the policy's, widened with the cache's EXISTING
        # names for higher ordinals (a narrower policy must not rename a
        # pinned slot's real device to a placeholder), without the legacy
        # fast/slow route overrides.
        device_names = self._route_names(n_devices, policy_names, None, None)
        out = dataclasses.replace(
            self,
            k_fast=jnp.asarray(new_k[0]), v_fast=jnp.asarray(new_v[0]),
            k_slow=jnp.asarray(new_k[1]), v_slow=jnp.asarray(new_v[1]),
            page_tier=jnp.asarray(new01, jnp.int8),
            page_local=jnp.asarray(new_local, jnp.int32),
            pos_fast=jnp.asarray(pos_fast),
            pos_slow=jnp.asarray(_pad_pos(pos_slow, Ts_cap)),
            page_device=jnp.asarray(new_dev, jnp.int8),
            device_names=device_names,
        )
        out.__dict__["_host_cache"] = np.asarray(new_dev)
        return out

    def partitions(self, layer: int):
        """[(k, v, valid)] per tier for decode attention (post-append)."""
        upto = self.lengths[:, None] + 1
        parts = [(self.k_fast[layer], self.v_fast[layer],
                  self.pos_fast < upto)]
        if self.k_slow.shape[2]:
            parts.append((self.k_slow[layer], self.v_slow[layer],
                          self.pos_slow < upto))
        return parts


def tiered_decode_step(cfg: ArchConfig, params: dict, cache: TieredKVCache,
                       tokens: jax.Array) -> tuple[jax.Array, TieredKVCache]:
    """One decode step for the dense family over a tiered KV cache."""
    B = tokens.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache.lengths

    for li in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[li], params["layers"])
        h = apply_norm(x[:, None], lp["ln1"], cfg.norm)[:, 0]
        q = h @ lp["attn"]["wq"]
        k = h @ lp["attn"]["wk"]
        v = h @ lp["attn"]["wv"]
        if "bq" in lp["attn"]:
            q, k, v = (q + lp["attn"]["bq"], k + lp["attn"]["bk"],
                       v + lp["attn"]["bv"])
        q = q.reshape(B, H, hd)
        k = k.reshape(B, K, hd)
        v = v.reshape(B, K, hd)
        if cfg.rope:
            from repro.models.common import apply_rope
            q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta, cfg.rope_pct)[:, 0]
            k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta, cfg.rope_pct)[:, 0]
        cache = cache.append_layer(li, k, v)
        parts = [attn.attend_partial(q, kk, vv, valid)
                 for (kk, vv, valid) in cache.partitions(li)]
        o = attn.merge_partials(parts).astype(x.dtype)
        x = x + o.reshape(B, H * hd) @ lp["attn"]["wo"]
        h = apply_norm(x[:, None], lp["ln2"], cfg.norm)[:, 0]
        x = x + mlp_apply(h, lp["mlp"], cfg.act)

    x = apply_norm(x[:, None], params["final_norm"], cfg.norm)[:, 0]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, dataclasses.replace(cache, lengths=cache.lengths + 1)
