"""Tiered, page-interleaved KV cache + decode step (the Redis §5.1 analogue).

The KV time axis is split into pages placed across (fast, slow) tiers by
a MemPolicy — the paper's N:M weighted interleave applied to serving
state.  Decode attends over both partitions and merges exactly via
log-sum-exp (attention.merge_partials); per-step per-tier byte counts
feed the perfmodel so benchmarks reproduce the paper's p99/QPS curves
on this CPU-only box.

Applies to the uniform-attention (dense/vlm/moe-attention) families;
recurrent state (rwkv/rglru) is latency-bound and planner-pinned fast.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.interleave import tier_page_map
from repro.core.policy import MemPolicy
from repro.core.telemetry import GLOBAL_TELEMETRY
from repro.models import attention as attn
from repro.models.common import apply_norm, dtype_of, mlp_apply


def _kv_layout(assign, page_t: int):
    """Physical layout for a page->tier map: local indices, part sizes
    (fast part keeps at least one page), and per-slot global positions."""
    assign01, page_local, counters = tier_page_map(assign)
    pos_parts: list[list[int]] = [[], []]
    for p, t in enumerate(assign01):
        pos_parts[t].extend(range(p * page_t, (p + 1) * page_t))
    Tf = max(counters[0] * page_t, page_t)  # at least one page fast
    Ts = counters[1] * page_t
    pos_fast = np.full(Tf, np.iinfo(np.int32).max, np.int32)
    pos_fast[: len(pos_parts[0])] = pos_parts[0]
    pos_slow = (np.asarray(pos_parts[1], np.int32) if Ts
                else np.zeros(0, np.int32))
    return assign01, page_local, Tf, Ts, pos_fast, pos_slow


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TieredKVCache:
    k_fast: jax.Array  # (L, B, Tf, K, hd)
    v_fast: jax.Array
    k_slow: jax.Array  # (L, B, Ts, K, hd)
    v_slow: jax.Array
    lengths: jax.Array  # (B,)
    # static addressing (from the policy's page assignment)
    page_tier: jax.Array  # (n_pages,) int8
    page_local: jax.Array  # (n_pages,)
    pos_fast: jax.Array  # (Tf,) global position held by each fast slot
    pos_slow: jax.Array  # (Ts,)
    page_t: int

    def tree_flatten(self):
        children = (self.k_fast, self.v_fast, self.k_slow, self.v_slow,
                    self.lengths, self.page_tier, self.page_local,
                    self.pos_fast, self.pos_slow)
        return children, (self.page_t,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, page_t=aux[0])

    # -- construction ---------------------------------------------------------
    @classmethod
    def create(cls, cfg: ArchConfig, batch: int, max_len: int,
               policy: MemPolicy, *, page_t: int = 256, dtype=None
               ) -> "TieredKVCache":
        dt = dtype or dtype_of(cfg.param_dtype)
        L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
        page_t = min(page_t, max_len)
        assert max_len % page_t == 0
        n_pages = max_len // page_t
        assign, page_local, Tf, Ts, pos_fast, pos_slow = _kv_layout(
            policy.page_is_slow(n_pages), page_t)
        return cls(
            k_fast=jnp.zeros((L, batch, Tf, K, hd), dt),
            v_fast=jnp.zeros((L, batch, Tf, K, hd), dt),
            k_slow=jnp.zeros((L, batch, max(Ts, 0), K, hd), dt),
            v_slow=jnp.zeros((L, batch, max(Ts, 0), K, hd), dt),
            lengths=jnp.zeros((batch,), jnp.int32),
            page_tier=jnp.asarray(assign, jnp.int8),
            page_local=jnp.asarray(page_local, jnp.int32),
            pos_fast=jnp.asarray(pos_fast),
            pos_slow=jnp.asarray(pos_slow),
            page_t=page_t,
        )

    # -- addressing -------------------------------------------------------------
    def _route(self, pos: jax.Array):
        page = pos // self.page_t
        page = jnp.minimum(page, self.page_tier.shape[0] - 1)
        tier = jnp.take(self.page_tier, page).astype(bool)
        local = jnp.take(self.page_local, page) * self.page_t + pos % self.page_t
        return tier, local

    def slow_fraction(self) -> float:
        return float(np.asarray(self.page_tier, np.float32).mean())

    # -- per-step traffic (drives the latency/QPS simulation) ------------------
    def read_bytes_per_step(self) -> dict[str, int]:
        """Bytes streamed per decode step per tier (both K and V)."""
        item = self.k_fast.dtype.itemsize
        L, B, Tf, K, hd = self.k_fast.shape
        Ts = self.k_slow.shape[2]
        return {
            "fast": 2 * L * B * Tf * K * hd * item,
            "slow": 2 * L * B * Ts * K * hd * item,
        }

    # -- append + attend --------------------------------------------------------
    def append_layer(self, layer: jax.Array, k_new: jax.Array, v_new: jax.Array):
        """Scatter one token's K/V for one layer. k_new: (B, K, hd)."""
        B = k_new.shape[0]
        is_slow, local = self._route(self.lengths)
        bidx = jnp.arange(B)
        f_idx = jnp.where(is_slow, self.k_fast.shape[2], local)
        s_idx = jnp.where(is_slow, local, self.k_slow.shape[2] or 1)
        k_fast = self.k_fast.at[layer, bidx, f_idx].set(
            k_new.astype(self.k_fast.dtype), mode="drop")
        v_fast = self.v_fast.at[layer, bidx, f_idx].set(
            v_new.astype(self.v_fast.dtype), mode="drop")
        if self.k_slow.shape[2]:
            k_slow = self.k_slow.at[layer, bidx, s_idx].set(
                k_new.astype(self.k_slow.dtype), mode="drop")
            v_slow = self.v_slow.at[layer, bidx, s_idx].set(
                v_new.astype(self.v_slow.dtype), mode="drop")
        else:
            k_slow, v_slow = self.k_slow, self.v_slow
        return dataclasses.replace(
            self, k_fast=k_fast, v_fast=v_fast, k_slow=k_slow, v_slow=v_slow)

    # -- dynamic re-tiering (Caption actuation path) ----------------------------
    def repartition(self, policy: MemPolicy, *, mover=None,
                    fast_tier: str = "fast", slow_tier: str = "slow",
                    telemetry=GLOBAL_TELEMETRY) -> "TieredKVCache":
        """Re-tier the KV pages under ``policy``, moving only delta pages.

        Host-side (between decode steps).  Pages whose tier is unchanged
        are sliced across; changed pages ship through the BulkMover (or
        are accounted to telemetry), so inter-tier traffic is exactly
        ``delta_pages * page_kv_bytes``.  Attention output is invariant:
        the same (position, K, V) triples exist after the move, only
        their owning tier changes.
        """
        n_pages = self.page_tier.shape[0]
        old_assign = np.asarray(self.page_tier)
        new_assign, new_local, Tf, Ts, pos_fast, pos_slow = _kv_layout(
            policy.page_is_slow(n_pages), self.page_t)
        delta = np.nonzero(new_assign != old_assign)[0]
        if delta.size == 0:
            return self

        old_local = np.asarray(self.page_local)
        k_parts = (np.asarray(self.k_fast), np.asarray(self.k_slow))
        v_parts = (np.asarray(self.v_fast), np.asarray(self.v_slow))
        pt = self.page_t

        def old_slice(part: np.ndarray, p: int) -> np.ndarray:
            l0 = old_local[p]
            return part[:, :, l0 * pt:(l0 + 1) * pt]

        L, B = self.k_fast.shape[:2]
        K, hd = self.k_fast.shape[3:]
        dt = self.k_fast.dtype
        new_k = (np.zeros((L, B, Tf, K, hd), dt), np.zeros((L, B, Ts, K, hd), dt))
        new_v = (np.zeros((L, B, Tf, K, hd), dt), np.zeros((L, B, Ts, K, hd), dt))
        page_kv_bytes = 2 * L * B * pt * K * hd * dt.itemsize
        descs = []
        for p in range(n_pages):
            t0, t1, l1 = int(old_assign[p]), int(new_assign[p]), new_local[p]
            k_page = old_slice(k_parts[t0], p)
            v_page = old_slice(v_parts[t0], p)
            new_k[t1][:, :, l1 * pt:(l1 + 1) * pt] = k_page
            new_v[t1][:, :, l1 * pt:(l1 + 1) * pt] = v_page
            if t0 != t1:
                src = slow_tier if t0 else fast_tier
                dst = fast_tier if t0 else slow_tier
                if mover is not None:
                    from repro.core.mover import Descriptor
                    descs.append(Descriptor(src, dst, (jnp.asarray(k_page),
                                                       jnp.asarray(v_page))))
                elif telemetry is not None:
                    telemetry.record_move(src, dst, page_kv_bytes, 0.0)
        if mover is not None:
            mover.submit(descs)  # one submission: descriptors batch (§6)
            if mover.asynchronous:
                mover.wait_all()
        return dataclasses.replace(
            self,
            k_fast=jnp.asarray(new_k[0]), v_fast=jnp.asarray(new_v[0]),
            k_slow=jnp.asarray(new_k[1]), v_slow=jnp.asarray(new_v[1]),
            page_tier=jnp.asarray(new_assign, jnp.int8),
            page_local=jnp.asarray(new_local, jnp.int32),
            pos_fast=jnp.asarray(pos_fast), pos_slow=jnp.asarray(pos_slow),
        )

    def repartition_fraction(self, fraction: float, **kwargs
                             ) -> "TieredKVCache":
        """Re-tier to ``fraction`` slow flipping the fewest KV pages."""
        from repro.core.interleave import (_ExplicitAssignment,
                                           minimal_delta_assignment)
        assign = minimal_delta_assignment(np.asarray(self.page_tier), fraction)
        return self.repartition(_ExplicitAssignment(assign), **kwargs)

    def partitions(self, layer: int):
        """[(k, v, valid)] per tier for decode attention (post-append)."""
        upto = self.lengths[:, None] + 1
        parts = [(self.k_fast[layer], self.v_fast[layer],
                  self.pos_fast[None, :] < upto)]
        if self.k_slow.shape[2]:
            parts.append((self.k_slow[layer], self.v_slow[layer],
                          self.pos_slow[None, :] < upto))
        return parts


def tiered_decode_step(cfg: ArchConfig, params: dict, cache: TieredKVCache,
                       tokens: jax.Array) -> tuple[jax.Array, TieredKVCache]:
    """One decode step for the dense family over a tiered KV cache."""
    B = tokens.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache.lengths

    for li in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[li], params["layers"])
        h = apply_norm(x[:, None], lp["ln1"], cfg.norm)[:, 0]
        q = h @ lp["attn"]["wq"]
        k = h @ lp["attn"]["wk"]
        v = h @ lp["attn"]["wv"]
        if "bq" in lp["attn"]:
            q, k, v = (q + lp["attn"]["bq"], k + lp["attn"]["bk"],
                       v + lp["attn"]["bv"])
        q = q.reshape(B, H, hd)
        k = k.reshape(B, K, hd)
        v = v.reshape(B, K, hd)
        if cfg.rope:
            from repro.models.common import apply_rope
            q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta, cfg.rope_pct)[:, 0]
            k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta, cfg.rope_pct)[:, 0]
        cache = cache.append_layer(li, k, v)
        parts = [attn.attend_partial(q, kk, vv, valid)
                 for (kk, vv, valid) in cache.partitions(li)]
        o = attn.merge_partials(parts).astype(x.dtype)
        x = x + o.reshape(B, H * hd) @ lp["attn"]["wo"]
        h = apply_norm(x[:, None], lp["ln2"], cfg.norm)[:, 0]
        x = x + mlp_apply(h, lp["mlp"], cfg.act)

    x = apply_norm(x[:, None], params["final_norm"], cfg.norm)[:, 0]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, dataclasses.replace(cache, lengths=cache.lengths + 1)
