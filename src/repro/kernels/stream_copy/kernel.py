"""Pallas TPU streaming (cache-bypass) bulk copy.

The nt-store / movdir64B analogue from the paper's §6 guidelines: data
moves HBM -> VMEM tile -> HBM with no reuse, so it cannot pollute any
cache-like resource, and the tile size is the explicit analogue of the
64 B cache-bypass granule (sized to VMEM instead).  Used by the
BulkMover for page staging; optional dtype cast fuses the compressed-
staging path (bf16 <-> fp32 moment pages) into the same single pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(src_ref, out_ref):
    out_ref[...] = src_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "out_dtype", "interpret"))
def stream_copy(
    src: jax.Array,  # (N, M) — page-major layout
    *,
    block_rows: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    out_dtype = out_dtype or src.dtype
    N, M = src.shape
    block_rows = min(block_rows, N)
    assert N % block_rows == 0, "rows must tile evenly"
    fn = pl.pallas_call(
        _kernel,
        grid=(N // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, M), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, M), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, M), out_dtype),
        interpret=interpret,
    )
    return fn(src)
