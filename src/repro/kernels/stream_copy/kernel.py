"""Pallas TPU double-buffered streaming (cache-bypass) migration kernel.

The nt-store / movdir64B analogue from the paper's §6 guidelines, now a
real migration pipeline instead of a blockwise memcpy: page runs move
HBM -> VMEM staging -> HBM through explicitly double-buffered async
DMAs, so chunk i's copy-out overlaps chunk i+1's copy-in and the whole
transfer overlaps surrounding compute instead of serializing on it.
Nothing is reused after the single pass, so no cache-like resource is
polluted; the VMEM chunk is the explicit analogue of the 64 B
cache-bypass granule.  The optional dtype cast (compressed-staging
bf16 <-> fp32 moment pages) happens in VMEM between the in- and
out-DMAs — still a single pass over the data.

Pipeline structure (slots 0/1 double-buffer the full chunks; a ragged
tail shorter than ``block_rows`` gets dedicated slot 2 whose in-DMA is
issued up front so it rides under the whole full-chunk pipeline):

    in-DMA(ci+1) ║ wait-in(ci) → cast in VMEM → out-DMA(ci) ║ wait-out(ci-2)

Used by ``BulkMover``'s stream executor for page staging; arbitrary row
counts are supported (no ``N % block_rows`` requirement — ISSUE 7
satellite), so coalesced page runs ship without caller-side padding.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _migrate_kernel(src_ref, out_ref, *, n_full, tail, block_rows):
    """Single-program kernel; the chunk loop plays the grid role so the
    double-buffered DMA chain is explicit rather than compiler-implied."""
    n_rows = n_full * block_rows + tail

    def body(ins, outs, in_sems, out_sems):
        def in_dma(slot, start, rows):
            return pltpu.make_async_copy(
                src_ref.at[pl.ds(start, rows)],
                ins.at[slot, pl.ds(0, rows)],
                in_sems.at[slot])

        def out_dma(slot, start, rows):
            return pltpu.make_async_copy(
                outs.at[slot, pl.ds(0, rows)],
                out_ref.at[pl.ds(start, rows)],
                out_sems.at[slot])

        if tail:
            # tail in-DMA issued first: overlaps the full-chunk pipeline.
            in_dma(2, n_full * block_rows, tail).start()

        if n_full:
            in_dma(0, 0, block_rows).start()

            def step(ci, carry):
                cur = jax.lax.rem(ci, 2)
                nxt = jax.lax.rem(ci + 1, 2)

                @pl.when(ci + 1 < n_full)
                def _prefetch():
                    in_dma(nxt, (ci + 1) * block_rows, block_rows).start()

                in_dma(cur, ci * block_rows, block_rows).wait()

                @pl.when(ci >= 2)
                def _drain_prev():
                    # outs[cur] still ships chunk ci-2; reclaim it.
                    out_dma(cur, (ci - 2) * block_rows, block_rows).wait()

                outs[cur, ...] = ins[cur, ...].astype(out_ref.dtype)
                out_dma(cur, ci * block_rows, block_rows).start()
                return carry

            jax.lax.fori_loop(0, n_full, step, 0)

            # Drain the last (up to) two in-flight out-DMAs.
            for ci in range(max(0, n_full - 2), n_full):
                out_dma(ci % 2, ci * block_rows, block_rows).wait()

        if tail:
            in_dma(2, n_full * block_rows, tail).wait()
            outs[2, pl.ds(0, tail)] = (
                ins[2, pl.ds(0, tail)].astype(out_ref.dtype))
            out_dma(2, n_full * block_rows, tail).start()
            out_dma(2, n_full * block_rows, tail).wait()

    del n_rows
    M = src_ref.shape[1]
    pl.run_scoped(
        body,
        ins=pltpu.VMEM((3, block_rows, M), src_ref.dtype),
        outs=pltpu.VMEM((3, block_rows, M), out_ref.dtype),
        in_sems=pltpu.SemaphoreType.DMA((3,)),
        out_sems=pltpu.SemaphoreType.DMA((3,)),
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "out_dtype", "interpret"))
def stream_copy(
    src: jax.Array,  # (N, M) — page-major layout
    *,
    block_rows: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    out_dtype = out_dtype or src.dtype
    N, M = src.shape
    block_rows = max(1, min(block_rows, N))
    n_full, tail = divmod(N, block_rows)
    fn = pl.pallas_call(
        functools.partial(_migrate_kernel, n_full=n_full, tail=tail,
                          block_rows=block_rows),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((N, M), out_dtype),
        interpret=interpret,
    )
    return fn(src)
