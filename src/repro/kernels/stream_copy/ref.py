"""Oracle for the cache-bypass streaming copy (optionally casting)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stream_copy(src: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or src.dtype
    return src.astype(out_dtype)
