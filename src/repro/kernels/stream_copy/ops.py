"""Jit'd public wrapper for the streaming copy kernel."""
from __future__ import annotations

import jax

from repro.kernels.common import interpret_default
from repro.kernels.stream_copy import kernel, ref


def stream_copy(src: jax.Array, *, out_dtype=None, block_rows: int = 256,
                use_kernel: bool = True) -> jax.Array:
    if not use_kernel or src.ndim != 2 or src.shape[0] % block_rows:
        return ref.stream_copy(src, out_dtype)
    return kernel.stream_copy(
        src, block_rows=block_rows, out_dtype=out_dtype,
        interpret=interpret_default(),
    )
