"""Jit'd public wrapper for the streaming migration kernel.

Arbitrary 2-D row counts go through the kernel (the double-buffered
pipeline splits a ragged tail into a dedicated staging slot), so the
old ``shape[0] % block_rows == 0`` fallback is gone.  Non-2-D payloads
and empty arrays still use the reference path.
"""
from __future__ import annotations

import jax

from repro.kernels.common import interpret_default
from repro.kernels.stream_copy import kernel, ref


def stream_copy(src: jax.Array, *, out_dtype=None, block_rows: int = 256,
                use_kernel: bool = True) -> jax.Array:
    if not use_kernel or src.ndim != 2 or src.shape[0] == 0 or src.shape[1] == 0:
        return ref.stream_copy(src, out_dtype)
    return kernel.stream_copy(
        src, block_rows=block_rows, out_dtype=out_dtype,
        interpret=interpret_default(),
    )
