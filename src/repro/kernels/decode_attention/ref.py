"""Oracle for flash-decode GQA attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array) -> jax.Array:
    """q: (B,H,hd); k,v: (B,T,K,hd); lengths: (B,) valid prefix.

    Returns (B,H,hd) in fp32.
    """
    B, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, k.astype(jnp.float32)) / np.sqrt(hd)
    valid = jnp.arange(T)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd)
