"""Jit'd public wrapper for flash-decode attention."""
from __future__ import annotations

import jax

from repro.kernels.common import interpret_default
from repro.kernels.decode_attention import kernel, ref


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, block_t: int = 512,
                     use_kernel: bool = True) -> jax.Array:
    if not use_kernel or k.shape[1] % min(block_t, k.shape[1]):
        return ref.decode_attention(q, k, v, lengths)
    return kernel.decode_attention(
        q, k, v, lengths, block_t=block_t, interpret=interpret_default()
    )
