"""Pallas TPU flash-decode kernel: one new token vs a long KV cache.

The decode-shape hot spot (decode_32k / long-context serving): per step
the whole KV prefix streams HBM -> VMEM exactly once (the cache-bypass
pattern the paper prescribes for far-tier reads), while the online-
softmax state (m, l, acc) stays VMEM-resident across KV blocks.  GQA is
exploited by processing all G = H/K query heads of one KV head per grid
cell, so each KV byte fetched serves G query heads (arithmetic-intensity
lever for the bandwidth-bound decode roofline).

Grid: (B, K, T // block_t), KV-block innermost (sequential accumulate).
Sequence lengths are scalar-prefetched: blocks past the valid prefix are
skipped entirely (no DMA compute waste for ragged batches).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, block_t: int, t_total: int):
    b = pl.program_id(0)
    tb = pl.program_id(2)
    n_tb = pl.num_programs(2)

    @pl.when(tb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(tb * block_t < length)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (Tb, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)  # (Tb, hd)
        s = jnp.dot(q, k.T) / np.sqrt(q.shape[-1])  # (G, Tb)
        t_idx = tb * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(t_idx < length, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
        m_ref[...] = m_new

    @pl.when(tb == n_tb - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def decode_attention(
    q: jax.Array,  # (B, H, hd)
    k: jax.Array,  # (B, T, K, hd)
    v: jax.Array,  # (B, T, K, hd)
    lengths: jax.Array,  # (B,) int32 valid prefix
    *,
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    block_t = min(block_t, T)
    assert T % block_t == 0, "cache length must tile by block_t"
    qg = q.reshape(B, K, G, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, T // block_t),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, kh, tb, L: (b, kh, 0, 0)),
            pl.BlockSpec((1, block_t, 1, hd), lambda b, kh, tb, L: (b, tb, kh, 0)),
            pl.BlockSpec((1, block_t, 1, hd), lambda b, kh, tb, L: (b, tb, kh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, kh, tb, L: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t, t_total=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), jnp.float32),
        interpret=interpret,
    )
    out = fn(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, hd)
