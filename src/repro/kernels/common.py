"""Shared kernel plumbing: interpret-mode selection for CPU validation."""
from __future__ import annotations

import jax


def interpret_default() -> bool:
    """Pallas TPU kernels execute via the interpreter on CPU backends."""
    return jax.default_backend() != "tpu"
