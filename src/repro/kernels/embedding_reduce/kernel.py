"""Pallas TPU embedding-bag reduction kernel.

The paper's DLRM embedding-reduction workload (§5.2) is a random-row
gather + weighted sum over a large table.  TPU adaptation: the per-bag
row indices are **scalar-prefetched** so the BlockSpec ``index_map`` can
steer each grid step's HBM->VMEM DMA straight to the right table row —
the cache-bypass streaming access the paper recommends (no reuse, no
pollution), with the accumulator resident in VMEM across the K axis.

Grid: (B, K).  Table block (1, D) selected by indices[b, k]; the output
block (1, D) revisits b for all k so the accumulation stays in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, row_ref, w_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[0, k].astype(jnp.float32)
    out_ref[...] += (row_ref[...].astype(jnp.float32) * w).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_reduce(
    table: jax.Array,  # (V, D)
    indices: jax.Array,  # (B, K) int32
    weights: jax.Array,  # (B, K)
    *,
    interpret: bool = False,
) -> jax.Array:
    B, K = indices.shape
    V, D = table.shape
    out_dtype = jnp.float32

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=[
            # one table row per grid step, chosen by the prefetched index
            pl.BlockSpec((1, D), lambda b, k, idx: (idx[b, k], 0)),
            # the bag's weights, resident per-b
            pl.BlockSpec((1, K), lambda b, k, idx: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, k, idx: (b, 0)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), out_dtype),
        interpret=interpret,
    )
    return fn(indices.astype(jnp.int32), table, weights).astype(table.dtype)
