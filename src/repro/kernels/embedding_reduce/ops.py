"""Jit'd public wrapper for the embedding-bag reduction kernel."""
from __future__ import annotations

import jax

from repro.kernels.common import interpret_default
from repro.kernels.embedding_reduce import kernel, ref


def embedding_reduce(table: jax.Array, indices: jax.Array, weights: jax.Array,
                     *, use_kernel: bool = True) -> jax.Array:
    """(V,D) x (B,K) -> (B,D).  Kernel on TPU / interpret on CPU."""
    if not use_kernel:
        return ref.embedding_reduce(table, indices, weights)
    return kernel.embedding_reduce(
        table, indices, weights, interpret=interpret_default()
    )
