"""Pure-jnp oracle for the embedding-bag reduction (DLRM §5.2 workload)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_reduce(table: jax.Array, indices: jax.Array,
                     weights: jax.Array) -> jax.Array:
    """table: (V, D); indices, weights: (B, K) -> (B, D) weighted sums."""
    gathered = jnp.take(table, indices, axis=0)  # (B, K, D)
    return jnp.einsum("bkd,bk->bd", gathered, weights.astype(table.dtype))
