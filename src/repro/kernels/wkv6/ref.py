"""Pure-jnp oracle for the WKV6 recurrence (same math as models.rwkv)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6(r, k, v, w, u, state):
    """r,k,v,w: (B,T,H,hd); u: (H,hd); state: (B,H,hd,hd) fp32.

    Returns (y (B,T,H,hd) fp32, final_state).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    rs, ks, vs, ws = (jnp.moveaxis(a.astype(jnp.float32), 1, 0)
                      for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state
