"""Pallas TPU WKV6 recurrence kernel.

TPU adaptation of the RWKV6 CUDA kernel: instead of one CUDA thread per
channel with shared-memory staging, the per-(batch, head) state matrix
(hd x hd fp32) lives in **VMEM scratch across the whole time axis**, and
r/k/v/w stream through VMEM in time chunks — HBM traffic is exactly one
pass over the inputs (the op is bandwidth-bound; state reuse is what the
VMEM residency buys).  The recurrence itself runs on the VPU via a
`fori_loop` over the chunk; numerically exact (no 1/P chunked rescaling,
which overflows for small decays).

Grid: (B*H, T // block_t), time innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, s_ref,
            *, block_t: int):
    tb = pl.program_id(1)
    n_tb = pl.num_programs(1)

    @pl.when(tb == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)  # (hd,)

    def step(t, _):
        rt = r_ref[0, t].astype(jnp.float32)  # (hd,)
        kt = k_ref[0, t].astype(jnp.float32)
        vt = v_ref[0, t].astype(jnp.float32)
        wt = w_ref[0, t].astype(jnp.float32)
        s = s_ref[...]  # (hd, hd): [k-dim, v-dim]
        kv = kt[:, None] * vt[None, :]
        y = jnp.sum(rt[:, None] * (s + u[:, None] * kv), axis=0)
        y_ref[0, t] = y
        s_ref[...] = wt[:, None] * s + kv
        return ()

    jax.lax.fori_loop(0, block_t, step, ())

    @pl.when(tb == n_tb - 1)
    def _finish():
        sT_ref[0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv6(
    r: jax.Array,  # (B, T, H, hd)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,  # (H, hd)
    state: jax.Array,  # (B, H, hd, hd) fp32
    *,
    block_t: int = 256,
    interpret: bool = False,
):
    B, T, H, hd = r.shape
    block_t = min(block_t, T)
    assert T % block_t == 0, "T must tile by block_t"
    BH = B * H

    def flat(x):  # (B,T,H,hd) -> (BH, T, hd)
        return x.transpose(0, 2, 1, 3).reshape(BH, T, hd)

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(BH, hd)
    s0 = state.reshape(BH, hd, hd)

    fn = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t),
        grid=(BH, T // block_t),
        in_specs=[
            pl.BlockSpec((1, block_t, hd), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, block_t, hd), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, block_t, hd), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, block_t, hd), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, hd), lambda bh, tb: (bh, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, tb: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, hd), lambda bh, tb: (bh, tb, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, tb: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )
    y, sT = fn(rf, kf, vf, wf, uf, s0)
    y = y.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    return y, sT.reshape(B, H, hd, hd)
