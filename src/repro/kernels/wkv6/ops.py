"""Jit'd public wrapper for the WKV6 recurrence kernel."""
from __future__ import annotations

import jax

from repro.kernels.common import interpret_default
from repro.kernels.wkv6 import kernel, ref


def wkv6(r, k, v, w, u, state, *, block_t: int = 256, use_kernel: bool = True):
    if not use_kernel or r.shape[1] % min(block_t, r.shape[1]):
        return ref.wkv6(r, k, v, w, u, state)
    return kernel.wkv6(r, k, v, w, u, state, block_t=block_t,
                       interpret=interpret_default())
