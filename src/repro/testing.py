"""Property-test compatibility layer.

When ``hypothesis`` is installed, this module re-exports the real
``given``/``settings``/``strategies``.  When it is not (minimal CI
images, the bare container), a thin deterministic fallback keeps the
property tests *running* instead of killing collection of the whole
module: each ``@given`` test is executed over boundary values plus a
fixed-seed random sample of the strategy space.  Weaker than hypothesis
(no shrinking, no database), but the invariants still get exercised.

Usage in tests::

    from repro.testing import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is present
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, boundary, draw):
            self._boundary = list(boundary)
            self._draw = draw

        def example(self, i: int, rng: np.random.Generator):
            if i < len(self._boundary):
                return self._boundary[i]
            return self._draw(rng)

    class st:  # noqa: N801 - mirrors `strategies as st`
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                [min_value, max_value],
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(
                [min_value, max_value],
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy([False, True], lambda rng: bool(rng.integers(2)))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            elements = list(elements)
            return _Strategy(
                elements[:1], lambda rng: elements[int(rng.integers(len(elements)))])

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn
        return deco

    def given(*strats: _Strategy):
        """Run the test over boundary + fixed-seed random draws.

        The drawn values fill the test's trailing parameters (hypothesis
        semantics); leading parameters stay visible to pytest as fixtures.
        """
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            keep = params[: len(params) - len(strats)]
            drawn_names = [p.name for p in params[len(keep):]]

            def runner(*args, **kwargs):
                n = getattr(fn, "_prop_max_examples", _DEFAULT_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    # Bind drawn values by NAME: pytest passes fixtures as
                    # kwargs, so positional appending would collide.
                    drawn = {name: s.example(i, rng)
                             for name, s in zip(drawn_names, strats)}
                    fn(*args, **kwargs, **drawn)

            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner.__signature__ = sig.replace(parameters=keep)
            return runner
        return deco
