"""starcoder2-3b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152, head_dim=128,
    qkv_bias=True, rope=True, rope_theta=100_000.0,
    norm="layernorm", act="gelu",
)
