"""Architecture config schema for the 10 assigned architectures.

Every config is constructed from the exact figures in the assignment
block; ``tiny()`` derives the reduced same-family config used by smoke
tests (small layers/width/experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    expert_d_ff: int
    n_shared: int = 0
    shared_d_ff: int = 0
    #: a MoE layer every `every` layers (1 = all layers; 2 = alternate)
    every: int = 1
    #: index of leading dense layers (deepseek: first layer dense)
    first_dense: int = 0
    capacity_factor: float = 1.25
    router_zloss: float = 1e-3


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    n_layers: int
    n_ctx: int  # encoder positions (whisper: 1500 mel frames)
    frontend: str = "stub"  # precomputed embeddings provided as input


@dataclasses.dataclass(frozen=True)
class VisionSpec:
    n_prefix_tokens: int  # patch embeddings prepended to the text sequence
    frontend: str = "stub"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0  # partial rotary (stablelm: 0.25)
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu | geglu | relu_sq
    tie_embeddings: bool = False
    #: block pattern repeat unit, e.g. ("rglru","rglru","local_attn");
    #: empty = uniform full-attention decoder
    block_pattern: tuple[str, ...] = ()
    local_window: int = 0
    moe: Optional[MoESpec] = None
    encoder: Optional[EncoderSpec] = None
    vision: Optional[VisionSpec] = None
    #: rwkv-specific
    rwkv_head_dim: int = 64
    max_seq: int = 131_072
    param_dtype: str = "bfloat16"
    #: sub-quadratic in sequence length (long_500k eligibility)
    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 128 (MXU lane alignment + TP
        divisibility); the true ``vocab`` stays in metadata/param counts."""
        return -(-self.vocab // 128) * 128

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder is not None

    def tiny(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-tiny",
            n_layers=max(2, len(self.block_pattern) or 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            d_ff=128,
            vocab=256,
            head_dim=16,
            max_seq=128,
            param_dtype="float32",
            local_window=min(self.local_window, 16) if self.local_window else 0,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=4,
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=32,
                shared_d_ff=32 if self.moe.n_shared else 0,
                # dropless at smoke-test scale so decode == forward exactly
                capacity_factor=4.0,
            )
            # keep the dense/moe alternation shape
            kw["n_layers"] = max(2, self.moe.every * 2 + self.moe.first_dense)
        if self.encoder is not None:
            kw["encoder"] = dataclasses.replace(self.encoder, n_layers=2, n_ctx=16)
        if self.vision is not None:
            kw["vision"] = dataclasses.replace(self.vision, n_prefix_tokens=4)
        if self.block_pattern:
            kw["n_layers"] = len(self.block_pattern) * 2  # two pattern units
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytical parameter count (used for 6·N·D roofline terms)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        emb = V * D * (1 if self.tie_embeddings else 2)

        def attn_p():
            p = D * q + 2 * D * kv + q * D
            if self.qkv_bias:
                p += q + 2 * kv
            return p

        def mlp_p(ff):
            return (3 if self.act in ("swiglu", "geglu") else 2) * D * ff

        total = emb
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind in ("attn", "local_attn"):
                total += attn_p() + mlp_p(F)
            elif kind == "rglru":
                # conv4 + in/out proj + gates + MLP
                total += 2 * D * D + 4 * D + 2 * D + mlp_p(F)
            elif kind == "rwkv":
                total += 4 * D * D + D * D + 2 * D * F  # time-mix + channel-mix
            elif kind == "moe":
                m = self.moe
                total += attn_p()
                total += m.n_experts * mlp_p(m.expert_d_ff)
                total += m.n_shared * mlp_p(m.shared_d_ff or m.expert_d_ff)
                total += D * m.n_experts  # router
            elif kind == "dense_moe_alt":
                total += attn_p() + mlp_p(F)
        if self.encoder is not None:
            for _ in range(self.encoder.n_layers):
                total += attn_p() + mlp_p(F)
            # decoder cross-attention
            total += self.n_layers * attn_p()
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        m = self.moe
        hd = self.resolved_head_dim
        q, kv = self.n_heads * hd, self.n_kv_heads * hd
        attn_p = D * q + 2 * D * kv + q * D
        mlp = lambda ff: (3 if self.act in ("swiglu", "geglu") else 2) * D * ff
        total = self.vocab * D * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind == "moe":
                total += attn_p + m.top_k * mlp(m.expert_d_ff)
                total += m.n_shared * mlp(m.shared_d_ff or m.expert_d_ff)
            else:
                total += attn_p + mlp(F)
        return total

    def block_kind(self, layer_idx: int) -> str:
        """Block type of decoder layer ``layer_idx``."""
        if self.family == "ssm":
            return "rwkv"
        if self.block_pattern:
            return self.block_pattern[layer_idx % len(self.block_pattern)]
        if self.moe is not None:
            if layer_idx < self.moe.first_dense:
                return "dense_moe_alt"
            # hf llama4 convention: MoE on every `every`-th layer
            return "moe" if (layer_idx + 1) % self.moe.every == 0 else "dense_moe_alt"
        return "attn"

    def layer_kinds(self) -> tuple[str, ...]:
        return tuple(self.block_kind(i) for i in range(self.n_layers))
