"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2 pattern.
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    qkv_bias=False, rope=True, rope_theta=10_000.0,
    norm="rmsnorm", act="geglu",
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=2048,
)
