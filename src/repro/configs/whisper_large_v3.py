"""whisper-large-v3 [audio] — enc-dec; conv mel frontend is a STUB
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig, EncoderSpec

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    qkv_bias=True, rope=False,
    norm="layernorm", act="gelu", tie_embeddings=True,
    encoder=EncoderSpec(n_layers=32, n_ctx=1500),
    max_seq=32_768,  # whisper spec is 448; extended so the assigned 32k shapes lower
)
