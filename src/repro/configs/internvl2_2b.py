"""internvl2-2b [vlm] — InternLM2 backbone + InternViT stub frontend.
[arXiv:2404.16821; hf]  The ViT supplies precomputed patch embeddings
(256 prefix tokens) via input_specs; the LM backbone is exact."""
from repro.configs.base import ArchConfig, VisionSpec

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, head_dim=128,
    qkv_bias=False, rope=True, rope_theta=1_000_000.0,
    norm="rmsnorm", act="swiglu",
    vision=VisionSpec(n_prefix_tokens=256),
)
