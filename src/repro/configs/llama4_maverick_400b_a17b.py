"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, MoE every other
layer, 1 shared expert. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
Assignment gives expert d_ff=8192; the alternating dense layers use the
hf intermediate_size_mlp=16384 so total/active params land at ~400B/17B."""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=16384, vocab=202048, head_dim=128,
    qkv_bias=False, rope=True, rope_theta=500_000.0,
    norm="rmsnorm", act="swiglu",
    moe=MoESpec(
        n_experts=128, top_k=1, expert_d_ff=8192,
        n_shared=1, shared_d_ff=8192, every=2,
    ),
)
