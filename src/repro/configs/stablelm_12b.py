"""stablelm-12b [dense] — partial rotary (25%), LayerNorm.
[hf:stabilityai/stablelm-2-1_6b; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab=100352, head_dim=160,
    qkv_bias=False, rope=True, rope_theta=10_000.0, rope_pct=0.25,
    norm="layernorm", act="swiglu",
)
