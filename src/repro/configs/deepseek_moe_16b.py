"""deepseek-moe-16b [moe] — fine-grained: 2 shared + 64 routed top-6,
first layer dense (d_ff=10944 per arXiv:2401.06066). [arXiv:2401.06066; hf]"""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab=102400, head_dim=128,
    qkv_bias=False, rope=True, rope_theta=10_000.0,
    norm="rmsnorm", act="swiglu",
    moe=MoESpec(
        n_experts=64, top_k=6, expert_d_ff=1408,
        n_shared=2, shared_d_ff=1408, every=1, first_dense=1,
    ),
)
