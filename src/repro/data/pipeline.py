"""Deterministic sharded token pipeline.

Batches are a pure function of (seed, step, shard) — so a restarted or
re-sharded worker reproduces the exact stream with no cursor files,
which is what makes the fault-tolerance test bit-exact.  Sources:
``synthetic`` (Zipf-ish token distribution) or a binary token file
(np.memmap).  Host-side prefetch uses the mover's double_buffer.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.mover import double_buffer


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int  # per-shard batch
    seq: int
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    path: Optional[str] = None  # binary uint32 token file; None = synthetic
    zipf_a: float = 1.2


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.path is not None:
            self._mm = np.memmap(cfg.path, dtype=np.uint32, mode="r")
            if len(self._mm) < cfg.seq + 1:
                raise ValueError("token file shorter than one sequence")

    def batch_at(self, step: int) -> dict:
        """The batch for global ``step`` on this shard (pure function)."""
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.shard_id])
        )
        if self._mm is None:
            # Zipf-ish synthetic tokens, clipped into vocab
            raw = rng.zipf(c.zipf_a, size=(c.batch, c.seq + 1))
            toks = (raw - 1) % c.vocab
        else:
            max_start = len(self._mm) - (c.seq + 1)
            starts = rng.integers(0, max_start + 1, size=c.batch)
            toks = np.stack([self._mm[s : s + c.seq + 1] for s in starts])
            toks = toks % c.vocab
        toks = toks.astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((c.batch, c.seq), np.float32),
        }

    def iter_from(self, start_step: int = 0, prefetch: bool = True) -> Iterator[dict]:
        steps = _count_from(start_step)
        if prefetch:
            yield from double_buffer(steps, self.batch_at)
        else:
            for s in steps:
                yield self.batch_at(s)


def _count_from(start: int):
    s = start
    while True:
        yield s
        s += 1
