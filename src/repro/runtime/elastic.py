"""Elastic re-meshing: re-plan mesh + tier placement when capacity changes.

When a pod loses hosts (or gains them back), the runtime must (1) choose
a new (data, model) factorization of the surviving chips, (2) re-run the
bandwidth-aware placement planner against the *shrunken* fast-tier
budget — exactly the paper's scenario of demand exceeding DRAM, where
weighted interleaving to the slow tier absorbs the loss — and (3) emit a
resharding plan mapping old checkpoint shards onto the new mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.planner import BufferReq, Plan, plan as plan_placement
from repro.core.tiers import TierTopology


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    n_chips: int
    data: int
    model: int
    pods: int = 1

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.pods, self.data, self.model) if self.pods > 1 else (self.data, self.model)

    @property
    def axis_names(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")


def choose_mesh(n_chips: int, *, model_parallel_hint: int = 16,
                pods: int = 1) -> MeshPlan:
    """Largest model axis <= hint that divides chips-per-pod; rest is data."""
    per_pod = n_chips // pods
    if per_pod * pods != n_chips:
        raise ValueError("chips must divide evenly into pods")
    model = min(model_parallel_hint, per_pod)
    while per_pod % model:
        model -= 1
    return MeshPlan(n_chips=n_chips, data=per_pod // model, model=model, pods=pods)


@dataclasses.dataclass
class ReshardMove:
    buffer: str
    kind: str  # "repartition" | "tier_shift"
    detail: str


@dataclasses.dataclass
class ElasticPlan:
    old_mesh: MeshPlan
    new_mesh: MeshPlan
    placement: Plan
    moves: list[ReshardMove]


def replan(
    old_mesh: MeshPlan,
    surviving_chips: int,
    buffers: Sequence[BufferReq],
    topology: TierTopology,
    *,
    compute_seconds: float,
    old_placement: Optional[Plan] = None,
    reserve_fast_bytes: int = 0,
) -> ElasticPlan:
    """Plan the shrink/grow: new mesh + new tier placement + moves.

    Per-chip state grows by old/new chip ratio; the planner decides how
    much of that growth spills to the slow tier (N:M re-weighting).
    """
    new_mesh = choose_mesh(surviving_chips, model_parallel_hint=old_mesh.model,
                           pods=old_mesh.pods if surviving_chips % old_mesh.pods == 0
                           else 1)
    growth = old_mesh.n_chips / new_mesh.n_chips
    scaled = [
        dataclasses.replace(
            b, nbytes=int(b.nbytes * growth),
            profile=dataclasses.replace(
                b.profile,
                bytes_read_per_step=b.profile.bytes_read_per_step * growth,
                bytes_written_per_step=b.profile.bytes_written_per_step * growth,
            ),
        )
        for b in buffers
    ]
    placement = plan_placement(
        scaled, topology, compute_seconds=compute_seconds * growth,
        reserve_fast_bytes=reserve_fast_bytes,
    )
    moves: list[ReshardMove] = []
    if (new_mesh.data, new_mesh.model) != (old_mesh.data, old_mesh.model):
        moves.append(ReshardMove(
            "*", "repartition",
            f"mesh {old_mesh.shape} -> {new_mesh.shape}: all-gather shards on "
            f"dead hosts' peers, re-scatter to the new layout",
        ))
    for name, d in placement.decisions.items():
        old_f = old_placement.slow_fraction(name) if old_placement and \
            name in old_placement.decisions else 0.0
        if abs(d.slow_fraction - old_f) > 1e-3:
            moves.append(ReshardMove(
                name, "tier_shift",
                f"slow fraction {old_f:.1%} -> {d.slow_fraction:.1%} "
                f"(bulk-mover demotion of {d.slow_fraction - old_f:+.1%} pages)",
            ))
    return ElasticPlan(old_mesh, new_mesh, placement, moves)
