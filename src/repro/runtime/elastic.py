"""Elastic re-meshing + device fault injection.

When a pod loses hosts (or gains them back), the runtime must (1) choose
a new (data, model) factorization of the surviving chips, (2) re-run the
bandwidth-aware placement planner against the *shrunken* fast-tier
budget — exactly the paper's scenario of demand exceeding DRAM, where
weighted interleaving to the slow tier absorbs the loss — and (3) emit a
resharding plan mapping old checkpoint shards onto the new mesh.

``FaultInjector`` is the emucxl-style harness for the device-level
analogue: per-device bandwidth/latency degradation (installed into the
perfmodel, so the mover's execution timing, the serving engine's modeled
step seconds, and every benchmark throughput model slow down together —
and the billed-bandwidth drift re-opens converged Caption walks) and
mid-run device kills, detected through missed heartbeats and recovered
through the elastic drain path (``ServingEngine.remove_device`` /
``CaptionController.remove_device``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core import perfmodel
from repro.core.planner import BufferReq, Plan, plan as plan_placement
from repro.core.tiers import TierTopology
from repro.runtime.fault_tolerance import HeartbeatMonitor


@dataclasses.dataclass(frozen=True)
class InjectionEvent:
    """One scheduled fault: fires when the run reaches ``step``."""

    step: int
    action: str  # "degrade" | "restore" | "kill" | "revive"
    device: str
    bw_scale: float = 1.0
    latency_scale: float = 1.0


class FaultInjector:
    """emucxl-style per-device fault harness.

    ``degrade``/``restore`` install per-device bandwidth/latency
    multipliers into the perfmodel (every model entry point sees them, so
    the degradation is visible end to end, telemetry included); ``kill``/
    ``revive`` mark a device dead so its heartbeats stop — the attached
    :class:`HeartbeatMonitor` then raises ``WorkerFailure`` on the next
    ``check()``, and the caller routes recovery through the elastic
    drain path.  Faults can fire immediately or on a ``schedule`` keyed
    by run step (``apply(step)`` each step).
    """

    def __init__(self, monitor: Optional[HeartbeatMonitor] = None):
        self.monitor = monitor
        self.dead: set[str] = set()
        self.degradations: dict[str, tuple[float, float]] = {}
        self.log: list[tuple[int, str, str]] = []
        self._schedule: list[InjectionEvent] = []
        self._step = 0

    # -- immediate faults ----------------------------------------------------
    def degrade(self, device: str, *, bw_scale: float = 1.0,
                latency_scale: float = 1.0) -> None:
        perfmodel.set_degradation(device, bw_scale=bw_scale,
                                  latency_scale=latency_scale)
        self.degradations[device] = (bw_scale, latency_scale)
        self.log.append((self._step, "degrade",
                         f"{device} bw x{bw_scale:g} lat x{latency_scale:g}"))

    def restore(self, device: str) -> None:
        perfmodel.clear_degradations(device)
        self.degradations.pop(device, None)
        self.log.append((self._step, "restore", device))

    def kill(self, device: str) -> None:
        """The device disappears mid-run: beats stop, so the monitor's
        next ``check()`` raises WorkerFailure naming it."""
        self.dead.add(device)
        self.log.append((self._step, "kill", device))

    def revive(self, device: str) -> None:
        self.dead.discard(device)
        if self.monitor is not None:
            self.monitor.forgive(device)
        self.log.append((self._step, "revive", device))

    def alive(self, device: str) -> bool:
        return device not in self.dead

    def beat_alive(self, devices: Sequence[str],
                   now: Optional[float] = None) -> None:
        """One health-poll round: every live device beats; dead ones go
        silent and age out past the monitor's timeout."""
        if self.monitor is None:
            return
        for d in devices:
            if d not in self.dead:
                self.monitor.beat(d, now)

    # -- scheduled faults ----------------------------------------------------
    def schedule(self, step: int, action: str, device: str, *,
                 bw_scale: float = 1.0,
                 latency_scale: float = 1.0) -> "FaultInjector":
        self._schedule.append(InjectionEvent(step, action, device,
                                             bw_scale, latency_scale))
        return self

    def apply(self, step: int) -> list[InjectionEvent]:
        """Fire every event scheduled for ``step``; returns them."""
        self._step = step
        fired = [e for e in self._schedule if e.step == step]
        for e in fired:
            if e.action == "degrade":
                self.degrade(e.device, bw_scale=e.bw_scale,
                             latency_scale=e.latency_scale)
            elif e.action == "restore":
                self.restore(e.device)
            elif e.action == "kill":
                self.kill(e.device)
            elif e.action == "revive":
                self.revive(e.device)
            else:
                raise ValueError(f"unknown injection action {e.action!r}")
        self._schedule = [e for e in self._schedule if e.step != step]
        return fired

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Lift every degradation this injector installed (the perfmodel
        registry is process-global; tests must not leak faults)."""
        for device in list(self.degradations):
            perfmodel.clear_degradations(device)
        self.degradations.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    n_chips: int
    data: int
    model: int
    pods: int = 1

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.pods, self.data, self.model) if self.pods > 1 else (self.data, self.model)

    @property
    def axis_names(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")


def choose_mesh(n_chips: int, *, model_parallel_hint: int = 16,
                pods: int = 1) -> MeshPlan:
    """Largest model axis <= hint that divides chips-per-pod; rest is data."""
    per_pod = n_chips // pods
    if per_pod * pods != n_chips:
        raise ValueError("chips must divide evenly into pods")
    model = min(model_parallel_hint, per_pod)
    while per_pod % model:
        model -= 1
    return MeshPlan(n_chips=n_chips, data=per_pod // model, model=model, pods=pods)


@dataclasses.dataclass
class ReshardMove:
    buffer: str
    kind: str  # "repartition" | "tier_shift"
    detail: str


@dataclasses.dataclass
class ElasticPlan:
    old_mesh: MeshPlan
    new_mesh: MeshPlan
    placement: Plan
    moves: list[ReshardMove]


def replan(
    old_mesh: MeshPlan,
    surviving_chips: int,
    buffers: Sequence[BufferReq],
    topology: TierTopology,
    *,
    compute_seconds: float,
    old_placement: Optional[Plan] = None,
    reserve_fast_bytes: int = 0,
) -> ElasticPlan:
    """Plan the shrink/grow: new mesh + new tier placement + moves.

    Per-chip state grows by old/new chip ratio; the planner decides how
    much of that growth spills to the slow tier (N:M re-weighting).
    """
    new_mesh = choose_mesh(surviving_chips, model_parallel_hint=old_mesh.model,
                           pods=old_mesh.pods if surviving_chips % old_mesh.pods == 0
                           else 1)
    growth = old_mesh.n_chips / new_mesh.n_chips
    scaled = [
        dataclasses.replace(
            b, nbytes=int(b.nbytes * growth),
            profile=dataclasses.replace(
                b.profile,
                bytes_read_per_step=b.profile.bytes_read_per_step * growth,
                bytes_written_per_step=b.profile.bytes_written_per_step * growth,
            ),
        )
        for b in buffers
    ]
    placement = plan_placement(
        scaled, topology, compute_seconds=compute_seconds * growth,
        reserve_fast_bytes=reserve_fast_bytes,
    )
    moves: list[ReshardMove] = []
    if (new_mesh.data, new_mesh.model) != (old_mesh.data, old_mesh.model):
        moves.append(ReshardMove(
            "*", "repartition",
            f"mesh {old_mesh.shape} -> {new_mesh.shape}: all-gather shards on "
            f"dead hosts' peers, re-scatter to the new layout",
        ))
    for name, d in placement.decisions.items():
        old_f = old_placement.slow_fraction(name) if old_placement and \
            name in old_placement.decisions else 0.0
        if abs(d.slow_fraction - old_f) > 1e-3:
            moves.append(ReshardMove(
                name, "tier_shift",
                f"slow fraction {old_f:.1%} -> {d.slow_fraction:.1%} "
                f"(bulk-mover demotion of {d.slow_fraction - old_f:+.1%} pages)",
            ))
    return ElasticPlan(old_mesh, new_mesh, placement, moves)
