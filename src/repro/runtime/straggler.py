"""Straggler mitigation: deadline-based micro-retry of stalled steps.

At pod scale, a slow host (thermal throttle, page-cache storm, a dying
HBM stack) stalls synchronous steps.  The driver-side mitigation here:
track a robust moving estimate of step time, and when a step exceeds
``threshold x`` the estimate, re-dispatch it (in production: to a hot
spare / re-issue the collective); the duplicate result is idempotent
because steps are pure functions of (state, step).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class StragglerStats:
    median_estimate: float = 0.0
    dispatched: int = 0
    redispatched: int = 0


class StragglerMitigator:
    def __init__(self, *, threshold: float = 3.0, alpha: float = 0.1,
                 min_timeout: float = 0.05):
        self.threshold = threshold
        self.alpha = alpha
        self.min_timeout = min_timeout
        self.stats = StragglerStats()
        self._pool = cf.ThreadPoolExecutor(max_workers=2)

    def _observe(self, dt: float) -> None:
        s = self.stats
        s.median_estimate = (
            dt if s.median_estimate == 0.0
            else (1 - self.alpha) * s.median_estimate + self.alpha * dt
        )

    def run(self, fn: Callable[[], object]) -> object:
        """Execute fn; if it exceeds the deadline, re-dispatch and take
        whichever finishes first WITHOUT raising (results are idempotent,
        so a failed original racing a healthy backup must not lose)."""
        self.stats.dispatched += 1
        deadline = max(self.min_timeout,
                       self.threshold * (self.stats.median_estimate or 1e9))

        def timed():
            # Per-dispatch timing: the EWMA must see the winner's OWN
            # latency.  Wall clock from the first dispatch folds the whole
            # stall (deadline wait + backup runtime) into the estimate,
            # inflating the deadline after every straggle.
            t0 = time.perf_counter()
            return fn(), time.perf_counter() - t0

        fut = self._pool.submit(timed)
        try:
            result, dt = fut.result(timeout=deadline)
        except cf.TimeoutError:
            self.stats.redispatched += 1
            backup = self._pool.submit(timed)
            result, dt = self._first_success((fut, backup))
        self._observe(dt)
        return result

    @staticmethod
    def _first_success(futures):
        """First completed future that did not raise; only when every
        dispatch failed does the first exception propagate."""
        pending = set(futures)
        first_exc = None
        while pending:
            done, pending = cf.wait(pending,
                                    return_when=cf.FIRST_COMPLETED)
            for f in done:
                exc = f.exception()
                if exc is None:
                    return f.result()
                if first_exc is None:
                    first_exc = exc
        raise first_exc

    def close(self):
        self._pool.shutdown(wait=False, cancel_futures=True)
