"""Fault tolerance: heartbeats, failure detection, checkpoint-restart.

``ResilientLoop`` wraps a step function: it checkpoints every
``checkpoint_every`` steps (async), detects worker failure (raised
``WorkerFailure`` — in production, a missed heartbeat or a collective
timeout), restores the last checkpoint, and replays.  Because the data
pipeline is a pure function of step, recovery is bit-exact (tested).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

from repro.checkpoint.checkpointer import Checkpointer


class WorkerFailure(RuntimeError):
    """A (simulated) node failure: lost heartbeat / dead collective."""


@dataclasses.dataclass
class Heartbeat:
    worker: str
    last_seen: float


class HeartbeatMonitor:
    """Detects missing heartbeats past ``timeout`` seconds."""

    def __init__(self, timeout: float = 10.0):
        self.timeout = timeout
        self._beats: dict[str, Heartbeat] = {}
        self._lock = threading.Lock()

    def beat(self, worker: str, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        with self._lock:
            self._beats[worker] = Heartbeat(worker, now)

    def dead_workers(self, now: Optional[float] = None) -> list[str]:
        now = time.monotonic() if now is None else now
        with self._lock:
            return [w for w, hb in self._beats.items()
                    if now - hb.last_seen > self.timeout]

    def check(self, now: Optional[float] = None) -> None:
        dead = self.dead_workers(now)
        if dead:
            raise WorkerFailure(f"lost heartbeat from {dead}")


class ResilientLoop:
    def __init__(
        self,
        checkpointer: Checkpointer,
        *,
        checkpoint_every: int = 50,
        max_restarts: int = 3,
    ):
        self.ckpt = checkpointer
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(
        self,
        state: dict,  # {"step": int, ...pytree of arrays}
        step_fn: Callable[[dict, int], dict],  # (state, step) -> state
        n_steps: int,
        *,
        failure_injector: Optional[Callable[[int], None]] = None,
    ) -> dict:
        """Run to ``n_steps``, surviving WorkerFailure via restore+replay."""
        step = int(state.pop("step"))
        while step < n_steps:
            try:
                if failure_injector is not None:
                    failure_injector(step)
                state = step_fn(state, step)
                step += 1
                if step % self.checkpoint_every == 0 or step == n_steps:
                    self.ckpt.save(step, state, metadata={"step": step})
            except WorkerFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = 0  # replay from scratch
                    continue
                restored_step, state, _ = self.ckpt.restore(state)
                step = restored_step
        self.ckpt.wait()
        return dict(state, step=step)
