"""Fault tolerance: heartbeats, failure detection, checkpoint-restart.

``ResilientLoop`` wraps a step function: it checkpoints every
``checkpoint_every`` steps (async), detects worker failure (raised
``WorkerFailure`` — in production, a missed heartbeat or a collective
timeout), restores the last checkpoint, and replays.  Because the data
pipeline is a pure function of step, recovery is bit-exact (tested).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer


def _snapshot(tree):
    """Deep-copy the mutable leaves of a state pytree.

    numpy buffers are the replay hazard: a step function that updates
    them in place corrupts any alias kept around for later replay.  jax
    arrays and Python scalars are immutable and pass through."""
    def copy_leaf(x):
        return np.array(x, copy=True) if isinstance(x, np.ndarray) else x
    return jax.tree_util.tree_map(copy_leaf, tree)


class WorkerFailure(RuntimeError):
    """A (simulated) node failure: lost heartbeat / dead collective."""


@dataclasses.dataclass
class Heartbeat:
    worker: str
    last_seen: float


class HeartbeatMonitor:
    """Detects missing heartbeats past ``timeout`` seconds."""

    def __init__(self, timeout: float = 10.0):
        self.timeout = timeout
        self._beats: dict[str, Heartbeat] = {}
        self._lock = threading.Lock()

    def beat(self, worker: str, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        with self._lock:
            self._beats[worker] = Heartbeat(worker, now)

    def dead_workers(self, now: Optional[float] = None) -> list[str]:
        now = time.monotonic() if now is None else now
        with self._lock:
            return [w for w, hb in self._beats.items()
                    if now - hb.last_seen > self.timeout]

    def check(self, now: Optional[float] = None) -> None:
        dead = self.dead_workers(now)
        if dead:
            raise WorkerFailure(f"lost heartbeat from {dead}")

    def remove(self, worker: str) -> bool:
        """Deregister a worker (elastic shrink / permanent removal).

        Without this, one missed timeout poisons the monitor forever:
        ``check()`` re-raises for the same dead worker on every later
        call, so recovery could never be acknowledged.  Returns whether
        the worker was registered."""
        with self._lock:
            return self._beats.pop(worker, None) is not None

    def forgive(self, worker: str, now: Optional[float] = None) -> None:
        """Recovery reset: the worker is healthy again (elastic re-add);
        restart its timeout window from ``now``."""
        self.beat(worker, now)


class ResilientLoop:
    def __init__(
        self,
        checkpointer: Checkpointer,
        *,
        checkpoint_every: int = 50,
        max_restarts: int = 3,
    ):
        self.ckpt = checkpointer
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(
        self,
        state: dict,  # {"step": int, ...pytree of arrays}
        step_fn: Callable[[dict, int], dict],  # (state, step) -> state
        n_steps: int,
        *,
        failure_injector: Optional[Callable[[int], None]] = None,
    ) -> dict:
        """Run to ``n_steps``, surviving WorkerFailure via restore+replay."""
        state = dict(state)  # never mutate the caller's dict
        step = start_step = int(state.pop("step"))
        # Snapshot the pristine initial state: a no-checkpoint failure
        # replays from scratch, and "scratch" must be bit-exact — not the
        # post-failure state a partially-executed step may have mutated.
        initial = _snapshot(state)
        while step < n_steps:
            try:
                if failure_injector is not None:
                    failure_injector(step)
                state = step_fn(state, step)
                step += 1
                if step % self.checkpoint_every == 0 or step == n_steps:
                    self.ckpt.save(step, state, metadata={"step": step})
            except WorkerFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    # replay from scratch: restore the snapshot (and keep a
                    # fresh copy in case this replay fails too)
                    state = _snapshot(initial)
                    step = start_step
                    continue
                restored_step, state, _ = self.ckpt.restore(state)
                step = restored_step
        self.ckpt.wait()
        return dict(state, step=step)
