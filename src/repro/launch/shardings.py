"""Sharding rules: logical-axis mapping from parameter paths to mesh axes.

TP shards the flattened head (H*hd), FFN (F), vocab (V), and expert (E)
dims; FSDP additionally shards one large dim of each weight over the
data(+pod) axes for models past ``fsdp_threshold`` params.  Head-count
dims (40, 20...) do not divide a 16-way model axis, so constraints are
placed on the flat projections and XLA propagates the rest — the
baseline recorded in EXPERIMENTS.md §Perf iterates from there.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import dp_axes


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    fsdp: bool = False  # shard params over data axes too (ZeRO-3-ish)
    zero1: bool = False  # params replicated over dp; ONLY moments dp-sharded
    fsdp_threshold: float = 10e9  # auto-enable above this many params
    seq_shard_prefill: bool = True  # shard long-seq activations over data axes

    @staticmethod
    def for_arch(cfg: ArchConfig) -> "ShardingConfig":
        # params bf16 + grads fp32 + moments fp32x2 = 14 B/param; enable
        # FSDP once a pure-TP layout would eat >25% of HBM per chip.
        per_chip = cfg.param_count() * 14 / 16
        return ShardingConfig(fsdp=per_chip > 0.25 * 16 * 1024**3)


# param-name classification --------------------------------------------------
_COL_KEYS = {"wq", "wk", "wv", "w_gate", "w_up", "Wk", "Wr", "Wv", "Wg",
             "W_gate", "W_in", "W_a", "W_i"}
_ROW_KEYS = {"wo", "w_down", "Wo", "W_out"}
_REPLICATE_KEYS = {"scale", "bias", "w0", "u", "gn_scale", "gn_bias",
                   "lam", "conv", "b_a", "b_i", "w_A", "w_B",
                   "mu_r", "mu_k", "mu_v", "mu_w", "mu_g"}


def _leaf_key(path) -> str:
    last = path[-1]
    return getattr(last, "key", str(last))


def param_spec(path, leaf, cfg: ArchConfig, mesh, scfg: ShardingConfig) -> P:
    """PartitionSpec for one parameter leaf (stacked layer dims included)."""
    key = _leaf_key(path)
    keys = [getattr(p, "key", "") for p in path]
    ndim = len(leaf.shape)
    dp = dp_axes(mesh)
    fs = dp if scfg.fsdp else None

    def spec(*tail: object) -> P:
        """Right-align ``tail`` onto the leaf's dims (leading dims unsharded)."""
        full = [None] * (ndim - len(tail)) + list(tail)
        return P(*full)

    if key in {"embed"}:
        return P("model", None)  # vocab-sharded table
    if key in {"lm_head"}:
        return P(None, "model")
    if key in {"router"}:
        return P(None, None) if ndim == 2 else spec(None, None)
    if key in {"enc_pos", "dec_pos"}:
        return P(None, None)
    if "experts" in keys:
        # (units, E, D, F) / (units, E, F, D): EP on data axes, TP on model
        if key in {"w_gate", "w_up"}:
            return spec(fs, None, "model") if ndim >= 3 else spec(None, "model")
        if key == "w_down":
            return spec(fs, "model", None) if ndim >= 3 else spec("model", None)
    if key in _REPLICATE_KEYS:
        return P(*([None] * ndim))
    if key.startswith("b") and ndim <= 2:  # qkv biases (stacked (L, Hhd))
        return spec("model")
    if key in _COL_KEYS and ndim >= 2:
        return spec(fs, "model")
    if key in _ROW_KEYS and ndim >= 2:
        return spec("model", fs)
    return P(*([None] * ndim))


def param_shardings(params_abstract, cfg: ArchConfig, mesh,
                    scfg: Optional[ShardingConfig] = None):
    scfg = scfg or ShardingConfig.for_arch(cfg)
    def one(path, leaf):
        return NamedSharding(mesh, param_spec(path, leaf, cfg, mesh, scfg))
    return jax.tree_util.tree_map_with_path(one, params_abstract)


def opt_state_shardings(params_shardings, params_abstract=None,
                        zero1: bool = False):
    """Moments follow their parameter's sharding; under ZeRO-1 they are
    additionally sharded over the dp axes (first evenly-divisible dim),
    so replicated params don't imply replicated optimizer state."""
    mesh = jax.tree_util.tree_leaves(params_shardings)[0].mesh
    moments = params_shardings
    if zero1 and params_abstract is not None:
        dp = dp_axes(mesh)
        n_dp = int(np.prod([mesh.shape[a] for a in dp]))

        def shard_more(sh, leaf):
            spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
            for i, (ax, dim) in enumerate(zip(spec, leaf.shape)):
                if ax is None and dim % n_dp == 0 and dim >= n_dp:
                    spec[i] = dp
                    return NamedSharding(mesh, P(*spec))
            return sh

        moments = jax.tree_util.tree_map(shard_more, params_shardings,
                                         params_abstract)
    return {
        "step": NamedSharding(mesh, P()),
        "mu": moments,
        "nu": moments,
    }


def batch_sharding(mesh, batch: int, extra_dims: int = 1,
                   feature_dims: int = 0):
    """(B, ...) batch-sharded over the dp axes when divisible."""
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    lead = dp if batch % n_dp == 0 else None
    return NamedSharding(mesh, P(lead, *([None] * (extra_dims - 1 + feature_dims))))


def activation_policy(mesh, *, seq_sharded: bool = False) -> dict:
    """Logical-name -> sharding constraints installed around model calls."""
    dp = dp_axes(mesh)
    seq = dp if seq_sharded else None
    return {
        "act_btd": NamedSharding(mesh, P(dp if not seq_sharded else None, seq, None)),
        "act_btf": NamedSharding(mesh, P(dp if not seq_sharded else None, seq, "model")),
        "act_btv": NamedSharding(mesh, P(dp if not seq_sharded else None, seq, "model")),
        "act_ecd": NamedSharding(mesh, P(dp, None, None)),  # experts over dp (EP)
        "act_ecd_flat": NamedSharding(mesh, P(dp, None)),  # (E*C, D) expert-major
        "act_td": NamedSharding(mesh, P(dp, None)),  # flat tokens, batch-major
        "_ep": (mesh, dp),  # shard_map expert-parallel dispatch context
        "_q_chunk": 256,  # score-block rows per flight
        "_flash": True,  # online-softmax KV chunking (no (C,S) score spill)
        "_kv_chunk": 1024,
    }


def cache_shardings(cache_abstract, mesh):
    """KV cache / recurrent state: batch dim sharded over dp axes."""
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))

    n_model = mesh.shape["model"]

    def one(path, leaf):
        key = _leaf_key(path)
        if key == "len":
            return NamedSharding(mesh, P(None))
        nd = len(leaf.shape)
        batch_ok = nd >= 2 and leaf.shape[1] % n_dp == 0
        # self-attention KV caches (L, B, T, K, hd): shard the time axis
        # over the model axis too — decode attention partial-softmaxes per
        # shard and all-reduces (flash-decode style); without this, MHA
        # caches (kv=40) blow HBM.
        if key in ("k", "v") and nd == 5 and leaf.shape[2] % n_model == 0:
            return NamedSharding(
                mesh, P(None, dp if batch_ok else None, "model", None, None))
        if key in ("xk", "xv") and nd == 5:
            return NamedSharding(
                mesh, P(None, dp if batch_ok else None, None, None, None))
        if batch_ok:
            return NamedSharding(mesh, P(None, dp, *([None] * (nd - 2))))
        if nd >= 1 and leaf.shape[0] % n_dp == 0:
            return NamedSharding(mesh, P(dp, *([None] * (nd - 1))))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(one, cache_abstract)
