"""Step functions + abstract input specs for every (arch x shape) cell.

``make_train_step`` builds the jitted training step: microbatched
gradient accumulation (lax.scan) with per-layer remat, fp32 grad
accumulators, AdamW update fused in (or grads returned for the tiered
offload path).  ``make_serve_step``/``make_prefill_step`` build the
decode/prefill programs.  ``input_specs`` produces the sharded
ShapeDtypeStruct stand-ins the dry run lowers against (no allocation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch import shardings as shmod
from repro.launch.mesh import dp_axes
from repro.launch.shapes import ShapeSpec
from repro.models.common import activation_sharding
from repro.models.registry import Arch
from repro.optim import adamw


# ---------------------------------------------------------------------------
# Abstract params / batch / cache
# ---------------------------------------------------------------------------
def abstract_params(arch: Arch):
    return jax.eval_shape(lambda k: arch.module.init(arch.cfg, k),
                          jax.random.PRNGKey(0))


def abstract_batch(arch: Arch, shape: ShapeSpec) -> dict:
    cfg = arch.cfg
    B, S = shape.batch, shape.seq
    from repro.models.common import dtype_of
    dt = dtype_of(cfg.param_dtype)
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision.n_prefix_tokens, cfg.d_model), dt)
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder.n_ctx, cfg.d_model), dt)
    return batch


def abstract_cache(arch: Arch, shape: ShapeSpec, dtype=None):
    return jax.eval_shape(
        lambda: arch.module.init_cache(arch.cfg, shape.batch, shape.seq,
                                       dtype=dtype))


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def make_micro_grad_step(arch: Arch, *, act_policy: Optional[dict] = None
                         ) -> Callable:
    """ZeRO-offload device program: ONE microbatch fwd+bwd, bf16 grads out.
    The host daemon accumulates grads in fp32 and pages the optimizer
    state (TieredAdamW) — no device-resident fp32 accumulator at all."""
    cfg, mod = arch.cfg, arch.module
    train_policy = dict(act_policy or {})
    train_policy.pop("_flash", None)

    def micro_step(params, micro_batch):
        with activation_sharding(train_policy):
            loss, grads = jax.value_and_grad(
                lambda p: mod.loss(cfg, p, micro_batch, remat=True))(params)
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.bfloat16), grads)
        return grads, {"loss": loss}

    return micro_step


def make_train_step(arch: Arch, opt_cfg: adamw.AdamWConfig, *,
                    n_micro: int = 1, act_policy: Optional[dict] = None,
                    return_grads: bool = False, mesh=None,
                    grad_shardings=None) -> Callable:
    cfg = arch.cfg
    mod = arch.module
    micro_sh = None
    if mesh is not None and n_micro > 1:
        micro_sh = NamedSharding(mesh, P(None, dp_axes(mesh)))

    # flash stays OFF in training: JAX's scan-bwd saves per-chunk score
    # residuals, so pure-JAX flash does not cut backward HBM traffic
    # (measured: §Perf, refuted hypothesis); needs the custom-VJP Pallas
    # kernel. Prefill/serve keep it (7.7x memory-term win measured).
    train_policy = dict(act_policy or {})
    train_policy.pop("_flash", None)

    def loss_fn(params, mb):
        with activation_sharding(train_policy):
            return mod.loss(cfg, params, mb, remat=True)

    def grads_of(params, batch):
        if n_micro == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def reshape(x):
            y = x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
            if micro_sh is not None:
                spec = P(None, micro_sh.spec[1], *([None] * (y.ndim - 2)))
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(micro_sh.mesh, spec))
            return y

        micro = jax.tree_util.tree_map(reshape, batch)

        def constrain(t):
            if grad_shardings is None:
                return t
            return jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, t, grad_shardings)

        zero = constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

        def acc(carry, mb):
            g_acc, l_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = constrain(jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g))
            return (g_acc, l_acc + l), None

        (g, l), _ = jax.lax.scan(acc, (zero, jnp.float32(0)), micro)
        inv = 1.0 / n_micro
        g = jax.tree_util.tree_map(lambda x: x * inv, g)
        return l * inv, g

    if return_grads:
        def train_step(params, batch):
            loss, grads = grads_of(params, batch)
            return grads, {"loss": loss}
        return train_step

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        params, opt_state, metrics = adamw.apply(opt_cfg, params, grads, opt_state)
        return params, opt_state, dict(metrics, loss=loss)

    return train_step


# ---------------------------------------------------------------------------
# Prefill / serve steps
# ---------------------------------------------------------------------------
def make_prefill_step(arch: Arch, *, act_policy: Optional[dict] = None) -> Callable:
    cfg, mod = arch.cfg, arch.module

    def prefill_step(params, batch):
        with activation_sharding(act_policy or {}):
            kwargs = {}
            if cfg.family == "audio":
                kwargs["frames"] = batch["frames"]
            if cfg.family == "vlm":
                kwargs["prefix_embeds"] = batch["prefix_embeds"]
            logits = mod.forward(cfg, params, batch["tokens"],
                                 last_only=True, **kwargs)
            return logits[:, -1, :]  # next-token logits only

    return prefill_step


def make_serve_step(arch: Arch, *, act_policy: Optional[dict] = None,
                    unroll: bool = False) -> Callable:
    cfg, mod = arch.cfg, arch.module
    import inspect
    kw = {}
    if unroll and "unroll" in inspect.signature(mod.decode_step).parameters:
        kw["unroll"] = True

    def serve_step(params, cache, tokens):
        with activation_sharding(act_policy or {}):
            return mod.decode_step(cfg, params, cache, tokens, **kw)

    return serve_step


# ---------------------------------------------------------------------------
# Sharded input specs for the dry run
# ---------------------------------------------------------------------------
def _with_sharding(abstract, shardings):
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        abstract, shardings)


def batch_shardings(batch_abstract: dict, mesh):
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))

    def one(path, leaf):
        b = leaf.shape[0]
        lead = dp if b % n_dp == 0 else None
        return NamedSharding(mesh, P(lead, *([None] * (len(leaf.shape) - 1))))

    return jax.tree_util.tree_map_with_path(one, batch_abstract)


@dataclasses.dataclass
class CellSpecs:
    """Everything the dry run needs for one (arch x shape x mesh) cell."""
    params: object
    param_sh: object
    batch: Optional[dict] = None
    batch_sh: Optional[dict] = None
    opt_state: Optional[dict] = None
    opt_sh: Optional[dict] = None
    cache: Optional[dict] = None
    cache_sh: Optional[dict] = None
    tokens: Optional[object] = None
    tokens_sh: Optional[object] = None


def self_cache_bytes(cfg, shape) -> int:
    """Self-attention KV bytes for one decode cell (0 for ssm)."""
    if cfg.family == "ssm":
        return 0
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    T = min(cfg.local_window, shape.seq) if cfg.local_window else shape.seq
    return 2 * cfg.n_layers * shape.batch * T * K * hd * 2


def input_specs(arch: Arch, shape: ShapeSpec, mesh,
                scfg: Optional[shmod.ShardingConfig] = None,
                cache_dtype=None) -> CellSpecs:
    cfg = arch.cfg
    scfg = scfg or shmod.ShardingConfig.for_arch(cfg)
    pa = abstract_params(arch)
    psh = shmod.param_shardings(pa, cfg, mesh, scfg)
    out = CellSpecs(params=_with_sharding(pa, psh), param_sh=psh)
    if shape.kind in ("train", "prefill"):
        ba = abstract_batch(arch, shape)
        bsh = batch_shardings(ba, mesh)
        out.batch = _with_sharding(ba, bsh)
        out.batch_sh = bsh
    if shape.kind == "train":
        oa = jax.eval_shape(lambda p: adamw.init_state(p), pa)
        osh = shmod.opt_state_shardings(psh, pa, zero1=scfg.zero1)
        out.opt_state = _with_sharding(oa, osh)
        out.opt_sh = osh
    if shape.kind == "decode":
        if cache_dtype is None:
            # fp8 KV quantization when the bf16 cache alone would blow HBM
            # (qwen1.5 MHA at 128x32k) — standard serving practice.
            per_chip = self_cache_bytes(cfg, shape) / mesh.devices.size
            # fp8 KV quantization once the bf16 cache would eat >15% of HBM
            # (leaves headroom for the decode working set) — standard
            # serving practice; exactness tests cover the bf16 path.
            if per_chip > 0.15 * 16 * 1024**3 and cfg.family != "ssm":
                cache_dtype = jnp.float8_e4m3fn
        ca = abstract_cache(arch, shape, dtype=cache_dtype)
        csh = shmod.cache_shardings(ca, mesh)
        out.cache = _with_sharding(ca, csh)
        out.cache_sh = csh
        dp = dp_axes(mesh)
        n_dp = int(np.prod([mesh.shape[a] for a in dp]))
        tsh = NamedSharding(mesh, P(dp if shape.batch % n_dp == 0 else None))
        out.tokens = jax.ShapeDtypeStruct((shape.batch,), jnp.int32, sharding=tsh)
        out.tokens_sh = tsh
    return out
