"""Training driver: data pipeline -> jitted train step -> checkpoints.

Runs for real on any backend (CPU for the examples/tests: tiny configs;
TPU pods with the production mesh).  Composes every substrate: the
deterministic pipeline, AdamW (optionally tiered/offloaded via the
planner), async checkpointing, fault-tolerant resume, straggler
mitigation, and telemetry.

Usage (CPU example — a ~100M model for a few hundred steps):
  python -m repro.launch.train --arch starcoder2-3b --tiny --steps 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import tiers as tiers_mod
from repro.core.arbiter import CaptionArbiter, budgeted_config
from repro.core.caption import CaptionConfig, CaptionController
from repro.core.classifier import AccessProfile
from repro.core.telemetry import EpochWindow
from repro.core.warmstart import WarmStartMemo
from repro.core.planner import BufferReq, plan as plan_placement
from repro.core.policy import BufferClass
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.registry import get as get_arch
from repro.optim import adamw, offload, schedules
from repro.runtime.straggler import StragglerMitigator


def build(arch_id: str, *, tiny: bool, batch: int, seq: int, lr: float,
          total_steps: int, offload_fraction: float | None = None,
          devices: str = "tpu-v5e", slow_budget: float = 0.0):
    arch = get_arch(arch_id)
    if tiny:
        arch = arch.tiny()
    cfg = arch.cfg
    opt_cfg = adamw.AdamWConfig(
        lr=lr, schedule=schedules.warmup_cosine(min(100, total_steps // 10),
                                                total_steps))
    params = arch.module.init(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))

    # Paper integration: plan optimizer-state placement against the target
    # topology; if the plan spills, use the tiered optimizer.  With an
    # arbiter budget, the plan is reconciled with it UP FRONT (arbiter-
    # aware seeding) instead of letting the runtime clip from a bad start.
    topo = tiers_mod.topology_from_spec(devices)
    opt_bytes = n_params * 12
    req = BufferReq(
        "opt_state", BufferClass.OPT_STATE, opt_bytes,
        AccessProfile(opt_bytes, opt_bytes, dependent_chain=1,
                      parallelism=1024, granularity=4 << 20,
                      compute_seconds=0.1),
    )
    placement = None
    slow_weights = None
    if offload_fraction is None:
        placement = plan_placement(
            [req], topo, compute_seconds=0.1,
            reserve_fast_bytes=int(2 * n_params + 4 * n_params),
            write_budget_bw=slow_budget if slow_budget > 0 else None)
        offload_fraction = placement.slow_fraction("opt_state")
        dfr = placement.decisions["opt_state"].device_fractions
        if topo.n_slow > 1 and dfr:
            slow_weights = [dfr.get(n, 0.0) for n in topo.slow_names]
    if offload_fraction > 0:
        opt = offload.TieredAdamW(
            opt_cfg, slow_fraction=offload_fraction,
            slow_weights=slow_weights,
            slow_device_names=topo.slow_names if topo.n_slow > 1 else None)
        opt_state = opt.init(params)
    else:
        opt = None
        opt_state = adamw.init_state(params)
    return arch, opt_cfg, opt, params, opt_state, n_params, placement, topo


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--offload-fraction", type=float, default=None)
    ap.add_argument("--devices", default="tpu-v5e",
                    help="tier topology: a preset (tpu-v5e, paper, paper3) "
                         "or a '+'-joined device list, fast tier first "
                         "(e.g. ddr5-l8+cxl-a+cxl-b)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--caption", action="store_true",
                    help="dynamic re-tiering of opt-state between steps")
    ap.add_argument("--caption-epoch-steps", type=int, default=8)
    ap.add_argument("--slow-budget", type=float, default=0.0,
                    help="aggregate slow-tier write budget in bytes/s for "
                         "the CaptionArbiter (0 = slow tier's nt-store bw)")
    ap.add_argument("--memo-path", default=None,
                    help="JSON warm-start memo: a recurring workload seeds "
                         "Caption at its remembered converged weights")
    ap.add_argument("--duels", type=int, default=0,
                    help="paired probe duels per Caption candidate point "
                         "(noise-robust probing); 0 = single-sample")
    args = ap.parse_args(argv)

    arch, opt_cfg, opt, params, opt_state, n_params, placement, topo = build(
        args.arch, tiny=args.tiny, batch=args.batch, seq=args.seq,
        lr=args.lr, total_steps=args.steps,
        offload_fraction=args.offload_fraction, devices=args.devices,
        slow_budget=args.slow_budget)
    cfg, mod = arch.cfg, arch.module
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"tiered_opt={'on' if opt else 'off'}")

    caption = None
    caption_window = None
    arbiter = None
    memo = None
    if args.caption and opt is not None:
        ccfg = CaptionConfig(epoch_steps=args.caption_epoch_steps,
                             duel_count=args.duels)
        if placement is not None:
            caption = CaptionController.from_plan(
                placement, "opt_state", topo, ccfg)
        else:
            caption = CaptionController(
                topo, ccfg, initial_fraction=opt.slow_fraction)
        # One arbiter spans every tiered buffer in this process; training
        # currently registers opt_state (a colocated serving engine or
        # tiered weights would register under the same budget).  An
        # explicit budget keeps per-device ceilings on multi-device
        # topologies (scaled to sum to it) instead of disabling them.
        arbiter = CaptionArbiter(topo, budgeted_config(topo, args.slow_budget))
        arbiter.register("opt_state", caption)
        caption_window = EpochWindow(opt.telemetry)
        if args.memo_path:
            memo = WarmStartMemo.load(args.memo_path)
            caption.attach_memo(memo)

    data = TokenPipeline(DataConfig(
        vocab=cfg.vocab_padded, batch=args.batch, seq=args.seq, seed=17))

    def make_batch(raw: dict) -> dict:
        b = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.family == "vlm":
            b["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.vision.n_prefix_tokens, cfg.d_model))
        if cfg.family == "audio":
            rng = np.random.default_rng(0)
            b["frames"] = jnp.asarray(rng.normal(
                size=(args.batch, cfg.encoder.n_ctx, cfg.d_model)), jnp.float32
            ).astype(jax.tree_util.tree_leaves(params)[0].dtype)
        return b

    loss_grad = jax.jit(jax.value_and_grad(
        lambda p, b: mod.loss(cfg, p, b, remat=True)))
    fused_step = None
    if opt is None:
        @jax.jit
        def fused_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: mod.loss(cfg, p, batch, remat=True))(params)
            params, opt_state, metrics = adamw.apply(
                opt_cfg, params, grads, opt_state)
            return params, opt_state, dict(metrics, loss=loss)

    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start, (params, opt_state_r), meta = ckpt.restore((params, opt_state))
        opt_state = opt_state_r
        print(f"resumed from step {start}")

    strag = StragglerMitigator()
    losses = []
    t0 = time.perf_counter()
    for step, raw in zip(range(start, args.steps), data.iter_from(start)):
        batch = make_batch(raw)
        if opt is None:
            def run():
                return fused_step(params, opt_state, batch)
            params, opt_state, metrics = strag.run(run)
        else:
            loss, grads = loss_grad(params, batch)
            params, opt_state, m2 = opt.step(params, grads, opt_state)
            metrics = dict(m2, loss=loss)
            if caption is not None and (step + 1) % caption.cfg.epoch_steps == 0:
                # Caption epoch: modeled step time on the target tiers is
                # the throughput signal; the window supplies write share
                # (paged state streams both ways) and writer concurrency
                # from the optimizer's actual route counters.
                slow_b = opt.traffic_per_step_bytes(opt_state)
                agg_nt_bw = sum(t.nt_store_bw for t in topo.slows)
                slow_s = slow_b / agg_nt_bw if agg_nt_bw else 0.0
                modeled = max(0.1, slow_s)  # compute floor from the plan
                fast_resident = (12 * n_params * (1 - caption.fraction)
                                 + 6 * n_params)  # opt state + params/grads
                decision = arbiter.observe_window(
                    "opt_state", caption_window, 1.0 / modeled,
                    mover=opt.mover,
                    fast_pressure=min(
                        1.0, fast_resident / topo.fast.capacity_bytes),
                    slow_name=(None if opt.mover is not None
                               else (topo.slow_names if topo.n_slow > 1
                                     else "host")))
                if decision.changed:
                    if topo.n_slow > 1 and len(decision.weights) > 1:
                        opt_state = opt.repartition_weights(
                            params, opt_state, decision.weights)
                        caption.actuated_weights(
                            opt.achieved_weights(params, opt_state))
                    else:
                        opt_state = opt.repartition(
                            params, opt_state, decision.fraction)
                        caption.actuated(sum(
                            opt.achieved_weights(params, opt_state)))
                    print(f"caption: slow_fraction -> "
                          f"{decision.fraction:.2f} ({decision.reason})")
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = (time.perf_counter() - t0) / args.log_every
            print(f"step {step+1:5d} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms/step")
            t0 = time.perf_counter()
        if (step + 1) % args.ckpt_every == 0 and opt is None:
            ckpt.save(step + 1, (params, opt_state), metadata={"arch": cfg.name})
    ckpt.wait()
    strag.close()
    if memo is not None:
        memo.save(args.memo_path)
        print(f"warmstart: entries={len(memo)} hits={memo.hits} "
              f"misses={memo.misses} -> {args.memo_path}")
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"redispatched={strag.stats.redispatched}")
    return losses


if __name__ == "__main__":
    main()
