"""Assigned input-shape set (per-arch applicability rules included)."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k decode needs "
                       "sub-quadratic attention (DESIGN.md skip note)")
    return True, ""


def cells(cfgs: dict[str, ArchConfig]):
    """All (arch, shape) cells with applicability flags."""
    out = []
    for arch_id, cfg in cfgs.items():
        for s in SHAPES.values():
            ok, why = applicable(cfg, s)
            out.append((arch_id, s, ok, why))
    return out
