"""Production mesh builders (TPU v5e: 16x16 chips/pod; pods over DCN)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (roofline) or 2x16x16 multi-pod (dry run)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh with pjit-style auto sharding propagation."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a production mesh (includes pod)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def chips(mesh) -> int:
    return mesh.devices.size
