"""Production mesh builders (TPU v5e: 16x16 chips/pod; pods over DCN)."""
from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; Auto is the default there anyway.
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

    def _axis_kwargs(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (roofline) or 2x16x16 multi-pod (dry run)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh with pjit-style auto sharding propagation."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_kwargs(len(axes)))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available; the Mesh object itself is a
    context manager on older jax (pjit-style), with the same scoping."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a production mesh (includes pod)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def chips(mesh) -> int:
    return mesh.devices.size
