"""Post-SPMD HLO cost analyzer with while-loop trip-count correction.

``compiled.cost_analysis()`` counts each scan/while body ONCE, which
undercounts a 64-layer scanned transformer by ~64x.  This analyzer
parses ``compiled.as_text()`` (the per-device partitioned module):

* computations are classified (entry / while body / fusion-inlined) and
  each gets a multiplier = product of enclosing loop trip counts (trip
  counts recovered from the ROOT compare constant of while conds);
* FLOPs: 2 x result x contracted-dim product for every ``dot`` (+conv),
  scaled by the multiplier — matmul flops are >95% of these models;
* HBM bytes: post-fusion top-level op I/O (operands + results of
  fusions, dots, copies, gathers/scatters, dynamic slices,
  collectives), scaled by multipliers — fusion internals are free, and
  loop-body intermediates smaller than ``VMEM_RESIDENT_BYTES`` are
  excluded (a TPU pipelines them through VMEM without an HBM
  round-trip), so this models TPU HBM traffic, fusion-optimistically;
* collective wire bytes per device, per op kind, with ring-cost
  formulas and ICI/DCN classification from decoded replica groups.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# Ops that do HBM I/O even under TPU-grade fusion.  The XLA:CPU module
# this analyzer reads is much less fused than the TPU module would be
# (standalone converts/broadcasts everywhere), so elementwise ops are
# EXCLUDED: on TPU they fuse into their consumers.  The resulting memory
# term is a fusion-optimistic estimate of TPU HBM traffic (documented in
# EXPERIMENTS.md §Roofline methodology).
_BYTE_OPS = {
    "fusion", "dot", "convolution", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "sort", "custom-call",
} | set(_COLLECTIVES) | {c + "-start" for c in _COLLECTIVES}

# Loop-body values at or below this size are assumed VMEM-resident on TPU
# (v5e: 128 MiB VMEM; leave headroom for double-buffering).
VMEM_RESIDENT_BYTES = 48 * 1024 * 1024


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: tuple[int, ...]

    @property
    def bytes(self) -> int:
        return _DTYPE_BYTES.get(self.dtype, 4) * int(np.prod(self.dims)) \
            if self.dims else _DTYPE_BYTES.get(self.dtype, 4)

    @property
    def elems(self) -> int:
        return int(np.prod(self.dims)) if self.dims else 1


def parse_type(s: str) -> list[Shape]:
    """'bf16[8,2]{1,0}' or '(f32[], bf16[4])' -> list of Shapes."""
    out = []
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", s):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append(Shape(m.group(1), dims))
    return out


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result: list[Shape]
    operands: list[str]
    attrs: str
    comp: str
    raw_operands: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict[str, Op]
    is_entry: bool = False


_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_OP_LINE = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")


def _split_type_and_rest(rest: str) -> tuple[str, str]:
    """Split 'TYPE kind(operands), attrs' at the op kind boundary."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                return rest[: i + 1], rest[i + 1:].strip()
    i = rest.find(" ")
    return rest[:i], rest[i + 1:].strip()


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.startswith(" ") and _HEADER.match(line) and line.rstrip().endswith("{"):
            m = _HEADER.match(line)
            cur = Computation(m.group(2), {}, is_entry=bool(m.group(1)))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            continue
        m = _OP_LINE.match(line)
        if not m or cur is None:
            continue
        name, rest = m.group(1), m.group(2)
        type_str, tail = _split_type_and_rest(rest)
        km = re.match(r"([\w\-]+)\(", tail)
        if not km:
            continue
        kind = km.group(1)
        # operand list: up to matching close paren
        depth, start = 0, tail.find("(")
        end = start
        for i in range(start, len(tail)):
            depth += tail[i] == "("
            depth -= tail[i] == ")"
            if depth == 0:
                end = i
                break
        operand_str = tail[start + 1: end]
        attrs = tail[end + 1:]
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        cur.ops[name] = Op(name, kind, parse_type(type_str), operands, attrs,
                           cur.name, operand_str)
    return comps


def _trip_count(cond: Computation) -> int:
    """Trip count from the cond's compare-vs-constant (scan convention)."""
    m = re.findall(r"constant\((\d+)\)", _comp_text(cond))
    if m:
        return max(int(x) for x in m)
    return 1


def _comp_text(comp: Computation) -> str:
    return " ".join(
        f"{op.kind}({op.raw_operands}) {op.attrs}" for op in comp.ops.values()
    )


def _attr_comp_refs(op: Op) -> dict[str, list[str]]:
    refs = defaultdict(list)
    for key in ("condition", "body", "calls", "to_apply"):
        for m in re.finditer(key + r"=%?([\w\.\-]+)", op.attrs):
            refs[key].append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
    if m:
        refs["branches"] = re.findall(r"%?([\w\.\-]+)", m.group(1))
    return refs


def decode_replica_groups(attrs: str, n_devices: int) -> list[list[int]]:
    m = re.search(r"replica_groups=\{\{([\d,{} ]*)\}\}", attrs)
    if m:
        return [[int(x) for x in g.split(",") if x.strip()]
                for g in m.group(1).split("},{")]
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", attrs)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            arr = arr.transpose([int(x) for x in m.group(4).split(",")])
        return arr.reshape(g, s).tolist()
    # default: one group of everything
    return [list(range(n_devices))]


@dataclasses.dataclass
class CollectiveRecord:
    kind: str
    comp: str
    multiplier: int
    group_size: int
    operand_bytes: int  # per device
    wire_bytes: int  # per device, x multiplier applied
    link: str  # "ici" | "dcn"


@dataclasses.dataclass
class HloCosts:
    flops: float  # per device, loop-corrected
    hbm_bytes: float  # per device, loop-corrected (post-fusion op I/O)
    collectives: list[CollectiveRecord]
    n_devices: int

    def collective_bytes(self, link: Optional[str] = None) -> float:
        return sum(c.wire_bytes for c in self.collectives
                   if link is None or c.link == link)

    def collective_counts(self) -> dict[str, int]:
        out: dict[str, int] = defaultdict(int)
        for c in self.collectives:
            out[c.kind] += c.multiplier
        return dict(out)


def _wire_bytes(kind: str, operand_bytes: int, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if kind.startswith("all-reduce"):
        return 2 * operand_bytes * frac
    if kind.startswith("all-gather"):
        return result_bytes * frac
    if kind.startswith("reduce-scatter"):
        return operand_bytes * frac
    if kind.startswith("all-to-all") or kind.startswith("ragged-all-to-all"):
        return operand_bytes * frac
    if kind.startswith("collective-permute"):
        return operand_bytes
    return operand_bytes


def analyze(text: str, *, n_devices: int, chips_per_pod: int = 256) -> HloCosts:
    comps = parse_module(text)
    entry = next(c for c in comps.values() if c.is_entry)

    # classify computations: multiplier per counted computation
    mult: dict[str, float] = {entry.name: 1.0}
    inlined: set[str] = set()
    stack = [entry.name]
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops.values():
            refs = _attr_comp_refs(op)
            if op.kind == "while":
                cond = refs.get("condition", [None])[0]
                body = refs.get("body", [None])[0]
                trips = _trip_count(comps[cond]) if cond in comps else 1
                for sub in (body,):
                    if sub and sub in comps and sub not in mult:
                        mult[sub] = m * trips
                        stack.append(sub)
            elif op.kind in ("fusion",) or refs.get("calls"):
                for sub in refs.get("calls", []):
                    inlined.add(sub)
            elif op.kind == "conditional":
                for sub in refs.get("branches", []):
                    if sub in comps and sub not in mult:
                        mult[sub] = m
                        stack.append(sub)
            elif op.kind in ("call", "async-start"):
                for sub in refs.get("to_apply", []) + refs.get("calls", []):
                    if sub in comps and sub not in mult:
                        mult[sub] = m
                        stack.append(sub)

    def _lookup(o: str, comp: Computation) -> Optional[Op]:
        src = comp.ops.get(o)
        if src is None:
            for c2 in comps.values():
                if o in c2.ops:
                    return c2.ops[o]
        return src

    def operand_bytes(op: Op, comp: Computation) -> int:
        # Sliced reads only touch the slice, not the whole operand: a
        # dynamic-slice of the stacked (L, ...) layer weights inside a scan
        # reads ONE layer's worth per trip.
        if op.kind in ("dynamic-slice", "slice"):
            return sum(s.bytes for s in op.result)
        if op.kind == "dynamic-update-slice":
            upd = _lookup(op.operands[1], comp) if len(op.operands) > 1 else None
            return sum(s.bytes for s in upd.result) if upd else 0
        if op.kind == "gather":
            return sum(s.bytes for s in op.result)
        total = 0
        per_param_counts = None
        res_bytes = sum(s.bytes for s in op.result)
        if op.kind == "fusion":
            if _fusion_is_trivial(op):
                # convert/copy/broadcast-only fusions fuse into their
                # consumers on TPU: no standalone HBM pass.
                return -res_bytes  # cancel the result bytes counted later
            per_param_counts = _fusion_param_bytes(op)
        for i, o in enumerate(op.operands):
            if per_param_counts is not None and i in per_param_counts:
                total += per_param_counts[i]
                continue
            src = _lookup(o, comp)
            if src is not None:
                b = sum(s.bytes for s in src.result)
                if op.kind == "fusion":
                    # slice-heavy fusion bodies read a fraction of huge
                    # operands; cap at 4x the result size
                    b = min(b, 4 * res_bytes)
                total += b
        return total

    _TRIVIAL_OPS = {"convert", "bitcast", "copy", "transpose", "broadcast",
                    "reshape", "parameter", "constant", "iota", "multiply",
                    "add", "subtract", "divide", "select", "compare",
                    "maximum", "minimum", "exponential", "tanh", "negate",
                    "rsqrt", "sqrt", "and", "or", "not", "abs", "clamp",
                    "power", "log", "logistic", "floor", "sign",
                    "get-tuple-element", "tuple"}

    def operand_bytes_vmem_aware(op: Op, comp: Computation) -> int:
        if op.kind in ("dynamic-slice", "slice", "gather",
                       "dynamic-update-slice"):
            return operand_bytes(op, comp)
        total = 0
        res_bytes = sum(s.bytes for s in op.result)
        per_param_counts = None
        if op.kind == "fusion":
            if _fusion_is_trivial(op):
                return 0
            per_param_counts = _fusion_param_bytes(op)
        for i, o in enumerate(op.operands):
            if per_param_counts is not None and i in per_param_counts:
                total += per_param_counts[i]
                continue
            src = _lookup(o, comp)
            if src is None:
                continue
            b = sum(s.bytes for s in src.result)
            if src.comp == comp.name and b <= VMEM_RESIDENT_BYTES:
                continue  # loop-local, VMEM-resident
            if op.kind == "fusion":
                b = min(b, 4 * res_bytes)
            total += b
        return total

    def _fusion_is_trivial(op: Op) -> bool:
        refs = _attr_comp_refs(op)
        called = refs.get("calls", [None])[0]
        fc = comps.get(called)
        if fc is None:
            return False
        return all(o.kind in _TRIVIAL_OPS for o in fc.ops.values())

    def _fusion_param_bytes(op: Op) -> dict[int, int]:
        """Per-operand read bytes for a fusion whose body only SLICES some
        parameter (the scan-over-stacked-weights pattern)."""
        refs = _attr_comp_refs(op)
        called = refs.get("calls", [None])[0]
        fc = comps.get(called)
        if fc is None:
            return {}
        param_name_by_idx: dict[int, str] = {}
        for o in fc.ops.values():
            if o.kind == "parameter":
                m = re.match(r"\s*(\d+)", o.raw_operands)
                if m:
                    param_name_by_idx[int(m.group(1))] = o.name
        out: dict[int, int] = {}
        for idx, pname in param_name_by_idx.items():
            consumers = [o for o in fc.ops.values() if pname in o.operands]
            if consumers and all(o.kind in ("dynamic-slice", "slice", "gather")
                                 for o in consumers):
                out[idx] = sum(sum(s.bytes for s in o.result)
                               for o in consumers)
        return out

    flops = 0.0
    hbm = 0.0
    colls: list[CollectiveRecord] = []
    seen_done = set()
    for cname, m in mult.items():
        if cname in inlined:
            continue
        comp = comps[cname]
        for op in comp.ops.values():
            res_bytes = sum(s.bytes for s in op.result)
            if op.kind == "dot":
                lhs = comp.ops.get(op.operands[0])
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
                csize = 1
                if lhs is not None and cdims and lhs.result:
                    dims = lhs.result[0].dims
                    for d in cdims.group(1).split(","):
                        if d:
                            csize *= dims[int(d)]
                out_elems = sum(s.elems for s in op.result)
                flops += m * 2.0 * out_elems * csize
            if op.kind == "convolution":
                flops += m * 2.0 * sum(s.elems for s in op.result)
            if op.kind in _BYTE_OPS:
                in_loop = m > 1
                rb = res_bytes
                if in_loop and not op.kind.startswith(tuple(_COLLECTIVES)):
                    ob = operand_bytes_vmem_aware(op, comp)
                    if res_bytes <= VMEM_RESIDENT_BYTES:
                        rb = 0
                    hbm += m * (rb + ob)
                else:
                    hbm += m * (rb + operand_bytes(op, comp))
            base = op.kind.replace("-start", "")
            if base.split(".")[0] in _COLLECTIVES or any(
                    op.kind.startswith(c) for c in _COLLECTIVES):
                if op.kind.endswith("-done"):
                    continue
                ob = operand_bytes(op, comp)
                if op.kind.startswith(("all-reduce-start", "all-gather-start")):
                    # start result duplicates operand in a tuple
                    res_bytes = res_bytes // 2
                groups = decode_replica_groups(op.attrs, n_devices)
                g = len(groups[0]) if groups else 1
                n_pods = 1
                for grp in groups[:8]:
                    pods = {d // chips_per_pod for d in grp}
                    n_pods = max(n_pods, len(pods))
                kind = next(c for c in _COLLECTIVES if op.kind.startswith(c))
                if n_pods <= 1:
                    colls.append(CollectiveRecord(
                        kind=kind, comp=cname, multiplier=int(m), group_size=g,
                        operand_bytes=ob,
                        wire_bytes=m * _wire_bytes(op.kind, ob, res_bytes, g),
                        link="ici",
                    ))
                else:
                    # hierarchical model: within-pod ring over g/n_pods chips
                    # on ICI, then a cross-pod phase of the same payload on DCN
                    g_in = max(g // n_pods, 1)
                    colls.append(CollectiveRecord(
                        kind=kind, comp=cname, multiplier=int(m), group_size=g_in,
                        operand_bytes=ob,
                        wire_bytes=m * _wire_bytes(op.kind, ob, res_bytes, g_in),
                        link="ici",
                    ))
                    colls.append(CollectiveRecord(
                        kind=kind, comp=cname, multiplier=int(m), group_size=n_pods,
                        operand_bytes=ob,
                        wire_bytes=m * _wire_bytes(op.kind, ob, res_bytes, n_pods),
                        link="dcn",
                    ))
    return HloCosts(flops=flops, hbm_bytes=hbm, collectives=colls,
                    n_devices=n_devices)
