"""Serving driver: batched engine over the tiered KV cache.

Usage (CPU demo):
  python -m repro.launch.serve --arch qwen2.5-32b --tiny --requests 16 \
      --slow-fraction 0.5
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.arbiter import CaptionArbiter, budgeted_config
from repro.core.caption import CaptionConfig, CaptionController
from repro.core.ledger import TierLedger
from repro.core.mover import BulkMover
from repro.core.policy import MemPolicy
from repro.core.tiers import topology_from_spec
from repro.core.warmstart import WarmStartMemo
from repro.models.registry import get as get_arch
from repro.serving.engine import ServingEngine, kv_access_profile


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slow-fraction", type=float, default=0.0)
    ap.add_argument("--devices", default="tpu-v5e",
                    help="tier topology: a preset (tpu-v5e, paper, paper3) "
                         "or a '+'-joined device list, fast tier first "
                         "(e.g. ddr5-l8+cxl-a+cxl-b)")
    ap.add_argument("--page-t", type=int, default=16)
    ap.add_argument("--caption", action="store_true",
                    help="dynamic re-tiering of KV pages between decode steps")
    ap.add_argument("--caption-epoch-steps", type=int, default=8)
    ap.add_argument("--slow-budget", type=float, default=0.0,
                    help="aggregate slow-tier write budget in bytes/s for "
                         "the CaptionArbiter (0 = slow tier's nt-store bw)")
    ap.add_argument("--latency-every", type=int, default=0,
                    help="every Nth request is latency-SLO class (pins its "
                         "KV pages fast); 0 = all batch-class")
    ap.add_argument("--prefix-pages", type=int, default=0,
                    help="shared-prefix page pool size; repeated prompt "
                         "prefixes attach by reference instead of replaying")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of prompt prefix shared across requests "
                         "(0 = fully independent prompts)")
    ap.add_argument("--admission", choices=("none", "cost"), default="none",
                    help="'cost': defer batch-class admissions the perf "
                         "model predicts would pressure latency pins")
    ap.add_argument("--async-mover", action="store_true",
                    help="issue Caption migrations unfenced so they overlap "
                         "decode compute (drained at epoch boundaries)")
    ap.add_argument("--memo-path", default=None,
                    help="JSON warm-start memo: converged Caption weights "
                         "are filed under a workload fingerprint and a "
                         "recurring workload seeds at its remembered "
                         "optimum, skipping the walk")
    ap.add_argument("--duels", type=int, default=0,
                    help="paired probe duels per Caption candidate point "
                         "(noise-robust probing); 0 = single-sample")
    ap.add_argument("--ledger-report", action="store_true",
                    help="register the serving pools (KV + shared-prefix "
                         "pages) in a TierLedger and print the per-tier "
                         "capacity report after the run")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.tiny:
        arch = arch.tiny()
    cfg = arch.cfg
    if cfg.family not in ("dense", "vlm", "moe"):
        raise SystemExit("tiered serving demo targets uniform-attention archs")
    params = arch.module.init(cfg, jax.random.PRNGKey(0))
    topology = topology_from_spec(args.devices)
    if topology.n_slow > 1:
        # Seed the per-device split bandwidth-proportionally (Fig. 10's
        # best static ratio); Caption tunes the vector from there.
        bw = topology.bandwidth_weights()
        policy = MemPolicy.from_tier_fractions(
            topology.fast.name, topology.slow_names,
            [args.slow_fraction * w for w in bw])
    else:
        policy = MemPolicy.from_slow_fraction("fast", "slow",
                                              args.slow_fraction)
    caption = None
    arbiter = None
    memo = None
    if args.caption:
        # §6.1 seeding: classify the KV cache's access profile against
        # the active slow pool — a latency-bound shape is fast-pinned
        # automatically (from_profile zeroes the prior and the floor).
        profile = kv_access_profile(cfg, args.max_batch, args.max_len,
                                    page_t=args.page_t)
        caption = CaptionController.from_profile(
            profile, topology,
            CaptionConfig(epoch_steps=args.caption_epoch_steps,
                          duel_count=args.duels),
            initial_fraction=args.slow_fraction)
        if args.memo_path:
            memo = WarmStartMemo.load(args.memo_path)
            caption.attach_memo(memo)
        # One arbiter owns the slow-tier write budget; the engine registers
        # its KV controller under it (more buffers would share the pool).
        arbiter = CaptionArbiter(topology,
                                 budgeted_config(topology, args.slow_budget))
    mover = (BulkMover(topology, asynchronous=True)
             if args.async_mover else None)
    ledger = TierLedger(topology) if args.ledger_report else None
    engine = ServingEngine(
        cfg, params, max_batch=args.max_batch, max_len=args.max_len,
        policy=policy, topology=topology, page_t=args.page_t,
        caption=caption, arbiter=arbiter, mover=mover,
        prefix_pages=args.prefix_pages, admission=args.admission,
        overlap=args.async_mover, ledger=ledger)
    rng = np.random.default_rng(0)
    shared = (rng.integers(0, cfg.vocab_padded,
                           size=args.shared_prefix).tolist()
              if args.shared_prefix else [])
    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = shared + rng.integers(0, cfg.vocab_padded, size=4).tolist()
        slo = ("latency" if args.latency_every
               and i % args.latency_every == 0 else "batch")
        engine.submit(prompt, max_new_tokens=args.new_tokens, slo=slo)
    done = engine.run_until_drained()
    wall = time.perf_counter() - t0
    if mover is not None:
        mover.close()
    lats = sorted(r.latency for r in done)
    modeled = sorted(r.modeled_seconds for r in done)
    p99 = lats[int(len(lats) * 0.99) - 1] if len(lats) > 1 else lats[0]
    print(f"completed={len(done)} wall={wall:.2f}s "
          f"p50={lats[len(lats)//2]*1e3:.1f}ms p99={p99*1e3:.1f}ms "
          f"modeled_p50={modeled[len(modeled)//2]*1e3:.3f}ms "
          f"slow_frac={engine.cache.slow_fraction():.2f}")
    if topology.n_slow > 1:
        fr = engine.cache.device_fractions()
        print("devices: " + " ".join(f"{k}={v:.2f}" for k, v in fr.items()))
    if caption is not None:
        traj = " -> ".join(f"{f:.2f}" for _, f in engine.caption_trace[-8:])
        print(f"caption: phase={caption.phase.value} trajectory {traj}")
    if memo is not None:
        memo.save(args.memo_path)
        print(f"warmstart: entries={len(memo)} hits={memo.hits} "
              f"misses={memo.misses} drift_misses={memo.drift_misses} "
              f"-> {args.memo_path}")
    if arbiter is not None:
        print(f"arbiter: budget={arbiter.cfg.slow_bw_budget:.3g} B/s "
              f"demand={arbiter.aggregate_demand_bw():.3g} B/s "
              f"grants={ {k: f'{v:.3g}' for k, v in arbiter.grants().items()} }")
    if args.prefix_pages:
        idx = engine.prefix_index
        print(f"prefix: hits={idx.hits} misses={idx.misses} "
              f"pages={idx.allocated_pages()} cow={idx.cow_copies} "
              f"evictions={idx.evictions} "
              f"prefill_avoided={engine.prefill_tokens_avoided}"
              f"/{engine.prefill_tokens_total}")
    if args.admission != "none":
        print(f"admission: deferrals={engine.admission_deferrals}")
    if args.async_mover:
        print(f"overlap: stall={engine.migration_stall_s*1e3:.1f}ms "
              f"hidden={engine.migration_hidden_s*1e3:.3f}ms "
              f"exposed={engine.migration_exposed_s*1e3:.3f}ms")
    if ledger is not None:
        engine.register_pools()
        print("ledger (framework-managed serving pools):")
        print(ledger.report())
    return done


if __name__ == "__main__":
    main()
