"""Serving driver: batched engine over the tiered KV cache.

Usage (CPU demo):
  python -m repro.launch.serve --arch qwen2.5-32b --tiny --requests 16 \
      --slow-fraction 0.5
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.caption import CaptionConfig, CaptionController
from repro.core.policy import MemPolicy
from repro.core.tiers import tpu_v5e_topology
from repro.models.registry import get as get_arch
from repro.serving.engine import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slow-fraction", type=float, default=0.0)
    ap.add_argument("--page-t", type=int, default=16)
    ap.add_argument("--caption", action="store_true",
                    help="dynamic re-tiering of KV pages between decode steps")
    ap.add_argument("--caption-epoch-steps", type=int, default=8)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.tiny:
        arch = arch.tiny()
    cfg = arch.cfg
    if cfg.family not in ("dense", "vlm", "moe"):
        raise SystemExit("tiered serving demo targets uniform-attention archs")
    params = arch.module.init(cfg, jax.random.PRNGKey(0))
    policy = MemPolicy.from_slow_fraction("fast", "slow", args.slow_fraction)
    topology = tpu_v5e_topology()
    caption = None
    if args.caption:
        caption = CaptionController(
            topology,
            CaptionConfig(epoch_steps=args.caption_epoch_steps),
            initial_fraction=args.slow_fraction)
    engine = ServingEngine(
        cfg, params, max_batch=args.max_batch, max_len=args.max_len,
        policy=policy, topology=topology, page_t=args.page_t,
        caption=caption)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_padded, size=4).tolist()
        engine.submit(prompt, max_new_tokens=args.new_tokens)
    done = engine.run_until_drained()
    wall = time.perf_counter() - t0
    lats = sorted(r.latency for r in done)
    modeled = sorted(r.modeled_seconds for r in done)
    p99 = lats[int(len(lats) * 0.99) - 1] if len(lats) > 1 else lats[0]
    print(f"completed={len(done)} wall={wall:.2f}s "
          f"p50={lats[len(lats)//2]*1e3:.1f}ms p99={p99*1e3:.1f}ms "
          f"modeled_p50={modeled[len(modeled)//2]*1e3:.3f}ms "
          f"slow_frac={engine.cache.slow_fraction():.2f}")
    if caption is not None:
        traj = " -> ".join(f"{f:.2f}" for _, f in engine.caption_trace[-8:])
        print(f"caption: phase={caption.phase.value} trajectory {traj}")
    return done


if __name__ == "__main__":
    main()
