import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, without allocating any model-scale array:
  * proof the sharding config compiles (SPMD partitioning succeeds),
  * ``memory_analysis()`` per-device bytes (fits-in-HBM proof),
  * ``cost_analysis()`` raw numbers plus loop-corrected FLOPs / HBM bytes
    / per-link collective wire bytes from the HLO analyzer,
  * the tier ledger for framework-managed (host) state when the planner
    offloads optimizer moments (llama4-class models).

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.caption import CaptionConfig
from repro.launch import hlo_analysis, shardings as shmod, steps as steps_mod
from repro.launch.mesh import (chips as mesh_chips, make_production_mesh,
                               mesh_context)
from repro.launch.shapes import SHAPES, ShapeSpec, applicable
from repro.models.registry import ARCH_IDS, get as get_arch
from repro.optim import adamw

HBM_PER_CHIP = 16 * 1024**3
# Offload optimizer state when (moments+master) would eat >35% of HBM.
OFFLOAD_BYTES_FRAC = 0.35


def should_offload_opt(cfg: ArchConfig, n_chips: int) -> bool:
    opt_bytes = cfg.param_count() * 12  # fp32 mu+nu+master
    return opt_bytes / n_chips > OFFLOAD_BYTES_FRAC * HBM_PER_CHIP


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.batch * shape.seq
    return 2.0 * n_active * shape.batch  # decode: one token per sequence


def lower_cell(arch_id: str, shape: ShapeSpec, mesh, *, n_micro: int = 0,
               fsdp=None, seq_shard=None, zero1: bool = False,
               wkv_chunked: bool = True, flash: bool = True):
    """Build + lower + compile one cell; returns (record, compiled)."""
    arch = get_arch(arch_id)
    cfg = arch.cfg
    scfg = shmod.ShardingConfig.for_arch(cfg)
    if fsdp is not None:
        scfg = dataclasses.replace(scfg, fsdp=fsdp)
    if zero1:
        scfg = dataclasses.replace(scfg, fsdp=False, zero1=True)
    specs = steps_mod.input_specs(arch, shape, mesh, scfg)
    n_dp = mesh_chips(mesh) // mesh.shape["model"]
    act_policy = shmod.activation_policy(
        mesh, seq_sharded=(shape.kind == "prefill" and shape.batch < n_dp
                           if seq_shard is None else seq_shard))
    if wkv_chunked:
        act_policy["_wkv_chunked"] = True
    if not flash:
        act_policy.pop("_flash", None)
    record_extra = {"zero1": zero1, "wkv_chunked": wkv_chunked, "flash": flash}

    if n_micro <= 0 and shape.kind == "train":
        # default: per-device microbatch of 1 sequence
        n_micro = max(1, shape.batch // n_dp)
    offload = shape.kind == "train" and should_offload_opt(cfg, mesh_chips(mesh))
    opt_cfg = adamw.AdamWConfig()
    record = {
        "arch": arch_id, "shape": shape.name, "kind": shape.kind,
        "mesh": dict(zip(mesh.axis_names, [int(mesh.shape[a]) for a in mesh.axis_names])),
        "chips": mesh_chips(mesh), "fsdp": scfg.fsdp,
        "n_micro": n_micro if shape.kind == "train" else 0,
        "offload_opt": offload,
        "model_flops_total": model_flops(cfg, shape),
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        **record_extra,
    }

    t0 = time.time()
    with mesh_context(mesh):
        if shape.kind == "train":
            if offload:
                # ZeRO-offload structure: the device program is ONE
                # microbatch fwd+bwd emitting bf16 param-sharded grads; the
                # host daemon (TieredAdamW + BulkMover) accumulates in fp32
                # and pages moments/master. Per optimizer step the program
                # runs n_micro times (roofline aggregates accordingly).
                fn = steps_mod.make_micro_grad_step(arch, act_policy=act_policy)
                micro_batch = jax.tree_util.tree_map(
                    lambda l: jax.ShapeDtypeStruct(
                        (l.shape[0] // n_micro,) + l.shape[1:], l.dtype,
                        sharding=l.sharding),
                    specs.batch)
                lowered = jax.jit(
                    fn, donate_argnums=(),
                    out_shardings=(specs.param_sh, None)).lower(
                    specs.params, micro_batch)
                record["offload_micro_step"] = True
                # host-side tier ledger: moments + master live on host DRAM
                opt_bytes = cfg.param_count() * 12
                per_host = opt_bytes / (mesh_chips(mesh) / 8)  # 8 chips/host
                record["offload_host_bytes_per_host"] = per_host
                record["offload_traffic_bytes_per_step_per_chip"] = (
                    cfg.param_count() * (12 + 12 + 2) / mesh_chips(mesh))
                # Caption migration cost: during convergence the controller
                # re-tiers one hill-climb step's worth of state every
                # (epoch_steps x probe_epochs) app steps; amortized over
                # steps this is repartition traffic the roofline must see
                # (benchmarks/roofline.py folds it into the tier term).
                ccfg = CaptionConfig()
                record["migration_bytes_per_step_per_chip"] = (
                    opt_bytes * ccfg.step
                    / (ccfg.epoch_steps * ccfg.probe_epochs)
                    / mesh_chips(mesh))
            else:
                fn = steps_mod.make_train_step(
                    arch, opt_cfg, n_micro=n_micro, act_policy=act_policy,
                    mesh=mesh, grad_shardings=specs.param_sh)
                lowered = jax.jit(
                    fn, donate_argnums=(0, 1),
                    out_shardings=(specs.param_sh, specs.opt_sh, None)).lower(
                    specs.params, specs.opt_state, specs.batch)
        elif shape.kind == "prefill":
            fn = steps_mod.make_prefill_step(arch, act_policy=act_policy)
            lowered = jax.jit(fn).lower(specs.params, specs.batch)
        else:
            fn = steps_mod.make_serve_step(arch, act_policy=act_policy)
            lowered = jax.jit(
                fn, donate_argnums=(1,),
                out_shardings=(None, specs.cache_sh)).lower(
                specs.params, specs.cache, specs.tokens)
        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    if ma is not None:
        record["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device": int(ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes
                                   - ma.alias_size_in_bytes),
        }
        record["fits_hbm"] = record["memory"]["peak_per_device"] <= HBM_PER_CHIP
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    record["cost_analysis"] = {
        "flops": float(ca.get("flops", -1)),
        "bytes_accessed": float(ca.get("bytes accessed", -1)),
    }
    t2 = time.time()
    hc = hlo_analysis.analyze(compiled.as_text(), n_devices=mesh_chips(mesh))
    record["analyze_s"] = round(time.time() - t2, 2)
    record["hlo"] = {
        "flops_per_device": hc.flops,
        "hbm_bytes_per_device": hc.hbm_bytes,
        "collective_counts": hc.collective_counts(),
        "ici_bytes_per_device": hc.collective_bytes("ici"),
        "dcn_bytes_per_device": hc.collective_bytes("dcn"),
    }
    return record, compiled


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: str,
             **kw) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_arch(arch_id).cfg
    ok, why = applicable(cfg, shape)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"{arch_id}__{shape_name}__{mesh_tag}"
    if not ok:
        record = {"arch": arch_id, "shape": shape_name, "skipped": why,
                  "mesh": mesh_tag}
        print(f"SKIP {name}: {why}")
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        try:
            record, compiled = lower_cell(arch_id, shape, mesh, **kw)
            mem = record.get("memory", {})
            print(f"OK   {name}: compile={record['compile_s']}s "
                  f"peak={mem.get('peak_per_device', 0)/2**30:.2f}GiB "
                  f"fits={record.get('fits_hbm')} "
                  f"flops/dev={record['hlo']['flops_per_device']:.3e} "
                  f"colls={record['hlo']['collective_counts']}")
        except Exception as e:
            record = {"arch": arch_id, "shape": shape_name, "mesh": mesh_tag,
                      "error": f"{type(e).__name__}: {e}",
                      "traceback": traceback.format_exc()[-2000:]}
            print(f"FAIL {name}: {type(e).__name__}: {str(e)[:200]}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(record, f, indent=1, default=float)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--n-micro", type=int, default=0)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--no-wkv-chunked", action="store_true")
    ap.add_argument("--no-flash", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch_id in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                rec = run_cell(arch_id, shape_name, multi_pod, args.out,
                               n_micro=args.n_micro, zero1=args.zero1,
                               wkv_chunked=not args.no_wkv_chunked,
                               flash=not args.no_flash)
                failures += "error" in rec
    print(f"\ndone; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
