"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA,
pattern (recurrent, recurrent, local_attn) — the paper-pool "1:2" mix.

Recurrent block: gated branch (GeLU) x (conv1d(4) -> RG-LRU) -> out proj;
RG-LRU: a = exp(-c * softplus(L) * sigmoid(W_a x)), h = a h + sqrt(1-a^2)
* (i (.) x).  Every temporal block is followed by a GeGLU MLP block.
State is O(window + d_model) in sequence length -> long_500k in scope.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.common import (
    apply_norm,
    cross_entropy,
    dtype_of,
    embed_init,
    he,
    maybe_shard,
    mlp_apply,
    mlp_params,
    norm_params,
)

RG_C = 8.0
CONV_W = 4


def _unit(cfg: ArchConfig) -> tuple[int, int]:
    unit = len(cfg.block_pattern)
    return cfg.n_layers // unit, cfg.n_layers % unit


def init_rec_layer(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    D = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "ln1": norm_params(D, cfg.norm, jnp.float32),
        "rec": {
            "W_gate": he(ks[0], (D, D), dt),
            "W_in": he(ks[1], (D, D), dt),
            "conv": he(ks[2], (CONV_W, D), dt, 0.5),
            "W_a": he(ks[3], (D, D), dt, 0.5),
            "b_a": jnp.zeros((D,), jnp.float32),
            "W_i": he(ks[4], (D, D), dt, 0.5),
            "b_i": jnp.zeros((D,), jnp.float32),
            "lam": jnp.full((D,), 0.655, jnp.float32),  # softplus^-1 tuning
            "W_out": he(ks[5], (D, D), dt),
        },
        "ln2": norm_params(D, cfg.norm, jnp.float32),
        "mlp": mlp_params(jax.random.fold_in(key, 7), D, cfg.d_ff, cfg.act, dt),
    }


def init_attn_layer(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "attn": attn.attn_params(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, dt, cfg.qkv_bias,
        ),
        "ln2": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.act, dt),
    }


def init(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    n_units, n_tail = _unit(cfg)
    ke, ku, kt, kh = jax.random.split(key, 4)
    uks = jax.random.split(ku, n_units)
    unit = {
        "rec_a": jax.vmap(lambda k: init_rec_layer(cfg, jax.random.fold_in(k, 0)))(uks),
        "rec_b": jax.vmap(lambda k: init_rec_layer(cfg, jax.random.fold_in(k, 1)))(uks),
        "attn": jax.vmap(lambda k: init_attn_layer(cfg, jax.random.fold_in(k, 2)))(uks),
    }
    params = {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dt),
        "units": unit,
        "final_norm": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "lm_head": embed_init(kh, cfg.vocab_padded, cfg.d_model, dt).T,
    }
    if n_tail:
        tks = jax.random.split(kt, n_tail)
        params["tail_rec"] = jax.vmap(lambda k: init_rec_layer(cfg, k))(tks)
    return params


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------
def rg_lru_scan(x: jax.Array, a: jax.Array, gated: jax.Array, h0: jax.Array):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) * gated_t, via associative scan.

    x unused except shape; a, gated: (B,T,D) fp32; h0: (B,D).
    """
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, b_s = jax.lax.associative_scan(combine, (a, beta), axis=1)
    h = a_s * h0[:, None, :] + b_s
    return h, h[:, -1]


def rec_block(cfg: ArchConfig, x, rp, conv_state, h_state):
    """x: (B,T,D). Returns (out, (new_conv_state, new_h))."""
    gate = jax.nn.gelu(x @ rp["W_gate"])
    u = x @ rp["W_in"]
    # temporal conv width 4 (causal), carrying CONV_W-1 inputs across calls
    hist = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)  # (B,T+3,D)
    conv = sum(
        hist[:, CONV_W - 1 - i : hist.shape[1] - i] * rp["conv"][CONV_W - 1 - i]
        for i in range(CONV_W)
    )
    uf = conv.astype(jnp.float32)
    r = jax.nn.sigmoid((x @ rp["W_a"]).astype(jnp.float32) + rp["b_a"])
    i = jax.nn.sigmoid((x @ rp["W_i"]).astype(jnp.float32) + rp["b_i"])
    log_a = -RG_C * jax.nn.softplus(rp["lam"]) * r
    a = jnp.exp(log_a)
    h, h_last = rg_lru_scan(uf, a, i * uf, h_state)
    out = (h.astype(x.dtype) * gate) @ rp["W_out"]
    new_conv = hist[:, -(CONV_W - 1):].astype(jnp.float32)
    return out, (new_conv, h_last)


def _rec_layer_fwd(cfg, x, lp, states):
    conv_s, h_s = states
    h = apply_norm(x, lp["ln1"], cfg.norm)
    out, new_states = rec_block(cfg, h, lp["rec"], conv_s, h_s)
    x = x + out
    h = apply_norm(x, lp["ln2"], cfg.norm)
    x = x + maybe_shard(mlp_apply(h, lp["mlp"], cfg.act), "act_btd")
    return x, new_states


def _attn_layer_fwd(cfg, x, lp, positions):
    h = apply_norm(x, lp["ln1"], cfg.norm)
    h = attn.attention(
        h, lp["attn"],
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, positions=positions,
        causal=True, window=cfg.local_window,
        rope_theta=cfg.rope_theta, rope_pct=cfg.rope_pct, use_rope=cfg.rope,
    )
    x = x + h
    h = apply_norm(x, lp["ln2"], cfg.norm)
    return x + maybe_shard(mlp_apply(h, lp["mlp"], cfg.act), "act_btd")


def init_states(cfg: ArchConfig, batch: int):
    n_units, n_tail = _unit(cfg)
    D = cfg.d_model
    def rec_state(n):
        return (
            jnp.zeros((n, batch, CONV_W - 1, D), jnp.float32),
            jnp.zeros((n, batch, D), jnp.float32),
        )
    return {"a": rec_state(n_units), "b": rec_state(n_units),
            "tail": rec_state(n_tail) if n_tail else None}


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array, *,
            remat: bool = False, last_only: bool = False):
    B, T = tokens.shape
    x = maybe_shard(jnp.take(params["embed"], tokens, axis=0), "act_btd")
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    states = init_states(cfg, B)

    rec_body = partial(_rec_layer_fwd, cfg)
    attn_body = partial(_attn_layer_fwd, cfg)
    if remat:
        rec_body = jax.checkpoint(rec_body)
        attn_body = jax.checkpoint(attn_body)

    def unit_fn(x, inp):
        up, sa_c, sa_h, sb_c, sb_h = inp
        x, _ = rec_body(x, up["rec_a"], (sa_c, sa_h))
        x, _ = rec_body(x, up["rec_b"], (sb_c, sb_h))
        x = attn_body(x, up["attn"], positions)
        return x, None

    (sa_c, sa_h), (sb_c, sb_h) = states["a"], states["b"]
    x, _ = jax.lax.scan(unit_fn, x, (params["units"], sa_c, sa_h, sb_c, sb_h))
    if "tail_rec" in params:
        tc, th = states["tail"]
        def tail_fn(x, inp):
            lp, c, h = inp
            x, _ = rec_body(x, lp, (c, h))
            return x, None
        x, _ = jax.lax.scan(tail_fn, x, (params["tail_rec"], tc, th))
    if last_only:
        x = x[:, -1:]
    x = apply_norm(x, params["final_norm"], cfg.norm)
    return maybe_shard(x @ params["lm_head"], "act_btv")


def loss(cfg: ArchConfig, params: dict, batch: dict, *, remat: bool = False):
    logits = forward(cfg, params, batch["tokens"], remat=remat)
    return cross_entropy(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# Decode: recurrent states + ring-buffer KV for local attention layers.
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or dtype_of(cfg.param_dtype)
    n_units, n_tail = _unit(cfg)
    D, K, hd = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    W = min(cfg.local_window or max_len, max_len)
    def rec(n):
        return {
            "conv": jnp.zeros((n, batch, CONV_W - 1, D), jnp.float32),
            "h": jnp.zeros((n, batch, D), jnp.float32),
        }
    return {
        "rec_a": rec(n_units), "rec_b": rec(n_units),
        "tail": rec(n_tail) if n_tail else None,
        "k": jnp.zeros((n_units, batch, W, K, hd), dt),
        "v": jnp.zeros((n_units, batch, W, K, hd), dt),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def _rec_decode(cfg, x, lp, conv_s, h_s):
    """One-token recurrent layer. x: (B,D)."""
    h = apply_norm(x[:, None], lp["ln1"], cfg.norm)[:, 0]
    rp = lp["rec"]
    gate = jax.nn.gelu(h @ rp["W_gate"])
    u = h @ rp["W_in"]
    hist = jnp.concatenate([conv_s.astype(u.dtype), u[:, None]], axis=1)  # (B,4,D)
    conv = sum(hist[:, CONV_W - 1 - i] * rp["conv"][CONV_W - 1 - i] for i in range(CONV_W))
    uf = conv.astype(jnp.float32)
    r = jax.nn.sigmoid((h @ rp["W_a"]).astype(jnp.float32) + rp["b_a"])
    i = jax.nn.sigmoid((h @ rp["W_i"]).astype(jnp.float32) + rp["b_i"])
    a = jnp.exp(-RG_C * jax.nn.softplus(rp["lam"]) * r)
    h_new = a * h_s + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (i * uf)
    out = (h_new.astype(x.dtype) * gate) @ rp["W_out"]
    x = x + out
    h2 = apply_norm(x[:, None], lp["ln2"], cfg.norm)[:, 0]
    x = x + mlp_apply(h2, lp["mlp"], cfg.act)
    return x, hist[:, 1:].astype(jnp.float32), h_new


def decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens: jax.Array):
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache["len"]

    def unit_fn(x, inp):
        up, ca, ha, cb, hb, kc, vc = inp
        x, ca, ha = _rec_decode(cfg, x, up["rec_a"], ca, ha)
        x, cb, hb = _rec_decode(cfg, x, up["rec_b"], cb, hb)
        lp = up["attn"]
        h = apply_norm(x[:, None], lp["ln1"], cfg.norm)[:, 0]
        h, kc, vc = attn.decode_attention(
            h, lp["attn"], kc, vc, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, positions=pos,
            rope_theta=cfg.rope_theta, rope_pct=cfg.rope_pct,
            use_rope=cfg.rope, window=cfg.local_window,
        )
        x = x + h
        h = apply_norm(x[:, None], lp["ln2"], cfg.norm)[:, 0]
        x = x + mlp_apply(h, lp["mlp"], cfg.act)
        return x, (ca, ha, cb, hb, kc, vc)

    x, (ca, ha, cb, hb, kc, vc) = jax.lax.scan(
        unit_fn, x,
        (params["units"], cache["rec_a"]["conv"], cache["rec_a"]["h"],
         cache["rec_b"]["conv"], cache["rec_b"]["h"], cache["k"], cache["v"]),
    )
    new_cache = {
        "rec_a": {"conv": ca, "h": ha}, "rec_b": {"conv": cb, "h": hb},
        "tail": cache["tail"], "k": kc, "v": vc, "len": cache["len"] + 1,
    }
    if "tail_rec" in params:
        def tail_fn(x, inp):
            lp, c, h = inp
            x, c, h = _rec_decode(cfg, x, lp, c, h)
            return x, (c, h)
        x, (tc, th) = jax.lax.scan(
            tail_fn, x, (params["tail_rec"], cache["tail"]["conv"], cache["tail"]["h"])
        )
        new_cache["tail"] = {"conv": tc, "h": th}
    x = apply_norm(x[:, None], params["final_norm"], cfg.norm)[:, 0]
    logits = x @ params["lm_head"]
    return logits, new_cache
