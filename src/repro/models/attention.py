"""GQA attention: training (chunked, exact), decode (KV cache), tiered merge.

Training attention chunks the query axis through ``lax.scan`` so the
materialized score block is (chunk, S) instead of (S, S) — the memory
shape a flash kernel gives on TPU, expressed portably.  Decode attention
supports full, local (ring-buffer), and cross variants, and exposes
``attend_partial`` + ``merge_partials`` so a KV cache split across
memory tiers (the paper's N:M interleave) combines exactly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import apply_rope, he, maybe_shard

NEG_INF = -1e30


def attn_params(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
                dtype, qkv_bias: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": he(kq, (d_model, n_heads * head_dim), dtype),
        "wk": he(kk, (d_model, n_kv_heads * head_dim), dtype),
        "wv": he(kv, (d_model, n_kv_heads * head_dim), dtype),
        "wo": he(ko, (n_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(x, p, n_heads, n_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv_heads, head_dim)
    v = v.reshape(B, S, n_kv_heads, head_dim)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,Sq,H,hd), k: (B,Sk,K,hd) -> scores (B,K,H/K,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    qg = q.reshape(B, Sq, K, H // K, hd)
    return jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / np.sqrt(hd)


def _gqa_out(probs, v):
    """probs: (B,K,G,Sq,Sk), v: (B,Sk,K,hd) -> (B,Sq,H,hd)."""
    B, K, G, Sq, Sk = probs.shape
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, K * G, v.shape[-1])


def attention(
    x: jax.Array,
    p: dict,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    positions: jax.Array,  # (B, S)
    causal: bool = True,
    window: int = 0,  # 0 = full
    rope_theta: float = 10_000.0,
    rope_pct: float = 1.0,
    use_rope: bool = True,
    q_chunk: int = 1024,
    kv_override: Optional[tuple] = None,  # cross-attention (k, v, kv_positions)
) -> jax.Array:
    """Full-sequence attention; exact, q-chunked. Returns (B, S, D)."""
    from repro.models.common import current_policy
    pol = current_policy()
    if pol and "_q_chunk" in pol:
        q_chunk = pol["_q_chunk"]
    B, S, _ = x.shape
    q, k, v = _project_qkv(x, p, n_heads, n_kv_heads, head_dim)
    if kv_override is not None:
        k, v, kv_pos = kv_override
        causal = False
    else:
        kv_pos = positions
        if use_rope:
            k = apply_rope(k, kv_pos, rope_theta, rope_pct)
    if use_rope:
        q = apply_rope(q, positions, rope_theta, rope_pct)
    q = maybe_shard(q, "act_bshd")
    k = maybe_shard(k, "act_bskd")
    v = maybe_shard(v, "act_bskd")

    def block_exact(q_blk, pos_blk):
        scores = _gqa_scores(q_blk, k)  # (B,K,G,C,Sk)
        mask = jnp.ones((B, pos_blk.shape[1], kv_pos.shape[1]), bool)
        if causal:
            mask &= pos_blk[:, :, None] >= kv_pos[:, None, :]
        if window:
            mask &= pos_blk[:, :, None] - kv_pos[:, None, :] < window
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
        return _gqa_out(probs, v)  # (B,C,H,hd)

    kv_chunk = int(pol.get("_kv_chunk", 1024)) if pol else 1024

    def block_flash(q_blk, pos_blk):
        """Online-softmax over KV chunks: the (C, S_kv) score tensor never
        materializes — only (C, kv_chunk) blocks, sized to stay
        VMEM-resident on TPU (EXPERIMENTS.md §Perf, flash iteration)."""
        Sk = k.shape[1]
        nkv = Sk // kv_chunk
        C = q_blk.shape[1]
        K = k.shape[2]
        G = q_blk.shape[2] // K
        kc = jnp.moveaxis(k.reshape(B, nkv, kv_chunk, K, hd_), 1, 0)
        vc = jnp.moveaxis(v.reshape(B, nkv, kv_chunk, K, hd_), 1, 0)
        pc = jnp.moveaxis(kv_pos.reshape(B, nkv, kv_chunk), 1, 0)

        def body(carry, inp):
            acc, m, l = carry
            kj, vj, pj = inp
            s = _gqa_scores(q_blk, kj).astype(jnp.float32)  # (B,K,G,C,ck)
            mask = jnp.ones((B, C, kv_chunk), bool)
            if causal:
                mask &= pos_blk[:, :, None] >= pj[:, None, :]
            if window:
                mask &= pos_blk[:, :, None] - pj[:, None, :] < window
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p_, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p_.astype(vj.dtype), vj)
            acc = acc * alpha[..., None] + pv.astype(jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, K, G, C, hd_), jnp.float32)
        m0 = jnp.full((B, K, G, C), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, C), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kc, vc, pc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,K,G,C,hd)
        return out.transpose(0, 3, 1, 2, 4).reshape(
            B, C, K * G, hd_).astype(x.dtype)

    hd_ = head_dim
    use_flash = bool(pol and pol.get("_flash")) \
        and k.shape[1] % kv_chunk == 0 and k.shape[1] > kv_chunk
    block = block_flash if use_flash else block_exact

    if S % q_chunk:
        # largest divisor of S that is <= q_chunk (whisper's 1500-frame
        # encoder etc.); 1 leaves attention unchunked
        q_chunk = max(d for d in range(1, q_chunk + 1) if S % d == 0)
    if S <= q_chunk or q_chunk == 1:
        out = block(q, positions)
    else:
        n = S // q_chunk
        qs = q.reshape(B, n, q_chunk, n_heads, head_dim).transpose(1, 0, 2, 3, 4)
        ps = positions.reshape(B, n, q_chunk).transpose(1, 0, 2)
        def body(_, qp):
            return None, block(qp[0], qp[1])
        _, outs = jax.lax.scan(body, None, (qs, ps))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, n_heads, head_dim)
    out = out.reshape(B, S, n_heads * head_dim)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# Decode path (one new token against a KV cache)
# ---------------------------------------------------------------------------
def attend_partial(q, k, v, valid: jax.Array):
    """Unnormalized attention over one KV partition.

    q: (B,H,hd); k,v: (B,T,K,hd); valid: (B,T) bool.
    Returns (acc (B,H,hd), lse-pieces (m, l): (B,H)).
    """
    B, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg,
                        k.astype(jnp.float32)) / np.sqrt(hd)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)  # (B,K,G)
    p = jnp.exp(scores - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return acc.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H)


def merge_partials(parts):
    """Exactly merge [(acc, m, l), ...] partial attentions (flash combine)."""
    accs, ms, ls = zip(*parts)
    m_all = jnp.max(jnp.stack(ms), axis=0)  # (B,H)
    acc_t, l_t = 0.0, 0.0
    for acc, m, l in parts:
        w = jnp.exp(m - m_all)
        acc_t = acc_t + acc * w[..., None]
        l_t = l_t + l * w
    return acc_t / jnp.maximum(l_t, 1e-30)[..., None]


def decode_attention(
    x_tok: jax.Array,  # (B, D) current token activations
    p: dict,
    k_cache: jax.Array,  # (B, T, K, hd)
    v_cache: jax.Array,
    cache_len: jax.Array,  # (B,) valid prefix length (pre-append)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    positions: jax.Array,  # (B,) absolute position of the new token
    rope_theta: float = 10_000.0,
    rope_pct: float = 1.0,
    use_rope: bool = True,
    window: int = 0,  # ring-buffer semantics when > 0
    extra_partitions: tuple = (),  # [(k, v, valid)] e.g. the slow-tier split
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step. Returns (out (B,D), new_k_cache, new_v_cache)."""
    B, D = x_tok.shape
    q = (x_tok @ p["wq"])
    k = (x_tok @ p["wk"])
    v = (x_tok @ p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, n_heads, head_dim)
    k = k.reshape(B, n_kv_heads, head_dim)
    v = v.reshape(B, n_kv_heads, head_dim)
    if use_rope:
        q = apply_rope(q[:, None], positions[:, None], rope_theta, rope_pct)[:, 0]
        k = apply_rope(k[:, None], positions[:, None], rope_theta, rope_pct)[:, 0]
    T = k_cache.shape[1]
    slot = (cache_len % T) if window else jnp.minimum(cache_len, T - 1)
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, slot].set(k.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, slot].set(v.astype(v_cache.dtype))
    t_idx = jnp.arange(T)[None, :]
    if window:
        valid = t_idx < jnp.minimum(cache_len + 1, T)[:, None]
    else:
        valid = t_idx <= cache_len[:, None]
    parts = [attend_partial(q, k_cache, v_cache, valid)]
    for (ke, ve, vald) in extra_partitions:
        parts.append(attend_partial(q, ke, ve, vald))
    out = merge_partials(parts).astype(x_tok.dtype)  # (B,H,hd)
    return out.reshape(B, n_heads * head_dim) @ p["wo"], k_cache, v_cache
