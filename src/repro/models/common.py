"""Shared model primitives: norms, RoPE, MLPs, embeddings, sharding hooks."""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Activation-sharding hooks.  launch/shardings.py installs a policy dict
# {logical_name: PartitionSpec}; models call maybe_shard(x, name).  Without a
# policy (smoke tests) this is the identity.
# ---------------------------------------------------------------------------
_SHARDING_POLICY = threading.local()


def current_policy() -> Optional[dict]:
    return getattr(_SHARDING_POLICY, "policy", None)


@contextlib.contextmanager
def activation_sharding(policy: dict):
    prev = current_policy()
    _SHARDING_POLICY.policy = policy
    try:
        yield
    finally:
        _SHARDING_POLICY.policy = prev


def maybe_shard(x: jax.Array, name: str) -> jax.Array:
    pol = current_policy()
    if pol is None or name not in pol:
        return x
    return jax.lax.with_sharding_constraint(x, pol[name])


# ---------------------------------------------------------------------------
# Norms (computed in fp32, cast back)
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_params(d: int, kind: str, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# RoPE (supports partial rotary)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, rope_pct: float = 1.0) -> jax.Array:
    rot = int(head_dim * rope_pct) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rope_pct: float = 1.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    rot = int(hd * rope_pct) // 2 * 2
    if rot == 0:
        return x
    freqs = rope_freqs(hd, theta, rope_pct)  # (rot/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, rot/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype) if rot < hd else out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_apply(x, p, act: str):
    if act in ("swiglu", "geglu"):
        gate = x @ p["w_gate"]
        up = x @ p["w_up"]
        inner = (jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate)) * up
        return inner @ p["w_down"]
    if act == "relu_sq":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


def mlp_params(key, d: int, f: int, act: str, dtype) -> dict:
    if act in ("swiglu", "geglu"):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": he(k1, (d, f), dtype),
            "w_up": he(k2, (d, f), dtype),
            "w_down": he(k3, (f, d), dtype),
        }
    k1, k2 = jax.random.split(key)
    return {"w_up": he(k1, (d, f), dtype), "w_down": he(k2, (f, d), dtype)}


def he(key, shape, dtype=jnp.float32, scale: float = 1.0):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL, fp32 accumulation."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]
