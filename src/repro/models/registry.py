"""Architecture registry: ``--arch <id>`` -> (config, model module).

Every model module exposes: init(cfg, key), forward(...), loss(cfg,
params, batch, *, remat), init_cache(cfg, B, T), decode_step(cfg,
params, cache, tokens).  ``batch_spec``/``decode_spec`` document the
input names each family needs (used by launch.input_specs).
"""
from __future__ import annotations

import dataclasses
import importlib
from types import ModuleType

from repro.configs.base import ArchConfig

_CONFIG_MODULES = {
    "qwen2.5-32b": "qwen2_5_32b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen1.5-32b": "qwen1_5_32b",
    "stablelm-12b": "stablelm_12b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-2b": "internvl2_2b",
    "rwkv6-7b": "rwkv6_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-large-v3": "whisper_large_v3",
}

_FAMILY_MODULES = {
    "dense": "repro.models.transformer",
    "vlm": "repro.models.transformer",  # backbone; vision stub via prefix_embeds
    "moe": "repro.models.moe",
    "ssm": "repro.models.rwkv",
    "hybrid": "repro.models.rglru",
    "audio": "repro.models.whisper",
}

ARCH_IDS = tuple(_CONFIG_MODULES)


@dataclasses.dataclass(frozen=True)
class Arch:
    cfg: ArchConfig
    module: ModuleType

    @property
    def name(self) -> str:
        return self.cfg.name

    def tiny(self) -> "Arch":
        return Arch(self.cfg.tiny(), self.module)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _CONFIG_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_CONFIG_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_CONFIG_MODULES[arch_id]}")
    return mod.CONFIG


def get(arch_id: str) -> Arch:
    cfg = get_config(arch_id)
    return Arch(cfg, importlib.import_module(_FAMILY_MODULES[cfg.family]))


def all_archs() -> dict[str, Arch]:
    return {a: get(a) for a in ARCH_IDS}
