"""Whisper-large-v3 backbone: encoder-decoder transformer.

The conv1d mel frontend is a STUB per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, n_ctx, D) directly to the
encoder.  Learned positional embeddings, pre-LN, GELU, full (not GQA)
attention with kv = heads.  Cross-attention K/V are computed once per
request (``prepare_cross``) — the bandwidth-bound, read-only buffer that
DESIGN.md marks as the ideal slow-tier tenant for this arch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import transformer as dense
from repro.models.common import (
    apply_norm,
    cross_entropy,
    dtype_of,
    embed_init,
    he,
    maybe_shard,
    mlp_apply,
    mlp_params,
    norm_params,
)


def _attn_p(cfg, key, dt):
    return attn.attn_params(
        key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
        cfg.resolved_head_dim, dt, qkv_bias=True,
    )


def init_enc_layer(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "attn": _attn_p(cfg, k1, dt),
        "ln2": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.act, dt),
    }


def init_dec_layer(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "attn": _attn_p(cfg, k1, dt),
        "ln_x": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "xattn": _attn_p(cfg, k2, dt),
        "ln2": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "mlp": mlp_params(k3, cfg.d_model, cfg.d_ff, cfg.act, dt),
    }


def init(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    enc = cfg.encoder
    ke, kp, kq, kl, kd, kh = jax.random.split(key, 6)
    enc_layers = jax.vmap(lambda k: init_enc_layer(cfg, k))(
        jax.random.split(kl, enc.n_layers))
    dec_layers = jax.vmap(lambda k: init_dec_layer(cfg, k))(
        jax.random.split(kd, cfg.n_layers))
    return {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dt),
        "enc_pos": he(kp, (enc.n_ctx, cfg.d_model), dt, 0.02),
        "dec_pos": he(kq, (cfg.max_seq, cfg.d_model), dt, 0.02),
        "enc_layers": enc_layers,
        "enc_norm": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "dec_layers": dec_layers,
        "final_norm": norm_params(cfg.d_model, cfg.norm, jnp.float32),
    }


def encode(cfg: ArchConfig, params: dict, frames: jax.Array,
           *, remat: bool = False) -> jax.Array:
    """frames: (B, n_ctx, D) precomputed mel-frame embeddings (stub)."""
    B, T, D = frames.shape
    x = frames + params["enc_pos"][None, :T]
    x = maybe_shard(x, "act_btd")
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))

    def body(x, lp):
        h = apply_norm(x, lp["ln1"], cfg.norm)
        h = attn.attention(
            h, lp["attn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, positions=positions,
            causal=False, use_rope=False,
        )
        x = x + h
        h = apply_norm(x, lp["ln2"], cfg.norm)
        return x + mlp_apply(h, lp["mlp"], cfg.act)

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(lambda c, lp: (body(c, lp), None), x, params["enc_layers"])
    return apply_norm(x, params["enc_norm"], cfg.norm)


def _dec_layer_fwd(cfg, x, lp, positions, enc_out, enc_pos):
    h = apply_norm(x, lp["ln1"], cfg.norm)
    h = attn.attention(
        h, lp["attn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, positions=positions,
        causal=True, use_rope=False,
    )
    x = x + h
    h = apply_norm(x, lp["ln_x"], cfg.norm)
    B = h.shape[0]
    k = (enc_out @ lp["xattn"]["wk"] + lp["xattn"]["bk"]).reshape(
        B, enc_out.shape[1], cfg.n_kv_heads, cfg.resolved_head_dim)
    v = (enc_out @ lp["xattn"]["wv"] + lp["xattn"]["bv"]).reshape(
        B, enc_out.shape[1], cfg.n_kv_heads, cfg.resolved_head_dim)
    h = attn.attention(
        h, lp["xattn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, positions=positions,
        use_rope=False, kv_override=(k, v, enc_pos),
    )
    x = x + h
    h = apply_norm(x, lp["ln2"], cfg.norm)
    return x + maybe_shard(mlp_apply(h, lp["mlp"], cfg.act), "act_btd")


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array, *,
            frames: jax.Array, remat: bool = False,
            last_only: bool = False) -> jax.Array:
    enc_out = encode(cfg, params, frames, remat=remat)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0) + params["dec_pos"][None, :S]
    x = maybe_shard(x, "act_btd")
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    enc_pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1])[None, :], enc_out.shape[:2])

    body = partial(_dec_layer_fwd, cfg)
    if remat:
        body = jax.checkpoint(body)
    def scan_fn(x, lp):
        return body(x, lp, positions, enc_out, enc_pos), None
    x, _ = jax.lax.scan(scan_fn, x, params["dec_layers"])
    if last_only:
        x = x[:, -1:]
    x = apply_norm(x, params["final_norm"], cfg.norm)
    return maybe_shard(x @ params["embed"].T, "act_btv")  # tied head (whisper)


def loss(cfg: ArchConfig, params: dict, batch: dict, *, remat: bool = False):
    logits = forward(cfg, params, batch["tokens"], frames=batch["frames"],
                     remat=remat)
    return cross_entropy(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def prepare_cross(cfg: ArchConfig, params: dict, enc_out: jax.Array):
    """Per-layer cross K/V, computed once per request. (L,B,Tc,K,hd) x2."""
    B, Tc, D = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def per_layer(lp):
        k = (enc_out @ lp["xattn"]["wk"] + lp["xattn"]["bk"]).reshape(B, Tc, K, hd)
        v = (enc_out @ lp["xattn"]["wv"] + lp["xattn"]["bv"]).reshape(B, Tc, K, hd)
        return k, v

    return jax.vmap(per_layer, in_axes=0)(params["dec_layers"])


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or dtype_of(cfg.param_dtype)
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    Tc = cfg.encoder.n_ctx
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, K, hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, K, hd), dt),
        "xk": jnp.zeros((cfg.n_layers, batch, Tc, K, hd), dt),
        "xv": jnp.zeros((cfg.n_layers, batch, Tc, K, hd), dt),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens: jax.Array):
    B = tokens.shape[0]
    pos = cache["len"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + jnp.take(params["dec_pos"], jnp.minimum(pos, cfg.max_seq - 1), axis=0)
    Tc = cache["xk"].shape[2]
    xvalid = jnp.ones((B, Tc), bool)

    def layer_fn(x, lp, kc, vc, xk, xv):
        h = apply_norm(x[:, None], lp["ln1"], cfg.norm)[:, 0]
        h, kc, vc = attn.decode_attention(
            h, lp["attn"], kc, vc, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, positions=pos, use_rope=False,
        )
        x = x + h
        h = apply_norm(x[:, None], lp["ln_x"], cfg.norm)[:, 0]
        q = (h @ lp["xattn"]["wq"] + lp["xattn"]["bq"]).reshape(
            B, cfg.n_heads, cfg.resolved_head_dim)
        acc, m, l = attn.attend_partial(q, xk, xv, xvalid)
        o = attn.merge_partials([(acc, m, l)]).astype(x.dtype)
        x = x + o.reshape(B, -1) @ lp["xattn"]["wo"]
        h = apply_norm(x[:, None], lp["ln2"], cfg.norm)[:, 0]
        x = x + mlp_apply(h, lp["mlp"], cfg.act)
        return x, kc, vc

    # fori + in-place updates: self-KV stays one donated buffer
    def body(i, carry):
        x, kc, vc = carry
        lp = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False),
            params["dec_layers"])
        ki = jax.lax.dynamic_index_in_dim(kc, i, 0, False)
        vi = jax.lax.dynamic_index_in_dim(vc, i, 0, False)
        xk = jax.lax.dynamic_index_in_dim(cache["xk"], i, 0, False)
        xv = jax.lax.dynamic_index_in_dim(cache["xv"], i, 0, False)
        x, k2, v2 = layer_fn(x, lp, ki, vi, xk, xv)
        kc = jax.lax.dynamic_update_index_in_dim(kc, k2.astype(kc.dtype), i, 0)
        vc = jax.lax.dynamic_update_index_in_dim(vc, v2.astype(vc.dtype), i, 0)
        return x, kc, vc

    x, k_new, v_new = jax.lax.fori_loop(
        0, cfg.n_layers, body, (x, cache["k"], cache["v"]))
    x = apply_norm(x[:, None], params["final_norm"], cfg.norm)[:, 0]
    logits = x @ params["embed"].T
    new_cache = dict(cache, k=k_new, v=v_new, len=cache["len"] + 1)
    return logits, new_cache
