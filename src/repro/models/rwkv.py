"""RWKV6 "Finch" — attention-free RNN LM with data-dependent decay.

Faithful structure: token-shift lerps, LoRA-modulated per-channel decay
``w = exp(-exp(w0 + tanh(x @ A) @ B))``, per-head WKV state
``S <- diag(w) S + k^T v`` with bonus ``u``, grouped head-norm + silu
output gate, and squared-ReLU channel mixing.  Training runs a
`lax.scan` over time (exact reference); the Pallas ``wkv6`` kernel
provides the TPU chunked form.  State is O(1) in sequence length, so the
long_500k shape is in scope (DESIGN.md §Arch-applicability), and the
recurrent state is classified latency-bound -> pinned to the fast tier.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import (
    cross_entropy,
    dtype_of,
    embed_init,
    he,
    layer_norm,
    maybe_shard,
)

LORA_RANK = 64


def _heads(cfg: ArchConfig) -> tuple[int, int]:
    hd = cfg.rwkv_head_dim
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def init_layer(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    D, F = cfg.d_model, cfg.d_ff
    H, hd = _heads(cfg)
    ks = jax.random.split(key, 10)
    return {
        "ln1": {"scale": jnp.ones((D,), jnp.float32), "bias": jnp.zeros((D,), jnp.float32)},
        "ln2": {"scale": jnp.ones((D,), jnp.float32), "bias": jnp.zeros((D,), jnp.float32)},
        "tm": {
            "mu_r": jnp.full((D,), 0.5, dt), "mu_k": jnp.full((D,), 0.5, dt),
            "mu_v": jnp.full((D,), 0.5, dt), "mu_w": jnp.full((D,), 0.5, dt),
            "mu_g": jnp.full((D,), 0.5, dt),
            "w0": jnp.full((D,), -6.0, jnp.float32),
            "w_A": he(ks[0], (D, LORA_RANK), dt, 0.1),
            "w_B": he(ks[1], (LORA_RANK, D), dt, 0.1),
            "u": jnp.zeros((H, hd), jnp.float32),
            "Wr": he(ks[2], (D, D), dt), "Wk": he(ks[3], (D, D), dt),
            "Wv": he(ks[4], (D, D), dt), "Wg": he(ks[5], (D, D), dt),
            "Wo": he(ks[6], (D, D), dt),
            "gn_scale": jnp.ones((H, hd), jnp.float32),
            "gn_bias": jnp.zeros((H, hd), jnp.float32),
        },
        "cm": {
            "mu_k": jnp.full((D,), 0.5, dt), "mu_r": jnp.full((D,), 0.5, dt),
            "Wk": he(ks[7], (D, F), dt), "Wv": he(ks[8], (F, D), dt),
            "Wr": he(ks[9], (D, D), dt),
        },
    }


def init(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: init_layer(cfg, k))(jax.random.split(kl, cfg.n_layers))
    return {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dt),
        "layers": layers,
        "final_norm": {"scale": jnp.ones((cfg.d_model,), jnp.float32),
                       "bias": jnp.zeros((cfg.d_model,), jnp.float32)},
        "lm_head": embed_init(kh, cfg.vocab_padded, cfg.d_model, dt).T,
    }


def _group_norm(y: jax.Array, scale, bias, eps=64e-5):
    """Per-head layer norm; y: (..., H, hd)."""
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    return (yf - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _decay(tm: dict, xw: jax.Array) -> jax.Array:
    """Data-dependent per-channel decay w in (0,1); xw: (..., D)."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ tm["w_A"].astype(jnp.float32))
    lora = lora @ tm["w_B"].astype(jnp.float32)
    return jnp.exp(-jnp.exp(tm["w0"] + lora))


def wkv_scan(r, k, v, w, u, state):
    """Exact WKV6 recurrence over time.

    r,k,w: (B,T,H,hd); v: (B,T,H,hd); u: (H,hd); state: (B,H,hd,hd).
    Returns (y (B,T,H,hd) fp32, final state).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,hd) each
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    rs, ks, vs, ws = (jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state


def wkv_chunked(r, k, v, w, u, state, *, chunk: int = 16):
    """Chunked WKV6: intra-chunk matrix form + inter-chunk state carry.

    The TPU-native reformulation (mirrors the Pallas kernel's VMEM
    blocking in pure JAX, so the dry run lowers it): per chunk, decay
    ratios exp(L_{t-1} - L_s) for s < t are all <= 1 — numerically safe,
    no 1/P blowup — and the recurrent state is read/written once per
    CHUNK instead of once per token, cutting state HBM traffic by the
    chunk length (EXPERIMENTS.md §Perf, rwkv hillclimb).
    """
    B, T, H, hd = r.shape
    C = min(chunk, T)
    assert T % C == 0
    n = T // C
    f32 = jnp.float32
    rs, ks, vs = (a.astype(f32).reshape(B, n, C, H, hd) for a in (r, k, v))
    logw = jnp.log(jnp.maximum(w.astype(f32), 1e-38)).reshape(B, n, C, H, hd)
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)  # s < t

    def per_chunk(S, inp):
        rc, kc, vc, lw = inp  # (B,C,H,hd)
        lam = jnp.cumsum(lw, axis=1)  # L_t (inclusive)
        lam_prev = lam - lw  # L_{t-1}
        rP = rc * jnp.exp(lam_prev)
        y = jnp.einsum("bthi,bhij->bthj", rP, S)
        diff = lam_prev[:, :, None] - lam[:, None, :]  # (B,t,s,H,hd), <= 0
        dmat = jnp.where(tri[None, :, :, None, None], jnp.exp(diff), 0.0)
        coeff = jnp.einsum("bthi,btshi,bshi->btsh", rc, dmat, kc)
        y = y + jnp.einsum("btsh,bshj->bthj", coeff, vc)
        diag = jnp.einsum("bthi,hi,bthi->bth", rc, u.astype(f32), kc)
        y = y + diag[..., None] * vc
        lam_C = lam[:, -1:]
        S = jnp.exp(lam_C[:, 0])[..., None] * S + jnp.einsum(
            "bshi,bshj->bhij", kc * jnp.exp(lam_C - lam), vc)
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rs, ks, vs, logw))
    state, ys = jax.lax.scan(per_chunk, state.astype(f32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, n * C, H, hd)
    return y, state


def _use_chunked() -> bool:
    from repro.models.common import current_policy
    pol = current_policy()
    return bool(pol and pol.get("_wkv_chunked"))


def time_mix(cfg: ArchConfig, x: jax.Array, tm: dict, state, shift_in):
    """x: (B,T,D). Returns (out, (new_shift, new_state))."""
    B, T, D = x.shape
    H, hd = _heads(cfg)
    xs = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)  # x_{t-1}
    mix = lambda mu: x + (xs - x) * mu
    r = (mix(tm["mu_r"]) @ tm["Wr"]).reshape(B, T, H, hd)
    k = (mix(tm["mu_k"]) @ tm["Wk"]).reshape(B, T, H, hd)
    v = (mix(tm["mu_v"]) @ tm["Wv"]).reshape(B, T, H, hd)
    g = jax.nn.silu(mix(tm["mu_g"]) @ tm["Wg"])
    w = _decay(tm, mix(tm["mu_w"])).reshape(B, T, H, hd)
    wkv = wkv_chunked if (_use_chunked() and T % 16 == 0) else wkv_scan
    y, new_state = wkv(r, k, v, w, tm["u"], state)
    y = _group_norm(y, tm["gn_scale"], tm["gn_bias"]).reshape(B, T, D)
    out = (y.astype(x.dtype) * g) @ tm["Wo"]
    return out, (x[:, -1], new_state)


def channel_mix(x: jax.Array, cm: dict, shift_in):
    xs = jnp.concatenate([shift_in[:, None], x[:, :-1]], axis=1)
    xk = x + (xs - x) * cm["mu_k"]
    xr = x + (xs - x) * cm["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ cm["Wk"]))
    return jax.nn.sigmoid(xr @ cm["Wr"]) * (k @ cm["Wv"]), x[:, -1]


def _layer_fwd(cfg: ArchConfig, x, lp, states):
    tm_shift, cm_shift, wkv_state = states
    h = layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
    out, (tm_shift, wkv_state) = time_mix(cfg, h, lp["tm"], wkv_state, tm_shift)
    x = x + out
    h = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
    out, cm_shift = channel_mix(h, lp["cm"], cm_shift)
    x = x + maybe_shard(out, "act_btd")
    return x, (tm_shift, cm_shift, wkv_state)


def init_states(cfg: ArchConfig, batch: int):
    H, hd = _heads(cfg)
    D = cfg.d_model
    return (
        jnp.zeros((cfg.n_layers, batch, D), jnp.float32),
        jnp.zeros((cfg.n_layers, batch, D), jnp.float32),
        jnp.zeros((cfg.n_layers, batch, H, hd, hd), jnp.float32),
    )


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array, *,
            states=None, remat: bool = False, return_states: bool = False,
            last_only: bool = False):
    B, T = tokens.shape
    x = maybe_shard(jnp.take(params["embed"], tokens, axis=0), "act_btd")
    if states is None:
        states = init_states(cfg, B)
    body = partial(_layer_fwd, cfg)
    if remat:
        body = jax.checkpoint(body)

    def scan_fn(x, inp):
        lp, tm_s, cm_s, wkv_s = inp
        x, new_states = body(x, lp, (tm_s.astype(x.dtype), cm_s.astype(x.dtype), wkv_s))
        return x, new_states

    x, new_states = jax.lax.scan(
        scan_fn, x, (params["layers"],) + tuple(states)
    )
    if last_only:
        x = x[:, -1:]
    x = layer_norm(x, params["final_norm"]["scale"], params["final_norm"]["bias"])
    logits = maybe_shard(x @ params["lm_head"], "act_btv")
    if return_states:
        return logits, new_states
    return logits


def loss(cfg: ArchConfig, params: dict, batch: dict, *, remat: bool = False):
    logits = forward(cfg, params, batch["tokens"], remat=remat)
    return cross_entropy(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# Decode: one token per call; cache = recurrent states (O(1) in seq len).
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int = 0, dtype=None) -> dict:
    tm, cm, wkv = init_states(cfg, batch)
    return {"tm": tm, "cm": cm, "wkv": wkv, "len": jnp.zeros((batch,), jnp.int32)}


def decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens: jax.Array):
    x = jnp.take(params["embed"], tokens, axis=0)[:, None]  # (B,1,D)

    def scan_fn(x, inp):
        lp, tm_s, cm_s, wkv_s = inp
        x, ns = _layer_fwd(cfg, x, lp, (tm_s.astype(x.dtype), cm_s.astype(x.dtype), wkv_s))
        return x, ns

    x, (tm, cm, wkv) = jax.lax.scan(
        scan_fn, x, (params["layers"], cache["tm"], cache["cm"], cache["wkv"])
    )
    x = layer_norm(x, params["final_norm"]["scale"], params["final_norm"]["bias"])
    logits = (x @ params["lm_head"])[:, 0]
    return logits, {"tm": tm, "cm": cm, "wkv": wkv, "len": cache["len"] + 1}
