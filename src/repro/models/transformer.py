"""Dense decoder-only transformer (qwen2.5/qwen1.5, starcoder2, stablelm,
InternLM2-backbone).  Layers are scanned (stacked params, `lax.scan`) so
HLO stays compact at 64 layers; remat is applied per layer for training.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.common import (
    apply_norm,
    cross_entropy,
    dtype_of,
    embed_init,
    maybe_shard,
    mlp_apply,
    mlp_params,
    norm_params,
)


def init_layer(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "attn": attn.attn_params(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, dt, cfg.qkv_bias,
        ),
        "ln2": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.act, dt),
    }


def init(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(cfg, k))(layer_keys)
    params = {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dt),
        "layers": layers,
        "final_norm": norm_params(cfg.d_model, cfg.norm, jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(kh, cfg.vocab_padded, cfg.d_model, dt).T
    return params


def _layer_fwd(cfg: ArchConfig, x, lp, positions):
    h = apply_norm(x, lp["ln1"], cfg.norm)
    h = attn.attention(
        h, lp["attn"],
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, positions=positions,
        causal=True, window=cfg.local_window,
        rope_theta=cfg.rope_theta, rope_pct=cfg.rope_pct, use_rope=cfg.rope,
    )
    x = x + h
    h = apply_norm(x, lp["ln2"], cfg.norm)
    h = mlp_apply(h, lp["mlp"], cfg.act)
    h = maybe_shard(h, "act_btd")
    return x + h


def forward(
    cfg: ArchConfig,
    params: dict,
    tokens: jax.Array,  # (B, S)
    *,
    prefix_embeds: Optional[jax.Array] = None,  # (B, P, D) VLM stub input
    remat: bool = False,
    last_only: bool = False,  # prefill: logits for the final position only
) -> jax.Array:
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x[:, P:]], axis=1)
    x = maybe_shard(x, "act_btd")
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    body = partial(_layer_fwd, cfg)
    if remat:
        body = jax.checkpoint(body, static_argnums=())

    def scan_fn(x, lp):
        return body(x, lp, positions), None

    x, _ = jax.lax.scan(scan_fn, x, params["layers"])
    if last_only:
        x = x[:, -1:]
    x = apply_norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return maybe_shard(logits, "act_btv")


def loss(cfg: ArchConfig, params: dict, batch: dict, *, remat: bool = False):
    logits = forward(
        cfg, params, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"), remat=remat,
    )
    return cross_entropy(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# Decode (serve) path
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or dtype_of(cfg.param_dtype)
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    T = min(max_len, cfg.local_window) if cfg.local_window else max_len
    return {
        "k": jnp.zeros((cfg.n_layers, batch, T, K, hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, T, K, hd), dt),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(
    cfg: ArchConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,  # (B,) current token ids
    *,
    extra_partitions_fn=None,  # layer_idx -> [(k, v, valid)] tiered KV split
) -> tuple[jax.Array, dict]:
    """One token for every sequence in the batch. Returns (logits, cache)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, D)
    pos = cache["len"]

    def scan_fn(carry, inp):
        x = carry
        lp, kc, vc, idx = inp
        h = apply_norm(x[:, None], lp["ln1"], cfg.norm)[:, 0]
        extra = extra_partitions_fn(idx) if extra_partitions_fn else ()
        h, kc, vc = attn.decode_attention(
            h, lp["attn"], kc, vc, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, positions=pos,
            rope_theta=cfg.rope_theta, rope_pct=cfg.rope_pct,
            use_rope=cfg.rope, window=cfg.local_window,
            extra_partitions=extra,
        )
        x = x + h
        h = apply_norm(x[:, None], lp["ln2"], cfg.norm)[:, 0]
        x = x + mlp_apply(h, lp["mlp"], cfg.act)
        return x, (kc, vc)

    if extra_partitions_fn is None:
        # fori + in-place dynamic updates: the (L,B,T,K,hd) cache stays a
        # single donated buffer (a scan would double-buffer its carry).
        def body(i, carry):
            x, kc, vc = carry
            lp = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False),
                params["layers"])
            ki = jax.lax.dynamic_index_in_dim(kc, i, 0, False)
            vi = jax.lax.dynamic_index_in_dim(vc, i, 0, False)
            x, (k2, v2) = scan_fn(x, (lp, ki, vi, i))
            kc = jax.lax.dynamic_update_index_in_dim(kc, k2.astype(kc.dtype), i, 0)
            vc = jax.lax.dynamic_update_index_in_dim(vc, v2.astype(vc.dtype), i, 0)
            return x, kc, vc
        x, k_new, v_new = jax.lax.fori_loop(
            0, cfg.n_layers, body, (x, cache["k"], cache["v"]))
    else:
        # per-layer python loop when tier partitions differ per layer
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, (kc, vc) = scan_fn(x, (lp, cache["k"][i], cache["v"][i], i))
            ks.append(kc)
            vs.append(vc)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    x = apply_norm(x[:, None], params["final_norm"], cfg.norm)[:, 0]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    new_cache = {"k": k_new, "v": v_new, "len": cache["len"] + 1}
    return logits, new_cache
