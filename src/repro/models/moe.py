"""Mixture-of-Experts decoder (deepseek-moe-16b, llama4-maverick).

Sort-based token dispatch (no (T,E,C) one-hot tensor): tokens are
argsorted by expert id, placed into per-expert capacity slots, processed
by batched expert matmuls, and combined by scatter-add.  With expert
weights sharded over the ``data`` axis this lowers to the EP all-to-all
pattern; shared experts are merged into one dense MLP.

Layer grouping for scan: ``first_dense`` leading dense layers (deepseek)
run unscanned; the repeating unit (optional dense layer + MoE layer,
``every`` ∈ {1, 2}) is scanned.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoESpec
from repro.models import attention as attn
from repro.models import transformer as dense
from repro.models.common import (
    apply_norm,
    cross_entropy,
    dtype_of,
    embed_init,
    he,
    maybe_shard,
    mlp_apply,
    mlp_params,
    norm_params,
)


# ---------------------------------------------------------------------------
# Expert MLP (stacked over E) + sort-based dispatch
# ---------------------------------------------------------------------------
def expert_params(key, E: int, d: int, f: int, act: str, dtype) -> dict:
    keys = jax.random.split(key, E)
    return jax.vmap(lambda k: mlp_params(k, d, f, act, dtype))(keys)


def expert_apply(xs: jax.Array, p: dict, act: str) -> jax.Array:
    """xs: (E, C, D) -> (E, C, D) via per-expert MLP."""
    if "w_gate" in p:
        gate = jnp.einsum("ecd,edf->ecf", xs, p["w_gate"])
        up = jnp.einsum("ecd,edf->ecf", xs, p["w_up"])
        inner = jax.nn.silu(gate) * up
        return jnp.einsum("ecf,efd->ecd", inner, p["w_down"])
    h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xs, p["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_mlp(x2d: jax.Array, p: dict, spec: MoESpec, act: str):
    """Routed expert MLP over flat tokens. Returns (y (T,D), aux dict)."""
    T, D = x2d.shape
    E, K = spec.n_experts, spec.top_k
    logits = (x2d.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_ids = jax.lax.top_k(probs, K)  # (T,K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    C = int(math.ceil(T * K / E * spec.capacity_factor))
    C = max(8, -(-C // 8) * 8)

    flat_ids = gate_ids.reshape(-1)  # (T*K,)
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(E), side="left")
    pos = jnp.arange(T * K) - seg_start[sorted_ids]  # rank within expert
    keep = pos < C
    dest = jnp.where(keep, sorted_ids * C + pos, E * C)  # overflow -> dropped
    src_tok = order // K

    x2d = maybe_shard(x2d, "act_td")
    pulled = maybe_shard(x2d[src_tok], "act_td")  # (T*K, D) token-major
    buf = jnp.zeros((E * C, D), x2d.dtype).at[dest].set(pulled, mode="drop")
    buf = maybe_shard(buf, "act_ecd_flat")  # (E*C, D) expert-major
    expert_in = maybe_shard(buf.reshape(E, C, D), "act_ecd")
    expert_out = expert_apply(expert_in, p["experts"], act)
    expert_out = maybe_shard(expert_out, "act_ecd")
    out_buf = maybe_shard(expert_out.reshape(E * C, D), "act_ecd_flat")

    contrib = maybe_shard(out_buf[jnp.where(keep, dest, 0)], "act_td")
    w = (flat_w[order] * keep).astype(x2d.dtype)
    y = jnp.zeros((T, D), x2d.dtype).at[src_tok].add(contrib * w[:, None])
    y = maybe_shard(y, "act_td")

    if spec.n_shared:
        y = y + mlp_apply(x2d, p["shared"], act)

    # Switch-style load-balance + router z-loss
    top1 = gate_ids[:, 0]
    f_e = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = {
        "lb_loss": E * jnp.sum(f_e * p_e),
        "z_loss": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "dropped": jnp.mean(1.0 - keep.astype(jnp.float32)),
        # per-expert dispatch histogram (kept slots only): the access-
        # frequency signal the hotness ledger places expert weights by
        # (core/hotness.py) — routing already computed it for free.
        "expert_counts": jnp.zeros((E,), jnp.float32)
        .at[sorted_ids].add(keep.astype(jnp.float32), mode="drop"),
    }
    return y, aux


def moe_mlp_ep(x2d: jax.Array, p: dict, spec: MoESpec, act: str,
               mesh, dp: tuple[str, ...]):
    """Expert-parallel dispatch via shard_map + all_to_all (the production
    EP pattern): per-shard routing/sort/capacity, one all_to_all to move
    token slots to their expert's shard, local expert matmuls (experts
    stay TP-sharded on the auto ``model`` axis), and the reverse
    all_to_all.  No global sort, no replicated dispatch buffers — this is
    what lets the MoE train/prefill cells fit HBM (EXPERIMENTS.md §Perf).
    """
    import numpy as _np
    T, D = x2d.shape
    E, K = spec.n_experts, spec.top_k
    ndp = int(_np.prod([mesh.shape[a] for a in dp]))
    E_loc = E // ndp
    T_loc = T // ndp
    C = int(math.ceil(T_loc * K / E * spec.capacity_factor))
    C = max(4, -(-C // 4) * 4)

    def local(x_loc, router, experts):
        logits = x_loc.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_ids = jax.lax.top_k(probs, K)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
        flat_ids = gate_ids.reshape(-1)
        flat_w = gate_w.reshape(-1)
        order = jnp.argsort(flat_ids, stable=True)
        sorted_ids = flat_ids[order]
        seg_start = jnp.searchsorted(sorted_ids, jnp.arange(E), side="left")
        pos = jnp.arange(T_loc * K) - seg_start[sorted_ids]
        keep = pos < C
        dest = jnp.where(keep, sorted_ids * C + pos, E * C)
        src_tok = order // K

        buf = jnp.zeros((E * C, D), x2d.dtype).at[dest].set(
            x_loc[src_tok], mode="drop")
        # -> expert shards: (ndp, E_loc*C, D), dim0 = destination shard
        send = buf.reshape(ndp, E_loc * C, D)
        recv = jax.lax.all_to_all(send, dp, 0, 0)  # dim0 = source shard
        ein = recv.reshape(ndp, E_loc, C, D).transpose(1, 0, 2, 3) \
            .reshape(E_loc, ndp * C, D)
        eout = expert_apply(ein, experts, act)
        back = eout.reshape(E_loc, ndp, C, D).transpose(1, 0, 2, 3) \
            .reshape(ndp, E_loc * C, D)
        got = jax.lax.all_to_all(back, dp, 0, 0).reshape(E * C, D)

        contrib = got[jnp.where(keep, dest, 0)]
        w = (flat_w[order] * keep).astype(x2d.dtype)
        y = jnp.zeros((T_loc, D), x2d.dtype).at[src_tok].add(
            contrib * w[:, None])

        top1 = gate_ids[:, 0]
        f_e = jax.lax.pmean(
            jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0), dp)
        p_e = jax.lax.pmean(jnp.mean(probs, axis=0), dp)
        lb = E * jnp.sum(f_e * p_e)
        zl = jax.lax.pmean(
            jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))), dp)
        dropped = jax.lax.pmean(jnp.mean(1.0 - keep.astype(jnp.float32)), dp)
        counts = jax.lax.psum(
            jnp.zeros((E,), jnp.float32)
            .at[sorted_ids].add(keep.astype(jnp.float32), mode="drop"), dp)
        return y, lb, zl, dropped, counts

    in_specs = (P(dp, None), P(None, None), {
        k: P(dp, None, None) for k in p["experts"]
    })
    out_specs = (P(dp, None), P(), P(), P(), P())
    if hasattr(jax, "shard_map"):
        smap = jax.shard_map(local, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(dp))
    else:  # jax 0.4.x: no partial-manual axes; every axis is manual, so
        # outputs replicated over the unmentioned model axis need
        # check_rep off (they are replicated by construction).
        from jax.experimental.shard_map import shard_map as _shard_map
        smap = _shard_map(local, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    y, lb, zl, dropped, counts = smap(x2d, p["router"], p["experts"])
    if spec.n_shared:
        y = y + mlp_apply(x2d, p["shared"], act)
    return y, {"lb_loss": lb, "z_loss": zl, "dropped": dropped,
               "expert_counts": counts}


def _ep_context():
    """(mesh, dp_axes) from the installed activation policy, if EP is on."""
    from repro.models.common import current_policy
    pol = current_policy()
    if pol is None:
        return None
    return pol.get("_ep")


def routed_mlp(x2d: jax.Array, p: dict, spec: MoESpec, act: str):
    """EP shard_map dispatch when a mesh policy provides it; else the
    single-device/auto-spmd path."""
    ep = _ep_context()
    if ep is not None:
        mesh, dp = ep
        import numpy as _np
        ndp = int(_np.prod([mesh.shape[a] for a in dp]))
        if spec.n_experts % ndp == 0 and x2d.shape[0] % ndp == 0:
            return moe_mlp_ep(x2d, p, spec, act, mesh, dp)
    return moe_mlp(x2d, p, spec, act)


def moe_layer_params(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    spec = cfg.moe
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "attn": attn.attn_params(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, dt, cfg.qkv_bias,
        ),
        "ln2": norm_params(cfg.d_model, cfg.norm, jnp.float32),
        "router": he(k2, (cfg.d_model, spec.n_experts), jnp.float32),
        "experts": expert_params(
            k3, spec.n_experts, cfg.d_model, spec.expert_d_ff, cfg.act, dt
        ),
    }
    if spec.n_shared:
        # n parallel shared experts == one MLP with n*f hidden units
        p["shared"] = mlp_params(
            k4, cfg.d_model, spec.n_shared * (spec.shared_d_ff or spec.expert_d_ff),
            cfg.act, dt,
        )
    return p


def _moe_layer_fwd(cfg: ArchConfig, x, lp, positions):
    h = apply_norm(x, lp["ln1"], cfg.norm)
    h = attn.attention(
        h, lp["attn"],
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, positions=positions,
        causal=True, window=cfg.local_window,
        rope_theta=cfg.rope_theta, rope_pct=cfg.rope_pct, use_rope=cfg.rope,
    )
    x = x + h
    h = apply_norm(x, lp["ln2"], cfg.norm)
    B, S, D = h.shape
    y, aux = routed_mlp(h.reshape(B * S, D), lp, cfg.moe, cfg.act)
    x = x + maybe_shard(y.reshape(B, S, D), "act_btd")
    return x, aux


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
def _unit_structure(cfg: ArchConfig) -> tuple[int, int, bool]:
    """(n_head_dense, n_units, unit_has_dense)."""
    spec = cfg.moe
    every = spec.every
    n_head = spec.first_dense
    rest = cfg.n_layers - n_head
    assert rest % every == 0, "layer count must fit the MoE pattern"
    return n_head, rest // every, every == 2


def init(cfg: ArchConfig, key) -> dict:
    dt = dtype_of(cfg.param_dtype)
    ke, kd, ku, kh = jax.random.split(key, 4)
    n_head, n_units, has_dense = _unit_structure(cfg)
    params = {
        "embed": embed_init(ke, cfg.vocab_padded, cfg.d_model, dt),
        "final_norm": norm_params(cfg.d_model, cfg.norm, jnp.float32),
    }
    if n_head:
        hk = jax.random.split(kd, n_head)
        params["head_dense"] = jax.vmap(lambda k: dense.init_layer(cfg, k))(hk)
    uk = jax.random.split(ku, n_units)
    unit = {"moe": jax.vmap(lambda k: moe_layer_params(cfg, k))(
        jax.vmap(lambda k: jax.random.fold_in(k, 1))(uk))}
    if has_dense:
        unit["dense"] = jax.vmap(lambda k: dense.init_layer(cfg, k))(
            jax.vmap(lambda k: jax.random.fold_in(k, 0))(uk))
    params["units"] = unit
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(kh, cfg.vocab_padded, cfg.d_model, dt).T
    return params


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array, *,
            prefix_embeds=None, remat: bool = False, last_only: bool = False):
    logits, _aux = forward_with_aux(
        cfg, params, tokens, prefix_embeds=prefix_embeds, remat=remat,
        last_only=last_only,
    )
    return logits


def forward_with_aux(cfg: ArchConfig, params: dict, tokens: jax.Array, *,
                     prefix_embeds=None, remat: bool = False,
                     last_only: bool = False):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x[:, P:]], axis=1)
    x = maybe_shard(x, "act_btd")
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    dense_body = partial(dense._layer_fwd, cfg)
    moe_body = partial(_moe_layer_fwd, cfg)
    if remat:
        dense_body = jax.checkpoint(dense_body)
        moe_body = jax.checkpoint(moe_body)

    if "head_dense" in params:
        def head_fn(x, lp):
            return dense_body(x, lp, positions), None
        x, _ = jax.lax.scan(head_fn, x, params["head_dense"])

    has_dense = "dense" in params["units"]

    def unit_fn(carry, up):
        x, lb, zl = carry
        if has_dense:
            x = dense_body(x, up["dense"], positions)
        x, aux = moe_body(x, up["moe"], positions)
        return (x, lb + aux["lb_loss"], zl + aux["z_loss"]), \
            (aux["dropped"], aux["expert_counts"])

    (x, lb, zl), (dropped, counts) = jax.lax.scan(
        unit_fn, (x, jnp.float32(0), jnp.float32(0)), params["units"]
    )
    if last_only:
        x = x[:, -1:]
    x = apply_norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = maybe_shard(x @ head, "act_btv")
    n_units = jax.tree_util.tree_leaves(params["units"])[0].shape[0]
    aux = {
        "lb_loss": lb / n_units,
        "z_loss": zl / n_units,
        "dropped": jnp.mean(dropped),
        # summed over the scanned units: (E,) dispatch histogram for the
        # hotness ledger (HotnessLedger.record).
        "expert_counts": counts.sum(axis=0),
    }
    return logits, aux


def loss(cfg: ArchConfig, params: dict, batch: dict, *, remat: bool = False):
    logits, aux = forward_with_aux(
        cfg, params, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"), remat=remat,
    )
    nll = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return nll + 0.01 * aux["lb_loss"] + cfg.moe.router_zloss * aux["z_loss"]


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
init_cache = dense.init_cache  # same KV layout (uniform attention stack)


def decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens: jax.Array,
                *, unroll: bool = False):
    """One decode step; fori over the repeating (dense?, moe) unit, or a
    python unroll (``unroll=True``) when the KV cache is large enough that
    the while-loop carry double-buffer matters."""
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache["len"]
    n_head, n_units, has_dense = _unit_structure(cfg)
    every = cfg.moe.every

    def attn_step(x, lp, kc, vc):
        h = apply_norm(x[:, None], lp["ln1"], cfg.norm)[:, 0]
        h, kc, vc = attn.decode_attention(
            h, lp["attn"], kc, vc, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, positions=pos,
            rope_theta=cfg.rope_theta, rope_pct=cfg.rope_pct,
            use_rope=cfg.rope, window=cfg.local_window,
        )
        return x + h, kc, vc

    def dense_step(x, lp, kc, vc):
        x, kc, vc = attn_step(x, lp, kc, vc)
        h = apply_norm(x[:, None], lp["ln2"], cfg.norm)[:, 0]
        return x + mlp_apply(h, lp["mlp"], cfg.act), kc, vc

    def moe_step(x, lp, kc, vc):
        x, kc, vc = attn_step(x, lp, kc, vc)
        h = apply_norm(x[:, None], lp["ln2"], cfg.norm)[:, 0]
        y, _aux = routed_mlp(h, lp, cfg.moe, cfg.act)
        return x + y, kc, vc

    # fori + in-place dynamic updates keep the (L,B,T,K,hd) cache a single
    # donated buffer (a scan would double-buffer its carry).
    def _idx(tree, i):
        return jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False), tree)

    def _layer(carry, li, lp, step):
        x, kc, vc = carry
        ki = jax.lax.dynamic_index_in_dim(kc, li, 0, False)
        vi = jax.lax.dynamic_index_in_dim(vc, li, 0, False)
        x, k2, v2 = step(x, lp, ki, vi)
        kc = jax.lax.dynamic_update_index_in_dim(kc, k2.astype(kc.dtype), li, 0)
        vc = jax.lax.dynamic_update_index_in_dim(vc, v2.astype(vc.dtype), li, 0)
        return x, kc, vc

    carry = (x, cache["k"], cache["v"])
    if n_head:
        def head_body(i, carry):
            return _layer(carry, i, _idx(params["head_dense"], i), dense_step)
        carry = jax.lax.fori_loop(0, n_head, head_body, carry)

    def unit_body(u, carry):
        li = n_head + u * every
        if has_dense:
            carry = _layer(carry, li, _idx(params["units"]["dense"], u), dense_step)
            li = li + 1
        return _layer(carry, li, _idx(params["units"]["moe"], u), moe_step)

    if unroll:
        for u in range(n_units):
            carry = unit_body(u, carry)
        x, k_all, v_all = carry
    else:
        x, k_all, v_all = jax.lax.fori_loop(0, n_units, unit_body, carry)
    x = apply_norm(x[:, None], params["final_norm"], cfg.norm)[:, 0]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, {"k": k_all, "v": v_all, "len": cache["len"] + 1}
