"""Sharded, async, integrity-checked checkpointing.

Layout: ``<dir>/step_<N>/`` with one ``.npy`` per leaf (keyed by the
flattened tree path) plus ``index.json`` carrying the treedef, shapes,
dtypes, crc32 digests, and user metadata (data cursor, rng, mesh shape).
Writes run on a background thread against host snapshots so the train
loop never blocks (async checkpointing = overlap guideline); ``restore``
verifies digests.  ``keep`` bounds retained checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _flatten(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return {(_path_str(p)): v for p, v in leaves}


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, asynchronous: bool = True):
        self.directory = directory
        self.keep = keep
        self.asynchronous = asynchronous
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> None:
        self.wait()
        # Snapshot to host memory synchronously (cheap vs. disk I/O).
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        meta = dict(metadata or {})
        if self.asynchronous:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat, meta)

    def _write(self, step: int, flat: dict, meta: dict) -> None:
        try:
            tmp = os.path.join(self.directory, f".tmp_step_{step}")
            final = os.path.join(self.directory, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            index = {"step": step, "metadata": meta, "leaves": {}}
            for i, (key, arr) in enumerate(sorted(flat.items())):
                fname = f"leaf_{i:05d}.npy"
                np.save(os.path.join(tmp, fname), arr)
                index["leaves"][key] = {
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
                }
            with open(os.path.join(tmp, "index.json"), "w") as f:
                json.dump(index, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(self.available_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def available_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                out.append(int(name.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> tuple[int, Any, dict]:
        """Restore into the structure of ``template``; verifies digests."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "index.json")) as f:
            index = json.load(f)
        loaded = {}
        for key, info in index["leaves"].items():
            arr = np.load(os.path.join(d, info["file"]))
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != info["crc32"]:
                raise IOError(f"checkpoint corruption in {key}")
            loaded[key] = arr
        paths = jax.tree_util.tree_leaves_with_path(template)
        leaves = []
        for p, tmpl in paths:
            key = _path_str(p)
            if key not in loaded:
                raise KeyError(f"missing leaf {key} in checkpoint")
            arr = loaded[key]
            if isinstance(tmpl, jax.Array):
                leaves.append(jax.numpy.asarray(arr).astype(tmpl.dtype))
            elif hasattr(tmpl, "dtype"):
                leaves.append(np.asarray(arr).astype(tmpl.dtype))
            else:
                leaves.append(arr)
        tdef = jax.tree_util.tree_structure(template)
        return step, tdef.unflatten(leaves), index["metadata"]
