"""AdamW with fp32 moments, global-norm clipping, decoupled weight decay."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None

    def lr_at(self, step: jax.Array) -> jax.Array:
        base = jnp.asarray(self.lr, jnp.float32)
        return base * self.schedule(step) if self.schedule else base


def init_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)
    ))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def moment_update(g, mu, nu, b1, b2):
    gf = g.astype(jnp.float32)
    return b1 * mu + (1 - b1) * gf, b2 * nu + (1 - b2) * gf * gf


def param_update(p, mu_hat, nu_hat, lr, eps, wd):
    pf = p.astype(jnp.float32)
    upd = mu_hat / (jnp.sqrt(nu_hat) + eps) + wd * pf
    return (pf - lr * upd).astype(p.dtype)


def apply(cfg: AdamWConfig, params, grads, state) -> tuple[dict, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cfg.lr_at(step)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        mu, nu = moment_update(g, mu, nu, cfg.b1, cfg.b2)
        new_p = param_update(p, mu / c1, nu / c2, lr, cfg.eps, cfg.weight_decay)
        return new_p, mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {
        "step": step,
        "mu": tdef.unflatten([o[1] for o in out]),
        "nu": tdef.unflatten([o[2] for o in out]),
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
