"""Gradient compression with error feedback for the cross-pod (DCN) hop.

The ``pod`` mesh axis crosses the data-center network, where bandwidth
(~12.5 GB/s/host) is ~50x scarcer than ICI — the distributed-system
twin of the paper's CXL link.  int8 per-tensor-scaled quantization with
an error-feedback residual keeps the DCN all-reduce 4x smaller (bf16->
int8x2 round trip) without biasing convergence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, residual: jax.Array):
    """Error-feedback compression of one gradient tensor.

    Returns (q, scale, new_residual): ``dequant(q)*scale + new_residual ==
    g + residual`` (up to rounding of the carried term).
    """
    corrected = g.astype(jnp.float32) + residual
    q, scale = quantize_int8(corrected)
    new_residual = corrected - dequantize_int8(q, scale)
    return q, scale, new_residual


def init_residuals(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads
    )


def cross_axis_mean_compressed(grads, residuals, axis_name: str):
    """Mean-reduce grads over ``axis_name`` with int8 + error feedback.

    Must run inside shard_map with ``axis_name`` bound.  The int8 payload
    is what crosses the wire; scales (one fp32 per tensor) ride along.
    """
    def one(g, r):
        q, scale, new_r = compress_with_feedback(g, r)
        # int8 payloads sum without overflow in int32
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_sum = jax.lax.psum(scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        # each shard used its own scale; use the mean scale for dequant
        mean = summed.astype(jnp.float32) * (scale_sum / n) / n
        return mean.astype(g.dtype), new_r

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        tdef.unflatten([o[0] for o in out]),
        tdef.unflatten([o[1] for o in out]),
    )
