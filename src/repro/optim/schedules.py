"""LR schedules (multiplicative factors on the base LR)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(warmup_steps: int, total_steps: int, min_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)
    return f


def warmup_linear(warmup_steps: int, total_steps: int, min_frac: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        return jnp.where(s < warmup_steps, warm, 1 - (1 - min_frac) * prog)
    return f


def constant():
    return lambda step: jnp.ones((), jnp.float32)
