"""Tiered optimizer-state offload — the paper's technique in the optimizer.

AdamW moments (+ fp32 master weights) for a planner-chosen subset of
parameters live on the slow tier (host DRAM behind PCIe — the CXL
analogue) as flat fp32 pages.  Each step, pages stream through the
BulkMover (batched, double-buffered, writer-limited — §6 guidelines) to
a fixed-shape jitted page-update program, and stream back; the bf16
device copy of each offloaded parameter is reassembled from the updated
master pages.  This is what makes llama4-maverick-400B (4.8 TB of
optimizer state) trainable on 512 x 16 GiB chips.

Access pattern justification (classifier): optimizer state is touched
once per step, sequentially, in page granularity, with zero dependent
chaining — the definition of a bandwidth-bound, slow-tier-tolerant
buffer (§6.1).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mover import BulkMover, Descriptor, double_buffer
from repro.core.telemetry import GLOBAL_TELEMETRY
from repro.optim import adamw

PAGE_ELEMS = 1 << 20  # 4 MiB fp32 pages
QBLOCK = 256  # block size for int8 moment quantization
#: consecutive page writebacks coalesced into one mover descriptor (§6
#: descriptor batching: the drain pool handles O(pages / RUN) payloads
#: per step instead of one per page).
WRITEBACK_RUN_PAGES = 8


@dataclasses.dataclass
class OffloadedLeaf:
    """Host-resident optimizer state for one parameter.

    With ``quantized`` moments, mu/nu live as int8 + per-block fp32
    scales (block-wise absmax, 8-bit-Adam style) — 4x less host DRAM and
    4x less PCIe traffic per step (EXPERIMENTS.md §Perf, llama4 tier
    iteration)."""

    shape: tuple
    dtype: np.dtype
    n_pages: int
    size: int
    master: np.ndarray  # (n_pages * PAGE, ) fp32
    mu: np.ndarray  # fp32, or int8 when quantized
    nu: np.ndarray
    quantized: bool = False
    mu_scale: Optional[np.ndarray] = None  # (n_pages * PAGE / QBLOCK,) fp32
    nu_scale: Optional[np.ndarray] = None
    #: slow device holding this leaf's pages (tier name for routing —
    #: multi-device topologies spread leaves across their CXL pool).
    device: str = "host"


def _q_moments(x: jax.Array, *, sqrt_domain: bool = False
               ) -> tuple[jax.Array, jax.Array]:
    """Block-wise symmetric int8: x (N,) -> (q (N,) int8, scale (N/QB,)).

    ``sqrt_domain`` stores sqrt(x) (for the non-negative second moment:
    compresses the dynamic range so small nu entries survive int8)."""
    xq = jnp.sqrt(jnp.maximum(x, 0.0)) if sqrt_domain else x
    blocks = xq.reshape(-1, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-20) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def _dq_moments(q: jax.Array, scale: jax.Array, *, sqrt_domain: bool = False
                ) -> jax.Array:
    x = (q.reshape(-1, QBLOCK).astype(jnp.float32)
         * scale[:, None]).reshape(-1)
    return jnp.square(x) if sqrt_domain else x


@partial(jax.jit, donate_argnums=(0, 2, 3),
         static_argnames=("b1", "b2", "eps", "wd"))
def _page_update(master, grad_page, mu, nu, scale, lr, c1, c2,
                 *, b1, b2, eps, wd):
    """Fixed-shape fused AdamW on one fp32 page. All (PAGE,) fp32."""
    g = grad_page.astype(jnp.float32) * scale
    mu = b1 * mu + (1 - b1) * g
    nu = b2 * nu + (1 - b2) * g * g
    upd = (mu / c1) / (jnp.sqrt(nu / c2) + eps) + wd * master
    master = master - lr * upd
    return master, mu, nu


def _flat_pages(x: np.ndarray) -> tuple[np.ndarray, int]:
    flat = np.asarray(x, np.float32).ravel()
    n_pages = max(1, -(-flat.size // PAGE_ELEMS))
    out = np.zeros(n_pages * PAGE_ELEMS, np.float32)
    out[: flat.size] = flat
    return out, n_pages


class TieredAdamW:
    """AdamW with planner-directed moment/master offload to the slow tier."""

    def __init__(
        self,
        cfg: adamw.AdamWConfig,
        *,
        slow_fraction: float = 0.0,
        slow_weights: Optional[Sequence[float]] = None,
        slow_device_names: Optional[Sequence[str]] = None,
        mover: Optional[BulkMover] = None,
        min_offload_bytes: int = 1 << 20,
        quantize_moments: bool = False,
        telemetry=GLOBAL_TELEMETRY,
        source: str = "opt_state",
    ):
        self.cfg = cfg
        # ``slow_weights`` is the N-device form: per-slow-device shares of
        # the moment bytes (summing to the total slow fraction).  The
        # scalar ``slow_fraction`` remains the two-device shorthand.
        if slow_weights is not None:
            slow_fraction = float(sum(slow_weights))
        self.slow_fraction = slow_fraction
        self.slow_weights = (tuple(float(w) for w in slow_weights)
                             if slow_weights is not None else None)
        # Without a mover the routes are modeled; real device names can
        # still be supplied so per-device telemetry (and the arbiter's
        # device budgets, which are keyed by tier name) stay meaningful.
        self.slow_device_names = (tuple(slow_device_names)
                                  if slow_device_names else None)
        self.mover = mover
        self.min_offload_bytes = min_offload_bytes
        self.quantize_moments = quantize_moments
        self.telemetry = telemetry
        # Buffer name this optimizer's slow-tier traffic is billed to
        # (CaptionArbiter source attribution).
        self.source = source

    # -- placement ----------------------------------------------------------
    def _slow_device_names(self) -> tuple[str, ...]:
        if self.mover is not None and self.mover.topology.slows:
            return self.mover.topology.slow_names
        if self.slow_device_names:
            return self.slow_device_names
        return ("host",)

    def _fast_name(self) -> str:
        if self.mover is not None:
            return self.mover.topology.fast.name
        return "hbm"

    def choose_offloaded(self, params) -> list[tuple]:
        """Greedy knapsack: largest params spill first until the target
        fraction of moment bytes is host-resident."""
        leaves = jax.tree_util.tree_leaves_with_path(params)
        total = sum(x.size for _, x in leaves)
        target = total * self.slow_fraction
        picked, acc = [], 0
        for path, x in sorted(leaves, key=lambda kv: -kv[1].size):
            if acc >= target:
                break
            if x.size * 4 < self.min_offload_bytes:
                continue
            picked.append(path)
            acc += x.size
        return picked

    def assign_devices(self, params, picked) -> dict[str, str]:
        """Distribute the offloaded leaves across the slow devices.

        Greedy largest-first fill against per-device byte targets set by
        ``slow_weights`` (bandwidth-proportional when seeded from the
        planner) — the Fig. 10 discipline applied to optimizer pages."""
        names = self._slow_device_names()
        sizes = {str(p): x.size
                 for p, x in jax.tree_util.tree_leaves_with_path(params)}
        keys = sorted((str(p) for p in picked),
                      key=lambda k: -sizes.get(k, 0))
        if len(names) == 1 or not self.slow_weights:
            return {k: names[0] for k in keys}
        w = list(self.slow_weights[: len(names)])
        w += [0.0] * (len(names) - len(w))
        total_w = sum(w) or 1.0
        total_b = sum(sizes.get(k, 0) for k in keys)
        remaining = [total_b * x / total_w for x in w]
        out = {}
        for k in keys:
            i = max(range(len(names)), key=lambda j: remaining[j])
            out[k] = names[i]
            remaining[i] -= sizes.get(k, 0)
        return out

    # -- state --------------------------------------------------------------
    def init(self, params) -> dict:
        picked = self.choose_offloaded(params)
        offloaded_paths = set(map(str, picked))
        fast_tree = jax.tree_util.tree_map_with_path(
            lambda p, x: None if str(p) in offloaded_paths else x, params,
            is_leaf=lambda x: x is None,
        )
        fast_params = {"_": fast_tree}
        state = {
            "step": jnp.zeros((), jnp.int32),
            "fast": {
                "mu": jax.tree_util.tree_map(
                    lambda x: None if x is None else jnp.zeros(x.shape, jnp.float32),
                    fast_tree, is_leaf=lambda x: x is None),
                "nu": jax.tree_util.tree_map(
                    lambda x: None if x is None else jnp.zeros(x.shape, jnp.float32),
                    fast_tree, is_leaf=lambda x: x is None),
            },
            "slow": {},
        }
        devmap = self.assign_devices(params, picked)
        for path, x in jax.tree_util.tree_leaves_with_path(params):
            if str(path) in offloaded_paths:
                master, n_pages = _flat_pages(np.asarray(x, np.float32))
                device = devmap.get(str(path), self._slow_device_names()[0])
                if self.quantize_moments:
                    n_blocks = master.size // QBLOCK
                    state["slow"][str(path)] = OffloadedLeaf(
                        shape=tuple(x.shape), dtype=np.dtype(str(x.dtype)),
                        n_pages=n_pages, size=x.size, master=master,
                        mu=np.zeros(master.size, np.int8),
                        nu=np.zeros(master.size, np.int8),
                        quantized=True,
                        mu_scale=np.zeros(n_blocks, np.float32),
                        nu_scale=np.zeros(n_blocks, np.float32),
                        device=device,
                    )
                else:
                    state["slow"][str(path)] = OffloadedLeaf(
                        shape=tuple(x.shape), dtype=np.dtype(str(x.dtype)),
                        n_pages=n_pages, size=x.size,
                        master=master,
                        mu=np.zeros_like(master), nu=np.zeros_like(master),
                        device=device,
                    )
        return state

    def repartition_weights(self, params, state, weights: Sequence[float],
                            **kwargs) -> dict:
        """Re-tier to a per-slow-device weight vector (N-device Caption
        actuation): total offload = sum(weights); newly offloaded leaves
        land on devices per the vector."""
        self.slow_weights = tuple(float(w) for w in weights)
        return self.repartition(params, state, float(sum(weights)), **kwargs)

    def repartition(self, params, state, new_fraction: float, *,
                    mover: Optional[BulkMover] = None,
                    fast_tier: Optional[str] = None,
                    slow_tier: Optional[str] = None) -> dict:
        """Re-tier optimizer state to ``new_fraction``, moving only the
        leaves that actually transition (the Caption actuation path for
        opt-state buffers).

        Newly offloaded leaves serialize master+moments to host pages;
        reclaimed leaves rebuild device moments from their pages.  Leaves
        on the same side are untouched, so inter-tier traffic is exactly
        the transitioned bytes (through the BulkMover when given, else
        accounted to telemetry).  Returns the new state; ``params`` are
        unchanged (the master pages were written from them and vice versa).
        """
        mover = mover if mover is not None else self.mover
        if mover is not None:  # tier names must exist in the mover's topology
            fast_tier = fast_tier or mover.topology.fast.name
            slow = mover.topology.slow
            slow_tier = slow_tier or (slow.name if slow else fast_tier)
        else:
            fast_tier = fast_tier or "hbm"
            slow_tier = slow_tier or "host"
        self.slow_fraction = new_fraction
        new_paths = set(map(str, self.choose_offloaded(params)))
        old_paths = set(state["slow"])
        devmap = self.assign_devices(params, sorted(new_paths))
        names = self._slow_device_names()
        if new_paths == old_paths and all(
                state["slow"][k].device == devmap.get(
                    k, state["slow"][k].device)
                for k in old_paths):
            return state
        mu_map = {str(p): x for p, x in jax.tree_util.tree_flatten_with_path(
            state["fast"]["mu"], is_leaf=lambda x: x is None)[0]}
        nu_map = {str(p): x for p, x in jax.tree_util.tree_flatten_with_path(
            state["fast"]["nu"], is_leaf=lambda x: x is None)[0]}
        slow: dict[str, OffloadedLeaf] = dict(state["slow"])
        moved_down = moved_up = 0

        for path, x in jax.tree_util.tree_leaves_with_path(params):
            key = str(path)
            if key in new_paths and key not in old_paths:
                # fast -> slow: page out master (from params) + moments.
                device = devmap.get(key, names[0])
                if device not in names and slow_tier in names:
                    device = slow_tier
                master, n_pages = _flat_pages(np.asarray(x, np.float32))
                mu_flat, _ = _flat_pages(np.asarray(mu_map[key], np.float32))
                nu_flat, _ = _flat_pages(np.asarray(nu_map[key], np.float32))
                if self.quantize_moments:
                    qmu, smu = _q_moments(jnp.asarray(mu_flat))
                    qnu, snu = _q_moments(jnp.asarray(nu_flat),
                                          sqrt_domain=True)
                    slow[key] = OffloadedLeaf(
                        shape=tuple(x.shape), dtype=np.dtype(str(x.dtype)),
                        n_pages=n_pages, size=x.size, master=master,
                        mu=np.asarray(qmu), nu=np.asarray(qnu),
                        quantized=True, mu_scale=np.asarray(smu),
                        nu_scale=np.asarray(snu), device=device)
                else:
                    slow[key] = OffloadedLeaf(
                        shape=tuple(x.shape), dtype=np.dtype(str(x.dtype)),
                        n_pages=n_pages, size=x.size, master=master,
                        mu=mu_flat, nu=nu_flat, device=device)
                mu_map[key] = nu_map[key] = None
                nbytes = master.nbytes + slow[key].mu.nbytes + slow[key].nu.nbytes
                moved_down += nbytes
                dst = device if mover is not None or device != names[0] \
                    else slow_tier
                self._record_move(fast_tier, dst if dst else slow_tier,
                                  nbytes, mover,
                                  (jnp.asarray(master),
                                   jnp.asarray(slow[key].mu),
                                   jnp.asarray(slow[key].nu)))
            elif key in old_paths and key not in new_paths:
                # slow -> fast: rebuild device moments from the host pages.
                leaf = slow.pop(key)
                if leaf.quantized:
                    mu_flat = np.asarray(_dq_moments(
                        jnp.asarray(leaf.mu), jnp.asarray(leaf.mu_scale)))
                    nu_flat = np.asarray(_dq_moments(
                        jnp.asarray(leaf.nu), jnp.asarray(leaf.nu_scale),
                        sqrt_domain=True))
                else:
                    mu_flat, nu_flat = leaf.mu, leaf.nu
                mu_map[key] = jnp.asarray(
                    mu_flat[: leaf.size].reshape(leaf.shape), jnp.float32)
                nu_map[key] = jnp.asarray(
                    nu_flat[: leaf.size].reshape(leaf.shape), jnp.float32)
                nbytes = leaf.master.nbytes + leaf.mu.nbytes + leaf.nu.nbytes
                moved_up += nbytes
                src = (leaf.device if mover is not None
                       or leaf.device != names[0] else slow_tier)
                self._record_move(src if src else slow_tier, fast_tier,
                                  nbytes, mover,
                                  (jnp.asarray(leaf.master),
                                   jnp.asarray(leaf.mu),
                                   jnp.asarray(leaf.nu)))
            elif key in old_paths:
                # staying offloaded, but the weight vector reassigned its
                # device: ship the pages on the slow->slow (C2C) route so
                # a device-share-only adjustment actually actuates.
                leaf = slow[key]
                want = devmap.get(key, leaf.device)
                if want != leaf.device and want in names:
                    nbytes = (leaf.master.nbytes + leaf.mu.nbytes
                              + leaf.nu.nbytes)
                    self._record_move(leaf.device, want, nbytes, mover,
                                      (jnp.asarray(leaf.master),
                                       jnp.asarray(leaf.mu),
                                       jnp.asarray(leaf.nu)))
                    slow[key] = dataclasses.replace(leaf, device=want)
        if mover is not None and mover.asynchronous:
            mover.wait_all()
        self.telemetry.bump("caption.opt_repartitions")
        self.telemetry.bump("caption.opt_bytes_down", moved_down)
        self.telemetry.bump("caption.opt_bytes_up", moved_up)
        fast_mu = jax.tree_util.tree_map_with_path(
            lambda p, x: mu_map[str(p)], params)
        fast_nu = jax.tree_util.tree_map_with_path(
            lambda p, x: nu_map[str(p)], params)
        return {"step": state["step"],
                "fast": {"mu": fast_mu, "nu": fast_nu},
                "slow": slow}

    def _record_move(self, src: str, dst: str, nbytes: int,
                     mover: Optional[BulkMover], payload) -> None:
        if mover is not None:
            mover.submit([Descriptor(src, dst, payload, source=self.source)])
        else:
            self.telemetry.record_move(src, dst, nbytes, 0.0,
                                       source=self.source)

    def _leaf_dst(self, leaf: OffloadedLeaf) -> str:
        """Routing name for a leaf's pages (valid in the mover's topology)."""
        names = self._slow_device_names()
        return leaf.device if leaf.device in names else names[0]

    def achieved_weights(self, params, state) -> tuple[float, ...]:
        """Per-slow-device share of param elements actually offloaded —
        the operating point to feed back to the controller
        (``actuated_weights``): leaf granularity rounds the request, and
        the walk must continue from what the system really runs."""
        names = self._slow_device_names()
        total = sum(x.size for x in jax.tree_util.tree_leaves(params))
        per = {n: 0 for n in names}
        for leaf in state["slow"].values():
            per[leaf.device if leaf.device in per else names[0]] += leaf.size
        return tuple(per[n] / max(total, 1) for n in names)

    def host_bytes(self, state) -> int:
        return sum(
            leaf.master.nbytes + leaf.mu.nbytes + leaf.nu.nbytes
            for leaf in state["slow"].values()
        )

    def host_bytes_by_device(self, state) -> dict[str, int]:
        """Slow-tier residency per device (capacity accounting)."""
        out: dict[str, int] = {}
        for leaf in state["slow"].values():
            b = leaf.master.nbytes + leaf.mu.nbytes + leaf.nu.nbytes
            out[leaf.device] = out.get(leaf.device, 0) + b
        return out

    def traffic_per_step_bytes(self, state) -> int:
        """Host<->device bytes each step (reads + writes), for the roofline
        tier term (nt-store path: no RFO): fp32 master + fp32-or-int8
        moments, each direction."""
        total = 0
        for l in state["slow"].values():
            elems = l.n_pages * PAGE_ELEMS
            moment_b = 1 + 4 / QBLOCK if l.quantized else 4
            total += int(elems * (4 + 2 * moment_b) * 2)
        return total

    # -- step ---------------------------------------------------------------
    def step(self, params, grads, state) -> tuple[dict, dict, dict]:
        gnorm = adamw.global_norm(grads)
        scale = jnp.minimum(1.0, self.cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        step = state["step"] + 1
        sf = step.astype(jnp.float32)
        lr = self.cfg.lr_at(step)
        c1 = 1.0 - self.cfg.b1 ** sf
        c2 = 1.0 - self.cfg.b2 ** sf

        slow_paths = set(state["slow"])
        flat, tdef = jax.tree_util.tree_flatten_with_path(params)
        flat_g = [g for _, g in jax.tree_util.tree_leaves_with_path(grads)]
        flat_mu = [m for _, m in jax.tree_util.tree_leaves_with_path(state["fast"]["mu"])] \
            if False else None  # fast moments aligned below

        # --- fast subset: fused jit update ---------------------------------
        new_leaves = {}
        mu_map = dict(jax.tree_util.tree_flatten_with_path(
            state["fast"]["mu"], is_leaf=lambda x: x is None)[0])
        nu_map = dict(jax.tree_util.tree_flatten_with_path(
            state["fast"]["nu"], is_leaf=lambda x: x is None)[0])
        new_mu, new_nu = {}, {}
        for (path, p), g in zip(flat, flat_g):
            key = str(path)
            if key in slow_paths:
                continue
            mu, nu = mu_map[path], nu_map[path]
            gf = g.astype(jnp.float32) * scale
            mu = self.cfg.b1 * mu + (1 - self.cfg.b1) * gf
            nu = self.cfg.b2 * nu + (1 - self.cfg.b2) * gf * gf
            upd = (mu / c1) / (jnp.sqrt(nu / c2) + self.cfg.eps) \
                + self.cfg.weight_decay * p.astype(jnp.float32)
            new_leaves[key] = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            new_mu[path], new_nu[path] = mu, nu

        # --- slow subset: paged streaming update ---------------------------
        bytes_moved = 0
        dev_bytes: dict[str, int] = {}
        for (path, p), g in zip(flat, flat_g):
            key = str(path)
            if key not in slow_paths:
                continue
            leaf = state["slow"][key]
            g_flat = jnp.ravel(g)
            pad = leaf.n_pages * PAGE_ELEMS - leaf.size
            if pad:
                g_flat = jnp.concatenate([g_flat, jnp.zeros((pad,), g_flat.dtype)])
            out_pages = [None] * leaf.n_pages

            blocks_per_page = PAGE_ELEMS // QBLOCK

            # Run-coalesced writebacks: consecutive page commits for this
            # leaf accumulate and ship as ONE batched descriptor every
            # WRITEBACK_RUN_PAGES pages (billed bytes unchanged; the
            # commit closures still patch their own host slices).
            pending: list[tuple] = []

            def flush_writebacks(leaf=leaf):
                if not pending:
                    return
                payloads = [p for p, _ in pending]
                commits = tuple(c for _, c in pending)

                def on_done(res, commits=commits):
                    for c in commits:
                        c(res)

                self.mover.submit([Descriptor(
                    self._fast_name(), self._leaf_dst(leaf), payloads,
                    on_done=on_done, source=self.source)])
                pending.clear()

            def load(i):
                sl = slice(i * PAGE_ELEMS, (i + 1) * PAGE_ELEMS)
                if leaf.quantized:
                    bs = slice(i * blocks_per_page, (i + 1) * blocks_per_page)
                    mu = _dq_moments(jnp.asarray(leaf.mu[sl]),
                                     jnp.asarray(leaf.mu_scale[bs]))
                    nu = _dq_moments(jnp.asarray(leaf.nu[sl]),
                                     jnp.asarray(leaf.nu_scale[bs]),
                                     sqrt_domain=True)
                    return i, (jnp.asarray(leaf.master[sl]), mu, nu)
                return i, (jnp.asarray(leaf.master[sl]), jnp.asarray(leaf.mu[sl]),
                           jnp.asarray(leaf.nu[sl]))

            for i, (ms, mu, nu) in double_buffer(range(leaf.n_pages), load):
                gp = jax.lax.dynamic_slice(g_flat, (i * PAGE_ELEMS,), (PAGE_ELEMS,))
                ms2, mu2, nu2 = _page_update(
                    ms, gp, mu, nu, scale, lr, c1, c2,
                    b1=self.cfg.b1, b2=self.cfg.b2,
                    eps=self.cfg.eps, wd=self.cfg.weight_decay,
                )
                sl = slice(i * PAGE_ELEMS, (i + 1) * PAGE_ELEMS)
                if leaf.quantized:
                    bs = slice(i * blocks_per_page, (i + 1) * blocks_per_page)
                    qmu, smu = _q_moments(mu2)
                    qnu, snu = _q_moments(nu2, sqrt_domain=True)
                    def commit_q(res=None, sl=sl, bs=bs, w=(np.asarray(ms2),
                                 np.asarray(qmu), np.asarray(smu),
                                 np.asarray(qnu), np.asarray(snu))):
                        leaf.master[sl], leaf.mu[sl] = w[0], w[1]
                        leaf.mu_scale[bs], leaf.nu[sl] = w[2], w[3]
                        leaf.nu_scale[bs] = w[4]
                    if self.mover is not None:
                        pending.append((
                            (np.asarray(ms2), np.asarray(qmu),
                             np.asarray(qnu)), commit_q))
                        if len(pending) >= WRITEBACK_RUN_PAGES:
                            flush_writebacks()
                    else:
                        commit_q()
                else:
                    writeback = (np.asarray(ms2), np.asarray(mu2), np.asarray(nu2))
                    if self.mover is not None:
                        def commit(res, sl=sl, wb=writeback, leaf=leaf):
                            leaf.master[sl], leaf.mu[sl], leaf.nu[sl] = wb
                        pending.append((writeback, commit))
                        if len(pending) >= WRITEBACK_RUN_PAGES:
                            flush_writebacks()
                    else:
                        leaf.master[sl], leaf.mu[sl], leaf.nu[sl] = writeback
                out_pages[i] = ms2
                bytes_moved += PAGE_ELEMS * 4 * 6
                dst = self._leaf_dst(leaf)
                dev_bytes[dst] = dev_bytes.get(dst, 0) + PAGE_ELEMS * 4 * 6
            if self.mover is not None:
                flush_writebacks()
                self.mover.wait_all()
            assembled = jnp.concatenate(out_pages)[: leaf.size]
            new_leaves[key] = assembled.reshape(leaf.shape).astype(p.dtype)

        if self.mover is None and bytes_moved:
            # No movement engine: still surface the paging traffic so an
            # EpochWindow (Caption's sampler) sees real route counters —
            # per device, so the arbiter's device budgets (keyed by tier
            # name) meter the right links.  Half the bytes stream
            # device-ward (page reads), half back.
            fast = self._fast_name()
            for dev, b in dev_bytes.items():
                self.telemetry.record_move(dev, fast, b // 2, 0.0,
                                           source=self.source)
                self.telemetry.record_move(fast, dev, b // 2, 0.0,
                                           source=self.source)

        new_params = tdef.unflatten([new_leaves[str(path)] for path, _ in flat])
        new_state = {
            "step": step,
            "fast": {
                "mu": jax.tree_util.tree_map_with_path(
                    lambda p, x: new_mu.get(p, x), state["fast"]["mu"],
                    is_leaf=lambda x: x is None),
                "nu": jax.tree_util.tree_map_with_path(
                    lambda p, x: new_nu.get(p, x), state["fast"]["nu"],
                    is_leaf=lambda x: x is None),
            },
            "slow": state["slow"],
        }
        metrics = {"grad_norm": gnorm, "lr": lr,
                   "offload_bytes": jnp.asarray(bytes_moved)}
        return new_params, new_state, metrics
