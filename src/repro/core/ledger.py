"""Per-tier capacity accounting.

XLA's ``memory_analysis()`` proves the device-resident side of a program
fits; the ledger proves the *framework-managed* (staged host) side fits,
and produces the combined per-tier report used in EXPERIMENTS.md
§Dry-run.  Every planner decision registers its buffers here.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from repro.core.tiers import TierTopology


class CapacityError(RuntimeError):
    pass


@dataclasses.dataclass
class LedgerEntry:
    buffer: str
    tier: str
    nbytes: int
    note: str = ""


class TierLedger:
    def __init__(self, topology: TierTopology):
        self.topology = topology
        self.entries: list[LedgerEntry] = []

    def register(self, buffer: str, tier: str, nbytes: int, note: str = "",
                 *, strict: bool = True) -> LedgerEntry:
        if nbytes < 0:
            raise ValueError("nbytes must be >= 0")
        self.topology.by_name(tier)  # validate tier exists
        e = LedgerEntry(buffer, tier, int(nbytes), note)
        self.entries.append(e)
        if strict:
            try:
                self.check(tiers=(tier,))
            except CapacityError:
                self.entries.pop()
                raise
        return e

    def release(self, buffer: str) -> int:
        freed = sum(e.nbytes for e in self.entries if e.buffer == buffer)
        self.entries = [e for e in self.entries if e.buffer != buffer]
        return freed

    def used(self, tier: str) -> int:
        return sum(e.nbytes for e in self.entries if e.tier == tier)

    def free(self, tier: str) -> int:
        return self.topology.by_name(tier).capacity_bytes - self.used(tier)

    def check(self, tiers=None) -> None:
        for t in self.topology.tiers:
            if tiers is not None and t.name not in tiers:
                continue
            if self.used(t.name) > t.capacity_bytes:
                raise CapacityError(
                    f"tier {t.name}: {self.used(t.name)/2**30:.2f} GiB used "
                    f"> {t.capacity_bytes/2**30:.2f} GiB capacity"
                )

    def per_buffer(self) -> dict[str, dict[str, int]]:
        out: dict[str, dict[str, int]] = defaultdict(lambda: defaultdict(int))
        for e in self.entries:
            out[e.buffer][e.tier] += e.nbytes
        return {k: dict(v) for k, v in out.items()}

    def report(self) -> str:
        lines = ["tier        used(GiB)  cap(GiB)  util"]
        for t in self.topology.tiers:
            used = self.used(t.name)
            lines.append(
                f"{t.name:<11s} {used/2**30:9.3f} {t.capacity_bytes/2**30:9.2f}"
                f"  {used/t.capacity_bytes*100:5.1f}%"
            )
        return "\n".join(lines)
