"""CaptionArbiter — a fleet-level control plane over per-buffer Caption loops.

The paper's contention findings (§3, Fig. 3) are about a *shared*
resource: a handful of concurrent writers collapses the CXL controller,
and per-link bandwidth is one pool that independent agents will
oversubscribe.  After PR 2 every tiered buffer (weights, KV cache,
optimizer state) ran its own :class:`~repro.core.caption.CaptionController`
— N local optimizers, each blind to the traffic the others push onto the
same slow tier.  ``CaptionArbiter`` turns those into one coordinated
subsystem:

  * it owns a **global slow-tier write-bandwidth budget** (bytes/s);
  * every per-buffer controller **registers** with it, and each epoch the
    arbiter collects that buffer's *billed* slow-tier traffic from the
    :class:`~repro.core.telemetry.EpochWindow` source-attributed route
    counters;
  * it **grants** each buffer a bandwidth share — latency-bound buffers
    are served first in full (Fig. 7: they should not be on the slow
    tier at all, so what little floor-forced traffic they have has
    absolute priority), the rest split the remainder proportionally to
    ``share x demand`` with a **starvation floor** so no buffer is
    squeezed to zero by a louder neighbor;
  * growth steps are **gated** (a buffer at/over its grant cannot grow
    its slow fraction) and over-budget operating points are **clipped**
    (fraction scaled back toward its grant, never below the capacity
    floor), so the *sum* of slow-tier writes converges under budget.

The per-buffer controllers keep doing the §7 hill-climb; the arbiter
only vetoes/clips — local search under a global constraint.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.caption import (CaptionController, Decision, EpochMetrics,
                                window_metrics)
from repro.core.tiers import TierTopology


@dataclasses.dataclass(frozen=True)
class ArbiterConfig:
    """Knobs of the global budget (documented in ROADMAP.md)."""

    #: aggregate slow-tier write-bandwidth budget (bytes/s). The natural
    #: setting is the slow tier's nt-store bandwidth (or the link bw).
    slow_bw_budget: float
    #: minimum share of the budget reserved for every registered
    #: bandwidth-class buffer (starvation floor), in [0, 1/n_buffers].
    starvation_floor: float = 0.05
    #: relative overshoot of the aggregate budget tolerated before
    #: operating points are clipped back toward their grants.
    slack: float = 0.05
    #: EWMA smoothing for per-buffer demand (one noisy window never clips).
    ewma_alpha: float = 0.5

    def __post_init__(self):
        if self.slow_bw_budget <= 0:
            raise ValueError("slow_bw_budget must be > 0")
        if not 0.0 <= self.starvation_floor < 1.0:
            raise ValueError("starvation_floor in [0, 1)")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha in (0, 1]")


@dataclasses.dataclass
class _Entry:
    name: str
    controller: CaptionController
    share: float = 1.0
    demand_bw: float = 0.0  # EWMA of billed slow-tier write bandwidth
    grant_bw: float = 0.0
    epochs: int = 0


class CaptionArbiter:
    """Owns the slow-tier bandwidth budget; registers per-buffer loops."""

    def __init__(self, topology: TierTopology,
                 config: Optional[ArbiterConfig] = None):
        if config is None:
            slow = topology.slow or topology.fast
            config = ArbiterConfig(slow_bw_budget=slow.nt_store_bw)
        self.topology = topology
        self.cfg = config
        self._entries: dict[str, _Entry] = {}
        self.history: list[dict] = []

    # -- registration --------------------------------------------------------
    def register(self, name: str, controller: CaptionController,
                 *, share: float = 1.0) -> CaptionController:
        """Register a per-buffer controller under the global budget.

        Installs the growth gate on the controller and returns it (so
        ``arbiter.register("kv", CaptionController(...))`` reads fluently).
        """
        if name in self._entries:
            raise ValueError(f"buffer {name!r} already registered")
        if share <= 0:
            raise ValueError("share must be > 0")
        entry = _Entry(name=name, controller=controller, share=share)
        controller.set_growth_gate(self._gate(name))
        self._entries[name] = entry
        self._recompute_grants()
        return controller

    def controller(self, name: str) -> CaptionController:
        return self._entries[name].controller

    @property
    def buffers(self) -> tuple[str, ...]:
        return tuple(self._entries)

    # -- accounting ----------------------------------------------------------
    def aggregate_demand_bw(self) -> float:
        return sum(e.demand_bw for e in self._entries.values())

    def grants(self) -> dict[str, float]:
        return {n: e.grant_bw for n, e in self._entries.items()}

    def demands(self) -> dict[str, float]:
        return {n: e.demand_bw for n, e in self._entries.items()}

    def _bill(self, name: str, slow_bw: float) -> None:
        e = self._entries[name]
        a = self.cfg.ewma_alpha
        e.demand_bw = (slow_bw if e.epochs == 0
                       else a * slow_bw + (1 - a) * e.demand_bw)
        e.epochs += 1
        self._recompute_grants()

    def _recompute_grants(self) -> None:
        """Split the budget: latency-bound first in full, then the floor,
        then proportional to ``share x demand`` (weighted max-min)."""
        entries = list(self._entries.values())
        if not entries:
            return
        budget = self.cfg.slow_bw_budget
        lat = [e for e in entries if e.controller.latency_bound]
        rest = [e for e in entries if not e.controller.latency_bound]
        remaining = budget
        for e in lat:  # absolute priority (Fig. 7)
            e.grant_bw = min(e.demand_bw, remaining)
            remaining -= e.grant_bw
        if not rest:
            return
        floor = min(self.cfg.starvation_floor * budget,
                    remaining / len(rest))
        extra = remaining - floor * len(rest)
        weights = [e.share * max(e.demand_bw, 1e-12) for e in rest]
        total_w = sum(weights)
        for e, w in zip(rest, weights):
            e.grant_bw = floor + extra * w / total_w

    # -- the gate + clip -----------------------------------------------------
    def _gate(self, name: str):
        def gate(ctl: CaptionController, metrics: EpochMetrics
                 ) -> tuple[float, str]:
            e = self._entries[name]
            total = self.aggregate_demand_bw()
            budget = self.cfg.slow_bw_budget
            if total > budget:
                return 0.0, (f"arbiter: fleet over budget "
                             f"({total:.3g}>{budget:.3g} B/s)")
            if e.grant_bw > 0 and e.demand_bw >= e.grant_bw:
                return 0.0, (f"arbiter: at grant "
                             f"({e.demand_bw:.3g}>={e.grant_bw:.3g} B/s)")
            if e.grant_bw > 0:
                # Taper growth as the buffer approaches its grant so the
                # fleet glides into the budget instead of slamming it.
                headroom = 1.0 - e.demand_bw / e.grant_bw
                if headroom < 0.5:
                    return 2 * headroom, f"arbiter: taper x{2*headroom:.2f}"
            return 1.0, ""
        return gate

    def _clip(self, name: str, decision: Decision) -> Decision:
        """Scale an over-budget buffer's operating point back toward its
        grant (never below the capacity floor — the starvation guarantee
        in fraction space)."""
        e = self._entries[name]
        total = self.aggregate_demand_bw()
        budget = self.cfg.slow_bw_budget
        if (total <= budget * (1.0 + self.cfg.slack)
                or e.demand_bw <= e.grant_bw
                or e.grant_bw <= 0):
            return decision
        ctl = e.controller
        scale = e.grant_bw / e.demand_bw
        target = max(ctl.min_fraction, decision.fraction * scale)
        if target >= decision.fraction - 1e-12:
            return decision
        ctl.actuated(target)
        return dataclasses.replace(
            decision, fraction=target, changed=True,
            reason=(decision.reason
                    + f" [arbiter clip x{scale:.2f} -> {target:.3f}]"))

    # -- the loop ------------------------------------------------------------
    def observe(self, name: str, metrics: EpochMetrics, *,
                slow_bw: Optional[float] = None) -> Decision:
        """One epoch for buffer ``name``: bill its slow-tier bandwidth,
        recompute grants, run its controller, clip if over budget."""
        if slow_bw is not None:
            self._bill(name, slow_bw)
        decision = self._entries[name].controller.observe(metrics)
        decision = self._clip(name, decision)
        self.history.append({
            "buffer": name, "fraction": decision.fraction,
            "demand_bw": self._entries[name].demand_bw,
            "grant_bw": self._entries[name].grant_bw,
            "aggregate_bw": self.aggregate_demand_bw(),
            "reason": decision.reason,
        })
        return decision

    def observe_window(self, name: str, window, throughput: float, *,
                       mover=None, fast_pressure: Optional[float] = None,
                       slow_name: Optional[str] = None,
                       seconds: Optional[float] = None) -> Decision:
        """The EpochWindow glue, source-billed: closes ``window``, derives
        the buffer's metrics (same shared glue as
        ``CaptionController.observe_window``), and bills its slow-tier
        writes from the source-attributed route counters.  Only when the
        window saw NO attribution at all (single-buffer legacy telemetry)
        do the raw route bytes stand in — a window with co-tenant
        attribution must never bill a quiet buffer its neighbors' bytes."""
        metrics, counters, slow_name = window_metrics(
            window, throughput, mover=mover, fast_pressure=fast_pressure,
            slow_name=slow_name, seconds=seconds)
        billed = counters.bytes_into(slow_name, source=name)
        if billed == 0 and not any(counters.source_route_bytes.values()):
            # This window saw no attributed bytes at all (zero-delta keys
            # from past epochs don't count): legacy single-buffer telemetry,
            # bill the raw route bytes.
            billed = counters.bytes_into(slow_name)
        return self.observe(name, metrics,
                            slow_bw=billed / max(counters.seconds, 1e-9))

    def actuated(self, name: str, fraction: float) -> None:
        """Feed back what the buffer's actuator actually achieved."""
        self._entries[name].controller.actuated(fraction)
