"""CaptionArbiter — a fleet-level control plane over per-buffer Caption loops.

The paper's contention findings (§3, Fig. 3) are about a *shared*
resource: a handful of concurrent writers collapses the CXL controller,
and per-link bandwidth is one pool that independent agents will
oversubscribe.  After PR 2 every tiered buffer (weights, KV cache,
optimizer state) ran its own :class:`~repro.core.caption.CaptionController`
— N local optimizers, each blind to the traffic the others push onto the
same slow tier.  ``CaptionArbiter`` turns those into one coordinated
subsystem:

  * it owns a **global slow-tier write-bandwidth budget** (bytes/s);
  * every per-buffer controller **registers** with it, and each epoch the
    arbiter collects that buffer's *billed* slow-tier traffic from the
    :class:`~repro.core.telemetry.EpochWindow` source-attributed route
    counters;
  * it **grants** each buffer a bandwidth share — latency-bound buffers
    are served first in full (Fig. 7: they should not be on the slow
    tier at all, so what little floor-forced traffic they have has
    absolute priority), the rest split the remainder proportionally to
    ``share x demand`` with a **starvation floor** so no buffer is
    squeezed to zero by a louder neighbor;
  * growth steps are **gated** (a buffer at/over its grant cannot grow
    its slow fraction) and over-budget operating points are **clipped**
    (fraction scaled back toward its grant, never below the capacity
    floor), so the *sum* of slow-tier writes converges under budget.

The per-buffer controllers keep doing the §7 hill-climb; the arbiter
only vetoes/clips — local search under a global constraint.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.caption import (CaptionController, Decision, EpochMetrics,
                                window_metrics)
from repro.core.tiers import TierTopology


@dataclasses.dataclass(frozen=True)
class ArbiterConfig:
    """Knobs of the global budget (documented in ROADMAP.md)."""

    #: aggregate slow-tier write-bandwidth budget (bytes/s). The natural
    #: setting is the sum of the slow devices' nt-store bandwidths (or
    #: their link bandwidths).
    slow_bw_budget: float
    #: minimum share of the budget reserved for every registered
    #: bandwidth-class buffer (starvation floor), in [0, 1/n_buffers].
    starvation_floor: float = 0.05
    #: relative overshoot of the aggregate budget tolerated before
    #: operating points are clipped back toward their grants.
    slack: float = 0.05
    #: EWMA smoothing for per-buffer demand (one noisy window never clips).
    ewma_alpha: float = 0.5
    #: per-slow-device write-bandwidth budgets (bytes/s, by tier name).
    #: The paper's devices collapse independently (Fig. 3 is per
    #: controller), so each device carries its own ceiling; None keeps the
    #: single aggregate pool of the two-device era.
    device_budgets: Optional[dict[str, float]] = None
    #: coordinated growth: freeze every buffer's unilateral slow-share
    #: growth and grant it through :meth:`CaptionArbiter.joint_move`
    #: propose/commit rounds instead (clipping independent greed is
    #: replaced by a marginal-utility-ordered joint allocation under the
    #: same budgets).  Shrink steps stay local either way.
    joint_moves: bool = False

    def __post_init__(self):
        if self.slow_bw_budget <= 0:
            raise ValueError("slow_bw_budget must be > 0")
        if not 0.0 <= self.starvation_floor < 1.0:
            raise ValueError("starvation_floor in [0, 1)")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha in (0, 1]")
        if self.device_budgets is not None:
            if any(v <= 0 for v in self.device_budgets.values()):
                raise ValueError("device budgets must be > 0")


def budgeted_config(topology: TierTopology,
                    slow_budget: float) -> Optional[ArbiterConfig]:
    """ArbiterConfig for an explicit scalar budget (the drivers'
    ``--slow-budget``): on a multi-device topology the per-device
    ceilings survive, scaled nt-store-proportionally so they sum to the
    given budget — a scalar budget must not silently disable per-device
    enforcement.  Returns None (the defaults) for a non-positive budget."""
    if slow_budget <= 0:
        return None
    if topology.n_slow > 1:
        nts = {t.name: t.nt_store_bw for t in topology.slows}
        total = sum(nts.values())
        return ArbiterConfig(
            slow_bw_budget=slow_budget,
            device_budgets={k: v / total * slow_budget
                            for k, v in nts.items()})
    return ArbiterConfig(slow_bw_budget=slow_budget)


@dataclasses.dataclass
class _Entry:
    name: str
    controller: CaptionController
    share: float = 1.0
    demand_bw: float = 0.0  # EWMA of billed slow-tier write bandwidth
    grant_bw: float = 0.0
    epochs: int = 0
    #: EWMA of billed write bandwidth per slow device (by tier name).
    demand_dev: dict[str, float] = dataclasses.field(default_factory=dict)


class CaptionArbiter:
    """Owns the slow-tier bandwidth budgets; registers per-buffer loops."""

    def __init__(self, topology: TierTopology,
                 config: Optional[ArbiterConfig] = None):
        if config is None:
            slows = topology.slows or (topology.fast,)
            budgets = {t.name: t.nt_store_bw for t in slows}
            config = ArbiterConfig(
                slow_bw_budget=sum(budgets.values()),
                device_budgets=budgets if len(budgets) > 1 else None)
        self.topology = topology
        self.cfg = config
        self._entries: dict[str, _Entry] = {}
        self.history: list[dict] = []

    # -- registration --------------------------------------------------------
    def register(self, name: str, controller: CaptionController,
                 *, share: float = 1.0) -> CaptionController:
        """Register a per-buffer controller under the global budget.

        Installs the growth gate on the controller and returns it (so
        ``arbiter.register("kv", CaptionController(...))`` reads fluently).
        """
        if name in self._entries:
            raise ValueError(f"buffer {name!r} already registered")
        if share <= 0:
            raise ValueError("share must be > 0")
        entry = _Entry(name=name, controller=controller, share=share)
        controller.set_growth_gate(self._gate(name))
        self._entries[name] = entry
        self._recompute_grants()
        return controller

    def controller(self, name: str) -> CaptionController:
        return self._entries[name].controller

    # -- elastic topology ----------------------------------------------------
    def remove_device(self, name: str) -> None:
        """Hot-remove slow device ``name`` from the budget pool: drop its
        per-device ceiling, forget its billed demand (a dead device's
        EWMA must not keep gating survivors' growth), and recompute the
        grants over the shrunken topology."""
        self.topology = self.topology.remove_device(name)
        if self.cfg.device_budgets and name in self.cfg.device_budgets:
            budgets = {k: v for k, v in self.cfg.device_budgets.items()
                       if k != name}
            self.cfg = dataclasses.replace(self.cfg,
                                           device_budgets=budgets or None)
        for e in self._entries.values():
            e.demand_dev.pop(name, None)
        self._recompute_grants()

    def add_device(self, spec) -> None:
        """Hot-add a slow device (TierSpec or name): extend the per-device
        budgets with its nt-store bandwidth (the natural ceiling) and
        recompute grants."""
        self.topology = self.topology.add_device(spec)
        added = self.topology.slows[-1]
        if self.cfg.device_budgets is not None:
            budgets = dict(self.cfg.device_budgets)
            budgets.setdefault(added.name, added.nt_store_bw)
            self.cfg = dataclasses.replace(self.cfg, device_budgets=budgets)
        self._recompute_grants()

    @property
    def buffers(self) -> tuple[str, ...]:
        return tuple(self._entries)

    # -- accounting ----------------------------------------------------------
    def aggregate_demand_bw(self) -> float:
        return sum(e.demand_bw for e in self._entries.values())

    def grants(self) -> dict[str, float]:
        return {n: e.grant_bw for n, e in self._entries.items()}

    def demands(self) -> dict[str, float]:
        return {n: e.demand_bw for n, e in self._entries.items()}

    def device_demands(self) -> dict[str, float]:
        """Aggregate billed write bandwidth per slow device (all buffers)."""
        out: dict[str, float] = {}
        for e in self._entries.values():
            for dev, bw in e.demand_dev.items():
                out[dev] = out.get(dev, 0.0) + bw
        return out

    def _bill(self, name: str, slow_bw: float,
              device_bw: Optional[dict[str, float]] = None) -> None:
        e = self._entries[name]
        a = self.cfg.ewma_alpha
        e.demand_bw = (slow_bw if e.epochs == 0
                       else a * slow_bw + (1 - a) * e.demand_bw)
        if device_bw is not None:
            for dev, bw in device_bw.items():
                prev = e.demand_dev.get(dev)
                e.demand_dev[dev] = (bw if prev is None or e.epochs == 0
                                     else a * bw + (1 - a) * prev)
        e.epochs += 1
        self._recompute_grants()

    def _recompute_grants(self) -> None:
        """Split the budget: latency-bound first in full, then the floor,
        then proportional to ``share x demand`` (weighted max-min)."""
        entries = list(self._entries.values())
        if not entries:
            return
        budget = self.cfg.slow_bw_budget
        lat = [e for e in entries if e.controller.latency_bound]
        rest = [e for e in entries if not e.controller.latency_bound]
        remaining = budget
        for e in lat:  # absolute priority (Fig. 7)
            e.grant_bw = min(e.demand_bw, remaining)
            remaining -= e.grant_bw
        if not rest:
            return
        floor = min(self.cfg.starvation_floor * budget,
                    remaining / len(rest))
        extra = remaining - floor * len(rest)
        weights = [e.share * max(e.demand_bw, 1e-12) for e in rest]
        total_w = sum(weights)
        for e, w in zip(rest, weights):
            e.grant_bw = floor + extra * w / total_w

    # -- the gate + clip -----------------------------------------------------
    def _gate(self, name: str):
        def gate(ctl: CaptionController, metrics: EpochMetrics
                 ) -> tuple[float, str]:
            if self.cfg.joint_moves:
                # Growth is coordinated: buffers propose, joint_move
                # commits.  Local climbs keep full authority to shrink.
                return 0.0, "arbiter: joint-move round"
            e = self._entries[name]
            total = self.aggregate_demand_bw()
            budget = self.cfg.slow_bw_budget
            # Per-device enforcement: the device whose share the controller
            # is about to grow must itself have headroom — a quiet CXL-B
            # cannot excuse pushing more writers onto a saturated CXL-A.
            dev = getattr(ctl, "active_slow_device", None)
            if dev is not None and self.cfg.device_budgets:
                dev_budget = self.cfg.device_budgets.get(dev)
                if dev_budget:
                    dev_total = self.device_demands().get(dev, 0.0)
                    if dev_total >= dev_budget:
                        return 0.0, (f"arbiter: device {dev} at budget "
                                     f"({dev_total:.3g}>="
                                     f"{dev_budget:.3g} B/s)")
            if total > budget:
                return 0.0, (f"arbiter: fleet over budget "
                             f"({total:.3g}>{budget:.3g} B/s)")
            if e.grant_bw > 0 and e.demand_bw >= e.grant_bw:
                return 0.0, (f"arbiter: at grant "
                             f"({e.demand_bw:.3g}>={e.grant_bw:.3g} B/s)")
            if e.grant_bw > 0:
                # Taper growth as the buffer approaches its grant so the
                # fleet glides into the budget instead of slamming it.
                headroom = 1.0 - e.demand_bw / e.grant_bw
                if headroom < 0.5:
                    return 2 * headroom, f"arbiter: taper x{2*headroom:.2f}"
            return 1.0, ""
        return gate

    def _clip(self, name: str, decision: Decision) -> Decision:
        """Scale an over-budget buffer's operating point back toward its
        grant (never below the capacity floor — the starvation guarantee
        in fraction space)."""
        e = self._entries[name]
        total = self.aggregate_demand_bw()
        budget = self.cfg.slow_bw_budget
        if (total <= budget * (1.0 + self.cfg.slack)
                or e.demand_bw <= e.grant_bw
                or e.grant_bw <= 0):
            return self._clip_devices(name, decision)
        ctl = e.controller
        scale = e.grant_bw / e.demand_bw
        target = max(ctl.min_fraction, decision.fraction * scale)
        if target >= decision.fraction - 1e-12:
            return self._clip_devices(name, decision)
        ctl.actuated(target)
        return self._clip_devices(name, dataclasses.replace(
            decision, fraction=target, changed=True,
            weights=tuple(ctl.weights),
            reason=(decision.reason
                    + f" [arbiter clip x{scale:.2f} -> {target:.3f}]")))

    def _clip_devices(self, name: str, decision: Decision) -> Decision:
        """Per-device over-budget clip: scale this buffer's share of a
        saturated device back toward that device's budget, leaving its
        shares on devices with headroom untouched (never dropping the
        total below the capacity floor)."""
        if not self.cfg.device_budgets:
            return decision
        e = self._entries[name]
        ctl = e.controller
        names = self.topology.slow_names
        weights = list(decision.weights)
        if len(weights) != len(names) or not weights:
            return decision
        dev_totals = self.device_demands()
        clipped = []
        for i, dev in enumerate(names):
            dev_budget = self.cfg.device_budgets.get(dev)
            if not dev_budget or weights[i] <= 0:
                continue
            dev_total = dev_totals.get(dev, 0.0)
            mine = e.demand_dev.get(dev, 0.0)
            if dev_total <= dev_budget * (1.0 + self.cfg.slack) or mine <= 0:
                continue
            scale = dev_budget / dev_total
            floor_slack = sum(weights) - ctl.min_fraction
            cut = min(weights[i] * (1.0 - scale), max(floor_slack, 0.0))
            if cut <= 1e-12:
                continue
            weights[i] -= cut
            clipped.append(f"{dev} x{scale:.2f}")
        if not clipped:
            return decision
        ctl.actuated_weights(weights)
        return dataclasses.replace(
            decision, fraction=sum(weights), weights=tuple(weights),
            changed=True,
            reason=decision.reason + f" [device clip {', '.join(clipped)}]")

    # -- joint moves (propose/commit) ----------------------------------------
    def _growth_cost_bw(self, e: _Entry) -> float:
        """Estimated slow-tier write-bandwidth cost of one slow-fraction
        point for buffer ``e`` — its billed demand scaled by its current
        share.  A cold buffer (nothing billed yet, or a ~zero fraction)
        borrows the fleet average; with no evidence at all, one fraction
        point is conservatively priced at the whole budget, so the first
        round still grants but cannot blow through the ceiling."""
        f = e.controller.fraction
        if e.demand_bw > 0 and f > 1e-3:
            return e.demand_bw / f
        known = [x.demand_bw / x.controller.fraction
                 for x in self._entries.values()
                 if x.demand_bw > 0 and x.controller.fraction > 1e-3]
        if known:
            return sum(known) / len(known)
        return self.cfg.slow_bw_budget

    def joint_move(self, utilities: Optional[dict[str, float]] = None
                   ) -> dict[str, float]:
        """One propose/commit round of coordinated growth.

        PROPOSE: every registered buffer reports the slow-share step it
        would take next (:meth:`CaptionController.propose_growth`) and
        its marginal utility — Δthroughput per Δfraction from its recent
        duel outcomes / accepted moves, overridable per buffer via
        ``utilities`` (e.g. a perfmodel estimate).  COMMIT: proposals
        are granted in utility-per-bandwidth-cost order against the
        remaining budget headroom (global and per device), partially
        when headroom runs short, and applied with
        :meth:`CaptionController.commit_joint`.

        This replaces clip-the-greedy coordination: instead of every
        buffer growing independently and the over-budget ones being
        scaled back after the fact, the fleet's growth is allocated
        where a byte of slow-tier bandwidth buys the most throughput.
        Returns {buffer: granted fraction points} (committed proposals
        only)."""
        headroom = self.cfg.slow_bw_budget - self.aggregate_demand_bw()
        dev_free: dict[str, float] = {}
        if self.cfg.device_budgets:
            dev_demand = self.device_demands()
            dev_free = {d: max(b - dev_demand.get(d, 0.0), 0.0)
                        for d, b in self.cfg.device_budgets.items()}
        proposals = []
        for name, e in self._entries.items():
            want = e.controller.propose_growth()
            if want <= 1e-12:
                continue
            u = (utilities or {}).get(name, e.controller.marginal_utility())
            cost = max(self._growth_cost_bw(e), 1e-12)
            proposals.append((u / cost, name, want, cost, e.controller))
        grants: dict[str, float] = {}
        headroom = max(headroom, 0.0)
        for _, name, want, cost, ctl in sorted(
                proposals, key=lambda p: (-p[0], p[1])):
            afford = headroom / cost
            dev = ctl.active_slow_device
            if dev in dev_free:
                afford = min(afford, dev_free[dev] / cost)
            granted = min(want, max(afford, 0.0))
            if granted <= 1e-12:
                continue
            decision = ctl.commit_joint(granted)
            if not decision.changed:
                continue
            grants[name] = granted
            headroom -= granted * cost
            if dev in dev_free:
                dev_free[dev] = max(dev_free[dev] - granted * cost, 0.0)
        self.history.append({
            "joint_grants": dict(grants),
            "headroom_bw": headroom,
            "aggregate_bw": self.aggregate_demand_bw(),
        })
        return grants

    # -- the loop ------------------------------------------------------------
    def observe(self, name: str, metrics: EpochMetrics, *,
                slow_bw: Optional[float] = None,
                device_bw: Optional[dict[str, float]] = None) -> Decision:
        """One epoch for buffer ``name``: bill its slow-tier bandwidth
        (aggregate and per device), recompute grants, run its controller,
        clip if over budget."""
        if slow_bw is not None:
            self._bill(name, slow_bw, device_bw)
        decision = self._entries[name].controller.observe(metrics)
        decision = self._clip(name, decision)
        self.history.append({
            "buffer": name, "fraction": decision.fraction,
            "demand_bw": self._entries[name].demand_bw,
            "grant_bw": self._entries[name].grant_bw,
            "aggregate_bw": self.aggregate_demand_bw(),
            "reason": decision.reason,
        })
        return decision

    def observe_window(self, name: str, window, throughput: float, *,
                       mover=None, fast_pressure: Optional[float] = None,
                       slow_name=None,
                       seconds: Optional[float] = None) -> Decision:
        """The EpochWindow glue, source-billed: closes ``window``, derives
        the buffer's metrics (same shared glue as
        ``CaptionController.observe_window``), and bills its slow-tier
        writes — per device — from the source-attributed route counters.
        Only when the window saw NO attribution at all (single-buffer
        legacy telemetry) do the raw route bytes stand in — a window with
        co-tenant attribution must never bill a quiet buffer its
        neighbors' bytes."""
        metrics, counters, slow_name = window_metrics(
            window, throughput, mover=mover, fast_pressure=fast_pressure,
            slow_name=slow_name, seconds=seconds)
        names = ((slow_name,) if isinstance(slow_name, str)
                 else tuple(slow_name))
        dt = max(counters.seconds, 1e-9)
        per_dev = {n: counters.bytes_into(n, source=name) for n in names}
        per_dev_out = {n: counters.bytes_from(n, source=name) for n in names}
        billed = sum(per_dev.values())
        if billed == 0 and not any(counters.source_route_bytes.values()):
            # This window saw no attributed bytes at all (zero-delta keys
            # from past epochs don't count): legacy single-buffer telemetry,
            # bill the raw route bytes.
            per_dev = {n: counters.bytes_into(n) for n in names}
            per_dev_out = {n: counters.bytes_from(n) for n in names}
            billed = sum(per_dev.values())
        # The drift signal must also be THIS buffer's traffic: raw route
        # bytes would let a co-tenant's ramp-up spuriously re-open a quiet
        # buffer's converged walk.  The per-device vectors get the same
        # source-billed treatment so the guardrails' split stays coherent.
        dev_bw = {}
        dev_wr = {}
        for n in names:
            tot = per_dev[n] + per_dev_out[n]
            dev_bw[n] = per_dev[n] / dt
            dev_wr[n] = per_dev[n] / tot if tot else 0.0
        metrics = dataclasses.replace(
            metrics, slow_bw=billed / dt, device_slow_bw=dev_bw,
            device_write_ratio=dev_wr)
        return self.observe(
            name, metrics, slow_bw=billed / dt,
            device_bw={n: b / dt for n, b in per_dev.items()})

    def actuated(self, name: str, fraction: float) -> None:
        """Feed back what the buffer's actuator actually achieved."""
        self._entries[name].controller.actuated(fraction)
