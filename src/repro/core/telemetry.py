"""Movement/access telemetry: bytes and descriptors per tier route.

The paper's guidelines hinge on knowing per-route traffic (D2C, C2D,
C2C, D2D in Fig. 4).  Every mover/interleave operation records here so
benchmarks and the planner's feedback loop see real traffic, and so a
"centralized daemon" (§6) has the data to throttle writers.

:class:`EpochWindow` is the PMU-sampling analogue the Caption
controller (§7) reads: it closes fixed observation windows over the
cumulative route counters and reports per-epoch deltas plus EWMA
bandwidths, writer concurrency, and fast-tier pressure gauges.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import defaultdict
from typing import Optional


@dataclasses.dataclass
class RouteStats:
    bytes_moved: int = 0
    descriptors: int = 0
    batches: int = 0
    seconds: float = 0.0

    @property
    def bandwidth(self) -> float:
        return self.bytes_moved / self.seconds if self.seconds > 0 else 0.0


class Telemetry:
    def __init__(self):
        self._lock = threading.Lock()
        self.routes: dict[tuple[str, str], RouteStats] = defaultdict(RouteStats)
        self.counters: dict[str, float] = defaultdict(float)
        # Per-buffer attribution: (source, src, dst) -> bytes.  The arbiter
        # bills shared slow-tier traffic to the buffer that caused it.
        self.source_routes: dict[tuple[str, str, str], int] = defaultdict(int)

    def record_move(self, src: str, dst: str, nbytes: int, seconds: float,
                    descriptors: int = 1, batches: int = 1,
                    source: Optional[str] = None) -> None:
        with self._lock:
            r = self.routes[(src, dst)]
            r.bytes_moved += int(nbytes)
            r.descriptors += descriptors
            r.batches += batches
            r.seconds += seconds
            if source is not None:
                self.source_routes[(source, src, dst)] += int(nbytes)

    def bump(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] += value

    def record_overlap(self, hidden_s: float, exposed_s: float,
                       source: Optional[str] = None) -> None:
        """Bill one migration's hidden-vs-exposed split (async mover).

        ``hidden_s`` rode under concurrent decode compute; ``exposed_s``
        stalled the issuing thread.  Benchmarks read the counters
        ``migration_hidden_s`` / ``migration_exposed_s`` (optionally
        per-source) to audit how much wire time the overlap actually hid.
        """
        with self._lock:
            self.counters["migration_hidden_s"] += float(hidden_s)
            self.counters["migration_exposed_s"] += float(exposed_s)
            if source is not None:
                self.counters[f"migration_hidden_s|{source}"] += float(hidden_s)
                self.counters[f"migration_exposed_s|{source}"] += float(exposed_s)

    def record_semantic(self, promoted_pages: int, demoted_pages: int,
                        source: Optional[str] = None) -> None:
        """Bill one semantic re-tier (core/hotness.py): pages promoted
        INTO the fast tier and demoted OUT of it.  Lateral slow<->slow
        shuffles appear on the mover routes, not here — these counters
        answer "how much hot-set churn is the placement loop doing",
        which benchmarks and the example read back per source."""
        with self._lock:
            self.counters["semantic_promoted_pages"] += int(promoted_pages)
            self.counters["semantic_demoted_pages"] += int(demoted_pages)
            if source is not None:
                self.counters[f"semantic_promoted_pages|{source}"] += int(
                    promoted_pages)
                self.counters[f"semantic_demoted_pages|{source}"] += int(
                    demoted_pages)

    def route(self, src: str, dst: str) -> RouteStats:
        return self.routes[(src, dst)]

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "routes": {
                    f"{s}->{d}": dataclasses.asdict(v)
                    for (s, d), v in self.routes.items()
                },
                "counters": dict(self.counters),
                "source_routes": {
                    f"{src}|{s}->{d}": v
                    for (src, s, d), v in self.source_routes.items()
                },
            }

    def reset(self) -> None:
        with self._lock:
            self.routes.clear()
            self.counters.clear()
            self.source_routes.clear()


GLOBAL_TELEMETRY = Telemetry()


@dataclasses.dataclass(frozen=True)
class EpochCounters:
    """One closed observation window over the telemetry counters.

    ``route_bytes``/``route_bw`` are this epoch's deltas; ``route_bw_ewma``
    smooths bandwidth across epochs (the controller never trusts a single
    sample — Caption's measurement-smoothing stage).  ``gauges`` carry
    instantaneous readings published by the subsystems (writer
    concurrency, fast-tier pressure, per-step throughput proxies).
    """

    epoch: int
    seconds: float
    route_bytes: dict[str, int]
    route_bw: dict[str, float]
    route_bw_ewma: dict[str, float]
    counters: dict[str, float]  # per-epoch deltas of Telemetry.counters
    gauges: dict[str, float]
    #: per-source route deltas, keyed "source|src->dst" (arbiter billing).
    source_route_bytes: dict[str, int] = dataclasses.field(
        default_factory=dict)

    def bytes_into(self, dst, source: Optional[str] = None) -> int:
        """Bytes into tier ``dst`` (a name, or a sequence of device names
        — multi-device topologies sum their slow pool in one call)."""
        if not isinstance(dst, str):
            return sum(self.bytes_into(d, source) for d in dst)
        if source is not None:
            return sum(v for k, v in self.source_route_bytes.items()
                       if k.startswith(f"{source}|") and k.endswith(f"->{dst}"))
        return sum(v for k, v in self.route_bytes.items()
                   if k.endswith(f"->{dst}"))

    def bytes_from(self, src, source: Optional[str] = None) -> int:
        if not isinstance(src, str):
            return sum(self.bytes_from(s, source) for s in src)
        if source is not None:
            return sum(v for k, v in self.source_route_bytes.items()
                       if k.startswith(f"{source}|{src}->"))
        return sum(v for k, v in self.route_bytes.items()
                   if k.startswith(f"{src}->"))

    def workload_features(self, slow, source: Optional[str] = None
                          ) -> dict[str, float]:
        """AccessProfile-style features of this window against the slow
        pool (``slow``: one tier name or a sequence) — the warm-start
        fingerprint inputs: write share, slow-route bandwidth, writer
        parallelism.  Optionally source-scoped (per-buffer billing)."""
        into = self.bytes_into(slow, source)
        out = self.bytes_from(slow, source)
        total = into + out
        return {
            "write_ratio": into / total if total else 0.0,
            "slow_bw": total / max(self.seconds, 1e-9),
            "parallelism": float(self.gauges.get("writer_concurrency", 0)),
        }


class EpochWindow:
    """Windowed view over a :class:`Telemetry`: per-route epoch counters.

    Usage::

        win = EpochWindow(telemetry)
        ... traffic happens ...
        win.gauge("writer_concurrency", mover_writers)
        sample = win.tick()          # closes the epoch, returns deltas
    """

    def __init__(self, telemetry: Telemetry = GLOBAL_TELEMETRY,
                 *, ewma_alpha: float = 0.5):
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha in (0, 1]")
        self.telemetry = telemetry
        self.ewma_alpha = ewma_alpha
        self.epoch = 0
        self._gauges: dict[str, float] = {}
        self._ewma: dict[str, float] = {}
        self._t0 = time.perf_counter()
        self._base = self._snapshot()

    def _snapshot(self) -> dict:
        snap = self.telemetry.snapshot()
        return {
            "routes": {k: v["bytes_moved"] for k, v in snap["routes"].items()},
            "counters": dict(snap["counters"]),
            "source_routes": dict(snap.get("source_routes", {})),
        }

    def gauge(self, name: str, value: float) -> None:
        """Publish an instantaneous gauge for the current epoch."""
        self._gauges[name] = float(value)

    def tick(self, seconds: Optional[float] = None) -> EpochCounters:
        """Close the current epoch and start the next one."""
        now = time.perf_counter()
        dt = seconds if seconds is not None else max(now - self._t0, 1e-9)
        cur = self._snapshot()
        route_bytes = {}
        for k, v in cur["routes"].items():
            route_bytes[k] = v - self._base["routes"].get(k, 0)
        route_bw = {k: v / dt for k, v in route_bytes.items()}
        a = self.ewma_alpha
        for k, bw in route_bw.items():
            prev = self._ewma.get(k)
            self._ewma[k] = bw if prev is None else a * bw + (1 - a) * prev
        counters = {}
        for k, v in cur["counters"].items():
            counters[k] = v - self._base["counters"].get(k, 0.0)
        source_bytes = {}
        for k, v in cur["source_routes"].items():
            source_bytes[k] = v - self._base["source_routes"].get(k, 0)
        sample = EpochCounters(
            epoch=self.epoch,
            seconds=dt,
            route_bytes=route_bytes,
            route_bw=route_bw,
            route_bw_ewma=dict(self._ewma),
            counters=counters,
            gauges=dict(self._gauges),
            source_route_bytes=source_bytes,
        )
        self.epoch += 1
        self._base = cur
        self._t0 = now
        self._gauges = {}
        return sample


class Timer:
    """Context-manager wall timer (blocks on jax arrays if passed)."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False
