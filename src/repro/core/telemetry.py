"""Movement/access telemetry: bytes and descriptors per tier route.

The paper's guidelines hinge on knowing per-route traffic (D2C, C2D,
C2C, D2D in Fig. 4).  Every mover/interleave operation records here so
benchmarks and the planner's feedback loop see real traffic, and so a
"centralized daemon" (§6) has the data to throttle writers.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import defaultdict


@dataclasses.dataclass
class RouteStats:
    bytes_moved: int = 0
    descriptors: int = 0
    batches: int = 0
    seconds: float = 0.0

    @property
    def bandwidth(self) -> float:
        return self.bytes_moved / self.seconds if self.seconds > 0 else 0.0


class Telemetry:
    def __init__(self):
        self._lock = threading.Lock()
        self.routes: dict[tuple[str, str], RouteStats] = defaultdict(RouteStats)
        self.counters: dict[str, float] = defaultdict(float)

    def record_move(self, src: str, dst: str, nbytes: int, seconds: float,
                    descriptors: int = 1, batches: int = 1) -> None:
        with self._lock:
            r = self.routes[(src, dst)]
            r.bytes_moved += int(nbytes)
            r.descriptors += descriptors
            r.batches += batches
            r.seconds += seconds

    def bump(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] += value

    def route(self, src: str, dst: str) -> RouteStats:
        return self.routes[(src, dst)]

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "routes": {
                    f"{s}->{d}": dataclasses.asdict(v)
                    for (s, d), v in self.routes.items()
                },
                "counters": dict(self.counters),
            }

    def reset(self) -> None:
        with self._lock:
            self.routes.clear()
            self.counters.clear()


GLOBAL_TELEMETRY = Telemetry()


class Timer:
    """Context-manager wall timer (blocks on jax arrays if passed)."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False
