"""Caption (§7): feedback-driven dynamic tiering via counter sampling.

The paper's headline proposal: instead of committing to one static
interleave ratio, sample hardware counters every epoch and *converge*
to an empirically favorable slow-tier percentage (up to +24% for
bandwidth-bound apps, Fig. 11).  ``CaptionController`` is that loop as
a small state machine over :class:`~repro.core.telemetry.EpochCounters`
style samples:

  PROBE    perturb the slow-tier fraction by one hill-climbing step;
  MEASURE  hold the candidate for ``probe_epochs`` windows, smoothing
           the throughput signal with an EWMA (Caption's measurement
           module — one noisy PMU window never decides anything);
  ADJUST   compare against the previous operating point with a
           hysteresis band: keep climbing on improvement, back off and
           halve the step on regression, declare convergence when the
           step underflows.

The §6 guardrails are first-class:
  * latency-bound profiles never gain slow-tier pages (Fig. 7: any CXL
    fraction hurts a µs-SLO app) — the controller only walks toward the
    fast tier;
  * write-heavy epochs damp the step toward the slow tier by the
    store/load bandwidth ratio (RFO doubles temporal-store traffic);
  * epochs that exceed the writer limit freeze growth of the slow
    fraction (concurrent writers collapse the CXL controller, Fig. 3);
  * the capacity floor from the static plan is a hard lower bound — the
    controller can tune *how much more* than the spill minimum lives on
    the slow tier, never less than fits.

The static planner supplies the *initial* state (``from_plan``), so the
one-shot §6 plan is the cold-start prior, not the final answer.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import TYPE_CHECKING, Optional

from repro.core.classifier import Boundedness
from repro.core.tiers import TierTopology

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.planner import Plan


class Phase(enum.Enum):
    WARMUP = "warmup"  # first operating point, no comparison baseline yet
    MEASURE = "measure"  # accumulating epochs at the current fraction
    ADJUST = "adjust"  # a decision was taken this epoch
    CONVERGED = "converged"  # step underflowed; holding


@dataclasses.dataclass(frozen=True)
class CaptionConfig:
    """Knobs of the control loop (documented in ROADMAP.md)."""

    #: application steps per observation epoch (the PMU window length).
    epoch_steps: int = 16
    #: epochs to hold each candidate fraction before judging it.
    probe_epochs: int = 2
    #: initial hill-climbing step, in slow-fraction points.
    step: float = 0.05
    #: convergence threshold: the walk stops once the step halves below.
    min_step: float = 0.01
    #: relative throughput change that counts as signal (hysteresis band).
    hysteresis: float = 0.02
    #: EWMA smoothing factor for the throughput signal.
    ewma_alpha: float = 0.5
    #: hard ceiling on the slow-tier fraction.
    max_fraction: float = 0.95
    #: writer-concurrency limit; above it the slow fraction cannot grow.
    writer_limit: int = 2
    #: fast-tier pressure above which pages are not pulled back fast.
    pressure_high: float = 0.95
    #: damp growth steps by write share (RFO/store-bandwidth guardrail).
    write_damp: bool = True

    def __post_init__(self):
        if self.epoch_steps < 1:
            raise ValueError("epoch_steps must be >= 1")
        if self.probe_epochs < 1:
            raise ValueError("probe_epochs must be >= 1")
        if not 0.0 < self.step <= 1.0:
            raise ValueError("step must be in (0, 1]")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if not 0.0 <= self.max_fraction <= 1.0:
            raise ValueError("max_fraction must be in [0, 1]")


@dataclasses.dataclass(frozen=True)
class EpochMetrics:
    """What one epoch tells the controller (derived from EpochCounters)."""

    #: application progress per second (tokens/s, samples/s, steps/s...).
    throughput: float
    #: written / (read + written) bytes this epoch.
    write_ratio: float = 0.0
    #: peak concurrent writers into the slow tier this epoch.
    writer_concurrency: int = 0
    #: fast-tier occupancy in [0, 1].
    fast_pressure: float = 0.0

    @staticmethod
    def from_counters(counters, *, throughput: float,
                      slow_name: str = "slow") -> "EpochMetrics":
        """Derive the guardrail inputs from an EpochCounters window."""
        into_slow = counters.bytes_into(slow_name)
        from_slow = counters.bytes_from(slow_name)
        total = into_slow + from_slow
        return EpochMetrics(
            throughput=throughput,
            write_ratio=into_slow / total if total else 0.0,
            writer_concurrency=int(
                counters.gauges.get("writer_concurrency", 0)),
            fast_pressure=float(counters.gauges.get("fast_pressure", 0.0)),
        )


def window_metrics(window, throughput: float, *, mover=None,
                   fast_pressure: Optional[float] = None,
                   slow_name: Optional[str] = None,
                   seconds: Optional[float] = None):
    """Close an EpochWindow into controller inputs — the one place the
    gauge publication / tick / metric-derivation glue lives (shared by
    CaptionController.observe_window and CaptionArbiter.observe_window,
    so the two paths can never derive from different route keys).
    Returns (metrics, counters, resolved slow tier name)."""
    if fast_pressure is not None:
        window.gauge("fast_pressure", fast_pressure)
    if mover is not None:
        window.gauge("writer_concurrency", mover.take_peak_writers())
        if slow_name is None and mover.topology.slow is not None:
            slow_name = mover.topology.slow.name
    slow_name = slow_name or "slow"
    counters = window.tick(seconds=seconds)
    metrics = EpochMetrics.from_counters(
        counters, throughput=throughput, slow_name=slow_name)
    return metrics, counters, slow_name


@dataclasses.dataclass(frozen=True)
class Decision:
    """Outcome of one observed epoch."""

    fraction: float
    changed: bool
    phase: Phase
    reason: str


class CaptionController:
    """Hill-climbing slow-fraction controller with hysteresis (§7)."""

    def __init__(
        self,
        topology: TierTopology,
        config: Optional[CaptionConfig] = None,
        *,
        initial_fraction: float = 0.0,
        min_fraction: float = 0.0,
        boundedness: Boundedness = Boundedness.BANDWIDTH_BOUND,
    ):
        self.topology = topology
        self.cfg = config or CaptionConfig()
        self.boundedness = boundedness
        self.min_fraction = min(max(min_fraction, 0.0), self.cfg.max_fraction)
        self.fraction = min(max(initial_fraction, self.min_fraction),
                            self.cfg.max_fraction)
        self.phase = Phase.WARMUP
        # Latency-bound state starts walking home to the fast tier; anything
        # else probes toward the slow tier from its static prior.
        self._dir = -1.0 if self.latency_bound else 1.0
        self._step = self.cfg.step
        self._growth_gate = None  # fleet-level gate (CaptionArbiter)
        self._ewma: Optional[float] = None
        self._epochs_here = 0
        self._prev: Optional[tuple[float, float]] = None  # (fraction, tput)
        self.history: list[Decision] = []

    # -- derived -------------------------------------------------------------
    @property
    def latency_bound(self) -> bool:
        return self.boundedness == Boundedness.LATENCY_BOUND

    @property
    def converged(self) -> bool:
        return self.phase == Phase.CONVERGED

    @classmethod
    def from_plan(cls, plan: "Plan", buffer: str, topology: TierTopology,
                  config: Optional[CaptionConfig] = None
                  ) -> "CaptionController":
        """Seed the loop with the static planner's decision for ``buffer``:
        its fraction is the cold-start prior, its capacity spill is the
        floor, and its boundedness selects the latency guardrail."""
        d = plan.decisions[buffer]
        return cls(
            topology, config,
            initial_fraction=d.slow_fraction,
            min_fraction=d.min_slow_fraction,
            boundedness=d.boundedness,
        )

    # -- the loop ------------------------------------------------------------
    def observe_window(self, window, throughput: float, *,
                       mover=None, fast_pressure: Optional[float] = None,
                       slow_name: Optional[str] = None,
                       seconds: Optional[float] = None) -> Decision:
        """One epoch straight from an EpochWindow: publish the standard
        gauges, close the window, derive metrics, decide.  The shared
        glue for every integration point (serving engine, train driver)."""
        metrics, _, _ = window_metrics(
            window, throughput, mover=mover, fast_pressure=fast_pressure,
            slow_name=slow_name, seconds=seconds)
        return self.observe(metrics)

    def set_growth_gate(self, gate) -> None:
        """Install a fleet-level growth gate (see core/arbiter.py).

        ``gate(controller, metrics) -> (scale, note)`` is consulted
        whenever a positive slow-fraction step is about to be taken; the
        returned multiplier in [0, 1] clips the step (0 freezes growth).
        A single buffer optimizing locally cannot see the *other* writers
        sharing the slow-tier link — the gate is where that global view
        (the aggregate bandwidth budget) vetoes local greed."""
        self._growth_gate = gate

    def actuated(self, fraction: float) -> None:
        """Feed back what the actuator actually achieved.

        Page-granular actuation rounds the requested fraction (a step
        smaller than one page moves nothing); the walk must continue from
        the real operating point, not the phantom request, or throughput
        measurements get attributed to fractions the system never ran."""
        self.fraction = float(fraction)

    def observe(self, metrics: EpochMetrics) -> Decision:
        """Feed one epoch; returns the (possibly updated) target fraction."""
        a = self.cfg.ewma_alpha
        self._ewma = (metrics.throughput if self._ewma is None
                      else a * metrics.throughput + (1 - a) * self._ewma)
        self._epochs_here += 1
        if self.phase == Phase.CONVERGED:
            return self._emit(False, "converged; holding")
        if self._epochs_here < self.cfg.probe_epochs:
            return self._emit(False, "measuring", phase=Phase.MEASURE)
        return self._adjust(metrics)

    def _adjust(self, metrics: EpochMetrics) -> Decision:
        cur_t = float(self._ewma)
        reason = ""
        if self._prev is not None:
            prev_f, prev_t = self._prev
            rel = (cur_t - prev_t) / max(abs(prev_t), 1e-12)
            if rel < -self.cfg.hysteresis:
                # Regression: back off to the better point, reverse, shrink.
                # A latency-bound buffer may only ever revert DOWNWARD (the
                # monotone guardrail beats the hill-climber's memory).
                self._dir, self._step = -self._dir, self._step / 2
                back = (min(prev_f, self.fraction) if self.latency_bound
                        else prev_f)
                if self._step < self.cfg.min_step:
                    return self._move_to(back, Phase.CONVERGED,
                                         "regressed; step underflow -> hold "
                                         f"at {back:.3f}")
                return self._move_to(back, Phase.ADJUST,
                                     f"regressed {rel*100:+.1f}%; revert + "
                                     "reverse")
            if rel <= self.cfg.hysteresis:
                # Flat within hysteresis: the gradient is gone; shrink.
                self._step /= 2
                if self._step < self.cfg.min_step:
                    return self._move_to(self.fraction, Phase.CONVERGED,
                                         "flat; converged")
                reason = f"flat ({rel*100:+.1f}%); refining"
            else:
                reason = f"improved {rel*100:+.1f}%; continue"
        else:
            reason = "cold start; probing"

        delta = self._dir * self._step
        delta, guard = self._guardrails(delta, metrics)
        target = min(max(self.fraction + delta, self.min_fraction),
                     self.cfg.max_fraction)
        if guard:
            reason = f"{reason} [{guard}]"
        if target == self.fraction:
            # Pinned against a bound or frozen by a guardrail; if the walk
            # cannot move it is done.
            phase = Phase.CONVERGED if self._at_bound() else Phase.ADJUST
            return self._move_to(target, phase, reason + "; immovable")
        return self._move_to(target, Phase.ADJUST, reason)

    def _guardrails(self, delta: float, m: EpochMetrics) -> tuple[float, str]:
        notes = []
        if self.latency_bound and delta > 0:
            # Guideline 5 / Fig. 7: never grow the slow share of a
            # latency-bound buffer.
            delta = 0.0
            notes.append("latency-bound: growth pinned")
        if delta > 0 and m.writer_concurrency > self.cfg.writer_limit:
            delta = 0.0
            notes.append(
                f"writers {m.writer_concurrency} > {self.cfg.writer_limit}")
        if delta > 0 and self.cfg.write_damp and m.write_ratio > 0:
            slow = self.topology.slow
            if slow is not None:
                damp = 1.0 - m.write_ratio * (1.0 - slow.store_bw / slow.load_bw)
                delta *= max(damp, 0.0)
                if damp < 1.0:
                    notes.append(f"write-damped x{damp:.2f}")
        if delta > 0 and self._growth_gate is not None:
            scale, note = self._growth_gate(self, m)
            delta *= min(max(scale, 0.0), 1.0)
            if note:
                notes.append(note)
        if delta < 0 and m.fast_pressure >= self.cfg.pressure_high:
            delta = 0.0
            notes.append(
                f"fast pressure {m.fast_pressure:.2f}: shrink frozen")
        return delta, "; ".join(notes)

    def _at_bound(self) -> bool:
        lo, hi = self.min_fraction, self.cfg.max_fraction
        return ((self.fraction <= lo and self._dir < 0)
                or (self.fraction >= hi and self._dir > 0))

    def _move_to(self, target: float, phase: Phase, reason: str) -> Decision:
        changed = abs(target - self.fraction) > 1e-12
        self._prev = (self.fraction, float(self._ewma))
        self.fraction = target
        self.phase = phase
        self._ewma = None
        self._epochs_here = 0
        return self._emit(changed, reason, phase=phase)

    def _emit(self, changed: bool, reason: str,
              phase: Optional[Phase] = None) -> Decision:
        if phase is not None:
            self.phase = phase
        d = Decision(self.fraction, changed, self.phase, reason)
        self.history.append(d)
        return d
