"""Caption (§7): feedback-driven dynamic tiering via counter sampling.

The paper's headline proposal: instead of committing to one static
interleave ratio, sample hardware counters every epoch and *converge*
to an empirically favorable slow-tier percentage (up to +24% for
bandwidth-bound apps, Fig. 11).  ``CaptionController`` is that loop as
a small state machine over :class:`~repro.core.telemetry.EpochCounters`
style samples:

  PROBE    perturb the slow-tier weight vector by one hill-climbing
           step on the active device's coordinate;
  MEASURE  hold the candidate for ``probe_epochs`` windows, smoothing
           the throughput signal with an EWMA (Caption's measurement
           module — one noisy PMU window never decides anything);
  ADJUST   compare against the previous operating point with a
           hysteresis band: keep climbing on improvement, back off and
           halve the step on regression, declare the coordinate done
           when the step underflows.

On an N-slow-device topology (the paper's CXL-A/B/C pool) the
controller walks the weight vector on the simplex by round-robin
coordinate descent: each device's share is hill-climbed in turn with
the same machinery, and the loop converges once a full pass over every
device moves nothing.  With one slow device this degenerates exactly to
the scalar ``slow_fraction`` walk.

The §6 guardrails are first-class (applied per active device):
  * latency-bound profiles never gain slow-tier pages (Fig. 7: any CXL
    fraction hurts a µs-SLO app) — the controller only walks toward the
    fast tier;
  * write-heavy epochs damp the step toward a slow device by THAT
    device's store/load bandwidth ratio (RFO doubles temporal-store
    traffic, and the three devices RFO differently);
  * epochs that exceed the writer limit freeze growth of the slow
    share (concurrent writers collapse the CXL controller, Fig. 3);
  * the capacity floor from the static plan is a hard lower bound — the
    controller can tune *how much more* than the spill minimum lives on
    the slow tier, never less than fits.

Workload shifts re-open a converged loop: while ``CONVERGED`` the
controller tracks the EWMA slow-route bandwidth, and a relative drift
beyond ``CaptionConfig.drift_threshold`` resets the walk (fresh step,
fresh baseline) — the counters said the workload changed, so the old
operating point is no longer evidence.

The static planner supplies the *initial* state (``from_plan``), so the
one-shot §6 plan is the cold-start prior, not the final answer.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import TYPE_CHECKING, Optional, Sequence

from repro.core.classifier import (AccessProfile, Boundedness,
                                   classify_pool)
from repro.core.tiers import TierTopology

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.planner import Plan
    from repro.core.warmstart import WarmStartMemo


class Phase(enum.Enum):
    WARMUP = "warmup"  # first operating point, no comparison baseline yet
    MEASURE = "measure"  # accumulating epochs at the current fraction
    ADJUST = "adjust"  # a decision was taken this epoch
    CONVERGED = "converged"  # step underflowed; holding


@dataclasses.dataclass(frozen=True)
class CaptionConfig:
    """Knobs of the control loop (documented in ROADMAP.md)."""

    #: application steps per observation epoch (the PMU window length).
    epoch_steps: int = 16
    #: epochs to hold each candidate fraction before judging it.
    probe_epochs: int = 2
    #: initial hill-climbing step, in slow-fraction points.
    step: float = 0.05
    #: convergence threshold: the walk stops once the step halves below.
    min_step: float = 0.01
    #: relative throughput change that counts as signal (hysteresis band).
    hysteresis: float = 0.02
    #: EWMA smoothing factor for the throughput signal.
    ewma_alpha: float = 0.5
    #: hard ceiling on the total slow-tier fraction (sum of weights).
    max_fraction: float = 0.95
    #: writer-concurrency limit; above it the slow fraction cannot grow.
    writer_limit: int = 2
    #: fast-tier pressure above which pages are not pulled back fast.
    pressure_high: float = 0.95
    #: damp growth steps by write share (RFO/store-bandwidth guardrail).
    write_damp: bool = True
    #: relative EWMA slow-route bandwidth drift that re-opens a CONVERGED
    #: walk (workload-shift re-probing); 0 disables.
    drift_threshold: float = 0.35
    #: paired probe duels per candidate point (noise-robust probing):
    #: the controller alternates ``probe_epochs``-long stints at the
    #: incumbent w and the candidate w±δ, and accepts the candidate only
    #: on a significant majority of duel wins.  0 keeps the legacy
    #: single-sample accept/reject.
    duel_count: int = 0
    #: adaptive step sizing: multiplier applied to the step after
    #: consecutive duel wins (1.0 disables expansion).  Rejections halve
    #: the step as always (expand on wins, shrink on reversals).
    step_expand: float = 2.0
    #: ceiling for the adaptively expanded step (the walk never probes
    #: coarser than this, whatever the win streak).
    max_step: float = 0.2

    def __post_init__(self):
        if self.epoch_steps < 1:
            raise ValueError("epoch_steps must be >= 1")
        if self.probe_epochs < 1:
            raise ValueError("probe_epochs must be >= 1")
        if not 0.0 < self.step <= 1.0:
            raise ValueError("step must be in (0, 1]")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if not 0.0 <= self.max_fraction <= 1.0:
            raise ValueError("max_fraction must be in [0, 1]")
        if self.drift_threshold < 0.0:
            raise ValueError("drift_threshold must be >= 0")
        if self.duel_count < 0:
            raise ValueError("duel_count must be >= 0")
        if self.step_expand < 1.0:
            raise ValueError("step_expand must be >= 1")
        if self.max_step <= 0.0:
            raise ValueError("max_step must be > 0")


@dataclasses.dataclass(frozen=True)
class EpochMetrics:
    """What one epoch tells the controller (derived from EpochCounters).

    ``write_ratio`` and ``slow_bw`` stay as POOL AGGREGATES for
    back-compat (every pre-split constructor call keeps meaning what it
    meant); the per-device vectors carry the same quantities split per
    slow device, so one device's write storm no longer damps growth
    toward all of them and the drift detector can tell WHICH device's
    route shifted.  Use :meth:`write_ratio_for` / :meth:`slow_bw_for`,
    which fall back to the aggregate when the split is absent (hand-built
    metrics in older tests/benchmarks)."""

    #: application progress per second (tokens/s, samples/s, steps/s...).
    throughput: float
    #: written / (read + written) bytes this epoch (whole slow pool).
    write_ratio: float = 0.0
    #: peak concurrent writers into the slow tier this epoch.
    writer_concurrency: int = 0
    #: fast-tier occupancy in [0, 1].
    fast_pressure: float = 0.0
    #: observed slow-route bandwidth this epoch (bytes/s, both directions)
    #: — the workload-shift drift signal (whole slow pool).
    slow_bw: float = 0.0
    #: per-device write ratio: {device name: written/(read+written)}.
    device_write_ratio: dict[str, float] = dataclasses.field(
        default_factory=dict)
    #: per-device slow-route bandwidth (bytes/s, both directions).
    device_slow_bw: dict[str, float] = dataclasses.field(
        default_factory=dict)

    def write_ratio_for(self, name: Optional[str]) -> float:
        """Device ``name``'s write ratio; the pool aggregate when the
        split was not populated (or no name is known)."""
        if name is not None and name in self.device_write_ratio:
            return self.device_write_ratio[name]
        return self.write_ratio

    def slow_bw_for(self, name: Optional[str]) -> float:
        if name is not None and name in self.device_slow_bw:
            return self.device_slow_bw[name]
        return self.slow_bw

    @staticmethod
    def from_counters(counters, *, throughput: float,
                      slow_name="slow") -> "EpochMetrics":
        """Derive the guardrail inputs from an EpochCounters window.

        ``slow_name`` is one tier name or a sequence of them (multi-device
        topologies get both the pool aggregate and the per-device split)."""
        names = ((slow_name,) if isinstance(slow_name, str)
                 else tuple(slow_name))
        dt = max(counters.seconds, 1e-9)
        dev_wr: dict[str, float] = {}
        dev_bw: dict[str, float] = {}
        into_slow = from_slow = 0
        for n in names:
            into = counters.bytes_into(n)
            out = counters.bytes_from(n)
            tot = into + out
            dev_wr[n] = into / tot if tot else 0.0
            dev_bw[n] = tot / dt
            into_slow += into
            from_slow += out
        total = into_slow + from_slow
        return EpochMetrics(
            throughput=throughput,
            write_ratio=into_slow / total if total else 0.0,
            writer_concurrency=int(
                counters.gauges.get("writer_concurrency", 0)),
            fast_pressure=float(counters.gauges.get("fast_pressure", 0.0)),
            slow_bw=total / dt,
            device_write_ratio=dev_wr,
            device_slow_bw=dev_bw,
        )


def window_metrics(window, throughput: float, *, mover=None,
                   fast_pressure: Optional[float] = None,
                   slow_name=None, seconds: Optional[float] = None):
    """Close an EpochWindow into controller inputs — the one place the
    gauge publication / tick / metric-derivation glue lives (shared by
    CaptionController.observe_window and CaptionArbiter.observe_window,
    so the two paths can never derive from different route keys).
    Returns (metrics, counters, resolved slow tier name(s))."""
    if fast_pressure is not None:
        window.gauge("fast_pressure", fast_pressure)
    if mover is not None:
        names = mover.topology.slow_names
        if len(names) > 1:
            # The §6 writer limit is per controller (Fig. 3 collapse is
            # per device): one writer on each of three devices is fine,
            # so gauge the WORST single device, not the pool total.
            peak = max(mover.take_peak_writers(n) for n in names)
        else:
            peak = mover.take_peak_writers()
        window.gauge("writer_concurrency", peak)
        if slow_name is None and names:
            slow_name = names[0] if len(names) == 1 else names
    slow_name = slow_name if slow_name is not None else "slow"
    counters = window.tick(seconds=seconds)
    metrics = EpochMetrics.from_counters(
        counters, throughput=throughput, slow_name=slow_name)
    return metrics, counters, slow_name


@dataclasses.dataclass(frozen=True)
class Decision:
    """Outcome of one observed epoch."""

    fraction: float
    changed: bool
    phase: Phase
    reason: str
    #: per-slow-device target shares (sum == fraction); single-element on
    #: a two-device topology.
    weights: tuple[float, ...] = ()


class CaptionController:
    """Hill-climbing slow-share controller with hysteresis (§7).

    Scalar on a two-device topology; round-robin coordinate descent over
    the per-device weight vector on an N-device pool."""

    def __init__(
        self,
        topology: TierTopology,
        config: Optional[CaptionConfig] = None,
        *,
        initial_fraction: float = 0.0,
        min_fraction: float = 0.0,
        boundedness: Boundedness = Boundedness.BANDWIDTH_BOUND,
        initial_weights: Optional[Sequence[float]] = None,
        min_weights: Optional[Sequence[float]] = None,
    ):
        self.topology = topology
        self.cfg = config or CaptionConfig()
        self.boundedness = boundedness
        self.n_slow = max(topology.n_slow, 1)
        self.min_fraction = min(max(min_fraction, 0.0), self.cfg.max_fraction)
        if initial_weights is None:
            f = min(max(initial_fraction, self.min_fraction),
                    self.cfg.max_fraction)
            initial_weights = self._spread(f)
        if len(initial_weights) != self.n_slow:
            raise ValueError(
                f"initial_weights needs {self.n_slow} entries")
        self.min_weights = tuple(
            min(max(w, 0.0), 1.0)
            for w in (min_weights or (0.0,) * self.n_slow))
        self.weights = [max(float(w), mw) for w, mw
                        in zip(initial_weights, self.min_weights)]
        # Explicit weight vectors honor the same hard ceiling the scalar
        # prior always did (a full capacity spill can seed at 1.0).
        total = sum(self.weights)
        if total > self.cfg.max_fraction:
            scale = (self.cfg.max_fraction / total
                     if self.cfg.max_fraction > 0 else 0.0)
            self.weights = [w * scale for w in self.weights]
        self.phase = Phase.WARMUP
        # Latency-bound state starts walking home to the fast tier; anything
        # else probes toward the slow tier from its static prior.
        self._dir = -1.0 if self.latency_bound else 1.0
        self._step = self.cfg.step
        #: step each coordinate walk restarts from; halves every full pass
        #: over the devices (annealing), so late passes probe gently and
        #: the stale test below can see the walk has stopped making
        #: progress.
        self._restart_step = self.cfg.step
        self._growth_gate = None  # fleet-level gate (CaptionArbiter)
        self._ewma: Optional[float] = None
        self._epochs_here = 0
        #: last operating point: (weights tuple, smoothed throughput).
        self._prev: Optional[tuple[tuple[float, ...], float]] = None
        self._coord = 0  # active slow device (coordinate descent)
        self._coord_start = self.weights[0]
        self._stale = 0  # consecutive coords that converged without moving
        self._hold_bw: Optional[float] = None  # drift reference (CONVERGED)
        self._hold_bw_dev: dict[str, float] = {}  # per-device references
        #: active duel: incumbent/candidate points + their stint samples.
        self._duel: Optional[dict] = None
        self._duel_wins = 0  # consecutive accepted duels (step expansion)
        self._duel_rejects = 0  # consecutive rejected duels (shrink patience)
        self._duel_losses = 0  # consecutive significant losses (reversal)
        #: EWMA marginal utility: Δthroughput per Δslow-fraction, from
        #: recent duel outcomes / accepted moves (arbiter joint rounds).
        self._utility: Optional[float] = None
        self._memo: Optional["WarmStartMemo"] = None
        self._memo_fp = None  # fingerprint of the workload being walked
        self._memo_checked = False
        self._confirm_hold = False  # warm-started: one stint, then hold
        self.history: list[Decision] = []

    def _spread(self, fraction: float) -> tuple[float, ...]:
        """Distribute a scalar fraction across the slow devices,
        bandwidth-proportionally (the Fig. 10 best-static-ratio prior)."""
        if self.n_slow == 1:
            return (fraction,)
        bw = self.topology.bandwidth_weights()
        if len(bw) != self.n_slow:
            bw = (1.0 / self.n_slow,) * self.n_slow
        return tuple(fraction * b for b in bw)

    # -- derived -------------------------------------------------------------
    @property
    def fraction(self) -> float:
        """Total slow-tier share (sum of the per-device weights)."""
        return float(sum(self.weights))

    @property
    def latency_bound(self) -> bool:
        return self.boundedness == Boundedness.LATENCY_BOUND

    @property
    def converged(self) -> bool:
        return self.phase == Phase.CONVERGED

    @property
    def active_slow_device(self) -> Optional[str]:
        """Name of the device whose share is being probed (arbiter gating)."""
        if self.topology.slows:
            return self.topology.slows[self._coord].name
        return None

    def headroom_pages(self, n_pages: int) -> int:
        """Shard capacity padding (pages) that keeps the WHOLE walk
        shape-stable.

        The walk is bounded: no device's share — and no slow pool's
        total — can exceed ``cfg.max_fraction``, and the fast tier can
        reclaim at most the initial slow share.  A shard padded by
        ``ceil(max_fraction * n_pages)`` pages therefore absorbs every
        actuation the controller can ever request, so a consumer built
        with this headroom (``InterleavedTensor.from_array(...,
        headroom=...)``, ``TieredKVCache.create(...,
        slow_headroom=...)``) never changes shape mid-walk and its
        jitted step functions trace exactly once across all probe
        epochs (asserted by tests/test_hotpaths.py)."""
        return int(math.ceil(self.cfg.max_fraction * max(n_pages, 0)))

    @classmethod
    def from_plan(cls, plan: "Plan", buffer: str, topology: TierTopology,
                  config: Optional[CaptionConfig] = None
                  ) -> "CaptionController":
        """Seed the loop with the static planner's decision for ``buffer``:
        its per-device fractions are the cold-start prior, its capacity
        spill is the floor, and its boundedness selects the latency
        guardrail."""
        d = plan.decisions[buffer]
        weights = None
        if topology.slows and d.device_fractions:
            weights = tuple(d.device_fractions.get(t.name, 0.0)
                            for t in topology.slows)
        return cls(
            topology, config,
            initial_fraction=d.slow_fraction,
            min_fraction=d.min_slow_fraction,
            boundedness=d.boundedness,
            initial_weights=weights,
        )

    @classmethod
    def from_profile(cls, profile: AccessProfile, topology: TierTopology,
                     config: Optional[CaptionConfig] = None, *,
                     initial_fraction: float = 0.0,
                     min_fraction: float = 0.0) -> "CaptionController":
        """Seed the loop straight from a buffer's :class:`AccessProfile`
        — the §6.1 taxonomy applied on the controller-seeding path.

        The profile is classified against the ACTIVE slow tier; a
        LATENCY_BOUND verdict gets fast-pin seeding automatically (zero
        initial share, zero slow floor — Fig. 7: any slow fraction hurts
        a µs-SLO buffer), and the latency guardrail then keeps the walk
        monotone toward fast.  Anything else keeps the caller's prior.
        The drivers use this so a serving KV cache or optimizer state is
        never cold-started onto a tier its access pattern cannot
        amortize."""
        bd = classify_pool(profile, topology)
        if bd == Boundedness.LATENCY_BOUND:
            initial_fraction = 0.0
            min_fraction = 0.0
        return cls(topology, config,
                   initial_fraction=initial_fraction,
                   min_fraction=min_fraction, boundedness=bd)

    # -- warm-start memo -----------------------------------------------------
    def attach_memo(self, memo: "WarmStartMemo") -> None:
        """Attach a :class:`~repro.core.warmstart.WarmStartMemo`.

        The first observed epoch fingerprints the workload (telemetry
        features + topology signature); on a memo hit the controller
        seeds at the remembered weight vector and enters MEASURE
        directly — one confirmation stint, then hold — skipping the
        walk.  On a miss the walk runs cold and the converged weights
        are filed under the fingerprint for next time.  Topology changes
        (hot remove/add) and drift re-probes reset the fingerprint, so a
        re-opened walk re-files under the workload it actually measured."""
        self._memo = memo
        self._memo_fp = None
        self._memo_checked = False

    def _memo_probe(self, metrics: EpochMetrics) -> Optional[Decision]:
        """First-epoch memo check: fingerprint, look up, maybe warm-start."""
        from repro.core.warmstart import fingerprint_metrics
        self._memo_checked = True
        self._memo_fp = fingerprint_metrics(
            metrics, self.topology, boundedness=self.boundedness.value)
        remembered = self._memo.lookup(self._memo_fp)
        if remembered is None or len(remembered) != self.n_slow:
            return None
        target = [min(max(w, mw), 1.0)
                  for w, mw in zip(remembered, self.min_weights)]
        total = sum(target)
        if total > self.cfg.max_fraction > 0:
            target = [w * self.cfg.max_fraction / total for w in target]
        elif total < self.min_fraction:
            # The capacity floor outranks the memory: what does not fit
            # fast must stay placed, remembered optimum or not.
            if total > 0:
                target = [w * self.min_fraction / total for w in target]
            else:
                target = list(self._spread(self.min_fraction))
        self._confirm_hold = True
        return self._move_to(
            tuple(target), Phase.MEASURE,
            f"warm-start: memo hit -> {sum(target):.3f} "
            "(MEASURE, walk skipped)")

    # -- the loop ------------------------------------------------------------
    def observe_window(self, window, throughput: float, *,
                       mover=None, fast_pressure: Optional[float] = None,
                       slow_name=None,
                       seconds: Optional[float] = None) -> Decision:
        """One epoch straight from an EpochWindow: publish the standard
        gauges, close the window, derive metrics, decide.  The shared
        glue for every integration point (serving engine, train driver)."""
        metrics, _, _ = window_metrics(
            window, throughput, mover=mover, fast_pressure=fast_pressure,
            slow_name=slow_name, seconds=seconds)
        return self.observe(metrics)

    def set_growth_gate(self, gate) -> None:
        """Install a fleet-level growth gate (see core/arbiter.py).

        ``gate(controller, metrics) -> (scale, note)`` is consulted
        whenever a positive slow-share step is about to be taken; the
        returned multiplier in [0, 1] clips the step (0 freezes growth).
        A single buffer optimizing locally cannot see the *other* writers
        sharing the slow-tier links — the gate is where that global view
        (the per-device bandwidth budgets) vetoes local greed."""
        self._growth_gate = gate

    def actuated(self, fraction: float) -> None:
        """Feed back what the actuator actually achieved (scalar form).

        Page-granular actuation rounds the requested fraction (a step
        smaller than one page moves nothing); the walk must continue from
        the real operating point, not the phantom request, or throughput
        measurements get attributed to fractions the system never ran.
        The scalar is redistributed over the devices in the current
        proportions (use :meth:`actuated_weights` when the actuator knows
        the per-device outcome)."""
        f = float(fraction)
        total = self.fraction
        if total > 1e-12:
            self.weights = [w * f / total for w in self.weights]
        else:
            self.weights = list(self._spread(f))

    def actuated_weights(self, weights: Sequence[float]) -> None:
        """Feed back the per-device shares the actuator actually achieved."""
        if len(weights) != self.n_slow:
            raise ValueError(f"need {self.n_slow} weights")
        self.weights = [float(w) for w in weights]

    # -- elastic topology (hot-remove / hot-add) -----------------------------
    def remove_device(self, name: str) -> None:
        """Hot-remove slow device ``name`` from the walk.

        The weight simplex loses the coordinate, the total slow share is
        preserved, and the surviving devices are re-seeded bandwidth-
        proportionally (the Fig. 10 best-static-ratio prior, same as a
        cold start on the shrunken topology).  The walk re-opens: the old
        operating point measured a pool that no longer exists."""
        names = self.topology.slow_names
        if name not in names:
            raise KeyError(name)
        if len(names) <= 1:
            raise ValueError("cannot remove the last slow device")
        i = names.index(name)
        total = self.fraction
        self.topology = self.topology.remove_device(name)
        self.n_slow = self.topology.n_slow
        self.min_weights = tuple(w for j, w in enumerate(self.min_weights)
                                 if j != i)
        bw = self.topology.bandwidth_weights()
        self.weights = [max(total * b, mw)
                        for b, mw in zip(bw, self.min_weights)]
        over = sum(self.weights)
        if over > self.cfg.max_fraction > 0:
            self.weights = [w * self.cfg.max_fraction / over
                            for w in self.weights]
        self._reopen()

    def add_device(self, spec, *, initial_weight: float = 0.0) -> None:
        """Hot-add a slow device (a TierSpec or a name the topology can
        promote from ``extra`` / the registry).

        The survivors keep their converged shares — re-probing starts
        from the converged point, not a cold restart — and the newcomer
        enters at ``initial_weight`` with the walk re-opened on ITS
        coordinate, so the next probe climbs the new device first."""
        self.topology = self.topology.add_device(spec)
        self.n_slow = self.topology.n_slow
        self.min_weights = self.min_weights + (0.0,)
        self.weights = list(self.weights) + [
            min(max(float(initial_weight), 0.0), self.cfg.max_fraction)]
        self._reopen()
        self._coord = self.n_slow - 1
        self._coord_start = self.weights[self._coord]

    def observe(self, metrics: EpochMetrics) -> Decision:
        """Feed one epoch; returns the (possibly updated) target weights."""
        a = self.cfg.ewma_alpha
        self._ewma = (metrics.throughput if self._ewma is None
                      else a * metrics.throughput + (1 - a) * self._ewma)
        self._epochs_here += 1
        if self._memo is not None and not self._memo_checked:
            warm = self._memo_probe(metrics)
            if warm is not None:
                return warm
        if self.phase == Phase.CONVERGED:
            drifted = self._check_drift(metrics)
            if drifted is not None:
                return drifted
            return self._emit(False, "converged; holding")
        if self._epochs_here < self.cfg.probe_epochs:
            return self._emit(False, "measuring", phase=Phase.MEASURE)
        return self._adjust(metrics)

    # -- workload-shift re-probing -------------------------------------------
    def _check_drift(self, metrics: EpochMetrics) -> Optional[Decision]:
        """While CONVERGED, watch the EWMA slow-route bandwidth; a drift
        beyond ``drift_threshold`` re-opens the walk (the §7 follow-up:
        Caption must notice the workload changed under it).

        With the per-device split each device's route is tracked against
        its own hold reference, so the detector names WHICH device
        shifted and a compensating shift (one route up, another down,
        aggregate flat) still re-opens the walk."""
        if self.cfg.drift_threshold <= 0:
            return None
        # Per-device references when the split is populated; otherwise the
        # aggregate route (hand-built metrics, single-device topologies).
        samples = (dict(metrics.device_slow_bw) or
                   {"<pool>": metrics.slow_bw})
        if self._hold_bw is None:
            self._hold_bw = metrics.slow_bw
            self._hold_bw_dev = dict(samples)
            return None
        worst_rel, worst_dev = 0.0, None
        for name, bw in samples.items():
            held = self._hold_bw_dev.get(name)
            if held is None:  # route appeared mid-hold (elastic add)
                self._hold_bw_dev[name] = bw
                continue
            rel = abs(bw - held) / max(held, 1.0)
            if rel > worst_rel:
                worst_rel, worst_dev = rel, name
        if worst_rel <= self.cfg.drift_threshold:
            a = self.cfg.ewma_alpha
            self._hold_bw = (a * metrics.slow_bw
                             + (1 - a) * self._hold_bw)
            for name, bw in samples.items():
                self._hold_bw_dev[name] = (
                    a * bw + (1 - a) * self._hold_bw_dev[name])
            return None
        self._reopen()
        where = "" if worst_dev in (None, "<pool>") else f" on {worst_dev}"
        return self._emit(
            False,
            f"route-bw drift {worst_rel*100:+.0f}%{where}: workload "
            "shift, re-probing",
            phase=Phase.MEASURE)

    def reopen(self, reason: str) -> Decision:
        """Re-open the walk on an EXTERNAL drift signal.

        The route-bandwidth drift detector above is the controller's own
        re-open trigger; semantic layers have their own notion of the
        workload shifting under a converged walk — hot-set membership
        churn in ``core/hotness.py`` is the canonical caller — and this
        is their public entry: reset the walk exactly like a bandwidth
        drift re-probe and emit the (unchanged-weights) MEASURE decision
        so the history records why."""
        self._reopen()
        return self._emit(False, f"re-opened: {reason}", phase=Phase.MEASURE)

    def _reopen(self) -> None:
        """Reset the walk state for a fresh convergence run."""
        self.phase = Phase.WARMUP
        self._step = self.cfg.step
        self._restart_step = self.cfg.step
        self._dir = -1.0 if self.latency_bound else 1.0
        self._prev = None
        self._ewma = None
        self._epochs_here = 0
        self._stale = 0
        self._coord = 0
        self._coord_start = self.weights[0]
        self._hold_bw = None
        self._hold_bw_dev = {}
        self._duel = None
        self._duel_wins = 0
        self._duel_rejects = 0
        self._duel_losses = 0
        self._confirm_hold = False
        # The workload (or topology) changed under us: the next observe
        # re-fingerprints, so the memo files the walk under what it
        # actually measured — and may warm-start if the NEW workload is
        # itself a remembered one.
        self._memo_fp = None
        self._memo_checked = False

    # -- the hill-climb ------------------------------------------------------
    def _adjust(self, metrics: EpochMetrics) -> Decision:
        if self._confirm_hold:
            # Warm-started from the memo: the remembered optimum measured
            # one full stint without surprises — hold (drift re-probing
            # guards staleness from here, exactly like a walked optimum).
            self._confirm_hold = False
            return self._move_to(tuple(self.weights), Phase.CONVERGED,
                                 "warm-start confirmed; holding")
        if self.cfg.duel_count > 0:
            return self._adjust_duel(metrics)
        cur_t = float(self._ewma)
        c = self._coord
        reason = ""
        if self._prev is not None:
            prev_w, prev_t = self._prev
            rel = (cur_t - prev_t) / max(abs(prev_t), 1e-12)
            self._note_utility(sum(prev_w), prev_t, self.fraction, cur_t)
            if rel < -self.cfg.hysteresis:
                # Regression: back off to the better point, reverse, shrink.
                # A latency-bound buffer may only ever revert DOWNWARD (the
                # monotone guardrail beats the hill-climber's memory).
                self._dir, self._step = -self._dir, self._step / 2
                back = (tuple(min(p, w) for p, w
                              in zip(prev_w, self.weights))
                        if self.latency_bound else prev_w)
                if self._step < self.cfg.min_step:
                    return self._finish_coord(
                        back, "regressed; step underflow -> hold at "
                        f"{sum(back):.3f}")
                return self._move_to(back, Phase.ADJUST,
                                     f"regressed {rel*100:+.1f}%; revert + "
                                     "reverse")
            if rel <= self.cfg.hysteresis:
                # Flat within hysteresis: the gradient is gone; shrink.
                self._step /= 2
                if self._step < self.cfg.min_step:
                    return self._finish_coord(tuple(self.weights),
                                              "flat; coordinate done")
                reason = f"flat ({rel*100:+.1f}%); refining"
            else:
                reason = f"improved {rel*100:+.1f}%; continue"
        else:
            reason = "cold start; probing"

        delta = self._dir * self._step
        delta, guard = self._guardrails(delta, metrics)
        target = list(self.weights)
        target[c] = self._clamp_coord(c, self.weights[c] + delta)
        if guard:
            reason = f"{reason} [{guard}]"
        if abs(target[c] - self.weights[c]) <= 1e-12:
            # Pinned against a bound or frozen by a guardrail; if the walk
            # cannot move this coordinate it is done here.
            if self._at_bound():
                return self._finish_coord(tuple(self.weights),
                                          reason + "; immovable")
            return self._move_to(tuple(target), Phase.ADJUST,
                                 reason + "; immovable")
        return self._move_to(tuple(target), Phase.ADJUST, reason)

    # -- noise-robust probing: paired duels ----------------------------------
    def _adjust_duel(self, metrics: EpochMetrics) -> Decision:
        """Dueling replacement for the single-sample accept/reject.

        A candidate point w±δ is judged by ``duel_count`` PAIRED stints:
        the controller alternates ``probe_epochs``-long holds at the
        incumbent and the candidate, compares each pair, and accepts
        only on a significant majority of wins — one lucky (or noisy)
        window never moves the operating point.  The step expands on
        consecutive accepted duels and shrinks on rejections (adaptive
        step sizing), bounded by ``max_step``/``min_step``."""
        cur_t = float(self._ewma)
        n = self.cfg.duel_count
        d = self._duel
        if d is not None:
            if d["at"] == "cand":
                d["cand_t"].append(cur_t)
                if len(d["cand_t"]) >= n:
                    return self._duel_decide()
                d["at"] = "base"
                return self._move_to(
                    d["base_w"], Phase.ADJUST,
                    f"duel {len(d['cand_t']) + 1}/{n}: re-measure incumbent")
            d["base_t"].append(cur_t)
            d["at"] = "cand"
            return self._move_to(
                d["cand_w"], Phase.ADJUST,
                f"duel {len(d['cand_t']) + 1}/{n}: probe candidate")
        # Fresh duel: the stint just measured is the incumbent's first
        # sample; pick the candidate exactly like the legacy climb does.
        c = self._coord
        delta = self._dir * self._step
        delta, guard = self._guardrails(delta, metrics)
        target = list(self.weights)
        target[c] = self._clamp_coord(c, self.weights[c] + delta)
        reason = f"duel 1/{n}: probe candidate"
        if guard:
            reason = f"{reason} [{guard}]"
        if abs(target[c] - self.weights[c]) <= 1e-12:
            # Pinned/frozen: no candidate to duel.  Without the legacy
            # flat-shrink (duels never consult _prev) the step must decay
            # here, or a guardrail-frozen coordinate would spin forever.
            self._step /= 2
            if self._at_bound() or self._step < self.cfg.min_step:
                return self._finish_coord(tuple(self.weights),
                                          reason + "; immovable")
            return self._move_to(tuple(self.weights), Phase.ADJUST,
                                 reason + "; immovable")
        self._duel = {"base_w": tuple(self.weights),
                      "cand_w": tuple(target),
                      "base_t": [cur_t], "cand_t": [], "at": "cand"}
        return self._move_to(tuple(target), Phase.ADJUST, reason)

    def _duel_decide(self) -> Decision:
        """All paired stints are in: the candidate must beat the
        incumbent on the PAIRED MEAN beyond the hysteresis band (noise
        averages down across the duels where a single sample cannot),
        and a significant majority of individual losses reverses the
        walk direction."""
        d, self._duel = self._duel, None
        wins = losses = 0
        rels = []
        for b, c in zip(d["base_t"], d["cand_t"]):
            rel = (c - b) / max(abs(b), 1e-12)
            rels.append(rel)
            if rel > self.cfg.hysteresis:
                wins += 1
            elif rel < -self.cfg.hysteresis:
                losses += 1
        n = len(d["cand_t"])
        mean_rel = sum(rels) / n
        base_w, cand_w = d["base_w"], d["cand_w"]
        self._note_utility(sum(base_w), sum(d["base_t"]) / len(d["base_t"]),
                           sum(cand_w), sum(d["cand_t"]) / n)
        tag = f"duel {wins}W-{losses}L/{n} mean {mean_rel*100:+.1f}%"
        if mean_rel > self.cfg.hysteresis and wins >= losses:
            # Significant paired win: commit the candidate; consecutive
            # wins expand the step (a clean gradient deserves coarser
            # probes).
            self._duel_wins += 1
            self._duel_rejects = 0
            self._duel_losses = 0
            if self._duel_wins >= 2 and self.cfg.step_expand > 1.0:
                cap = max(self.cfg.max_step, self.cfg.step)
                self._step = min(self._step * self.cfg.step_expand, cap)
                tag += f"; step up to {self._step:.3f}"
            return self._move_to(cand_w, Phase.ADJUST, tag + "; accept")
        self._duel_wins = 0
        sig_loss = (mean_rel < -self.cfg.hysteresis
                    and losses >= (n + 1) // 2)
        self._duel_losses = self._duel_losses + 1 if sig_loss else 0
        self._duel_rejects += 1
        if self._duel_losses >= 2:
            # TWO consecutive significant majority losses: real gradient
            # pointing the other way (a true overshoot loses every duel;
            # a single loss can be a noise blip) — reverse and shrink.
            self._dir = -self._dir
            self._duel_losses = 0
            self._duel_rejects = 0
            self._step /= 2
            if self._step < self.cfg.min_step:
                return self._finish_coord(base_w, tag + "; step underflow")
            return self._move_to(base_w, Phase.ADJUST,
                                 tag + "; confirmed loss, reverse")
        # A tie (or one loss) is not yet gradient: retry once at the same
        # step before shrinking (shrink patience).  A single unlucky duel
        # would otherwise halve the step, weaken the next duel's signal,
        # and spiral to a premature hold; a true peak still rejects twice
        # in a row and converges.
        if self._duel_rejects < 2:
            return self._move_to(base_w, Phase.ADJUST, tag + "; reject (retry)")
        self._duel_rejects = 0
        self._step /= 2
        if self._step < self.cfg.min_step:
            return self._finish_coord(base_w, tag + "; step underflow")
        return self._move_to(base_w, Phase.ADJUST, tag + "; reject")

    def _note_utility(self, prev_f: float, prev_t: float,
                      cur_f: float, cur_t: float) -> None:
        """EWMA the measured marginal utility (Δthroughput/Δfraction) —
        the controller's contribution to the arbiter's joint rounds."""
        df = cur_f - prev_f
        if abs(df) <= 1e-9:
            return
        u = (cur_t - prev_t) / df
        self._utility = (u if self._utility is None
                         else 0.5 * u + 0.5 * self._utility)

    # -- arbiter joint rounds (propose/commit) -------------------------------
    def propose_growth(self) -> float:
        """Slow-share growth this buffer would take next on its active
        coordinate, in fraction points (the PROPOSE half of the
        arbiter's joint round).  Zero while converged, mid-duel,
        walking down, or latency-bound — those states have no growth
        appetite to coordinate."""
        if (self.converged or self.latency_bound or self._duel is not None
                or self._confirm_hold or self._dir <= 0):
            return 0.0
        c = self._coord
        target = self._clamp_coord(c, self.weights[c] + self._step)
        return max(target - self.weights[c], 0.0)

    def marginal_utility(self) -> float:
        """Recent Δthroughput per Δslow-fraction (>= 0); 0 when the walk
        has not yet measured a move."""
        return max(self._utility or 0.0, 0.0)

    def commit_joint(self, delta: float) -> Decision:
        """COMMIT an arbiter-granted joint move: apply ``delta`` on the
        active coordinate (clamped to the same bounds the walk honors)
        and keep measuring from the new point.

        A grant is evidence of budget headroom, so the probe step is
        restored to at least its initial size — the walk only anneals to
        convergence once grants stop coming.  A bad grant is not
        terminal either: the next measured stint sees the regression and
        the local climb reverts it (shrink steps are never gated)."""
        if self._duel is not None or self.latency_bound:
            return self._emit(False, "joint grant ignored (mid-duel or "
                                     "latency-bound)")
        c = self._coord
        target = list(self.weights)
        target[c] = self._clamp_coord(c, self.weights[c] + float(delta))
        if abs(target[c] - self.weights[c]) <= 1e-12:
            return self._emit(False, "joint grant clamped to no-op")
        self._step = max(self._step, self.cfg.step)
        return self._move_to(
            tuple(target), Phase.ADJUST,
            f"arbiter joint grant {target[c] - self.weights[c]:+.3f} "
            f"on {self.active_slow_device or 'slow'}")

    def _clamp_coord(self, c: int, value: float) -> float:
        """Clamp one coordinate to its floor, the simplex ceiling, and the
        total-fraction floor (the capacity spill must stay placed)."""
        others = self.fraction - self.weights[c]
        lo = max(self.min_weights[c], self.min_fraction - others)
        hi = max(lo, self.cfg.max_fraction - others)
        return min(max(value, lo), hi)

    def _guardrails(self, delta: float, m: EpochMetrics) -> tuple[float, str]:
        notes = []
        if self.latency_bound and delta > 0:
            # Guideline 5 / Fig. 7: never grow the slow share of a
            # latency-bound buffer.
            delta = 0.0
            notes.append("latency-bound: growth pinned")
        if delta > 0 and m.writer_concurrency > self.cfg.writer_limit:
            delta = 0.0
            notes.append(
                f"writers {m.writer_concurrency} > {self.cfg.writer_limit}")
        if delta > 0 and self.cfg.write_damp:
            dev = self._active_spec()
            # The damp is per ACTIVE device: only ITS write share matters
            # (a write storm on CXL-B must not damp growth toward CXL-A).
            wr = m.write_ratio_for(dev.name if dev is not None else None)
            if dev is not None and wr > 0:
                damp = 1.0 - wr * (1.0 - dev.store_bw / dev.load_bw)
                delta *= max(damp, 0.0)
                if damp < 1.0:
                    notes.append(f"write-damped x{damp:.2f}")
        if delta > 0 and self._growth_gate is not None:
            scale, note = self._growth_gate(self, m)
            delta *= min(max(scale, 0.0), 1.0)
            if note:
                notes.append(note)
        if delta < 0 and m.fast_pressure >= self.cfg.pressure_high:
            delta = 0.0
            notes.append(
                f"fast pressure {m.fast_pressure:.2f}: shrink frozen")
        return delta, "; ".join(notes)

    def _active_spec(self):
        """TierSpec of the device whose coordinate is being walked."""
        if self.topology.slows:
            return self.topology.slows[min(self._coord,
                                           len(self.topology.slows) - 1)]
        return self.topology.slow

    def _at_bound(self) -> bool:
        c = self._coord
        w = self.weights[c]
        lo = max(self.min_weights[c],
                 self.min_fraction - (self.fraction - w))
        hi = self.cfg.max_fraction - (self.fraction - w)
        return (w <= lo + 1e-12 and self._dir < 0) or (
            w >= hi - 1e-12 and self._dir > 0)

    def _finish_coord(self, weights: tuple[float, ...], reason: str
                      ) -> Decision:
        """This coordinate's walk ended: converge (single device or a full
        stale pass) or hand the walk to the next device."""
        self._duel_wins = 0
        self._duel_rejects = 0
        self._duel_losses = 0
        if self.n_slow == 1:
            return self._move_to(weights, Phase.CONVERGED, reason)
        # "Moved" means net progress beyond the walk's own probe
        # granularity — the excursion-and-revert dance around an optimum
        # displaces by up to half the restart step without meaning it.
        moved = (abs(weights[self._coord] - self._coord_start)
                 > max(self.cfg.min_step, self._restart_step / 2) + 1e-12)
        self._stale = 0 if moved else self._stale + 1
        if self._stale >= self.n_slow:
            return self._move_to(weights, Phase.CONVERGED,
                                 reason + "; all devices stale")
        out = self._move_to(weights, Phase.ADJUST,
                            reason + "; next device")
        self._coord = (self._coord + 1) % self.n_slow
        if self._coord == 0:  # a full pass ended: anneal the probe step
            self._restart_step = max(2 * self.cfg.min_step,
                                     self._restart_step / 2)
        self._coord_start = self.weights[self._coord]
        self._step = self._restart_step
        self._dir = -1.0 if self.latency_bound else 1.0
        self._prev = None  # fresh baseline for the new coordinate
        return out

    def _move_to(self, weights: tuple[float, ...], phase: Phase,
                 reason: str) -> Decision:
        changed = any(abs(a - b) > 1e-12
                      for a, b in zip(weights, self.weights))
        # A joint grant can land before this stint measured anything; a
        # missing EWMA means there is no baseline worth remembering.
        self._prev = (None if self._ewma is None
                      else (tuple(self.weights), float(self._ewma)))
        self.weights = list(weights)
        self.phase = phase
        self._ewma = None
        self._epochs_here = 0
        if phase == Phase.CONVERGED:
            self._hold_bw = None  # fresh drift reference at the hold point
            self._hold_bw_dev = {}
            if self._memo is not None and self._memo_fp is not None:
                # File (or refresh) the converged answer under the
                # fingerprint taken when this walk opened.
                self._memo.record(self._memo_fp, tuple(self.weights))
        return self._emit(changed, reason, phase=phase)

    def _emit(self, changed: bool, reason: str,
              phase: Optional[Phase] = None) -> Decision:
        if phase is not None:
            self.phase = phase
        d = Decision(self.fraction, changed, self.phase, reason,
                     weights=tuple(self.weights))
        self.history.append(d)
        return d
