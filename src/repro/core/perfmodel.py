"""Analytical performance model calibrated to the paper's measurements.

Encodes the characterization facts (DESIGN.md F1-F5) as closed-form
curves over :class:`~repro.core.tiers.TierSpec`:

* stream-count contention (Fig. 3): linear ramp to ``peak_streams``,
  plateau, then collapse by ``collapse_factor`` beyond
  ``collapse_streams`` (CXL controller-buffer interference);
* random-block efficiency (Fig. 5): converges to sequential bandwidth as
  the block size grows past the latency-bandwidth product;
* RFO traffic doubling for temporal stores to far tiers (Fig. 2/F3);
* DSA-style offloaded bulk movement (Fig. 4b): per-descriptor offload
  latency amortized by batching, hidden entirely by asynchrony.

The planner consumes these curves; MEMO (``core/memo.py``) validates the
model's *shape* against real measurements on the running host.
"""
from __future__ import annotations

import dataclasses
import math

from typing import Optional

from repro.core.tiers import OpClass, TierSpec


# ---------------------------------------------------------------------------
# Fault-injection degradations (emucxl-style): per-device bandwidth/latency
# multipliers applied at every model entry point, so a degraded device is
# slower everywhere at once — mover execution timing (bulk_move_cost), the
# serving engine's modeled step seconds (stream_bandwidth), and the closed-
# loop benchmark throughput models (random_block_bandwidth).  The slowdown
# therefore shows up in telemetry-billed bandwidths, which is exactly the
# EWMA drift signal that re-opens a converged Caption walk.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Degradation:
    """Multipliers applied to one device: bw scales down, latency up."""

    bw_scale: float = 1.0
    latency_scale: float = 1.0


_DEGRADATIONS: dict[str, Degradation] = {}


def set_degradation(name: str, *, bw_scale: float = 1.0,
                    latency_scale: float = 1.0) -> None:
    """Install (or clear, at 1.0/1.0) a degradation for device ``name``."""
    if bw_scale <= 0 or latency_scale <= 0:
        raise ValueError("degradation scales must be > 0")
    if bw_scale == 1.0 and latency_scale == 1.0:
        _DEGRADATIONS.pop(name, None)
    else:
        _DEGRADATIONS[name] = Degradation(bw_scale, latency_scale)


def clear_degradations(name: Optional[str] = None) -> None:
    if name is None:
        _DEGRADATIONS.clear()
    else:
        _DEGRADATIONS.pop(name, None)


def degradation(name: str) -> Optional[Degradation]:
    return _DEGRADATIONS.get(name)


def _eff(tier: TierSpec) -> TierSpec:
    """The spec as currently seen: injected degradations applied.

    Only the public entry points call this (internal helpers take the
    already-degraded spec), so multipliers never compound."""
    d = _DEGRADATIONS.get(tier.name)
    if d is None:
        return tier
    return dataclasses.replace(
        tier,
        load_bw=tier.load_bw * d.bw_scale,
        store_bw=tier.store_bw * d.bw_scale,
        nt_store_bw=tier.nt_store_bw * d.bw_scale,
        load_latency_ns=tier.load_latency_ns * d.latency_scale,
        chase_latency_ns=tier.chase_latency_ns * d.latency_scale,
    )


def stream_bandwidth(tier: TierSpec, op: OpClass, n_streams: int) -> float:
    """Aggregate bandwidth (bytes/s) for ``n_streams`` concurrent streams.

    Reproduces the paper's Fig. 3 shapes: DDR5-L8 load ramps ~linearly to
    26 threads @ 221 GB/s; CXL load peaks near 8 threads then drops past
    12; CXL nt-store peaks at 2 threads then collapses.
    """
    return _stream_bandwidth(_eff(tier), op, n_streams)


def _stream_bandwidth(tier: TierSpec, op: OpClass, n_streams: int) -> float:
    if n_streams <= 0:
        return 0.0
    peak = tier.peak_bw(op)
    p = tier.peak_streams(op)
    c = tier.collapse_streams(op)
    if n_streams <= p:
        # Single-stream bandwidth is latency-bound: one cacheline-ish burst
        # per round trip, but streams overlap; model a concave ramp.
        ramp = n_streams / p
        return peak * min(1.0, ramp ** 0.85)
    if n_streams <= c:
        return peak
    # Collapse region: interference degrades throughput toward
    # collapse_factor * peak (and keeps degrading slowly).
    over = n_streams - c
    floor = peak * tier.collapse_factor
    decay = math.exp(-over / max(c, 1))
    return floor + (peak - floor) * decay


def random_block_bandwidth(
    tier: TierSpec, op: OpClass, block_bytes: int, n_streams: int
) -> float:
    """Fig. 5: random block access converges to sequential as blocks grow.

    Each random block pays one dependent-access latency, then streams at
    the sequential rate; efficiency = stream_time / (latency + stream_time).
    """
    tier = _eff(tier)
    seq = _stream_bandwidth(tier, op, n_streams)
    if seq <= 0.0:
        return 0.0
    per_stream = seq / n_streams
    lat_s = tier.load_latency_ns * 1e-9
    stream_t = block_bytes / per_stream
    eff = stream_t / (lat_s + stream_t)
    return seq * eff


def store_traffic_bytes(tier: TierSpec, nbytes: int, op: OpClass) -> int:
    """Actual bytes moved over the tier's link for a logical store.

    Temporal stores to far tiers fetch the line first (RFO / fetch-modify-
    flush), doubling the traffic; nt-stores write through once.
    """
    if op == OpClass.STORE:
        return int(nbytes * tier.rfo_traffic_multiplier)
    return int(nbytes)


@dataclasses.dataclass(frozen=True)
class MoveCost:
    """Cost breakdown for one bulk transfer (the DSA-analogue engine)."""

    seconds: float
    wire_bytes: int
    offload_overhead_s: float
    stream_seconds: float


# Per-descriptor offload costs for the mover, calibrated to Fig. 4b: a
# non-batched synchronous offload matches plain copy throughput; batching
# (16/128) and asynchrony each buy large wins.
DSA_DESCRIPTOR_OVERHEAD_S = 0.8e-6  # submit + completion poll, per descriptor
DSA_BATCH_OVERHEAD_S = 1.2e-6  # per batch submission


def bulk_move_cost(
    src: TierSpec,
    dst: TierSpec,
    nbytes: int,
    *,
    n_descriptors: int = 1,
    batch_size: int = 1,
    asynchronous: bool = False,
    op: OpClass = OpClass.NT_STORE,
    n_streams: int = 1,
) -> MoveCost:
    """Time to move ``nbytes`` from ``src`` to ``dst`` via the bulk engine.

    The route bandwidth is the min of the source load path, destination
    store path, and any intervening link (paper Fig. 4a: C2C is the
    slowest route because both sides cross the same link).
    """
    src, dst = _eff(src), _eff(dst)
    read_bw = _stream_bandwidth(src, OpClass.LOAD, n_streams)
    write_bw = _stream_bandwidth(dst, op, n_streams)
    if src.name == dst.name and src.link_bw is not None:
        # C2C: one far device serves both sides — controller + link are
        # shared, so read and write serialize (paper Fig. 4a: C2C slowest).
        route = min(1.0 / (1.0 / read_bw + 1.0 / write_bw), src.link_bw / 2)
    else:
        route = min(read_bw, write_bw)
        for t in (src, dst):
            if t.link_bw is not None:
                route = min(route, t.link_bw)
    wire = store_traffic_bytes(dst, nbytes, op)
    stream_s = wire / route
    n_batches = math.ceil(n_descriptors / max(batch_size, 1))
    overhead = (
        n_batches * DSA_BATCH_OVERHEAD_S + n_descriptors * DSA_DESCRIPTOR_OVERHEAD_S
    )
    if asynchronous:
        # Descriptor submission pipelines behind the wire time.
        total = max(stream_s, overhead) + DSA_BATCH_OVERHEAD_S
    else:
        total = stream_s + overhead
    return MoveCost(
        seconds=total,
        wire_bytes=wire,
        offload_overhead_s=overhead,
        stream_seconds=stream_s,
    )


def pipelined_move_cost(
    src: TierSpec,
    dst: TierSpec,
    nbytes: int,
    *,
    block_bytes: int = 1 << 20,
    n_descriptors: int = 1,
    batch_size: int = 1,
    asynchronous: bool = False,
    op: OpClass = OpClass.NT_STORE,
    n_streams: int = 1,
) -> MoveCost:
    """Staged double-buffered migration (the ``stream_copy`` kernel path).

    The transfer goes src -> staging -> dst in ``block_bytes`` chunks
    with the two DMA legs overlapped: chunk i's copy-out rides under
    chunk i+1's copy-in, so the stream time is max(read leg, write leg)
    plus one chunk of pipeline fill/drain — NOT the read+write sum a
    naive staged copy pays.  Relative to :func:`bulk_move_cost` (a
    direct single-leg DMA at the route bandwidth) the only extra cost
    is that fill/drain ramp, which shrinks with ``block_bytes``.
    """
    eff_src, eff_dst = _eff(src), _eff(dst)
    read_bw = _stream_bandwidth(eff_src, OpClass.LOAD, n_streams)
    write_bw = _stream_bandwidth(eff_dst, op, n_streams)
    wire = store_traffic_bytes(eff_dst, nbytes, op)
    if eff_src.name == eff_dst.name and eff_src.link_bw is not None:
        # C2C: both legs cross one shared controller/link — no overlap win.
        route = min(1.0 / (1.0 / read_bw + 1.0 / write_bw),
                    eff_src.link_bw / 2)
        stream_s = wire / route
    else:
        link = min((t.link_bw for t in (eff_src, eff_dst)
                    if t.link_bw is not None), default=float("inf"))
        read_s = wire / min(read_bw, link)
        write_s = wire / min(write_bw, link)
        block = min(max(block_bytes, 1), wire) if wire else 0
        fill = block / min(read_bw, link) + block / min(write_bw, link)
        stream_s = max(read_s, write_s) + fill
    n_batches = math.ceil(n_descriptors / max(batch_size, 1))
    overhead = (
        n_batches * DSA_BATCH_OVERHEAD_S + n_descriptors * DSA_DESCRIPTOR_OVERHEAD_S
    )
    if asynchronous:
        total = max(stream_s, overhead) + DSA_BATCH_OVERHEAD_S
    else:
        total = stream_s + overhead
    return MoveCost(
        seconds=total,
        wire_bytes=wire,
        offload_overhead_s=overhead,
        stream_seconds=stream_s,
    )


@dataclasses.dataclass(frozen=True)
class OverlapCost:
    """Hidden-vs-exposed split of a migration overlapped with compute.

    ``hidden_s`` rides under concurrent decode steps (free); ``exposed_s``
    is the tail that still stalls the issuing thread.  ``exposed_fraction``
    is what the serving engine's modeled step time actually pays.
    """

    move_s: float
    compute_s: float
    hidden_s: float
    exposed_s: float

    @property
    def exposed_fraction(self) -> float:
        return self.exposed_s / self.move_s if self.move_s > 0 else 0.0


def overlap_cost(move_s: float, compute_s: float) -> OverlapCost:
    """Split a migration's ``move_s`` into hidden/exposed time given
    ``compute_s`` of concurrent decode compute it can hide under.

    The async mover issues descriptors non-blocking and drains completions
    at epoch boundaries, so up to ``compute_s`` of wire time overlaps
    decode; only the remainder is exposed as a stall (the emucxl-style
    overlap the paper's DSA asynchrony result, Fig. 4b, predicts).
    """
    move_s = max(float(move_s), 0.0)
    compute_s = max(float(compute_s), 0.0)
    hidden = min(move_s, compute_s)
    return OverlapCost(move_s=move_s, compute_s=compute_s,
                       hidden_s=hidden, exposed_s=move_s - hidden)


def chase_seconds(tier: TierSpec, n_hops: int) -> float:
    """Dependent pointer-chase time (Fig. 2 ptr-chase)."""
    return n_hops * _eff(tier).chase_latency_ns * 1e-9


def effective_latency_amortized(
    tier: TierSpec, compute_ns_between_accesses: float
) -> float:
    """Perceived extra latency per access when computation interleaves.

    The paper's DSB finding (F8): ms-level layered computation amortizes
    the slow tier's extra latency. Returns the visible slowdown factor.
    """
    extra = _eff(tier).chase_latency_ns
    return 1.0 + extra / max(compute_ns_between_accesses + extra, 1e-9)
