"""Placement policies: the numactl / Linux-mempolicy analogue.

The paper drives all of its application studies (§5) through numactl's
``membind`` / ``preferred`` / ``interleave`` modes plus the then-new
kernel patch for **weighted (N:M) interleaving** across memory nodes
[Weiner, 30].  ``MemPolicy`` reproduces that interface at the framework
level: a policy maps the pages of one logical buffer onto tiers.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import numpy as np


def largest_remainder_split(quotas: Sequence[float], units: int,
                            caps: Sequence[int] | None = None
                            ) -> tuple[list[int], int]:
    """Split ``units`` integer slots across buckets with real quotas.

    Floor each quota, then deal the remaining slots one at a time in
    descending fractional-remainder order (cycling), skipping buckets at
    their ``caps``.  The one rounding discipline shared by the N:M policy
    builder, the planner's capacity-aware quantizer, and the minimal-move
    page targets — three hand-rolled copies WILL drift apart.  Returns
    ``(counts, shortfall)``; shortfall > 0 only when every bucket is
    capped."""
    n = len(quotas)
    if n == 0 or units <= 0:
        return [0] * n, max(units, 0)
    base = [int(q) for q in quotas]
    if sum(base) > units:
        # Quotas over-promise (e.g. clamped inputs): rebase proportionally.
        total_q = sum(quotas) or 1.0
        quotas = [q * units / total_q for q in quotas]
        base = [int(q) for q in quotas]
    if caps is not None:
        base = [min(b, c) for b, c in zip(base, caps)]
    order = sorted(range(n), key=lambda i: quotas[i] - base[i], reverse=True)
    need = units - sum(base)
    while need > 0:
        progressed = False
        for i in order:
            if need <= 0:
                break
            if caps is not None and base[i] + 1 > caps[i]:
                continue
            base[i] += 1
            need -= 1
            progressed = True
        if not progressed:
            break
    return base, need


class PolicyKind(enum.Enum):
    MEMBIND = "membind"  # all pages on one tier
    PREFERRED = "preferred"  # fill preferred tier, overflow to next
    INTERLEAVE = "interleave"  # round-robin 1:1
    WEIGHTED_INTERLEAVE = "weighted"  # N:M round-robin (kernel patch analogue)


class BufferClass(enum.Enum):
    """Named buffer classes the planner knows how to reason about."""

    PARAMS = "params"
    GRADS = "grads"
    OPT_STATE = "opt_state"
    KV_CACHE = "kv_cache"
    EMBEDDING = "embedding"
    ACTIVATION = "activation"
    RECURRENT_STATE = "recurrent_state"
    DATA = "data"


@dataclasses.dataclass(frozen=True)
class MemPolicy:
    """Page placement policy over an ordered list of tier names.

    ``weights[i]`` pages go to ``tiers[i]`` per round-robin cycle — the
    N:M interleave of the paper (e.g. DRAM:CXL = 30:1 is 3.23% on CXL).
    """

    kind: PolicyKind
    tiers: tuple[str, ...]
    weights: tuple[int, ...] = ()

    def __post_init__(self):
        if self.kind == PolicyKind.WEIGHTED_INTERLEAVE:
            if len(self.weights) != len(self.tiers):
                raise ValueError("weighted interleave needs one weight per tier")
            if any(w < 0 for w in self.weights) or sum(self.weights) == 0:
                raise ValueError("weights must be non-negative, not all zero")

    @staticmethod
    def membind(tier: str) -> "MemPolicy":
        return MemPolicy(PolicyKind.MEMBIND, (tier,))

    @staticmethod
    def preferred(tier: str, fallback: str) -> "MemPolicy":
        return MemPolicy(PolicyKind.PREFERRED, (tier, fallback))

    @staticmethod
    def interleave(tiers: Sequence[str]) -> "MemPolicy":
        return MemPolicy(PolicyKind.INTERLEAVE, tuple(tiers))

    @staticmethod
    def weighted(tiers: Sequence[str], weights: Sequence[int]) -> "MemPolicy":
        return MemPolicy(
            PolicyKind.WEIGHTED_INTERLEAVE, tuple(tiers), tuple(int(w) for w in weights)
        )

    @staticmethod
    def from_slow_fraction(fast: str, slow: str, fraction: float,
                           denominator: int = 64,
                           round_up: bool = False) -> "MemPolicy":
        """Build the N:M policy closest to placing ``fraction`` on ``slow``.

        Uses the smallest denominator within tolerance so short page runs
        still realize the ratio (a 64-long blocky cycle would leave an
        8-page cache entirely on the fast tier at 50%).  ``round_up``
        guarantees slow_fraction >= fraction (capacity spills must never
        under-shoot)."""
        if fraction <= 0.0:
            return MemPolicy.membind(fast)
        if fraction >= 1.0:
            return MemPolicy.membind(slow)
        import math
        from fractions import Fraction
        if round_up:
            fr = Fraction(math.ceil(fraction * denominator - 1e-12),
                          denominator)
        else:
            fr = Fraction(fraction).limit_denominator(denominator)
        if fr.numerator == 0:
            fr = Fraction(1, denominator)
        m, d = fr.numerator, fr.denominator
        if d == m:
            return MemPolicy.membind(slow)
        return MemPolicy.weighted((fast, slow), (d - m, m))

    @staticmethod
    def from_tier_fractions(fast: str, devices: Sequence[str],
                            fractions: Sequence[float],
                            denominator: int = 64,
                            exact: bool = False) -> "MemPolicy":
        """N-device weighted interleave from a per-device fraction vector.

        ``fractions[i]`` of pages land on ``devices[i]``; the fast tier
        gets the remainder.  By default the TOTAL slow share picks the
        smallest cycle within ``denominator`` (same discipline as
        :meth:`from_slow_fraction`: a 64-long blocky cycle would leave a
        32-page buffer entirely on the fast tier at 30%), and the cycle's
        slow slots split across devices by largest remainder.  ``exact``
        keeps the full ``denominator`` cycle so each device's fraction is
        represented to 1/denominator (the planner's capacity-quantized
        path, where buffers have thousands of pages)."""
        if len(devices) != len(fractions):
            raise ValueError("one fraction per device")
        fr = [min(max(float(f), 0.0), 1.0) for f in fractions]
        total = sum(fr)
        if total > 1.0 + 1e-9:
            raise ValueError(f"device fractions sum to {total:.3f} > 1")
        total = min(total, 1.0)
        if not devices:
            return MemPolicy.membind(fast)
        if total <= 0.0:
            # All-fast, but keep every device in the policy (zero-
            # weighted): membind would lose the device vocabulary, and a
            # fast name outside the well-known list would then be
            # misread as a slow device downstream.
            return MemPolicy.weighted((fast,) + tuple(devices),
                                      (1,) + (0,) * len(devices))
        from fractions import Fraction
        n_active = sum(1 for f in fr if f > 0)
        if exact:
            cycle, units = denominator, int(round(total * denominator))
        else:
            ft = Fraction(total).limit_denominator(denominator)
            if ft.numerator == 0:
                ft = Fraction(1, denominator)
            cycle, units = ft.denominator, ft.numerator
            if units < n_active:
                # Stretch the cycle so every active device owns at least
                # one slot — unless that would blow past the denominator
                # (then small devices must round away regardless).
                k = -(-n_active // units)
                if cycle * k <= denominator:
                    cycle, units = cycle * k, units * k
        units = max(units, 1)
        # Largest-remainder split of the cycle's slow slots across devices.
        base, _ = largest_remainder_split([f / total * units for f in fr],
                                          units)
        w_fast = cycle - units
        # Every tier stays in the policy — zero-weighted if it gets no
        # pages.  Dropping them would (a) let a full offload misread the
        # first slow device as the fast home and (b) shift device
        # ordinals out of topology order, so a later weight-vector
        # repartition would relabel pages onto the wrong device.
        tiers = (fast,) + tuple(devices)
        weights = (w_fast,) + tuple(base)
        return MemPolicy.weighted(tiers, weights)

    def tier_fractions(self) -> dict[str, float]:
        """Per-tier page share this policy realizes (by tier name)."""
        if self.kind in (PolicyKind.MEMBIND, PolicyKind.PREFERRED):
            return {self.tiers[0]: 1.0}
        if self.kind == PolicyKind.INTERLEAVE:
            out: dict[str, float] = {}
            for t in self.tiers:
                out[t] = out.get(t, 0.0) + 1.0 / len(self.tiers)
            return out
        total = sum(self.weights)
        out = {}
        for t, w in zip(self.tiers, self.weights):
            out[t] = out.get(t, 0.0) + w / total
        return out

    def slow_fraction(self, fast: str | None = None, *,
                      n_pages: int | None = None,
                      page_bytes: int | None = None,
                      ledger=None) -> float:
        """Fraction of pages landing beyond the ``fast`` tier.

        ``fast`` defaults to the policy's first tier; pass the topology's
        fast-tier name to get the fraction relative to it (so
        ``membind(slow)`` correctly reports 1.0).

        ``PREFERRED`` is capacity-dependent: pages fill the preferred
        tier and *overflow to the fallback*.  Pass ``n_pages`` +
        ``page_bytes`` + a ``ledger`` (TierLedger: knows free capacity per
        tier) to get the capacity-aware fraction; without them the
        optimistic no-overflow answer is returned.
        """
        fast = fast if fast is not None else self.tiers[0]
        if self.kind == PolicyKind.MEMBIND:
            return 0.0 if self.tiers[0] == fast else 1.0
        if self.kind == PolicyKind.PREFERRED:
            on_preferred = 1.0
            if (n_pages and page_bytes and ledger is not None):
                fit = max(0, int(ledger.free(self.tiers[0]))) // page_bytes
                on_preferred = min(n_pages, fit) / n_pages
            frac = 0.0
            if self.tiers[0] != fast:
                frac += on_preferred
            if len(self.tiers) > 1 and self.tiers[1] != fast:
                frac += 1.0 - on_preferred
            return frac
        if self.kind == PolicyKind.INTERLEAVE:
            on_fast = sum(1 for t in self.tiers if t == fast)
            return (len(self.tiers) - on_fast) / len(self.tiers)
        total = sum(self.weights)
        on_fast = sum(w for t, w in zip(self.tiers, self.weights) if t == fast)
        return (total - on_fast) / total

    def assign_pages(self, n_pages: int) -> np.ndarray:
        """page -> tier-ordinal assignment (int8), round-robin semantics.

        Matches the kernel patch: each cycle places ``weights[i]``
        consecutive pages on tier ``i``.
        """
        if n_pages < 0:
            raise ValueError("n_pages must be >= 0")
        if self.kind in (PolicyKind.MEMBIND, PolicyKind.PREFERRED):
            return np.zeros(n_pages, dtype=np.int8)
        if self.kind == PolicyKind.INTERLEAVE:
            return (np.arange(n_pages) % len(self.tiers)).astype(np.int8)
        cycle = np.concatenate(
            [np.full(w, i, dtype=np.int8) for i, w in enumerate(self.weights) if w > 0]
        )
        reps = -(-n_pages // len(cycle))
        return np.tile(cycle, reps)[:n_pages]

    _FAST_NAMES = ("fast", "hbm", "dram", "device", "ddr5-l8", "snc-2ch")

    def page_is_slow(self, n_pages: int) -> np.ndarray:
        """page -> bool slow-tier map (resolves ordinals via tier NAMES,
        so membind('slow') correctly lands every page on the slow tier)."""
        assign = self.assign_pages(n_pages)
        slow_ord = np.array([t.lower() not in self._FAST_NAMES
                             for t in self.tiers], dtype=bool)
        return slow_ord[np.minimum(assign, len(self.tiers) - 1)]

    def page_counts(self, n_pages: int) -> dict[str, int]:
        assign = self.assign_pages(n_pages)
        return {
            t: int((assign == i).sum())
            for i, t in enumerate(self.tiers)
        }
