"""Page-granular N:M tier interleaving of one logical array.

``InterleavedTensor`` is the framework object behind the paper's
weighted-interleave experiments: a logical ``(rows, *feature)`` array
whose pages are distributed across a fast and a slow tier according to a
:class:`~repro.core.policy.MemPolicy`.  Reads and writes are routed to
the owning tier; embedding-bag reduction (the paper's DLRM §5.2
workload) runs a reduce on each part and sums — numerically identical to
the un-tiered reduce (see tests/property tests).

On the CPU dry-run backend both parts are plain device arrays and the
tier split is accounting (ledger + telemetry + perfmodel); on a TPU
runtime the slow part carries a ``pinned_host`` sharding (backend
``memory_kind``) or is staged by the BulkMover (backend ``staged``).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ledger import TierLedger
from repro.core.policy import MemPolicy
from repro.core.telemetry import GLOBAL_TELEMETRY, Telemetry


def tier_page_map(assign: np.ndarray) -> tuple[np.ndarray, np.ndarray, list[int]]:
    """(assign01, local index within owning tier, per-tier page counts).

    The one place the page->tier bookkeeping lives: tiers beyond the
    second collapse onto slow for storage, and each page's local index
    is its arrival order within its tier.  Shared by construction and
    repartition here and by the tiered KV cache.
    """
    assign01 = np.minimum(np.asarray(assign), 1).astype(np.int8)
    local = np.zeros(len(assign01), np.int32)
    counters = [0, 0]
    for p, t in enumerate(assign01):
        local[p] = counters[t]
        counters[t] += 1
    return assign01, local, counters


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class InterleavedTensor:
    """A logical array paged across (fast, slow) tiers along axis 0."""

    fast: jax.Array  # (n_fast_pages * page_rows, *feature)
    slow: jax.Array  # (n_slow_pages * page_rows, *feature)
    page_tier: jax.Array  # (n_pages,) int8: 0 = fast, 1 = slow
    page_local: jax.Array  # (n_pages,) int32: page index within its tier
    page_rows: int
    rows: int  # logical row count (may be < n_pages * page_rows)

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        children = (self.fast, self.slow, self.page_tier, self.page_local)
        aux = (self.page_rows, self.rows)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        fast, slow, page_tier, page_local = children
        page_rows, rows = aux
        return cls(fast, slow, page_tier, page_local, page_rows, rows)

    # -- construction --------------------------------------------------------
    @classmethod
    def from_array(
        cls,
        array: jax.Array,
        policy: MemPolicy,
        page_rows: int = 256,
        *,
        ledger: Optional[TierLedger] = None,
        name: str = "interleaved",
    ) -> "InterleavedTensor":
        rows = array.shape[0]
        n_pages = max(1, math.ceil(rows / page_rows))
        assign01, page_local, _ = tier_page_map(policy.page_is_slow(n_pages))
        pad_rows = n_pages * page_rows - rows
        feature = array.shape[1:]
        padded = jnp.concatenate(
            [array, jnp.zeros((pad_rows,) + feature, array.dtype)], axis=0
        ) if pad_rows else array
        paged = padded.reshape((n_pages, page_rows) + feature)
        fast_ids = np.nonzero(assign01 == 0)[0]
        slow_ids = np.nonzero(assign01 == 1)[0]
        def take_pages(ids):
            if len(ids) == 0:
                return jnp.zeros((0, page_rows) + feature, array.dtype)
            return paged[np.asarray(ids)]
        fast = take_pages(fast_ids).reshape((-1,) + feature)
        slow = take_pages(slow_ids).reshape((-1,) + feature)
        out = cls(
            fast=fast,
            slow=slow,
            page_tier=jnp.asarray(assign01, jnp.int8),
            page_local=jnp.asarray(page_local, jnp.int32),
            page_rows=page_rows,
            rows=rows,
        )
        if ledger is not None:
            fast_tier = policy.tiers[0]
            slow_tier = policy.tiers[1] if len(policy.tiers) > 1 else policy.tiers[0]
            ledger.register(name, fast_tier, out.fast.size * out.fast.dtype.itemsize)
            if out.slow.size:
                ledger.register(name, slow_tier, out.slow.size * out.slow.dtype.itemsize)
        return out

    # -- derived -------------------------------------------------------------
    @property
    def n_pages(self) -> int:
        return self.page_tier.shape[0]

    @property
    def row_bytes(self) -> int:
        feat = int(np.prod(self.fast.shape[1:])) if self.fast.ndim > 1 else 1
        return feat * self.fast.dtype.itemsize

    def slow_fraction(self) -> float:
        return float(np.asarray(self.page_tier, np.float32).mean())

    # -- addressing ----------------------------------------------------------
    def _route(self, idx: jax.Array):
        """row idx -> (is_slow mask, local flat row index in owning part)."""
        page = idx // self.page_rows
        offset = idx % self.page_rows
        tier = jnp.take(self.page_tier, page, mode="clip")
        local_page = jnp.take(self.page_local, page, mode="clip")
        local = local_page * self.page_rows + offset
        return tier.astype(bool), local

    # -- access --------------------------------------------------------------
    def gather_rows(self, idx: jax.Array) -> jax.Array:
        """rows[idx] — routed gather across both tiers."""
        is_slow, local = self._route(idx)
        if self.fast.shape[0] == 0:  # everything slow (membind-slow / f=1.0)
            return jnp.take(self.slow, local, axis=0, mode="clip")
        from_fast = jnp.take(self.fast, local, axis=0, mode="clip")
        if self.slow.shape[0] == 0:
            return from_fast
        from_slow = jnp.take(self.slow, local, axis=0, mode="clip")
        mask = is_slow.reshape(is_slow.shape + (1,) * (from_fast.ndim - is_slow.ndim))
        return jnp.where(mask, from_slow, from_fast)

    def update_rows(self, idx: jax.Array, values: jax.Array) -> "InterleavedTensor":
        """Functional scatter-set of ``values`` at row ``idx``."""
        is_slow, local = self._route(idx)
        # Out-of-part indices are pushed out of bounds and dropped.
        fast_idx = jnp.where(is_slow, self.fast.shape[0], local)
        slow_idx = jnp.where(is_slow, local, self.slow.shape[0])
        fast = self.fast.at[fast_idx].set(values, mode="drop")
        slow = (
            self.slow.at[slow_idx].set(values, mode="drop")
            if self.slow.shape[0]
            else self.slow
        )
        return dataclasses.replace(self, fast=fast, slow=slow)

    def add_rows(self, idx: jax.Array, values: jax.Array) -> "InterleavedTensor":
        is_slow, local = self._route(idx)
        fast_idx = jnp.where(is_slow, self.fast.shape[0], local)
        slow_idx = jnp.where(is_slow, local, self.slow.shape[0])
        fast = self.fast.at[fast_idx].add(values, mode="drop")
        slow = (
            self.slow.at[slow_idx].add(values, mode="drop")
            if self.slow.shape[0]
            else self.slow
        )
        return dataclasses.replace(self, fast=fast, slow=slow)

    def bag_reduce(
        self,
        indices: jax.Array,  # (batch, bag)
        weights: Optional[jax.Array] = None,  # (batch, bag)
        reduce_fn: Optional[Callable] = None,
    ) -> jax.Array:
        """Embedding-bag sum over both tiers (DLRM §5.2 reduction).

        ``reduce_fn(table, indices, weights) -> (batch, feature)`` lets the
        Pallas ``embedding_reduce`` kernel slot in; default is pure jnp.
        Rows owned by the other tier contribute weight 0 to each part, so
        fast-part + slow-part equals the un-tiered reduction exactly.
        """
        if weights is None:
            weights = jnp.ones(indices.shape, self.fast.dtype)
        is_slow, local = self._route(indices)
        if reduce_fn is None:
            reduce_fn = _jnp_bag_reduce
        out = None
        if self.fast.shape[0]:
            w_fast = jnp.where(is_slow, 0, weights).astype(self.fast.dtype)
            local_fast = jnp.minimum(local, self.fast.shape[0] - 1)
            out = reduce_fn(self.fast, local_fast, w_fast)
        if self.slow.shape[0]:
            w_slow = jnp.where(is_slow, weights, 0).astype(self.slow.dtype)
            local_slow = jnp.minimum(local, self.slow.shape[0] - 1)
            part = reduce_fn(self.slow, local_slow, w_slow)
            out = part if out is None else out + part
        if out is None:  # zero-row tensor
            feat = self.fast.shape[1:]
            out = jnp.zeros((indices.shape[0],) + feat, self.fast.dtype)
        return out

    # -- migration (TPP-style page moves; used by elastic re-planning) -------
    def migrate_pages(self, page_ids: np.ndarray, to_slow: bool) -> "InterleavedTensor":
        """Move whole pages between tiers (host-side; not jit-traceable)."""
        dense = np.asarray(self.to_array())
        tier = np.asarray(self.page_tier).copy()
        tier[np.asarray(page_ids)] = 1 if to_slow else 0
        policy_like = _ExplicitAssignment(tier)
        return InterleavedTensor.from_array(
            jnp.asarray(dense), policy_like, self.page_rows
        )

    def repartition(
        self,
        policy: MemPolicy,
        *,
        mover=None,  # Optional[BulkMover]
        fast_tier: str = "fast",
        slow_tier: str = "slow",
        telemetry: Telemetry = GLOBAL_TELEMETRY,
        source: Optional[str] = None,
        lane: Optional[int] = None,
    ) -> "InterleavedTensor":
        """Re-tier under ``policy``, migrating ONLY the delta pages.

        The Caption controller's actuation path: diff the current
        page->tier map against the policy's and ship just the changed
        pages between tiers — through the
        :class:`~repro.core.mover.BulkMover` when one is given (batched,
        cache-bypass descriptors, writer-limited), else accounted directly
        to telemetry.  Unchanged pages are recompacted within their own
        tier and never cross the interconnect, so inter-tier traffic
        equals ``delta_pages * page_bytes`` exactly (asserted by
        benchmarks/fig11_caption.py).

        Numerically a no-op: ``to_array()`` before == after.
        """
        n = self.n_pages
        new_assign = np.asarray(policy.page_is_slow(n), np.int8)
        old_assign = np.asarray(self.page_tier)
        delta = np.nonzero(new_assign != old_assign)[0]
        if delta.size == 0:
            return self

        feature = self.fast.shape[1:]
        old_local = np.asarray(self.page_local)
        fast_paged = np.asarray(self.fast).reshape((-1, self.page_rows) + feature)
        slow_paged = np.asarray(self.slow).reshape((-1, self.page_rows) + feature)

        def old_page(p: int) -> np.ndarray:
            part = slow_paged if old_assign[p] else fast_paged
            return part[old_local[p]]

        # Ship only the delta through the movement engine.
        moved: dict[int, Any] = {}
        page_bytes = self.page_rows * self.row_bytes
        if mover is not None:
            from repro.core.mover import LANE_BULK, Descriptor
            descs = [
                Descriptor(
                    src_tier=slow_tier if old_assign[p] else fast_tier,
                    dst_tier=fast_tier if old_assign[p] else slow_tier,
                    payload=jnp.asarray(old_page(p)),
                    on_done=lambda r, p=int(p): moved.__setitem__(p, r),
                    lane=LANE_BULK if lane is None else lane,
                    source=source,
                )
                for p in delta
            ]
            mover.submit(descs)
            if mover.asynchronous:
                mover.wait_all()
        else:
            for p in delta:
                src = slow_tier if old_assign[p] else fast_tier
                dst = fast_tier if old_assign[p] else slow_tier
                telemetry.record_move(src, dst, page_bytes, 0.0, source=source)
                moved[int(p)] = old_page(p)

        new_assign, new_local, _ = tier_page_map(new_assign)
        parts: list[list[np.ndarray]] = [[], []]
        for p in range(n):
            parts[int(new_assign[p])].append(
                np.asarray(moved[p]) if p in moved else old_page(p))

        def stack(pages: list[np.ndarray]) -> jax.Array:
            if not pages:
                return jnp.zeros((0,) + feature, self.fast.dtype)
            return jnp.asarray(
                np.stack(pages).reshape((-1,) + feature), self.fast.dtype)

        return dataclasses.replace(
            self,
            fast=stack(parts[0]),
            slow=stack(parts[1]),
            page_tier=jnp.asarray(new_assign, jnp.int8),
            page_local=jnp.asarray(new_local, jnp.int32),
        )

    def repartition_fraction(self, fraction: float, **kwargs
                             ) -> "InterleavedTensor":
        """Re-tier to ``fraction`` slow with the minimal page delta.

        Unlike ``repartition(MemPolicy.from_slow_fraction(...))`` — whose
        N:M pattern can disagree with the current map on many pages — this
        flips exactly ``|target - current|`` pages (evenly spread), so the
        controller's small adjustments stay cheap.
        """
        assign = minimal_delta_assignment(
            np.asarray(self.page_tier), fraction)
        return self.repartition(_ExplicitAssignment(assign), **kwargs)

    def to_array(self) -> jax.Array:
        """Materialize the logical array (tests / checkpointing)."""
        idx = jnp.arange(self.rows)
        return self.gather_rows(idx)

    # -- accounting -----------------------------------------------------------
    def traffic_bytes(self, idx: np.ndarray) -> dict[str, int]:
        """Bytes touched per tier for a concrete index batch (host-side)."""
        page = np.asarray(idx).ravel() // self.page_rows
        tier = np.asarray(self.page_tier)[np.minimum(page, self.n_pages - 1)]
        slow_rows = int((tier == 1).sum())
        fast_rows = int(tier.size - slow_rows)
        return {
            "fast": fast_rows * self.row_bytes,
            "slow": slow_rows * self.row_bytes,
        }

    def record_gather(self, idx: np.ndarray, seconds: float,
                      telemetry: Telemetry = GLOBAL_TELEMETRY) -> None:
        t = self.traffic_bytes(idx)
        telemetry.record_move("fast", "engine", t["fast"], seconds)
        telemetry.record_move("slow", "engine", t["slow"], seconds)


class _ExplicitAssignment:
    """Adapter: a fixed page->tier map with the MemPolicy interface."""

    tiers = ("fast", "slow")

    def __init__(self, assignment: np.ndarray):
        self._assignment = assignment.astype(np.int8)

    def assign_pages(self, n_pages: int) -> np.ndarray:
        if n_pages != len(self._assignment):
            raise ValueError("page count mismatch")
        return self._assignment

    def page_is_slow(self, n_pages: int) -> np.ndarray:
        return self.assign_pages(n_pages).astype(bool)


def minimal_delta_assignment(current: np.ndarray, fraction: float) -> np.ndarray:
    """New page->tier map hitting ``fraction`` slow with the FEWEST flips.

    The Caption actuation helper: two N:M interleave patterns at nearby
    ratios can disagree on far more pages than the ratio delta, so the
    controller flips exactly ``|target - current|`` pages instead,
    spreading the flipped pages evenly (interleave discipline: clustered
    slow pages would serialize on one tier for strided access).
    """
    cur = np.asarray(current, np.int8)
    n = len(cur)
    target = int(round(min(max(fraction, 0.0), 1.0) * n))
    cur_slow = int(cur.sum())
    if target == cur_slow:
        return cur.copy()
    out = cur.copy()
    if target > cur_slow:
        cands = np.nonzero(cur == 0)[0]
        k = target - cur_slow
        new_tier = 1
    else:
        cands = np.nonzero(cur == 1)[0]
        k = cur_slow - target
        new_tier = 0
    pick = cands[(np.arange(k) * len(cands)) // k]  # even spread, distinct
    out[pick] = new_tier
    return out


def _jnp_bag_reduce(table: jax.Array, indices: jax.Array, weights: jax.Array):
    """(batch, bag) weighted gather-sum reference; oracle for the kernel."""
    gathered = jnp.take(table, indices, axis=0)  # (batch, bag, feature)
    return jnp.einsum("bkf,bk->bf", gathered, weights.astype(table.dtype))
