"""Page-granular N:M tier interleaving of one logical array.

``InterleavedTensor`` is the framework object behind the paper's
weighted-interleave experiments: a logical ``(rows, *feature)`` array
whose pages are distributed across a fast tier and N slow devices
according to a :class:`~repro.core.policy.MemPolicy` (the paper's
testbed exposes three CXL devices from different manufacturers at
once, §4/Table 1).  The tensor holds one page shard per device plus a
page->device map; reads and writes are routed to the owning device,
and embedding-bag reduction (the paper's DLRM §5.2 workload) runs a
reduce per shard and sums — numerically identical to the un-tiered
reduce (see tests/property tests).

Hot paths (the Caption loop's actuation and access costs, ISSUE 5):

* **Shape-stable shards** — with ``headroom > 0`` each device shard is
  capacity-padded by that many page chunks, and a repartition whose new
  per-device page counts fit the existing capacities rewrites only the
  index maps and the moved pages: shard shapes (and the pytree treedef)
  are unchanged, so jitted consumers never retrace across Caption probe
  epochs.  Only when headroom is exhausted does the shard grow (and the
  consumer retrace, once).
* **O(Δ) vectorized repartition** — the planner is numpy index
  arithmetic, and moved pages are coalesced into contiguous
  source-local *runs*, one batched mover :class:`Descriptor` per run
  (route-pure, billed bytes identical to per-page movement).
* **Single-pass routed access** — ``gather_rows``/``update_rows`` with
  concrete indices bucket rows by owning device (argsort), do one
  compact take/scatter per shard over only the rows it owns, and
  inverse-permute: one pass of memory traffic instead of one full pass
  per device.  Traced (jit) calls keep the masked N-pass formulation,
  whose shapes are static.

Memory backends (ISSUE 7 — ``backend=`` on :meth:`from_array`):

* ``modeled`` — every shard is a plain device array; the tier split is
  accounting (ledger + telemetry + perfmodel).  The CPU default.
* ``staged`` — same allocation, but actuation payloads stay device-side
  jax slabs so the mover's double-buffered Pallas ``stream_copy``
  executor moves them (HBM -> VMEM staging -> HBM, overlapped DMAs).
* ``memory_kind`` — slow shards physically live in ``pinned_host``
  memory via JAX memory-kind shardings; fast stays in ``device``.
  Requires a runtime exposing pinned-host memory (TPU/GPU); on CPU it
  falls back to ``modeled`` (``resolve_backend`` / ``"auto"``).

Donation (``donate=`` on the writers/repartitioners): when the caller
provably drops the parent tensor, the stable-path update runs through a
jitted ``donate_argnums`` scatter that reuses the receiving shard's
buffer in place — the last full-shard copy-on-write in the probe-epoch
loop goes away (see :mod:`repro.core.donation` for the contract).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.donation import FULL_SHARD_COPIES, donated_update
from repro.core.ledger import TierLedger
from repro.core.policy import MemPolicy, largest_remainder_split
from repro.core.telemetry import GLOBAL_TELEMETRY, Telemetry

#: shard memory backends (see module docstring).
BACKENDS = ("modeled", "staged", "memory_kind")


def supports_memory_kinds() -> bool:
    """True when the runtime exposes a ``pinned_host`` memory space
    (TPU/GPU runtimes); plain CPU only has ``unpinned_host``."""
    try:
        kinds = {m.kind for m in jax.devices()[0].addressable_memories()}
    except Exception:
        return False
    return "pinned_host" in kinds


def resolve_backend(backend: str = "auto") -> str:
    """Resolve a requested backend to one this runtime can honour.

    ``auto`` and ``memory_kind`` degrade to ``modeled`` when the runtime
    has no pinned-host memory space (the CPU-only fallback the README
    backend matrix documents); ``modeled``/``staged`` pass through."""
    if backend in ("auto", "memory_kind"):
        return "memory_kind" if supports_memory_kinds() else "modeled"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of "
                         f"{BACKENDS + ('auto',)}")
    return backend


def _place_part(part: jax.Array, ordinal: int, backend: str) -> jax.Array:
    """Pin a shard to its memory kind: ``device`` for the fast tier,
    ``pinned_host`` for slow devices (``memory_kind`` backend only)."""
    if backend != "memory_kind":
        return part
    try:
        dev = next(iter(part.devices()))
    except Exception:
        dev = jax.devices()[0]
    kind = "device" if ordinal == 0 else "pinned_host"
    sharding = jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
    return jax.device_put(part, sharding)

#: default movement-run length (pages) the minimal-move planner clusters
#: its picks into: one mover Descriptor ships one run, so a Δ-page shift
#: drains ~Δ/RUN descriptors instead of Δ (§6 descriptor batching).  Run
#: STARTS stay evenly spread across the address range, so the interleave
#: discipline holds at run granularity; raise for cheaper actuation,
#: lower toward 1 for finer spreading (1 = legacy page-at-a-time).
DEFAULT_RUN_PAGES = 16

#: gather-crossover cost model (ISSUE 8 satellite), calibrated on the CPU
#: backend: the masked gather pays one eager pass per non-empty shard
#: (fixed dispatch + per-byte XLA work over the WHOLE batch each pass);
#: the bucketed gather pays one host base (route + jnp.asarray hand-back)
#: plus per-row numpy fancy-indexing overhead.  ``choose_gather_path``
#: compares the two estimates per call.
_GATHER_DISPATCH_S = 45e-6  #: per eager-op dispatch, per shard pass
_GATHER_XLA_PER_BYTE = 0.0002e-6  #: masked per-byte cost, per shard pass
_GATHER_HOST_BASE_S = 100e-6  #: bucketed fixed cost (route + hand-back)
_GATHER_HOST_PER_ROW = 0.05e-6  #: numpy fancy-indexing per-row overhead
_GATHER_HOST_PER_BYTE = 0.0003e-6  #: bucketed per-byte copy cost


def device_page_map(assign: np.ndarray, n_devices: int
                    ) -> tuple[np.ndarray, np.ndarray, list[int]]:
    """(device ordinals, local index within owning device, per-device counts).

    The one place the page->device bookkeeping lives: each page's local
    index is its arrival order within its device.  Vectorized (cumsum
    per device) — it runs on every construction and repartition.  Shared
    by construction and repartition here and by the tiered KV cache."""
    dev = np.asarray(assign, np.int8)
    if dev.size and int(dev.max()) >= n_devices:
        raise ValueError(
            f"page assigned to device {int(dev.max())} >= {n_devices}")
    local = np.zeros(len(dev), np.int32)
    counts: list[int] = []
    for d in range(n_devices):
        mask = dev == d
        counts.append(int(mask.sum()))
        if counts[-1]:
            local[mask] = np.cumsum(mask)[mask] - 1
    return dev, local, counts


def tier_page_map(assign: np.ndarray) -> tuple[np.ndarray, np.ndarray, list[int]]:
    """Two-part storage view: devices beyond the second collapse onto the
    slow part, and each page's local index is its arrival order within its
    storage tier (the KV cache's shape-stable fast/slow pools)."""
    assign01 = np.minimum(np.asarray(assign), 1).astype(np.int8)
    return device_page_map(assign01, 2)


def contiguous_runs(values: np.ndarray) -> list[tuple[int, int]]:
    """(start, length) spans where ``values`` increments by exactly 1.

    The run-coalescing primitive: positions whose source locals are
    consecutive form one contiguous slab in the owning shard and ship as
    a single batched mover descriptor."""
    v = np.asarray(values)
    if v.size == 0:
        return []
    breaks = np.nonzero(np.diff(v) != 1)[0] + 1
    starts = np.concatenate(([0], breaks))
    ends = np.concatenate((breaks, [v.size]))
    return [(int(s), int(e - s)) for s, e in zip(starts, ends)]


def route_pure_runs(src: np.ndarray, dst: np.ndarray, loc: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sort moved items by (src, dst, source local) and split them into
    route-pure runs of consecutive locals.

    Returns ``(order, starts, ends)``: ``order`` permutes the inputs into
    run order, and ``[starts[i], ends[i])`` spans run ``i`` within it.
    The ONE place the coalescing rule lives — a run never mixes (src,
    dst) routes and its source locals are adjacent, so it is a single
    contiguous slab of the source pool and ships as one batched
    descriptor.  Shared by ``InterleavedTensor`` and ``TieredKVCache``
    so the two actuation paths can never bill runs differently."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    loc = np.asarray(loc, np.int64)
    if src.size == 0:
        empty = np.zeros(0, np.int64)
        return empty, empty, empty
    order = np.lexsort((loc, dst, src))
    s, d, lo = src[order], dst[order], loc[order]
    key = s * (int(max(s.max(), d.max())) + 2) + d
    brk = np.nonzero((np.diff(key) != 0) | (np.diff(lo) != 1))[0] + 1
    starts = np.concatenate(([0], brk))
    ends = np.concatenate((brk, [order.size]))
    return order, starts, ends


def _policy_device_map(policy, n_pages: int
                       ) -> tuple[np.ndarray, tuple[str, ...]]:
    """Resolve a policy to (page->device ordinals, canonical device names).

    Canonical order is fast first, then the policy's slow tiers in
    declaration order — so ``membind("slow")`` lands every page on device
    1 and a three-device weighted policy yields ordinals 0..3.  The fast
    tier is the first well-known fast name, else — for multi-tier
    policies — the FIRST tier (``from_tier_fractions`` always puts the
    fast home first, and registry fast tiers like ``ddr5-r1`` are not on
    the whitelist)."""
    assign = np.asarray(policy.assign_pages(n_pages))
    tiers = tuple(policy.tiers)
    fast_names = MemPolicy._FAST_NAMES
    fast_tier = next((t for t in tiers if t.lower() in fast_names), None)
    if fast_tier is None and len(tiers) > 1:
        fast_tier = tiers[0]
    if fast_tier is None and len(tiers) == 1:
        # membind on a registry device: infer fast-vs-slow from its KIND
        # (local DRAM/HBM is a fast home; CXL/host/remote are far tiers),
        # so membind('ddr5-r1') is not silently treated as 100% slow when
        # the operator made it the fast tier... and membind('cxl-a') still
        # correctly lands every page on the slow side.
        from repro.core.tiers import DEVICE_REGISTRY
        spec = DEVICE_REGISTRY.get(tiers[0].lower())
        if spec is not None and spec.kind in ("hbm", "ddr_local"):
            fast_tier = tiers[0]

    def is_fast(t: str) -> bool:
        return t == fast_tier or t.lower() in fast_names

    slow_tiers: list[str] = []
    for t in tiers:
        if not is_fast(t) and t not in slow_tiers:
            slow_tiers.append(t)
    names = (fast_tier or "fast",) + (tuple(slow_tiers) or ("slow",))
    dev_of = np.asarray(
        [0 if is_fast(t) else 1 + slow_tiers.index(t) for t in tiers],
        np.int8)
    dev = dev_of[np.minimum(assign, len(tiers) - 1)]
    return dev, names


def resolve_device_names(existing: Sequence[str], n_devices: int,
                         policy_names: Optional[Sequence[str]] = None,
                         fast_tier: Optional[str] = None,
                         slow_tier: Optional[str] = None) -> tuple[str, ...]:
    """Resolve device-ordinal route labels: a policy's names, widened
    with the EXISTING names for higher ordinals (a narrower policy must
    not rename a pinned page's real device), padded with placeholders,
    with the legacy fast/slow overrides on the first two (the two-device
    compatibility path).  Shared by InterleavedTensor and TieredKVCache
    so the two actuation paths can never resolve names differently."""
    names = list(policy_names or existing)
    for n in tuple(existing)[len(names):]:
        names.append(n)
    while len(names) < n_devices:
        names.append(f"slow{len(names)}")
    if fast_tier is not None:
        names[0] = fast_tier
    if slow_tier is not None and len(names) > 1:
        names[1] = slow_tier
    return tuple(names)


def _is_concrete(*arrays) -> bool:
    """True when every array can be materialized host-side (not a jit
    tracer) — the gate between the single-pass bucketed access path and
    the masked shape-static formulation."""
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class InterleavedTensor:
    """A logical array paged across (fast, slow devices...) along axis 0."""

    #: per-device page shards; ``parts[0]`` is the fast tier's.  With
    #: ``headroom > 0`` each shard is capacity-padded: only the slots the
    #: page->local map points at are valid, the rest is reserve.
    parts: tuple[jax.Array, ...]
    page_device: jax.Array  # (n_pages,) int8: 0 = fast, i >= 1 = slow dev i-1
    page_local: jax.Array  # (n_pages,) int32: page index within its device
    page_rows: int
    rows: int  # logical row count (may be < n_pages * page_rows)
    #: route labels per device ordinal (telemetry/mover tier names).
    device_names: tuple[str, ...] = ("fast", "slow")
    #: capacity padding, in page chunks per device shard.  0 = exact-size
    #: shards (every repartition resizes them — the legacy layout);
    #: > 0 = shape-stable shards (repartitions that fit never reallocate,
    #: so jitted consumers never retrace across Caption probe epochs).
    headroom: int = 0
    #: shard memory backend (see module docstring): ``modeled`` (plain
    #: buffers, accounted tiers), ``staged`` (device-side actuation
    #: payloads through the Pallas migration kernel), or ``memory_kind``
    #: (physical ``pinned_host`` slow shards; TPU/GPU runtimes).
    backend: str = "modeled"

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        children = (tuple(self.parts), self.page_device, self.page_local)
        aux = (self.page_rows, self.rows, self.device_names, self.headroom,
               self.backend)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        parts, page_device, page_local = children
        page_rows, rows, device_names, headroom, backend = aux
        return cls(tuple(parts), page_device, page_local, page_rows, rows,
                   device_names, headroom, backend)

    # -- host-side map cache --------------------------------------------------
    def _host_map(self) -> tuple[np.ndarray, np.ndarray]:
        """Cached numpy (page_device, page_local) — controller reads
        (slow_fraction / device_fractions / weights) happen every epoch
        and must not re-sync the device arrays each time."""
        cached = self.__dict__.get("_host_cache")
        if cached is None:
            cached = (np.asarray(self.page_device),
                      np.asarray(self.page_local))
            self.__dict__["_host_cache"] = cached
        return cached

    def _with_map(self, dev: np.ndarray, local: np.ndarray) -> None:
        """Seed the host cache when the maps were just built host-side."""
        self.__dict__["_host_cache"] = (dev, local)

    def _part_host(self, i: int) -> np.ndarray:
        """Cached host mirror of shard ``i``.

        The shards are immutable jax buffers, so a host copy stays valid
        for the instance's lifetime; repartitions hand the mirrors of
        untouched shards to the child instance, which is what makes the
        shape-stable actuation path O(Δ): only the receiving shard is
        copied, everything else is fancy-indexed through its mirror.
        Mirrors must NEVER be mutated — writers copy first."""
        cache = self.__dict__.get("_parts_host")
        if cache is None:
            cache = self.__dict__["_parts_host"] = [None] * len(self.parts)
        if cache[i] is None:
            cache[i] = np.asarray(self.parts[i])
        return cache[i]

    def _with_parts_host(self, mirrors: list) -> None:
        """Seed the host mirrors (entries may be None for lazy fill)."""
        self.__dict__["_parts_host"] = list(mirrors)

    def _inherit_parts_host(self) -> list:
        cache = self.__dict__.get("_parts_host")
        return list(cache) if cache is not None else [None] * len(self.parts)

    # -- two-device compatibility views --------------------------------------
    @property
    def fast(self) -> jax.Array:
        return self.parts[0]

    @property
    def slow(self) -> jax.Array:
        """The single slow shard (two-device path); ambiguous beyond that."""
        if len(self.parts) > 2:
            raise AttributeError(
                "tensor has multiple slow devices; index .parts directly")
        return self.parts[1]

    @property
    def page_tier(self) -> jax.Array:
        """(n_pages,) int8 0/1 fast-vs-slow view of the device map."""
        return jnp.minimum(self.page_device, 1).astype(jnp.int8)

    # -- construction --------------------------------------------------------
    @classmethod
    def from_array(
        cls,
        array: jax.Array,
        policy: MemPolicy,
        page_rows: int = 256,
        *,
        headroom: int = 0,
        backend: str = "modeled",
        ledger: Optional[TierLedger] = None,
        name: str = "interleaved",
    ) -> "InterleavedTensor":
        backend = resolve_backend(backend)
        rows = array.shape[0]
        n_pages = max(1, math.ceil(rows / page_rows))
        assign, names = _policy_device_map(policy, n_pages)
        dev, page_local, counts = device_page_map(assign, len(names))
        pad_rows = n_pages * page_rows - rows
        feature = array.shape[1:]
        padded = jnp.concatenate(
            [array, jnp.zeros((pad_rows,) + feature, array.dtype)], axis=0
        ) if pad_rows else array
        paged = padded.reshape((n_pages, page_rows) + feature)

        def take_pages(ids, cap: int):
            got = (paged[np.asarray(ids)] if len(ids)
                   else jnp.zeros((0, page_rows) + feature, array.dtype))
            if cap > len(ids):
                pad = jnp.zeros((cap - len(ids), page_rows) + feature,
                                array.dtype)
                got = jnp.concatenate([got, pad]) if len(ids) else pad
            return got

        parts = tuple(
            _place_part(
                take_pages(np.nonzero(dev == i)[0],
                           counts[i] + max(int(headroom), 0))
                .reshape((-1,) + feature),
                i, backend)
            for i in range(len(names)))
        out = cls(
            parts=parts,
            page_device=jnp.asarray(dev, jnp.int8),
            page_local=jnp.asarray(page_local, jnp.int32),
            page_rows=page_rows,
            rows=rows,
            device_names=names,
            headroom=max(int(headroom), 0),
            backend=backend,
        )
        out._with_map(dev, page_local)
        if ledger is not None:
            for i, part in enumerate(parts):
                if part.size:
                    ledger.register(name, names[i],
                                    part.size * part.dtype.itemsize)
        return out

    # -- derived -------------------------------------------------------------
    @property
    def n_pages(self) -> int:
        return self.page_device.shape[0]

    @property
    def n_devices(self) -> int:
        return len(self.parts)

    @property
    def row_bytes(self) -> int:
        f = self.parts[0]
        feat = int(np.prod(f.shape[1:])) if f.ndim > 1 else 1
        return feat * f.dtype.itemsize

    @property
    def capacity_pages(self) -> tuple[int, ...]:
        """Per-device shard capacity in pages (valid + headroom)."""
        return tuple(p.shape[0] // self.page_rows for p in self.parts)

    def valid_page_counts(self) -> tuple[int, ...]:
        """Per-device VALID page counts (what the map actually uses)."""
        dev, _ = self._host_map()
        return tuple(np.bincount(dev, minlength=len(self.parts)).tolist())

    def slow_fraction(self) -> float:
        dev, _ = self._host_map()
        return float((dev >= 1).mean())

    def device_fractions(self) -> dict[str, float]:
        """Per-device page share, keyed by device name."""
        dev, _ = self._host_map()
        return {n: float((dev == i).mean())
                for i, n in enumerate(self.device_names)}

    def weights(self) -> tuple[float, ...]:
        """Per-slow-device page shares (the Caption weight vector)."""
        dev, _ = self._host_map()
        return tuple(float((dev == i).mean())
                     for i in range(1, len(self.parts)))

    # -- addressing ----------------------------------------------------------
    def _route(self, idx: jax.Array):
        """row idx -> (owning device ordinal, local flat row index)."""
        page = idx // self.page_rows
        offset = idx % self.page_rows
        dev = jnp.take(self.page_device, page, mode="clip")
        local_page = jnp.take(self.page_local, page, mode="clip")
        local = local_page * self.page_rows + offset
        return dev, local

    def _route_host(self, idx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Host-side :meth:`_route` over the cached maps (no device sync)."""
        dev_map, local_map = self._host_map()
        # clip (not wrap) out-of-range pages, matching the traced path's
        # mode="clip" take semantics
        page = np.clip(idx // self.page_rows, 0, self.n_pages - 1)
        offset = idx % self.page_rows
        dev = dev_map[page]
        local = local_map[page].astype(np.int64) * self.page_rows + offset
        return dev, local

    # -- access --------------------------------------------------------------
    def choose_gather_path(self, n_rows: int) -> str:
        """Size-based crossover: ``"masked"`` or ``"bucketed"`` for a
        concrete gather of ``n_rows`` rows (ISSUE 8 satellite — the
        bucketed path lost to masked at large batches).

        The masked path pays one full eager pass PER NON-EMPTY SHARD
        (dispatch + a batch-sized take/where), so its cost scales with
        shard count and bytes; the bucketed path pays a fixed host base
        (route + the jnp.asarray hand-back) plus per-row fancy-indexing
        overhead, independent of shard count.  The constants are
        calibrated from measured CPU crossovers (2 shards: masked wins
        from ~1-2K rows; 4 shards: bucketed wins through ~4K rows and
        keeps winning at any size once rows are wide).  The chosen path
        is also what ``bench_hotpaths.py`` records in its JSON."""
        shards = sum(1 for p in self.parts if p.shape[0] > 0) or 1
        row_bytes = (int(np.prod(self.parts[0].shape[1:]))
                     * self.parts[0].dtype.itemsize)
        masked_est = shards * (_GATHER_DISPATCH_S
                               + n_rows * row_bytes * _GATHER_XLA_PER_BYTE)
        bucketed_est = (_GATHER_HOST_BASE_S
                        + n_rows * (_GATHER_HOST_PER_ROW
                                    + row_bytes * _GATHER_HOST_PER_BYTE))
        return "bucketed" if bucketed_est < masked_est else "masked"

    def gather_rows(self, idx: jax.Array) -> jax.Array:
        """rows[idx] — routed gather across the device shards.

        Concrete indices pick masked vs bucketed per call through
        :meth:`choose_gather_path`: the bucketed single-pass host gather
        (rows bucketed by owning device, one compact take per shard, no
        per-shard full pass) wins at small/mid batches and on many-shard
        topologies, while the masked N-pass formulation wins at large
        narrow batches where numpy's per-row overhead dominates.  Traced
        indices (inside jit) always use the masked formulation, which is
        shape-static.  The two are value-identical (asserted bit-exact
        by tests/test_hotpaths.py).
        """
        if _is_concrete(idx, self.page_device, *self.parts):
            if self.choose_gather_path(int(np.asarray(idx).size)) == "bucketed":
                return self._gather_rows_bucketed(np.asarray(idx))
            return self._gather_rows_masked(jnp.asarray(idx))
        return self._gather_rows_masked(idx)

    def _gather_rows_masked(self, idx: jax.Array) -> jax.Array:
        dev, local = self._route(idx)
        out = None
        for i, part in enumerate(self.parts):
            if part.shape[0] == 0:
                continue
            got = jnp.take(part, local, axis=0, mode="clip")
            if out is None:
                out = got
            else:
                mask = (dev == i)
                mask = mask.reshape(mask.shape + (1,) * (got.ndim - mask.ndim))
                out = jnp.where(mask, got, out)
        if out is None:  # zero-page tensor
            feat = self.parts[0].shape[1:]
            out = jnp.zeros(idx.shape + feat, self.parts[0].dtype)
        return out

    def _gather_rows_bucketed(self, idx: np.ndarray) -> jax.Array:
        # Host-side numpy on purpose: index shapes change call to call,
        # so staying in XLA would recompile the gather per shape; numpy
        # fancy indexing is the one-pass copy with zero compile cost on
        # the CPU-modeled backend.
        feat = self.parts[0].shape[1:]
        dtype = self.parts[0].dtype
        flat = np.asarray(idx).ravel()
        if flat.size == 0 or all(p.shape[0] == 0 for p in self.parts):
            return jnp.zeros(idx.shape + feat, dtype)
        dev, local = self._route_host(flat)
        out = np.empty((flat.size,) + feat, dtype)
        for i, part in enumerate(self.parts):
            mask = dev == i
            if not mask.any():
                continue  # shard untouched: no gather pass at all
            view = self._part_host(i)
            rows = np.minimum(local[mask], max(view.shape[0] - 1, 0))
            out[mask] = view[rows]
        return jnp.asarray(out).reshape(idx.shape + feat)

    def _scatter(self, idx: jax.Array, values: jax.Array, op: str,
                 donate: bool = False) -> "InterleavedTensor":
        if _is_concrete(idx, values, self.page_device, *self.parts):
            return self._scatter_bucketed(np.asarray(idx), values, op,
                                          donate=donate)
        return self._scatter_masked(idx, values, op)

    @staticmethod
    def _np_number(dtype) -> bool:
        """True when numpy can index-assign/accumulate this dtype natively
        (extension dtypes like bfloat16 fall back to the masked path)."""
        try:
            return np.issubdtype(np.dtype(dtype), np.number)
        except TypeError:
            return False

    def _scatter_masked(self, idx: jax.Array, values: jax.Array, op: str
                        ) -> "InterleavedTensor":
        dev, local = self._route(idx)
        parts = []
        for i, part in enumerate(self.parts):
            if part.shape[0] == 0:
                parts.append(part)
                continue
            # Out-of-device indices are pushed out of bounds and dropped.
            p_idx = jnp.where(dev == i, local, part.shape[0])
            ref = part.at[p_idx]
            parts.append(ref.set(values, mode="drop") if op == "set"
                         else ref.add(values, mode="drop"))
        return dataclasses.replace(self, parts=tuple(parts))

    def _donate_sharding(self, i: int):
        """out_sharding pin for donated updates (memory_kind shards only)."""
        if self.backend != "memory_kind":
            return None
        return self.parts[i].sharding

    def _scatter_bucketed(self, idx: np.ndarray, values: jax.Array, op: str,
                          donate: bool = False) -> "InterleavedTensor":
        # Same rationale as the bucketed gather: numpy fancy assignment
        # per owning shard, no XLA recompiles on changing index shapes.
        # With ``donate`` the per-shard update is the jitted donated
        # scatter instead — the shard buffer is patched in place, no
        # full copy-on-write (caller drops the parent; see
        # repro.core.donation for the contract).
        feat = self.parts[0].shape[1:]
        if (op == "add" and not donate
                and not self._np_number(self.parts[0].dtype)):
            return self._scatter_masked(jnp.asarray(idx), values, op)
        flat = np.asarray(idx).ravel()
        vals = np.asarray(values).reshape((flat.size,) + feat)
        dev, local = self._route_host(flat)
        parts = list(self.parts)
        mirrors = self._inherit_parts_host()
        for i, part in enumerate(self.parts):
            if part.shape[0] == 0:
                continue
            mask = dev == i
            if not mask.any():
                continue  # shard untouched: no scatter pass at all
            rows = local[mask]
            keep = rows < part.shape[0]
            if donate:
                # Release live zero-copy host views of the receiving
                # buffer first: any external reference blocks XLA input/
                # output aliasing and donation silently degrades to a
                # full copy (repro.core.donation VIEW HAZARD).
                mirrors[i] = None
                cache = self.__dict__.get("_parts_host")
                if cache is not None:
                    cache[i] = None
                new_jax = donated_update(
                    part, rows[keep], vals[mask][keep], op,
                    out_sharding=self._donate_sharding(i))
                parts[i] = new_jax
                mirrors[i] = np.asarray(new_jax)
                continue
            FULL_SHARD_COPIES.bump()
            new_part = self._part_host(i).copy()  # one writable copy
            if op == "set":
                new_part[rows[keep]] = vals[mask][keep]
            else:
                np.add.at(new_part, rows[keep], vals[mask][keep])
            parts[i] = jnp.asarray(new_part)
            mirrors[i] = new_part
        out = dataclasses.replace(self, parts=tuple(parts))
        out._with_parts_host(mirrors)
        return out

    def update_rows(self, idx: jax.Array, values: jax.Array, *,
                    donate: bool = False) -> "InterleavedTensor":
        """Functional scatter-set of ``values`` at row ``idx``.

        ``donate=True`` patches the receiving shards in place through the
        jitted donated scatter — only valid when the caller drops ``self``
        (and every ancestor aliasing its shards) after the call."""
        return self._scatter(idx, values, "set", donate)

    def add_rows(self, idx: jax.Array, values: jax.Array, *,
                 donate: bool = False) -> "InterleavedTensor":
        return self._scatter(idx, values, "add", donate)

    def bag_reduce(
        self,
        indices: jax.Array,  # (batch, bag)
        weights: Optional[jax.Array] = None,  # (batch, bag)
        reduce_fn: Optional[Callable] = None,
    ) -> jax.Array:
        """Embedding-bag sum across all device shards (DLRM §5.2 reduction).

        ``reduce_fn(table, indices, weights) -> (batch, feature)`` lets the
        Pallas ``embedding_reduce`` kernel slot in; default is pure jnp.
        Rows owned by another device contribute weight 0 to each shard, so
        the per-shard partials sum to the un-tiered reduction exactly.
        """
        if weights is None:
            weights = jnp.ones(indices.shape, self.parts[0].dtype)
        dev, local = self._route(indices)
        if reduce_fn is None:
            reduce_fn = _jnp_bag_reduce
        out = None
        for i, part in enumerate(self.parts):
            if part.shape[0] == 0:
                continue
            w_i = jnp.where(dev == i, weights, 0).astype(part.dtype)
            local_i = jnp.minimum(local, part.shape[0] - 1)
            partial = reduce_fn(part, local_i, w_i)
            out = partial if out is None else out + partial
        if out is None:  # zero-row tensor
            feat = self.parts[0].shape[1:]
            out = jnp.zeros((indices.shape[0],) + feat, self.parts[0].dtype)
        return out

    # -- migration (TPP-style page moves; used by elastic re-planning) -------
    def migrate_pages(self, page_ids: np.ndarray, to_slow: bool) -> "InterleavedTensor":
        """Move whole pages between tiers (host-side; not jit-traceable)."""
        dense = np.asarray(self.to_array())
        dev = np.asarray(self.page_device).copy()
        dev[np.asarray(page_ids)] = 1 if to_slow else 0
        policy_like = _ExplicitAssignment(dev, self.device_names)
        return InterleavedTensor.from_array(
            jnp.asarray(dense), policy_like, self.page_rows,
            headroom=self.headroom, backend=self.backend,
        )

    def repartition(
        self,
        policy: MemPolicy,
        *,
        mover=None,  # Optional[BulkMover]
        fast_tier: Optional[str] = None,
        slow_tier: Optional[str] = None,
        telemetry: Telemetry = GLOBAL_TELEMETRY,
        source: Optional[str] = None,
        lane: Optional[int] = None,
        donate: bool = False,
    ) -> "InterleavedTensor":
        """Re-tier under ``policy``, migrating ONLY the delta pages.

        The Caption controller's actuation path: diff the current
        page->device map against the policy's and ship just the changed
        pages between devices — through the
        :class:`~repro.core.mover.BulkMover` when one is given (batched,
        cache-bypass descriptors, writer-limited), else accounted directly
        to telemetry.  Unchanged pages never cross the interconnect, so
        inter-device traffic equals ``delta_pages * page_bytes`` exactly
        (asserted by benchmarks/fig11_caption.py).  Every move is billed
        to its real ``(src_device, dst_device)`` route — a page hopping
        between two slow devices is the paper's C2C traffic, not
        fast-tier churn.

        ``fast_tier``/``slow_tier`` override the first two route labels
        (the two-device compatibility path, e.g. hbm/host on v5e).

        ``donate=True`` lets the stable path patch receiving shards in
        place (jitted donated scatter, zero full-shard copies) — only
        valid when the caller drops ``self`` after the call (the Caption
        actuation pattern ``it = it.repartition(...)``).

        Numerically a no-op: ``to_array()`` before == after.
        """
        n = self.n_pages
        new_dev, names = _policy_device_map(policy, n)
        # Widen with the tensor's EXISTING names: a narrower policy on a
        # wider tensor must keep billing the higher ordinals' real
        # devices, not rename them to placeholders.
        names = resolve_device_names(
            self.device_names, max(len(names), len(self.parts)), names,
            fast_tier, slow_tier)
        return self._reassign(new_dev, names, mover=mover,
                              telemetry=telemetry, source=source, lane=lane,
                              donate=donate)

    def reassign_pages(self, new_dev: np.ndarray, *,
                       device_names: Optional[Sequence[str]] = None,
                       mover=None, telemetry: Telemetry = GLOBAL_TELEMETRY,
                       source: Optional[str] = None,
                       lane: Optional[int] = None,
                       donate: bool = False) -> "InterleavedTensor":
        """Re-tier to an EXPLICIT page -> device-ordinal map.

        The semantic-placement entry point (``core/hotness.py``): a
        caller that knows *what* each page holds hands the exact map
        instead of a share vector, and the move still rides the normal
        O(Δ) path — run-coalesced route-pure descriptors, shape-stable
        shards under ``headroom``, optional donation.  A map equal to
        the current assignment returns ``self`` unchanged."""
        new_dev = np.asarray(new_dev, np.int8)
        if new_dev.shape != (self.n_pages,):
            raise ValueError(
                f"assignment has {new_dev.shape} pages, tensor has "
                f"{self.n_pages}")
        if new_dev.size and int(new_dev.min()) < 0:
            raise ValueError("negative device ordinal")
        n_devices = max(len(self.parts), int(new_dev.max(initial=0)) + 1)
        names = resolve_device_names(self.device_names, n_devices,
                                     device_names)
        return self._reassign(new_dev, names, mover=mover,
                              telemetry=telemetry, source=source, lane=lane,
                              donate=donate)

    # -- the vectorized O(Δ) actuation core ----------------------------------
    def _move_runs(self, delta: np.ndarray, old_dev: np.ndarray,
                   old_local: np.ndarray, new_dev: np.ndarray
                   ) -> list[tuple[int, int, np.ndarray, int]]:
        """Coalesce the delta pages into route-pure movement runs.

        Returns ``(src_dev, dst_dev, page_ids, src_local_start)`` tuples
        where the pages' source locals are consecutive — each run is one
        contiguous slab of its source shard and ships as ONE batched
        descriptor.  Sorting is (src, dst, src_local), so coalescing
        never mixes routes and billed bytes are exactly
        ``delta_pages * page_bytes``."""
        if delta.size == 0:
            return []
        order, starts, ends = route_pure_runs(
            old_dev[delta], new_dev[delta], old_local[delta])
        pages = delta[order]
        src = old_dev[delta][order]
        dst = new_dev[delta][order]
        loc = old_local[delta][order]
        return [(int(src[s]), int(dst[s]), pages[s:e], int(loc[s]))
                for s, e in zip(starts, ends)]

    def _ship_runs(self, runs, names, *, mover, telemetry, source, lane
                   ) -> None:
        """Meter the movement runs: one batched descriptor per run
        through the mover, or one telemetry record per run."""
        if not runs:
            return
        page_bytes = self.page_rows * self.row_bytes

        def route_name(d: int) -> str:
            return names[d] if d < len(names) else f"dev{d}"

        if mover is not None:
            from repro.core.mover import LANE_BULK, Descriptor
            pr = self.page_rows

            def slab(s: int, l0: int, n_pages: int):
                # modeled backend ships zero-copy host-mirror views; the
                # staged / memory_kind backends keep the slab device-side
                # so the mover's double-buffered stream_copy executor is
                # the thing that actually moves it.
                if self.backend == "modeled":
                    return self._part_host(s)[l0 * pr: (l0 + n_pages) * pr]
                return self.parts[s][l0 * pr: (l0 + n_pages) * pr]

            descs = [
                Descriptor(
                    src_tier=route_name(s),
                    dst_tier=route_name(d),
                    payload=slab(s, l0, len(pages)),
                    lane=LANE_BULK if lane is None else lane,
                    source=source,
                )
                for s, d, pages, l0 in runs
            ]
            mover.submit(descs)
            if mover.asynchronous:
                mover.wait_all()
        else:
            for s, d, pages, _ in runs:
                telemetry.record_move(route_name(s), route_name(d),
                                      page_bytes * len(pages), 0.0,
                                      source=source)

    def _gather_pages(self, page_ids: np.ndarray, old_dev: np.ndarray,
                      old_local: np.ndarray) -> np.ndarray:
        """(len(page_ids), page_rows, *feature) page data, one compact
        fancy-indexed copy per source shard (vectorized; no per-page
        Python, no XLA recompiles on changing delta shapes)."""
        pr = self.page_rows
        feature = self.parts[0].shape[1:]
        out = np.empty((page_ids.size, pr) + feature, self.parts[0].dtype)
        if page_ids.size == 0:
            return out
        src = old_dev[page_ids]
        for s in np.unique(src):
            mask = src == s
            view = self._part_host(int(s)).reshape((-1, pr) + feature)
            out[mask] = view[old_local[page_ids[mask]]]
        return out

    def _reassign(self, new_dev: np.ndarray, names: tuple[str, ...], *,
                  mover=None, telemetry: Telemetry = GLOBAL_TELEMETRY,
                  source: Optional[str] = None,
                  lane: Optional[int] = None,
                  donate: bool = False) -> "InterleavedTensor":
        n = self.n_pages
        new_dev = np.asarray(new_dev, np.int8)
        old_dev, old_local = self._host_map()
        n_devices = max(len(names), len(self.parts),
                        int(new_dev.max(initial=0)) + 1)
        delta = np.nonzero(new_dev != old_dev)[0]
        if delta.size == 0 and n_devices == len(self.parts):
            return self

        new_counts = np.bincount(new_dev, minlength=n_devices)
        caps = self.capacity_pages

        # Bill / ship the movement first (payloads slice the CURRENT
        # shards): one route-pure batched descriptor per contiguous run.
        runs = self._move_runs(delta, old_dev, old_local, new_dev)
        self._ship_runs(runs, names, mover=mover, telemetry=telemetry,
                        source=source, lane=lane)

        def route_name(d: int) -> str:
            return names[d] if d < len(names) else f"dev{d}"

        stable = (self.headroom > 0 and n_devices == len(self.parts)
                  and all(int(new_counts[d]) <= caps[d]
                          for d in range(n_devices)))
        if stable:
            out = self._reassign_stable(delta, old_dev, old_local, new_dev,
                                        donate=donate)
        else:
            out = self._reassign_rebuild(old_dev, old_local, new_dev,
                                         n_devices)
        final = dataclasses.replace(
            out, device_names=tuple(route_name(d) for d in range(n_devices)))
        final._with_map(*out._host_map())
        final._with_parts_host(out._inherit_parts_host())
        return final

    def _reassign_stable(self, delta: np.ndarray, old_dev: np.ndarray,
                         old_local: np.ndarray, new_dev: np.ndarray,
                         donate: bool = False) -> "InterleavedTensor":
        """Shape-stable fast path: every moved page lands in a free slot
        of its destination shard — shard shapes, the treedef, and every
        unmoved page's slot are untouched, so jitted consumers keep their
        traces.  Planning, index updates, and metered movement are all
        O(Δ).  Materializing the functional update is either one
        copy-on-write of each RECEIVING shard (non-receiving shards are
        reused as-is), or — with ``donate`` — a jitted donated scatter
        that patches the receiving shard's buffer in place: zero full
        copies, O(Δ) rows written (the caller must drop the parent).

        ORDERING HAZARD: a leaving page's old slot counts as free in its
        shard, so an in-place write could clobber it before another
        destination gathers it — therefore every moved page's data is
        gathered into staging FIRST, then all writes happen."""
        pr = self.page_rows
        new_local = old_local.copy()
        parts = list(self.parts)
        mirrors = self._inherit_parts_host()
        caps = self.capacity_pages
        feat = self.parts[0].shape[1:]
        recv = new_dev[delta]
        data_all = self._gather_pages(delta, old_dev, old_local)
        for d in np.unique(recv):
            sel = recv == d
            incoming = delta[sel]
            # free slots = capacity minus the slots kept by staying pages
            staying = (old_dev == d) & (new_dev == d)
            used = np.zeros(caps[int(d)], bool)
            used[old_local[staying]] = True
            free = np.nonzero(~used)[0]
            slots = free[: incoming.size]
            new_local[incoming] = slots.astype(np.int32)
            data = data_all[sel]
            if donate:
                rows = (slots[:, None].astype(np.int64) * pr
                        + np.arange(pr)).reshape(-1)
                # Drop host views of the receiving buffer before the
                # donated call — a live view blocks the in-place alias
                # (repro.core.donation VIEW HAZARD).  ``data_all`` is a
                # fancy-indexed copy, so staging survives the release.
                mirrors[int(d)] = None
                cache = self.__dict__.get("_parts_host")
                if cache is not None:
                    cache[int(d)] = None
                new_jax = donated_update(
                    parts[int(d)], rows, data.reshape((-1,) + feat), "set",
                    out_sharding=self._donate_sharding(int(d)))
                parts[int(d)] = new_jax
                mirrors[int(d)] = np.asarray(new_jax)
                continue
            FULL_SHARD_COPIES.bump()
            new_part = self._part_host(int(d)).copy().reshape(
                (-1, pr) + data.shape[2:])
            new_part[slots] = data
            new_flat = new_part.reshape((-1,) + data.shape[2:])
            parts[int(d)] = jnp.asarray(new_flat)
            mirrors[int(d)] = new_flat
        out = dataclasses.replace(
            self,
            parts=tuple(parts),
            page_device=jnp.asarray(new_dev, jnp.int8),
            page_local=jnp.asarray(new_local, jnp.int32),
        )
        out._with_map(new_dev, new_local)
        out._with_parts_host(mirrors)
        return out

    def _reassign_rebuild(self, old_dev: np.ndarray, old_local: np.ndarray,
                          new_dev: np.ndarray, n_devices: int
                          ) -> "InterleavedTensor":
        """Exact-size (or grow) path: rebuild each shard at its new count
        plus headroom, gathering every device's pages in one vectorized
        take per (dst, src) pair.  This is the path that changes shapes —
        jitted consumers retrace once, by design (headroom exhausted or
        the device set widened)."""
        pr = self.page_rows
        feature = self.parts[0].shape[1:]
        dtype = self.parts[0].dtype
        dev2, local2, counts = device_page_map(new_dev, n_devices)
        parts = []
        mirrors: list = []
        for d in range(n_devices):
            cap = counts[d] + self.headroom
            if cap == 0:
                empty = np.zeros((0,) + tuple(feature), dtype)
                parts.append(_place_part(jnp.asarray(empty), d,
                                         self.backend))
                mirrors.append(empty)
                continue
            pages_d = np.nonzero(dev2 == d)[0]  # page-id order == rank order
            data = np.zeros((cap, pr) + tuple(feature), dtype)
            data[: counts[d]] = self._gather_pages(pages_d, old_dev,
                                                   old_local)
            flat = data.reshape((-1,) + tuple(feature))
            FULL_SHARD_COPIES.bump()
            parts.append(_place_part(jnp.asarray(flat), d, self.backend))
            mirrors.append(flat)
        out = dataclasses.replace(
            self,
            parts=tuple(parts),
            page_device=jnp.asarray(dev2, jnp.int8),
            page_local=jnp.asarray(local2, jnp.int32),
        )
        out._with_map(dev2, local2)
        out._with_parts_host(mirrors)
        return out

    def repartition_fraction(self, fraction: float, **kwargs
                             ) -> "InterleavedTensor":
        """Re-tier to ``fraction`` slow with the minimal page delta
        (two-device path: the single slow device absorbs the fraction)."""
        return self.repartition_weights((float(fraction),), **kwargs)

    def repartition_weights(self, weights: Sequence[float], *,
                            mover=None, fast_tier: Optional[str] = None,
                            slow_tier: Optional[str] = None,
                            device_names: Optional[Sequence[str]] = None,
                            telemetry: Telemetry = GLOBAL_TELEMETRY,
                            source: Optional[str] = None,
                            lane: Optional[int] = None,
                            run_pages: int = DEFAULT_RUN_PAGES,
                            donate: bool = False
                            ) -> "InterleavedTensor":
        """Re-tier to a per-slow-device weight vector with minimal moves.

        ``weights[i]`` is the target page share of slow device ``i``; the
        fast tier keeps the remainder.  Unlike building an N:M policy —
        whose round-robin pattern can disagree with the current map on far
        more pages than the share delta — this flips exactly the surplus/
        deficit page counts, clustered into evenly spread runs of up to
        ``run_pages`` consecutive pages so the mover drains O(runs)
        batched descriptors instead of O(pages).  A weight vector that
        rounds to the current per-device page counts is a true no-op: the
        same object is returned and no mover work is enqueued."""
        n_devices = max(len(self.parts), len(weights) + 1)
        dev, _ = self._host_map()
        new_dev = minimal_delta_weights(dev, tuple(weights), n_devices,
                                        run_pages=run_pages)
        if new_dev is None:  # rounds to the current assignment: no-op
            return self
        names = resolve_device_names(self.device_names, n_devices,
                                     device_names, fast_tier, slow_tier)
        return self._reassign(new_dev, names, mover=mover,
                              telemetry=telemetry, source=source, lane=lane,
                              donate=donate)

    def drain_device(self, device, **kwargs) -> "InterleavedTensor":
        """Move every page off one slow device (elastic hot-remove drain).

        ``device`` is a slow-device ordinal (>= 1) or its name.  The
        departing share is redistributed over the surviving slow devices
        proportionally to their current shares (the fast tier absorbs it
        when no survivor holds pages), and the move rides the normal
        minimal-delta repartition path: run-coalesced LANE_BULK
        descriptors on real (dead device -> survivor) routes.  Keyword
        arguments forward to :meth:`repartition_weights`."""
        if isinstance(device, str):
            if device not in self.device_names:
                raise KeyError(device)
            i = self.device_names.index(device)
        else:
            i = int(device)
        if not 1 <= i < self.n_devices:
            raise KeyError(device)
        cur = list(self.weights())
        departing, cur[i - 1] = cur[i - 1], 0.0
        rest = sum(cur)
        if departing > 0 and rest > 0:
            cur = [w + departing * w / rest for w in cur]
        return self.repartition_weights(tuple(cur), **kwargs)

    def to_array(self) -> jax.Array:
        """Materialize the logical array (tests / checkpointing)."""
        idx = jnp.arange(self.rows)
        return self.gather_rows(idx)

    # -- accounting -----------------------------------------------------------
    def traffic_bytes(self, idx: np.ndarray) -> dict[str, int]:
        """Bytes touched per device for a concrete index batch (host-side)."""
        page = np.asarray(idx).ravel() // self.page_rows
        dev_map, _ = self._host_map()
        dev = dev_map[np.minimum(page, self.n_pages - 1)]
        out = {}
        for i, name in enumerate(self.device_names):
            out[name] = int((dev == i).sum()) * self.row_bytes
        # two-device compatibility keys
        out.setdefault("fast", out.get(self.device_names[0], 0))
        out.setdefault("slow", sum(
            int((dev == i).sum()) * self.row_bytes
            for i in range(1, len(self.parts))))
        return out

    def record_gather(self, idx: np.ndarray, seconds: float,
                      telemetry: Telemetry = GLOBAL_TELEMETRY) -> None:
        t = self.traffic_bytes(idx)
        for i, name in enumerate(self.device_names):
            telemetry.record_move(name, "engine", t.get(name, 0), seconds)


class _ExplicitAssignment:
    """Adapter: a fixed page->device map with the MemPolicy interface."""

    def __init__(self, assignment: np.ndarray,
                 tiers: Sequence[str] = ("fast", "slow")):
        self._assignment = np.asarray(assignment).astype(np.int8)
        self.tiers = tuple(tiers)

    def assign_pages(self, n_pages: int) -> np.ndarray:
        if n_pages != len(self._assignment):
            raise ValueError("page count mismatch")
        return self._assignment

    def page_is_slow(self, n_pages: int) -> np.ndarray:
        return self.assign_pages(n_pages) >= 1


def _round_targets(weights: tuple[float, ...], n_pages: int) -> list[int]:
    """Per-slow-device page targets by largest-remainder rounding.

    The total slow count is ``round(sum(weights) * n)`` — identical to the
    scalar path's rounding — then split so the per-device counts sum to it
    exactly (plain per-device rounding can create or destroy pages)."""
    w = [min(max(float(x), 0.0), 1.0) for x in weights]
    total = min(sum(w), 1.0)
    want = int(round(total * n_pages))
    base, _ = largest_remainder_split([x * n_pages for x in w], want)
    return base


def _spread_run_picks(n_cands: int, k: int, run_pages: int) -> np.ndarray:
    """Indices (into a candidate list of length ``n_cands``) of ``k``
    picks grouped into evenly spaced runs of up to ``run_pages``
    consecutive candidates.

    The movement-coalescing compromise: perfectly even per-page spreading
    (stride n/k) keeps the interleave discipline but makes every moved
    page its own mover descriptor; clustering the picks into short runs
    whose *starts* stay evenly spread keeps the access interleave nearly
    uniform while letting the actuator ship each run as one contiguous
    batched descriptor."""
    if k >= n_cands:
        return np.arange(n_cands)
    n_runs = max(1, -(-k // max(run_pages, 1)))
    picked = np.zeros(n_cands, bool)
    taken = 0
    prev_end = 0
    for j in range(n_runs):
        want = (k - taken + (n_runs - j - 1)) // (n_runs - j)  # ceil spread
        start = max((j * n_cands) // n_runs, prev_end)
        end = min(start + want, n_cands)
        picked[start:end] = True
        taken += end - start
        prev_end = end
    if taken < k:  # dense move: fill from the unpicked complement
        rest = np.nonzero(~picked)[0][: k - taken]
        picked[rest] = True
    return np.nonzero(picked)[0]


def minimal_delta_weights(current: np.ndarray, weights: tuple[float, ...],
                          n_devices: int, *,
                          run_pages: int = DEFAULT_RUN_PAGES
                          ) -> Optional[np.ndarray]:
    """New page->device map hitting ``weights`` with the FEWEST moves.

    Returns ``None`` when the targets round to the current per-device
    counts (the no-op guarantee: callers must not churn page ids or
    enqueue empty-delta mover work).  Surplus pages are released in
    evenly spread *runs* of up to ``run_pages`` consecutive pages — each
    run is a contiguous slab of its device and ships as one batched
    mover descriptor — and the runs are dealt to deficit devices
    round-robin, so each deficit device's new pages stay spread across
    the address range (clustered pages would serialize one device on
    strided access).  ``run_pages=1`` recovers the legacy page-at-a-time
    even spreading exactly."""
    cur = np.asarray(current, np.int8)
    n = len(cur)
    targets = _round_targets(tuple(weights), n)
    targets += [0] * (n_devices - 1 - len(targets))
    counts = np.bincount(cur, minlength=n_devices)
    target_all = [n - sum(targets)] + list(targets)
    if all(int(counts[d]) == target_all[d] for d in range(n_devices)):
        return None
    out = cur.copy()
    # Release surplus pages as evenly spread runs within each device.  A
    # run is contiguous in the device's CANDIDATE order — i.e. in its
    # source locals when those are rank-ordered — which is exactly what
    # the actuator can ship as one contiguous-slab descriptor.
    runs_list: list[np.ndarray] = []
    for d in range(n_devices):
        surplus = int(counts[d]) - target_all[d]
        if surplus <= 0:
            continue
        cands = np.nonzero(cur == d)[0]
        picks = _spread_run_picks(len(cands), surplus, run_pages)
        for start, length in contiguous_runs(picks):
            runs_list.append(cands[picks[start: start + length]])
    # Deal whole runs to deficit devices, round-robin, so each deficit
    # device's new pages stay spread across the address range AND every
    # run stays route-pure (one (src, dst) per run; split only when a
    # deficit fills mid-run).
    runs_list.sort(key=lambda a: int(a[0]))
    deficits = [[d, target_all[d] - int(counts[d])]
                for d in range(n_devices) if target_all[d] > int(counts[d])]
    k = 0
    for run in runs_list:
        offset = 0
        while offset < len(run):
            entry = deficits[k % len(deficits)]
            if entry[1] <= 0:
                deficits.pop(k % len(deficits))
                continue
            take = min(entry[1], len(run) - offset)
            out[run[offset: offset + take]] = entry[0]
            entry[1] -= take
            offset += take
            k += 1
    return out


def minimal_delta_assignment(current: np.ndarray, fraction: float) -> np.ndarray:
    """Two-device view of :func:`minimal_delta_weights`.

    The Caption actuation helper: two N:M interleave patterns at nearby
    ratios can disagree on far more pages than the ratio delta, so the
    controller flips exactly ``|target - current|`` pages instead,
    spreading the flipped pages evenly.  When ``fraction`` rounds to the
    current slow-page count the current assignment is returned unchanged
    (no phantom page-id churn)."""
    cur = np.asarray(current, np.int8)
    out = minimal_delta_weights(np.minimum(cur, 1), (float(fraction),), 2)
    return cur.copy() if out is None else out


def _jnp_bag_reduce(table: jax.Array, indices: jax.Array, weights: jax.Array):
    """(batch, bag) weighted gather-sum reference; oracle for the kernel."""
    gathered = jnp.take(table, indices, axis=0)  # (batch, bag, feature)
    return jnp.einsum("bkf,bk->bf", gathered, weights.astype(table.dtype))
