"""Page-granular N:M tier interleaving of one logical array.

``InterleavedTensor`` is the framework object behind the paper's
weighted-interleave experiments: a logical ``(rows, *feature)`` array
whose pages are distributed across a fast tier and N slow devices
according to a :class:`~repro.core.policy.MemPolicy` (the paper's
testbed exposes three CXL devices from different manufacturers at
once, §4/Table 1).  The tensor holds one page shard per device plus a
page->device map; reads and writes are routed to the owning device,
and embedding-bag reduction (the paper's DLRM §5.2 workload) runs a
reduce per shard and sums — numerically identical to the un-tiered
reduce (see tests/property tests).

On the CPU dry-run backend every shard is a plain device array and the
tier split is accounting (ledger + telemetry + perfmodel); on a TPU
runtime the slow shards carry a ``pinned_host`` sharding (backend
``memory_kind``) or are staged by the BulkMover (backend ``staged``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ledger import TierLedger
from repro.core.policy import MemPolicy, largest_remainder_split
from repro.core.telemetry import GLOBAL_TELEMETRY, Telemetry


def device_page_map(assign: np.ndarray, n_devices: int
                    ) -> tuple[np.ndarray, np.ndarray, list[int]]:
    """(device ordinals, local index within owning device, per-device counts).

    The one place the page->device bookkeeping lives: each page's local
    index is its arrival order within its device.  Shared by construction
    and repartition here and by the tiered KV cache."""
    dev = np.asarray(assign, np.int8)
    if dev.size and int(dev.max()) >= n_devices:
        raise ValueError(
            f"page assigned to device {int(dev.max())} >= {n_devices}")
    local = np.zeros(len(dev), np.int32)
    counters = [0] * n_devices
    for p, d in enumerate(dev):
        local[p] = counters[d]
        counters[d] += 1
    return dev, local, counters


def tier_page_map(assign: np.ndarray) -> tuple[np.ndarray, np.ndarray, list[int]]:
    """Two-part storage view: devices beyond the second collapse onto the
    slow part, and each page's local index is its arrival order within its
    storage tier (the KV cache's shape-stable fast/slow pools)."""
    assign01 = np.minimum(np.asarray(assign), 1).astype(np.int8)
    return device_page_map(assign01, 2)


def _policy_device_map(policy, n_pages: int
                       ) -> tuple[np.ndarray, tuple[str, ...]]:
    """Resolve a policy to (page->device ordinals, canonical device names).

    Canonical order is fast first, then the policy's slow tiers in
    declaration order — so ``membind("slow")`` lands every page on device
    1 and a three-device weighted policy yields ordinals 0..3.  The fast
    tier is the first well-known fast name, else — for multi-tier
    policies — the FIRST tier (``from_tier_fractions`` always puts the
    fast home first, and registry fast tiers like ``ddr5-r1`` are not on
    the whitelist)."""
    assign = np.asarray(policy.assign_pages(n_pages))
    tiers = tuple(policy.tiers)
    fast_names = MemPolicy._FAST_NAMES
    fast_tier = next((t for t in tiers if t.lower() in fast_names), None)
    if fast_tier is None and len(tiers) > 1:
        fast_tier = tiers[0]
    if fast_tier is None and len(tiers) == 1:
        # membind on a registry device: infer fast-vs-slow from its KIND
        # (local DRAM/HBM is a fast home; CXL/host/remote are far tiers),
        # so membind('ddr5-r1') is not silently treated as 100% slow when
        # the operator made it the fast tier... and membind('cxl-a') still
        # correctly lands every page on the slow side.
        from repro.core.tiers import DEVICE_REGISTRY
        spec = DEVICE_REGISTRY.get(tiers[0].lower())
        if spec is not None and spec.kind in ("hbm", "ddr_local"):
            fast_tier = tiers[0]

    def is_fast(t: str) -> bool:
        return t == fast_tier or t.lower() in fast_names

    slow_tiers: list[str] = []
    for t in tiers:
        if not is_fast(t) and t not in slow_tiers:
            slow_tiers.append(t)
    names = (fast_tier or "fast",) + (tuple(slow_tiers) or ("slow",))
    dev_of = np.asarray(
        [0 if is_fast(t) else 1 + slow_tiers.index(t) for t in tiers],
        np.int8)
    dev = dev_of[np.minimum(assign, len(tiers) - 1)]
    return dev, names


def resolve_device_names(existing: Sequence[str], n_devices: int,
                         policy_names: Optional[Sequence[str]] = None,
                         fast_tier: Optional[str] = None,
                         slow_tier: Optional[str] = None) -> tuple[str, ...]:
    """Resolve device-ordinal route labels: a policy's names, widened
    with the EXISTING names for higher ordinals (a narrower policy must
    not rename a pinned page's real device), padded with placeholders,
    with the legacy fast/slow overrides on the first two (the two-device
    compatibility path).  Shared by InterleavedTensor and TieredKVCache
    so the two actuation paths can never resolve names differently."""
    names = list(policy_names or existing)
    for n in tuple(existing)[len(names):]:
        names.append(n)
    while len(names) < n_devices:
        names.append(f"slow{len(names)}")
    if fast_tier is not None:
        names[0] = fast_tier
    if slow_tier is not None and len(names) > 1:
        names[1] = slow_tier
    return tuple(names)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class InterleavedTensor:
    """A logical array paged across (fast, slow devices...) along axis 0."""

    #: per-device page shards; ``parts[0]`` is the fast tier's.
    parts: tuple[jax.Array, ...]
    page_device: jax.Array  # (n_pages,) int8: 0 = fast, i >= 1 = slow dev i-1
    page_local: jax.Array  # (n_pages,) int32: page index within its device
    page_rows: int
    rows: int  # logical row count (may be < n_pages * page_rows)
    #: route labels per device ordinal (telemetry/mover tier names).
    device_names: tuple[str, ...] = ("fast", "slow")

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        children = (tuple(self.parts), self.page_device, self.page_local)
        aux = (self.page_rows, self.rows, self.device_names)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        parts, page_device, page_local = children
        page_rows, rows, device_names = aux
        return cls(tuple(parts), page_device, page_local, page_rows, rows,
                   device_names)

    # -- two-device compatibility views --------------------------------------
    @property
    def fast(self) -> jax.Array:
        return self.parts[0]

    @property
    def slow(self) -> jax.Array:
        """The single slow shard (two-device path); ambiguous beyond that."""
        if len(self.parts) > 2:
            raise AttributeError(
                "tensor has multiple slow devices; index .parts directly")
        return self.parts[1]

    @property
    def page_tier(self) -> jax.Array:
        """(n_pages,) int8 0/1 fast-vs-slow view of the device map."""
        return jnp.minimum(self.page_device, 1).astype(jnp.int8)

    # -- construction --------------------------------------------------------
    @classmethod
    def from_array(
        cls,
        array: jax.Array,
        policy: MemPolicy,
        page_rows: int = 256,
        *,
        ledger: Optional[TierLedger] = None,
        name: str = "interleaved",
    ) -> "InterleavedTensor":
        rows = array.shape[0]
        n_pages = max(1, math.ceil(rows / page_rows))
        assign, names = _policy_device_map(policy, n_pages)
        dev, page_local, counts = device_page_map(assign, len(names))
        pad_rows = n_pages * page_rows - rows
        feature = array.shape[1:]
        padded = jnp.concatenate(
            [array, jnp.zeros((pad_rows,) + feature, array.dtype)], axis=0
        ) if pad_rows else array
        paged = padded.reshape((n_pages, page_rows) + feature)

        def take_pages(ids):
            if len(ids) == 0:
                return jnp.zeros((0, page_rows) + feature, array.dtype)
            return paged[np.asarray(ids)]

        parts = tuple(
            take_pages(np.nonzero(dev == i)[0]).reshape((-1,) + feature)
            for i in range(len(names)))
        out = cls(
            parts=parts,
            page_device=jnp.asarray(dev, jnp.int8),
            page_local=jnp.asarray(page_local, jnp.int32),
            page_rows=page_rows,
            rows=rows,
            device_names=names,
        )
        if ledger is not None:
            for i, part in enumerate(parts):
                if part.size:
                    ledger.register(name, names[i],
                                    part.size * part.dtype.itemsize)
        return out

    # -- derived -------------------------------------------------------------
    @property
    def n_pages(self) -> int:
        return self.page_device.shape[0]

    @property
    def n_devices(self) -> int:
        return len(self.parts)

    @property
    def row_bytes(self) -> int:
        f = self.parts[0]
        feat = int(np.prod(f.shape[1:])) if f.ndim > 1 else 1
        return feat * f.dtype.itemsize

    def slow_fraction(self) -> float:
        return float((np.asarray(self.page_device) >= 1).mean())

    def device_fractions(self) -> dict[str, float]:
        """Per-device page share, keyed by device name."""
        dev = np.asarray(self.page_device)
        return {n: float((dev == i).mean())
                for i, n in enumerate(self.device_names)}

    def weights(self) -> tuple[float, ...]:
        """Per-slow-device page shares (the Caption weight vector)."""
        dev = np.asarray(self.page_device)
        return tuple(float((dev == i).mean())
                     for i in range(1, len(self.parts)))

    # -- addressing ----------------------------------------------------------
    def _route(self, idx: jax.Array):
        """row idx -> (owning device ordinal, local flat row index)."""
        page = idx // self.page_rows
        offset = idx % self.page_rows
        dev = jnp.take(self.page_device, page, mode="clip")
        local_page = jnp.take(self.page_local, page, mode="clip")
        local = local_page * self.page_rows + offset
        return dev, local

    # -- access --------------------------------------------------------------
    def gather_rows(self, idx: jax.Array) -> jax.Array:
        """rows[idx] — routed gather across every device shard."""
        dev, local = self._route(idx)
        out = None
        for i, part in enumerate(self.parts):
            if part.shape[0] == 0:
                continue
            got = jnp.take(part, local, axis=0, mode="clip")
            if out is None:
                out = got
            else:
                mask = (dev == i)
                mask = mask.reshape(mask.shape + (1,) * (got.ndim - mask.ndim))
                out = jnp.where(mask, got, out)
        if out is None:  # zero-page tensor
            feat = self.parts[0].shape[1:]
            out = jnp.zeros(idx.shape + feat, self.parts[0].dtype)
        return out

    def _scatter(self, idx: jax.Array, values: jax.Array, op: str
                 ) -> "InterleavedTensor":
        dev, local = self._route(idx)
        parts = []
        for i, part in enumerate(self.parts):
            if part.shape[0] == 0:
                parts.append(part)
                continue
            # Out-of-device indices are pushed out of bounds and dropped.
            p_idx = jnp.where(dev == i, local, part.shape[0])
            ref = part.at[p_idx]
            parts.append(ref.set(values, mode="drop") if op == "set"
                         else ref.add(values, mode="drop"))
        return dataclasses.replace(self, parts=tuple(parts))

    def update_rows(self, idx: jax.Array, values: jax.Array) -> "InterleavedTensor":
        """Functional scatter-set of ``values`` at row ``idx``."""
        return self._scatter(idx, values, "set")

    def add_rows(self, idx: jax.Array, values: jax.Array) -> "InterleavedTensor":
        return self._scatter(idx, values, "add")

    def bag_reduce(
        self,
        indices: jax.Array,  # (batch, bag)
        weights: Optional[jax.Array] = None,  # (batch, bag)
        reduce_fn: Optional[Callable] = None,
    ) -> jax.Array:
        """Embedding-bag sum across all device shards (DLRM §5.2 reduction).

        ``reduce_fn(table, indices, weights) -> (batch, feature)`` lets the
        Pallas ``embedding_reduce`` kernel slot in; default is pure jnp.
        Rows owned by another device contribute weight 0 to each shard, so
        the per-shard partials sum to the un-tiered reduction exactly.
        """
        if weights is None:
            weights = jnp.ones(indices.shape, self.parts[0].dtype)
        dev, local = self._route(indices)
        if reduce_fn is None:
            reduce_fn = _jnp_bag_reduce
        out = None
        for i, part in enumerate(self.parts):
            if part.shape[0] == 0:
                continue
            w_i = jnp.where(dev == i, weights, 0).astype(part.dtype)
            local_i = jnp.minimum(local, part.shape[0] - 1)
            partial = reduce_fn(part, local_i, w_i)
            out = partial if out is None else out + partial
        if out is None:  # zero-row tensor
            feat = self.parts[0].shape[1:]
            out = jnp.zeros((indices.shape[0],) + feat, self.parts[0].dtype)
        return out

    # -- migration (TPP-style page moves; used by elastic re-planning) -------
    def migrate_pages(self, page_ids: np.ndarray, to_slow: bool) -> "InterleavedTensor":
        """Move whole pages between tiers (host-side; not jit-traceable)."""
        dense = np.asarray(self.to_array())
        dev = np.asarray(self.page_device).copy()
        dev[np.asarray(page_ids)] = 1 if to_slow else 0
        policy_like = _ExplicitAssignment(dev, self.device_names)
        return InterleavedTensor.from_array(
            jnp.asarray(dense), policy_like, self.page_rows
        )

    def repartition(
        self,
        policy: MemPolicy,
        *,
        mover=None,  # Optional[BulkMover]
        fast_tier: Optional[str] = None,
        slow_tier: Optional[str] = None,
        telemetry: Telemetry = GLOBAL_TELEMETRY,
        source: Optional[str] = None,
        lane: Optional[int] = None,
    ) -> "InterleavedTensor":
        """Re-tier under ``policy``, migrating ONLY the delta pages.

        The Caption controller's actuation path: diff the current
        page->device map against the policy's and ship just the changed
        pages between devices — through the
        :class:`~repro.core.mover.BulkMover` when one is given (batched,
        cache-bypass descriptors, writer-limited), else accounted directly
        to telemetry.  Unchanged pages are recompacted within their own
        device and never cross the interconnect, so inter-device traffic
        equals ``delta_pages * page_bytes`` exactly (asserted by
        benchmarks/fig11_caption.py).  Every move is billed to its real
        ``(src_device, dst_device)`` route — a page hopping between two
        slow devices is the paper's C2C traffic, not fast-tier churn.

        ``fast_tier``/``slow_tier`` override the first two route labels
        (the two-device compatibility path, e.g. hbm/host on v5e).

        Numerically a no-op: ``to_array()`` before == after.
        """
        n = self.n_pages
        new_dev, names = _policy_device_map(policy, n)
        # Widen with the tensor's EXISTING names: a narrower policy on a
        # wider tensor must keep billing the higher ordinals' real
        # devices, not rename them to placeholders.
        names = resolve_device_names(
            self.device_names, max(len(names), len(self.parts)), names,
            fast_tier, slow_tier)
        return self._reassign(new_dev, names, mover=mover,
                              telemetry=telemetry, source=source, lane=lane)

    def _reassign(self, new_dev: np.ndarray, names: tuple[str, ...], *,
                  mover=None, telemetry: Telemetry = GLOBAL_TELEMETRY,
                  source: Optional[str] = None,
                  lane: Optional[int] = None) -> "InterleavedTensor":
        n = self.n_pages
        new_dev = np.asarray(new_dev, np.int8)
        old_dev = np.asarray(self.page_device)
        n_devices = max(len(names), len(self.parts),
                        int(new_dev.max(initial=0)) + 1)
        delta = np.nonzero(new_dev != old_dev)[0]
        if delta.size == 0 and n_devices == len(self.parts):
            return self

        feature = self.parts[0].shape[1:]
        old_local = np.asarray(self.page_local)
        paged = [np.asarray(p).reshape((-1, self.page_rows) + feature)
                 for p in self.parts]

        def old_page(p: int) -> np.ndarray:
            return paged[old_dev[p]][old_local[p]]

        def route_name(d: int) -> str:
            return names[d] if d < len(names) else f"dev{d}"

        # Ship only the delta through the movement engine.
        moved: dict[int, Any] = {}
        page_bytes = self.page_rows * self.row_bytes
        if mover is not None and delta.size:
            from repro.core.mover import LANE_BULK, Descriptor
            descs = [
                Descriptor(
                    src_tier=route_name(int(old_dev[p])),
                    dst_tier=route_name(int(new_dev[p])),
                    payload=jnp.asarray(old_page(p)),
                    on_done=lambda r, p=int(p): moved.__setitem__(p, r),
                    lane=LANE_BULK if lane is None else lane,
                    source=source,
                )
                for p in delta
            ]
            mover.submit(descs)
            if mover.asynchronous:
                mover.wait_all()
        else:
            for p in delta:
                telemetry.record_move(
                    route_name(int(old_dev[p])), route_name(int(new_dev[p])),
                    page_bytes, 0.0, source=source)
                moved[int(p)] = old_page(p)

        new_dev, new_local, _ = device_page_map(new_dev, n_devices)
        groups: list[list[np.ndarray]] = [[] for _ in range(n_devices)]
        for p in range(n):
            groups[int(new_dev[p])].append(
                np.asarray(moved[p]) if p in moved else old_page(p))

        def stack(pages: list[np.ndarray]) -> jax.Array:
            if not pages:
                return jnp.zeros((0,) + feature, self.parts[0].dtype)
            return jnp.asarray(
                np.stack(pages).reshape((-1,) + feature),
                self.parts[0].dtype)

        return dataclasses.replace(
            self,
            parts=tuple(stack(g) for g in groups),
            page_device=jnp.asarray(new_dev, jnp.int8),
            page_local=jnp.asarray(new_local, jnp.int32),
            device_names=tuple(
                route_name(d) for d in range(n_devices)),
        )

    def repartition_fraction(self, fraction: float, **kwargs
                             ) -> "InterleavedTensor":
        """Re-tier to ``fraction`` slow with the minimal page delta
        (two-device path: the single slow device absorbs the fraction)."""
        return self.repartition_weights((float(fraction),), **kwargs)

    def repartition_weights(self, weights: Sequence[float], *,
                            mover=None, fast_tier: Optional[str] = None,
                            slow_tier: Optional[str] = None,
                            device_names: Optional[Sequence[str]] = None,
                            telemetry: Telemetry = GLOBAL_TELEMETRY,
                            source: Optional[str] = None,
                            lane: Optional[int] = None
                            ) -> "InterleavedTensor":
        """Re-tier to a per-slow-device weight vector with minimal moves.

        ``weights[i]`` is the target page share of slow device ``i``; the
        fast tier keeps the remainder.  Unlike building an N:M policy —
        whose round-robin pattern can disagree with the current map on far
        more pages than the share delta — this flips exactly the surplus/
        deficit page counts (evenly spread), so the controller's small
        weight-vector adjustments stay cheap.  A weight vector that rounds
        to the current per-device page counts is a true no-op: the same
        object is returned and no mover work is enqueued."""
        n_devices = max(len(self.parts), len(weights) + 1)
        new_dev = minimal_delta_weights(
            np.asarray(self.page_device), tuple(weights), n_devices)
        if new_dev is None:  # rounds to the current assignment: no-op
            return self
        names = resolve_device_names(self.device_names, n_devices,
                                     device_names, fast_tier, slow_tier)
        return self._reassign(new_dev, names, mover=mover,
                              telemetry=telemetry, source=source, lane=lane)

    def to_array(self) -> jax.Array:
        """Materialize the logical array (tests / checkpointing)."""
        idx = jnp.arange(self.rows)
        return self.gather_rows(idx)

    # -- accounting -----------------------------------------------------------
    def traffic_bytes(self, idx: np.ndarray) -> dict[str, int]:
        """Bytes touched per device for a concrete index batch (host-side)."""
        page = np.asarray(idx).ravel() // self.page_rows
        dev = np.asarray(self.page_device)[np.minimum(page, self.n_pages - 1)]
        out = {}
        for i, name in enumerate(self.device_names):
            out[name] = int((dev == i).sum()) * self.row_bytes
        # two-device compatibility keys
        out.setdefault("fast", out.get(self.device_names[0], 0))
        out.setdefault("slow", sum(
            int((dev == i).sum()) * self.row_bytes
            for i in range(1, len(self.parts))))
        return out

    def record_gather(self, idx: np.ndarray, seconds: float,
                      telemetry: Telemetry = GLOBAL_TELEMETRY) -> None:
        t = self.traffic_bytes(idx)
        for i, name in enumerate(self.device_names):
            telemetry.record_move(name, "engine", t.get(name, 0), seconds)


class _ExplicitAssignment:
    """Adapter: a fixed page->device map with the MemPolicy interface."""

    def __init__(self, assignment: np.ndarray,
                 tiers: Sequence[str] = ("fast", "slow")):
        self._assignment = np.asarray(assignment).astype(np.int8)
        self.tiers = tuple(tiers)

    def assign_pages(self, n_pages: int) -> np.ndarray:
        if n_pages != len(self._assignment):
            raise ValueError("page count mismatch")
        return self._assignment

    def page_is_slow(self, n_pages: int) -> np.ndarray:
        return self.assign_pages(n_pages) >= 1


def _round_targets(weights: tuple[float, ...], n_pages: int) -> list[int]:
    """Per-slow-device page targets by largest-remainder rounding.

    The total slow count is ``round(sum(weights) * n)`` — identical to the
    scalar path's rounding — then split so the per-device counts sum to it
    exactly (plain per-device rounding can create or destroy pages)."""
    w = [min(max(float(x), 0.0), 1.0) for x in weights]
    total = min(sum(w), 1.0)
    want = int(round(total * n_pages))
    base, _ = largest_remainder_split([x * n_pages for x in w], want)
    return base


def minimal_delta_weights(current: np.ndarray, weights: tuple[float, ...],
                          n_devices: int) -> Optional[np.ndarray]:
    """New page->device map hitting ``weights`` with the FEWEST moves.

    Returns ``None`` when the targets round to the current per-device
    counts (the no-op guarantee: callers must not churn page ids or
    enqueue empty-delta mover work).  Surplus pages are released evenly
    spread from their device and deficits filled round-robin, keeping the
    interleave discipline (clustered pages would serialize one device on
    strided access)."""
    cur = np.asarray(current, np.int8)
    n = len(cur)
    targets = _round_targets(tuple(weights), n)
    targets += [0] * (n_devices - 1 - len(targets))
    counts = np.bincount(cur, minlength=n_devices)
    target_all = [n - sum(targets)] + list(targets)
    if all(int(counts[d]) == target_all[d] for d in range(n_devices)):
        return None
    out = cur.copy()
    # Release surplus pages (evenly spread within each surplus device)...
    pool: list[int] = []
    for d in range(n_devices):
        surplus = int(counts[d]) - target_all[d]
        if surplus <= 0:
            continue
        cands = np.nonzero(cur == d)[0]
        pick = cands[(np.arange(surplus) * len(cands)) // surplus]
        pool.extend(int(p) for p in pick)
    # ... and deal them to deficit devices, round-robin so each deficit
    # device's new pages stay spread across the address range.
    pool.sort()
    deficits = [(d, target_all[d] - int(counts[d]))
                for d in range(n_devices) if target_all[d] > int(counts[d])]
    k = nxt = 0
    while nxt < len(pool):
        d, need = deficits[k % len(deficits)]
        if need > 0:
            out[pool[nxt]] = d
            nxt += 1
            deficits[k % len(deficits)] = (d, need - 1)
        else:
            deficits.pop(k % len(deficits))
            continue
        k += 1
    return out


def minimal_delta_assignment(current: np.ndarray, fraction: float) -> np.ndarray:
    """Two-device view of :func:`minimal_delta_weights`.

    The Caption actuation helper: two N:M interleave patterns at nearby
    ratios can disagree on far more pages than the ratio delta, so the
    controller flips exactly ``|target - current|`` pages instead,
    spreading the flipped pages evenly.  When ``fraction`` rounds to the
    current slow-page count the current assignment is returned unchanged
    (no phantom page-id churn)."""
    cur = np.asarray(current, np.int8)
    out = minimal_delta_weights(np.minimum(cur, 1), (float(fraction),), 2)
    return cur.copy() if out is None else out


def _jnp_bag_reduce(table: jax.Array, indices: jax.Array, weights: jax.Array):
    """(batch, bag) weighted gather-sum reference; oracle for the kernel."""
    gathered = jnp.take(table, indices, axis=0)  # (batch, bag, feature)
    return jnp.einsum("bkf,bk->bf", gathered, weights.astype(table.dtype))
