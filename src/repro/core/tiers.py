"""Memory-tier model: the paper's testbed and the TPU v5e target.

The paper (Sun et al., MICRO'23) characterizes three tiers on x86:
local 8-channel DDR5, CXL-attached DDR4 behind PCIe Gen5 x16, and
remote-NUMA single-channel DDR5.  On TPU v5e the analogous two tiers are
on-chip HBM and host DRAM behind PCIe.  ``TierSpec`` captures the
characteristics the paper shows matter: peak bandwidth per operation
class, latency (flushed-line and dependent pointer-chase), and the
stream counts beyond which the controller contends (Fig. 3/5 collapse).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

GiB = 1024**3
GB = 1e9


class OpClass(enum.Enum):
    """Access classes from the paper's MEMO microbenchmark."""

    LOAD = "load"
    STORE = "store"  # temporal store (+wb) — incurs RFO on the paper's CXL
    NT_STORE = "nt_store"  # cache-bypass store (nt-store / movdir64B analogue)
    COPY = "copy"  # paired load+store bulk movement


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One memory tier as seen from the compute engine."""

    name: str
    kind: str  # "hbm" | "host" | "ddr_local" | "cxl" | "ddr_remote"
    capacity_bytes: int
    # Peak aggregate bandwidth per op class (bytes/s).
    load_bw: float
    store_bw: float  # temporal store path (RFO-afflicted on CXL-like tiers)
    nt_store_bw: float  # cache-bypass store path
    # Latency (ns).
    load_latency_ns: float  # flushed-line single load
    chase_latency_ns: float  # dependent pointer-chase per hop
    # Contention model (Fig. 3/5): bandwidth ramps ~linearly with streams up
    # to *_peak_streams, stays flat to *_collapse_streams, then degrades by
    # collapse_factor (controller-buffer interference).
    load_peak_streams: int
    store_peak_streams: int
    load_collapse_streams: int
    store_collapse_streams: int
    collapse_factor: float
    # Link behind which the tier sits (PCIe for CXL/host); None = direct.
    link_bw: Optional[float] = None
    # Traffic multiplier for temporal (in-place) writes: read-for-ownership /
    # fetch-modify-flush costs 2x bytes on far tiers (paper §4.2 / F3).
    rfo_traffic_multiplier: float = 1.0

    def peak_bw(self, op: OpClass) -> float:
        if op == OpClass.LOAD:
            return self.load_bw
        if op == OpClass.STORE:
            return self.store_bw
        if op == OpClass.NT_STORE:
            return self.nt_store_bw
        # COPY: harmonic combination of a load and a store stream.
        return 1.0 / (1.0 / self.load_bw + 1.0 / self.nt_store_bw)

    def peak_streams(self, op: OpClass) -> int:
        return self.load_peak_streams if op == OpClass.LOAD else self.store_peak_streams

    def collapse_streams(self, op: OpClass) -> int:
        return (
            self.load_collapse_streams
            if op == OpClass.LOAD
            else self.store_collapse_streams
        )


# ---------------------------------------------------------------------------
# Paper testbed (Table 1 + Figs. 2/3): used to calibrate/validate perfmodel.
# Absolute latencies chosen to satisfy the paper's reported ratios:
#   CXL flushed-load = 2.2x DDR5-L8; CXL chase = 3.7x DDR5-L8 = 2.2x DDR5-R1.
# ---------------------------------------------------------------------------
DDR5_L8 = TierSpec(
    name="ddr5-l8",
    kind="ddr_local",
    capacity_bytes=128 * GiB,
    load_bw=221 * GB,  # Fig. 3a peak
    store_bw=140 * GB,
    nt_store_bw=170 * GB,  # Fig. 3a nt-store peak
    load_latency_ns=170.0,
    chase_latency_ns=90.0,
    load_peak_streams=26,
    store_peak_streams=16,
    load_collapse_streams=64,
    store_collapse_streams=64,
    collapse_factor=0.95,
)

CXL_AGILEX = TierSpec(
    name="cxl-agilex",
    kind="cxl",
    capacity_bytes=16 * GiB,
    load_bw=20 * GB,  # peaks ~8 threads (Fig. 3b)
    store_bw=8 * GB,  # temporal store, RFO-limited
    nt_store_bw=22 * GB,  # ~DDR4-2666 theoretical max, 2 threads
    load_latency_ns=374.0,  # 2.2x DDR5-L8
    chase_latency_ns=333.0,  # 3.7x DDR5-L8
    load_peak_streams=8,
    store_peak_streams=2,
    load_collapse_streams=12,
    store_collapse_streams=4,
    collapse_factor=0.65,  # drops to ~16.8/20 for loads; harsher for stores
    link_bw=64 * GB,  # PCIe Gen5 x16
    rfo_traffic_multiplier=2.0,
)

# ---------------------------------------------------------------------------
# The paper's three CXL devices (Table 1, §4): same host, three different
# manufacturers, markedly different latency/bandwidth/RFO behaviour.  A is
# the ASIC controller with DDR5 behind it (fastest of the three), B an ASIC
# with DDR4, C the FPGA-based prototype (the Agilex card above, renamed into
# the A/B/C scheme so a multi-device topology can hold all three at once).
# ---------------------------------------------------------------------------
CXL_A = TierSpec(
    name="cxl-a",
    kind="cxl",
    capacity_bytes=64 * GiB,
    load_bw=26 * GB,  # ASIC + DDR5-4800 single channel
    store_bw=13 * GB,
    nt_store_bw=24 * GB,
    load_latency_ns=340.0,  # 2.0x DDR5-L8: best of the three
    chase_latency_ns=290.0,
    load_peak_streams=8,
    store_peak_streams=4,
    load_collapse_streams=16,
    store_collapse_streams=8,
    collapse_factor=0.75,
    link_bw=64 * GB,  # PCIe Gen5 x16
    rfo_traffic_multiplier=2.0,
)

CXL_B = TierSpec(
    name="cxl-b",
    kind="cxl",
    capacity_bytes=32 * GiB,
    load_bw=22 * GB,  # ASIC + DDR4-3200
    store_bw=10 * GB,
    nt_store_bw=21 * GB,
    load_latency_ns=360.0,
    chase_latency_ns=310.0,
    load_peak_streams=8,
    store_peak_streams=3,
    load_collapse_streams=14,
    store_collapse_streams=6,
    collapse_factor=0.70,
    link_bw=64 * GB,
    rfo_traffic_multiplier=2.0,
)

#: the FPGA prototype is the paper's worst-case device; alias it into the
#: manufacturer scheme so ``paper_three_device_topology`` reads like Table 1.
CXL_C = dataclasses.replace(CXL_AGILEX, name="cxl-c")

DDR5_R1 = TierSpec(
    name="ddr5-r1",
    kind="ddr_remote",
    capacity_bytes=256 * GiB,
    load_bw=30 * GB,  # single channel DDR5-4800 behind UPI
    store_bw=16 * GB,
    nt_store_bw=26 * GB,
    load_latency_ns=306.0,  # ~1.8x DDR5-L8 (paper: 1x-2.5x band)
    chase_latency_ns=151.0,  # CXL chase / 2.2
    load_peak_streams=8,
    store_peak_streams=4,
    load_collapse_streams=24,
    store_collapse_streams=16,
    collapse_factor=0.85,
)

# ---------------------------------------------------------------------------
# TPU v5e target (deployment): HBM fast tier + host-DRAM "CXL" tier.
# ---------------------------------------------------------------------------
TPU_PEAK_FLOPS_BF16 = 197e12  # per chip
TPU_HBM_BW = 819 * GB
TPU_HBM_BYTES = 16 * GiB
TPU_ICI_LINK_BW = 50 * GB  # per link
TPU_ICI_LINKS_PER_CHIP = 4  # v5e 2D torus: 4 links
TPU_DCN_BW_PER_HOST = 12.5 * GB  # cross-pod (pod axis) effective
TPU_PCIE_BW = 32 * GB  # host<->chip effective (the "CXL" link)
TPU_CHIPS_PER_HOST = 8

HBM_V5E = TierSpec(
    name="hbm",
    kind="hbm",
    capacity_bytes=TPU_HBM_BYTES,
    load_bw=TPU_HBM_BW,
    store_bw=TPU_HBM_BW,
    nt_store_bw=TPU_HBM_BW,
    load_latency_ns=350.0,
    chase_latency_ns=500.0,
    load_peak_streams=8,
    store_peak_streams=8,
    load_collapse_streams=32,
    store_collapse_streams=32,
    collapse_factor=0.95,
)

HOST_V5E = TierSpec(
    name="host",
    kind="host",
    capacity_bytes=512 * GiB // TPU_CHIPS_PER_HOST,  # per-chip share of host DRAM
    load_bw=TPU_PCIE_BW,
    store_bw=TPU_PCIE_BW / 2,  # fetch-modify-flush path
    nt_store_bw=TPU_PCIE_BW,
    load_latency_ns=2_000.0,
    chase_latency_ns=5_000.0,
    load_peak_streams=4,
    store_peak_streams=2,
    load_collapse_streams=8,
    store_collapse_streams=4,
    collapse_factor=0.7,
    link_bw=TPU_PCIE_BW,
    rfo_traffic_multiplier=2.0,
)


@dataclasses.dataclass(frozen=True, init=False)
class TierTopology:
    """An ordered fast tier + N slow devices, as one compute engine sees them.

    ``slow`` accepts a single :class:`TierSpec` (the historical two-tier
    shape) or a sequence of them (the paper's multi-device pool: CXL-A/B/C
    from three manufacturers attached to one host).  The two-device
    compatibility path is the ``slow`` property: the *first* slow device,
    which every ``slow_fraction``-era call site keeps addressing.

    ``extra`` holds devices that are *present* (ledger-visible, memo-
    characterizable) but not placement targets — e.g. the remote-NUMA node
    the paper measures but never interleaves onto.
    """

    fast: TierSpec
    slows: tuple[TierSpec, ...]
    extra: tuple[TierSpec, ...]

    def __init__(self, fast: TierSpec, slow=None, extra: tuple = (), *,
                 slows=None):
        if slows is not None and slow is not None:
            raise ValueError("pass slow= or slows=, not both")
        if slows is None:
            if slow is None:
                slows = ()
            elif isinstance(slow, (tuple, list)):
                slows = tuple(slow)
            else:
                slows = (slow,)
        names = [fast.name] + [t.name for t in slows] + [t.name for t in extra]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names in topology: {names}")
        object.__setattr__(self, "fast", fast)
        object.__setattr__(self, "slows", tuple(slows))
        object.__setattr__(self, "extra", tuple(extra))

    @property
    def slow(self) -> Optional[TierSpec]:
        """Two-device compatibility: the first (primary) slow device."""
        return self.slows[0] if self.slows else None

    @property
    def n_slow(self) -> int:
        return len(self.slows)

    @property
    def slow_names(self) -> tuple[str, ...]:
        return tuple(t.name for t in self.slows)

    @property
    def tiers(self) -> tuple[TierSpec, ...]:
        return (self.fast,) + self.slows + self.extra

    @property
    def devices(self) -> tuple[TierSpec, ...]:
        """Placement targets in canonical order: fast first, then slows."""
        return (self.fast,) + self.slows

    def by_name(self, name: str) -> TierSpec:
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(name)

    def device_index(self, name: str) -> int:
        """Ordinal of ``name`` in the canonical device order (0 = fast)."""
        for i, t in enumerate(self.devices):
            if t.name == name:
                return i
        raise KeyError(name)

    def effective_bw(self, tier: TierSpec, op: OpClass = OpClass.LOAD) -> float:
        bw = tier.peak_bw(op)
        return min(bw, tier.link_bw) if tier.link_bw else bw

    def bandwidth_weights(self, op: OpClass = OpClass.LOAD
                          ) -> tuple[float, ...]:
        """Per-slow-device share of the aggregate slow bandwidth.

        The Fig. 10 seed: the best static interleave ratio tracks each
        device's relative bandwidth, so a weight vector proportional to
        effective (link-clipped) bandwidth is the planner's prior for how
        to split a given slow fraction across devices."""
        if not self.slows:
            return ()
        bws = [self.effective_bw(t, op) for t in self.slows]
        total = sum(bws)
        return tuple(b / total for b in bws)

    # -- elastic hot-plug / hot-remove ---------------------------------------
    def remove_device(self, name: str, *,
                      keep_visible: bool = True) -> "TierTopology":
        """Hot-remove a slow device: a new topology without ``name`` as a
        placement target.

        With ``keep_visible`` (the default) the departing spec moves to
        ``extra`` — still ledger-visible so in-flight drain descriptors
        and telemetry routes naming it keep resolving via ``by_name`` —
        but ``slows``/``devices``/``bandwidth_weights`` no longer include
        it, so every weight simplex rebuilt from this topology excludes
        the dead device.  Removing the fast tier is not a thing."""
        if name == self.fast.name:
            raise ValueError("cannot remove the fast tier")
        spec = next((t for t in self.slows if t.name == name), None)
        if spec is None:
            raise KeyError(name)
        slows = tuple(t for t in self.slows if t.name != name)
        extra = self.extra + ((spec,) if keep_visible else ())
        return TierTopology(fast=self.fast, slows=slows, extra=extra)

    def add_device(self, spec) -> "TierTopology":
        """Hot-add a slow device: a new topology with ``spec`` appended to
        the placement targets.

        ``spec`` is a :class:`TierSpec` or a name — a name is promoted
        back from ``extra`` (the re-add of a previously removed device)
        or looked up in :data:`DEVICE_REGISTRY`."""
        if isinstance(spec, str):
            match = next((t for t in self.extra if t.name == spec), None)
            if match is None:
                match = DEVICE_REGISTRY.get(spec)
            if match is None:
                raise KeyError(spec)
            spec = match
        if spec.name == self.fast.name or spec.name in self.slow_names:
            raise ValueError(f"device {spec.name!r} already in topology")
        extra = tuple(t for t in self.extra if t.name != spec.name)
        return TierTopology(fast=self.fast, slows=self.slows + (spec,),
                            extra=extra)


def paper_topology() -> TierTopology:
    """The paper's testbed: local DDR5 fast tier + CXL slow tier (+ remote)."""
    return TierTopology(fast=DDR5_L8, slow=CXL_AGILEX, extra=(DDR5_R1,))


def paper_three_device_topology() -> TierTopology:
    """Table 1's full pool: DDR5 fast tier + the three CXL devices at once."""
    return TierTopology(fast=DDR5_L8, slows=(CXL_A, CXL_B, CXL_C))


def tpu_v5e_topology() -> TierTopology:
    """Deployment target: HBM fast tier + host-DRAM-behind-PCIe slow tier."""
    return TierTopology(fast=HBM_V5E, slow=HOST_V5E)


#: devices addressable from a ``--devices`` spec (first name = fast tier).
DEVICE_REGISTRY: dict[str, TierSpec] = {
    t.name: t
    for t in (DDR5_L8, CXL_AGILEX, CXL_A, CXL_B, CXL_C, DDR5_R1, HBM_V5E,
              HOST_V5E)
}

_NAMED_TOPOLOGIES = {
    "tpu-v5e": tpu_v5e_topology,
    "paper": paper_topology,
    "paper3": paper_three_device_topology,
}


def topology_from_spec(spec: str) -> TierTopology:
    """Build a topology from a CLI ``--devices`` spec.

    Either a named preset (``tpu-v5e``, ``paper``, ``paper3``) or a
    ``+``-joined device list from :data:`DEVICE_REGISTRY` with the first
    entry as the fast tier, e.g. ``ddr5-l8+cxl-a+cxl-b``."""
    key = spec.strip().lower()
    if key in _NAMED_TOPOLOGIES:
        return _NAMED_TOPOLOGIES[key]()
    names = [n.strip() for n in key.split("+") if n.strip()]
    if not names:
        raise ValueError(f"empty --devices spec: {spec!r}")
    try:
        devs = [DEVICE_REGISTRY[n] for n in names]
    except KeyError as e:
        raise ValueError(
            f"unknown device {e.args[0]!r}; choose from "
            f"{sorted(DEVICE_REGISTRY)} or a preset "
            f"{sorted(_NAMED_TOPOLOGIES)}") from None
    return TierTopology(fast=devs[0], slows=tuple(devs[1:]))
