"""Memory-tier model: the paper's testbed and the TPU v5e target.

The paper (Sun et al., MICRO'23) characterizes three tiers on x86:
local 8-channel DDR5, CXL-attached DDR4 behind PCIe Gen5 x16, and
remote-NUMA single-channel DDR5.  On TPU v5e the analogous two tiers are
on-chip HBM and host DRAM behind PCIe.  ``TierSpec`` captures the
characteristics the paper shows matter: peak bandwidth per operation
class, latency (flushed-line and dependent pointer-chase), and the
stream counts beyond which the controller contends (Fig. 3/5 collapse).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

GiB = 1024**3
GB = 1e9


class OpClass(enum.Enum):
    """Access classes from the paper's MEMO microbenchmark."""

    LOAD = "load"
    STORE = "store"  # temporal store (+wb) — incurs RFO on the paper's CXL
    NT_STORE = "nt_store"  # cache-bypass store (nt-store / movdir64B analogue)
    COPY = "copy"  # paired load+store bulk movement


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One memory tier as seen from the compute engine."""

    name: str
    kind: str  # "hbm" | "host" | "ddr_local" | "cxl" | "ddr_remote"
    capacity_bytes: int
    # Peak aggregate bandwidth per op class (bytes/s).
    load_bw: float
    store_bw: float  # temporal store path (RFO-afflicted on CXL-like tiers)
    nt_store_bw: float  # cache-bypass store path
    # Latency (ns).
    load_latency_ns: float  # flushed-line single load
    chase_latency_ns: float  # dependent pointer-chase per hop
    # Contention model (Fig. 3/5): bandwidth ramps ~linearly with streams up
    # to *_peak_streams, stays flat to *_collapse_streams, then degrades by
    # collapse_factor (controller-buffer interference).
    load_peak_streams: int
    store_peak_streams: int
    load_collapse_streams: int
    store_collapse_streams: int
    collapse_factor: float
    # Link behind which the tier sits (PCIe for CXL/host); None = direct.
    link_bw: Optional[float] = None
    # Traffic multiplier for temporal (in-place) writes: read-for-ownership /
    # fetch-modify-flush costs 2x bytes on far tiers (paper §4.2 / F3).
    rfo_traffic_multiplier: float = 1.0

    def peak_bw(self, op: OpClass) -> float:
        if op == OpClass.LOAD:
            return self.load_bw
        if op == OpClass.STORE:
            return self.store_bw
        if op == OpClass.NT_STORE:
            return self.nt_store_bw
        # COPY: harmonic combination of a load and a store stream.
        return 1.0 / (1.0 / self.load_bw + 1.0 / self.nt_store_bw)

    def peak_streams(self, op: OpClass) -> int:
        return self.load_peak_streams if op == OpClass.LOAD else self.store_peak_streams

    def collapse_streams(self, op: OpClass) -> int:
        return (
            self.load_collapse_streams
            if op == OpClass.LOAD
            else self.store_collapse_streams
        )


# ---------------------------------------------------------------------------
# Paper testbed (Table 1 + Figs. 2/3): used to calibrate/validate perfmodel.
# Absolute latencies chosen to satisfy the paper's reported ratios:
#   CXL flushed-load = 2.2x DDR5-L8; CXL chase = 3.7x DDR5-L8 = 2.2x DDR5-R1.
# ---------------------------------------------------------------------------
DDR5_L8 = TierSpec(
    name="ddr5-l8",
    kind="ddr_local",
    capacity_bytes=128 * GiB,
    load_bw=221 * GB,  # Fig. 3a peak
    store_bw=140 * GB,
    nt_store_bw=170 * GB,  # Fig. 3a nt-store peak
    load_latency_ns=170.0,
    chase_latency_ns=90.0,
    load_peak_streams=26,
    store_peak_streams=16,
    load_collapse_streams=64,
    store_collapse_streams=64,
    collapse_factor=0.95,
)

CXL_AGILEX = TierSpec(
    name="cxl-agilex",
    kind="cxl",
    capacity_bytes=16 * GiB,
    load_bw=20 * GB,  # peaks ~8 threads (Fig. 3b)
    store_bw=8 * GB,  # temporal store, RFO-limited
    nt_store_bw=22 * GB,  # ~DDR4-2666 theoretical max, 2 threads
    load_latency_ns=374.0,  # 2.2x DDR5-L8
    chase_latency_ns=333.0,  # 3.7x DDR5-L8
    load_peak_streams=8,
    store_peak_streams=2,
    load_collapse_streams=12,
    store_collapse_streams=4,
    collapse_factor=0.65,  # drops to ~16.8/20 for loads; harsher for stores
    link_bw=64 * GB,  # PCIe Gen5 x16
    rfo_traffic_multiplier=2.0,
)

DDR5_R1 = TierSpec(
    name="ddr5-r1",
    kind="ddr_remote",
    capacity_bytes=256 * GiB,
    load_bw=30 * GB,  # single channel DDR5-4800 behind UPI
    store_bw=16 * GB,
    nt_store_bw=26 * GB,
    load_latency_ns=306.0,  # ~1.8x DDR5-L8 (paper: 1x-2.5x band)
    chase_latency_ns=151.0,  # CXL chase / 2.2
    load_peak_streams=8,
    store_peak_streams=4,
    load_collapse_streams=24,
    store_collapse_streams=16,
    collapse_factor=0.85,
)

# ---------------------------------------------------------------------------
# TPU v5e target (deployment): HBM fast tier + host-DRAM "CXL" tier.
# ---------------------------------------------------------------------------
TPU_PEAK_FLOPS_BF16 = 197e12  # per chip
TPU_HBM_BW = 819 * GB
TPU_HBM_BYTES = 16 * GiB
TPU_ICI_LINK_BW = 50 * GB  # per link
TPU_ICI_LINKS_PER_CHIP = 4  # v5e 2D torus: 4 links
TPU_DCN_BW_PER_HOST = 12.5 * GB  # cross-pod (pod axis) effective
TPU_PCIE_BW = 32 * GB  # host<->chip effective (the "CXL" link)
TPU_CHIPS_PER_HOST = 8

HBM_V5E = TierSpec(
    name="hbm",
    kind="hbm",
    capacity_bytes=TPU_HBM_BYTES,
    load_bw=TPU_HBM_BW,
    store_bw=TPU_HBM_BW,
    nt_store_bw=TPU_HBM_BW,
    load_latency_ns=350.0,
    chase_latency_ns=500.0,
    load_peak_streams=8,
    store_peak_streams=8,
    load_collapse_streams=32,
    store_collapse_streams=32,
    collapse_factor=0.95,
)

HOST_V5E = TierSpec(
    name="host",
    kind="host",
    capacity_bytes=512 * GiB // TPU_CHIPS_PER_HOST,  # per-chip share of host DRAM
    load_bw=TPU_PCIE_BW,
    store_bw=TPU_PCIE_BW / 2,  # fetch-modify-flush path
    nt_store_bw=TPU_PCIE_BW,
    load_latency_ns=2_000.0,
    chase_latency_ns=5_000.0,
    load_peak_streams=4,
    store_peak_streams=2,
    load_collapse_streams=8,
    store_collapse_streams=4,
    collapse_factor=0.7,
    link_bw=TPU_PCIE_BW,
    rfo_traffic_multiplier=2.0,
)


@dataclasses.dataclass(frozen=True)
class TierTopology:
    """A fast tier + optional slow tier(s), as one compute engine sees them."""

    fast: TierSpec
    slow: Optional[TierSpec] = None
    extra: tuple[TierSpec, ...] = ()

    @property
    def tiers(self) -> tuple[TierSpec, ...]:
        out = (self.fast,)
        if self.slow is not None:
            out = out + (self.slow,)
        return out + self.extra

    def by_name(self, name: str) -> TierSpec:
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(name)


def paper_topology() -> TierTopology:
    """The paper's testbed: local DDR5 fast tier + CXL slow tier (+ remote)."""
    return TierTopology(fast=DDR5_L8, slow=CXL_AGILEX, extra=(DDR5_R1,))


def tpu_v5e_topology() -> TierTopology:
    """Deployment target: HBM fast tier + host-DRAM-behind-PCIe slow tier."""
    return TierTopology(fast=HBM_V5E, slow=HOST_V5E)
